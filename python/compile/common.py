"""Shared shape constants for the fogml build pipeline.

These constants define the single source of truth for every tensor shape
that crosses the python -> rust AOT boundary.  `aot.py` embeds them in
`artifacts/manifest.json`, which the rust runtime parses at startup, so the
two sides can never silently disagree.
"""

# Image geometry of the SynthDigits dataset (see rust/src/data/dataset.rs).
IMG_SIDE = 14
IMG_PIXELS = IMG_SIDE * IMG_SIDE  # 196
NUM_CLASSES = 10

# Maximum (padded) microbatch size for one compiled train/eval step.  Larger
# per-interval workloads are chunked by the rust trainer.
BATCH = 32

# Compiled device-stack sizes for the batched multi-device train entries
# (`<model>_train_many_d<D>`): one interval's local updates for up to D
# devices execute as a single [D, BATCH, ...] PJRT call.  The rust runtime
# picks the smallest D >= the number of actively-training devices and pads
# idle slots with zero sample weights (see model.make_train_many).
DEVICE_TILES = (4, 8, 16, 32)

# MLP: 196 -> 128 -> 10
MLP_HIDDEN = 128

# CNN: 14x14x1 -> conv 3x3 x8 (same) -> relu -> maxpool 2x2 -> 7*7*8=392
# -> dense 392 -> 64 -> relu -> dense 64 -> 10
CNN_CHANNELS = 8
CNN_KSIZE = 3
CNN_POOLED = (IMG_SIDE // 2) * (IMG_SIDE // 2) * CNN_CHANNELS  # 392
CNN_HIDDEN = 64

# Default tile sizes for the pallas dense kernel (MXU-oriented blocking).
BLOCK_M = 128
BLOCK_N = 128
