"""fogml AOT pipeline: lower every L2 entry point to HLO *text* artifacts.

Run once at build time (`make artifacts`); python never appears on the rust
request path afterwards.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 (used by the `xla` rust crate) rejects (`proto.id() <= INT_MAX`).  The
HLO text parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  <entry>.hlo.txt   one per ENTRY_POINTS entry + the dense microkernel
  manifest.json     positional ABI: input/output dtypes+shapes per entry,
                    plus the shared shape constants the rust side needs.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import common
from .kernels import dense
from .model import ENTRY_POINTS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s):
    return {"dtype": str(s.dtype), "shape": list(s.shape)}


def dense_micro(x, w, b):
    """Standalone pallas dense layer for runtime micro-benchmarks."""
    return (dense(x, w, b, True),)


def dense_micro_specs():
    f32 = lambda sh: jax.ShapeDtypeStruct(sh, jnp.float32)
    return (
        f32((common.BLOCK_M, common.IMG_PIXELS)),
        f32((common.IMG_PIXELS, common.MLP_HIDDEN)),
        f32((common.MLP_HIDDEN,)),
    )


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = dict(ENTRY_POINTS)
    entries["dense_micro"] = (dense_micro, dense_micro_specs, {})

    manifest = {
        "format": "hlo-text",
        "constants": {
            "img_side": common.IMG_SIDE,
            "img_pixels": common.IMG_PIXELS,
            "num_classes": common.NUM_CLASSES,
            "batch": common.BATCH,
            "device_tiles": list(common.DEVICE_TILES),
            "mlp_hidden": common.MLP_HIDDEN,
            "cnn_channels": common.CNN_CHANNELS,
            "cnn_hidden": common.CNN_HIDDEN,
            "cnn_pooled": common.CNN_POOLED,
        },
        "entries": {},
    }

    for name, (fn, spec_builder, meta) in entries.items():
        specs = spec_builder()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *specs)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [_spec_json(s) for s in specs],
            "outputs": [_spec_json(s) for s in out_specs],
            **meta,
        }
        print(f"  {name}: {len(text)} chars, {len(specs)} inputs, "
              f"{len(out_specs)} outputs")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    print(f"fogml aot: lowering to {args.out_dir}")
    build_all(args.out_dir)
    print("fogml aot: done")


if __name__ == "__main__":
    main()
