"""Pure-jnp oracles for the pallas kernels.

Every kernel in this package must match its oracle here to float tolerance;
`python/tests/test_kernels.py` sweeps shapes and dtypes with hypothesis and
asserts allclose.  The oracles are also what the kernels fall back to for
degenerate shapes the blocked kernels do not support (e.g. zero-sized
batches), so they are part of the public contract, not just test helpers.
"""

import jax.numpy as jnp


def dense_ref(x, w, b, relu: bool = False):
    """y = x @ w + b, optionally followed by ReLU.

    x: [B, K] float
    w: [K, N] float
    b: [N]    float
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def matmul_ref(a, b):
    """Plain a @ b in f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def softmax_xent_ref(logits, onehot, wt):
    """Weighted mean softmax cross-entropy.

    logits: [B, C], onehot: [B, C], wt: [B] (0/1 mask or arbitrary weights)
    Returns a scalar: sum_i wt_i * xent_i / max(sum_i wt_i, 1).
    """
    logits = logits.astype(jnp.float32)
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    logsumexp = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
    xent = logsumexp - jnp.sum(z * onehot.astype(jnp.float32), axis=-1)
    denom = jnp.maximum(jnp.sum(wt), 1.0)
    return jnp.sum(xent * wt) / denom


def softmax_xent_grad_ref(logits, onehot, wt):
    """Closed-form gradient of `softmax_xent_ref` w.r.t. logits."""
    logits = logits.astype(jnp.float32)
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(z) / jnp.sum(jnp.exp(z), axis=-1, keepdims=True)
    denom = jnp.maximum(jnp.sum(wt), 1.0)
    return (p - onehot.astype(jnp.float32)) * (wt / denom)[:, None]
