"""Pallas dense-layer kernel — the compute hot-spot of every local update.

The paper's local update (eq. 3) is dominated by the dense matmuls of the
MLP/CNN forward and backward passes.  This module implements them as a
blocked pallas matmul:

  * grid over (M/bm, N/bn) output tiles, K resident per tile — the
    HBM->VMEM schedule a TPU MXU wants (see DESIGN.md §Hardware-Adaptation);
  * f32 accumulation regardless of input dtype;
  * `dense` is wrapped in a `jax.custom_vjp` whose backward pass reuses the
    same pallas matmul for dx = g @ w.T and dw = x.T @ g, so the whole
    fwd+bwd lowers to pallas-blocked compute.

`interpret=True` is mandatory here: the image's CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO that
the rust runtime executes.  Numerical equivalence with `ref.dense_ref` is
enforced by `python/tests/test_kernels.py`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import BLOCK_M, BLOCK_N


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile: full-K matmul with f32 accumulation."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (>=1)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def matmul(x, w, *, block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """Blocked pallas matmul x[M,K] @ w[K,N] -> [M,N].

    Degenerate shapes (empty dims) fall back to jnp.dot, which is also the
    correctness oracle (`ref.matmul_ref`).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    if m == 0 or n == 0 or k == 0:
        return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, relu: bool = False):
    """y = x @ w + b (pallas matmul), optionally ReLU-fused.

    Differentiable: the custom VJP routes both gradient matmuls through the
    same pallas kernel, so fwd *and* bwd of the model are pallas-blocked.
    """
    y = matmul(x, w) + b.astype(x.dtype)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def _dense_fwd(x, w, b, relu: bool):
    y = matmul(x, w) + b.astype(x.dtype)
    if relu:
        y = jnp.maximum(y, 0.0)
        return y, (x, w, y)
    return y, (x, w, None)


def _dense_bwd(relu: bool, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0.0).astype(g.dtype)
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g.astype(jnp.float32), axis=0).astype(g.dtype)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
