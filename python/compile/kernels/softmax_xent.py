"""Pallas fused weighted softmax cross-entropy kernel.

Computes the masked/weighted mean cross-entropy the fogml trainer minimizes:

    loss = sum_i wt_i * xent(logits_i, onehot_i) / max(sum_i wt_i, 1)

The per-sample weight vector `wt` is how a single compiled train step serves
any microbatch size <= BATCH: the rust trainer pads the batch and zeroes the
padded rows' weights, which provably removes them from both the loss and the
gradient (tested in test_models.py::test_padding_invariance).

Forward is a single pallas kernel over the whole [B, C] tile (B, C are small
and VMEM-resident); backward uses the closed-form softmax gradient, also as
a pallas kernel, wired up via `jax.custom_vjp`.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xent_fwd_kernel(logits_ref, onehot_ref, wt_ref, loss_ref):
    logits = logits_ref[...].astype(jnp.float32)
    onehot = onehot_ref[...].astype(jnp.float32)
    wt = wt_ref[...].astype(jnp.float32)
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    logsumexp = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
    xent = logsumexp - jnp.sum(z * onehot, axis=-1)
    denom = jnp.maximum(jnp.sum(wt), 1.0)
    # scalar output as a (1, 1) tile
    loss_ref[...] = (jnp.sum(xent * wt) / denom).reshape(1, 1)


def _xent_bwd_kernel(logits_ref, onehot_ref, wt_ref, g_ref, dlogits_ref):
    logits = logits_ref[...].astype(jnp.float32)
    onehot = onehot_ref[...].astype(jnp.float32)
    wt = wt_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)  # (1, 1) upstream cotangent
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    ez = jnp.exp(z)
    p = ez / jnp.sum(ez, axis=-1, keepdims=True)
    denom = jnp.maximum(jnp.sum(wt), 1.0)
    dlogits = (p - onehot) * (wt / denom)[:, None] * g[0, 0]
    dlogits_ref[...] = dlogits.astype(dlogits_ref.dtype)


@jax.custom_vjp
def softmax_xent(logits, onehot, wt):
    """Weighted mean softmax cross-entropy (scalar)."""
    loss = pl.pallas_call(
        _xent_fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(logits, onehot, wt)
    return loss[0, 0]


def _fwd(logits, onehot, wt):
    return softmax_xent(logits, onehot, wt), (logits, onehot, wt)


def _bwd(res, g):
    logits, onehot, wt = res
    dlogits = pl.pallas_call(
        _xent_bwd_kernel,
        out_shape=jax.ShapeDtypeStruct(logits.shape, logits.dtype),
        interpret=True,
    )(logits, onehot, wt, jnp.reshape(g, (1, 1)).astype(jnp.float32))
    # onehot and wt are data, not trainables; return zero cotangents.
    return dlogits, jnp.zeros_like(onehot), jnp.zeros_like(wt)


softmax_xent.defvjp(_fwd, _bwd)
