"""fogml L1: pallas kernels + pure-jnp oracles (build-time only)."""

from .dense import dense, matmul  # noqa: F401
from .softmax_xent import softmax_xent  # noqa: F401
from . import ref  # noqa: F401
