"""fogml L2: JAX model definitions (build-time only; never on request path).

Two classifiers from the paper's evaluation (§V-A) — an MLP and a small CNN —
plus the weight-masked SGD train step each device runs for its local update
(eq. 3 of the paper).  The dense layers and the loss are the pallas kernels
from `kernels/`; the conv layer stays in plain jnp (XLA fuses it fine and the
paper's hot-spot is the dense compute).

Design decisions that matter to the rust side:
  * Parameters are a flat tuple of arrays (not a pytree dict), so the AOT'd
    entry points have a stable positional ABI recorded in manifest.json.
  * Every train step takes a per-sample weight vector `wt`: the rust trainer
    pads microbatches to BATCH and zeroes padded rows, which removes them
    from loss and gradient exactly (see tests).
  * The step returns (new_params..., loss) so the rust hot loop is a single
    PJRT execution per microbatch with no host round-trips in between.
"""

import jax
import jax.numpy as jnp

from .common import (
    BATCH,
    CNN_CHANNELS,
    CNN_HIDDEN,
    CNN_KSIZE,
    CNN_POOLED,
    DEVICE_TILES,
    IMG_PIXELS,
    IMG_SIDE,
    MLP_HIDDEN,
    NUM_CLASSES,
)
from .kernels import dense, softmax_xent

# ---------------------------------------------------------------------------
# MLP: 196 -> 128 -> 10
# ---------------------------------------------------------------------------

MLP_PARAM_SHAPES = (
    ("w1", (IMG_PIXELS, MLP_HIDDEN)),
    ("b1", (MLP_HIDDEN,)),
    ("w2", (MLP_HIDDEN, NUM_CLASSES)),
    ("b2", (NUM_CLASSES,)),
)


def mlp_apply(params, x):
    """Logits for a batch of flattened images x[B, 196]."""
    w1, b1, w2, b2 = params
    h = dense(x, w1, b1, True)
    return dense(h, w2, b2, False)


def mlp_loss(params, x, onehot, wt):
    return softmax_xent(mlp_apply(params, x), onehot, wt)


def mlp_train_step(w1, b1, w2, b2, x, onehot, wt, lr):
    """One weight-masked SGD step; returns (w1', b1', w2', b2', loss)."""
    params = (w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(mlp_loss)(params, x, onehot, wt)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new, loss)


def mlp_eval_step(w1, b1, w2, b2, x):
    """Logits only; argmax/accuracy is computed on the rust side."""
    return (mlp_apply((w1, b1, w2, b2), x),)


# ---------------------------------------------------------------------------
# CNN: 14x14x1 -> conv3x3 x8 -> relu -> maxpool2 -> dense 392->64 -> 64->10
# ---------------------------------------------------------------------------

CNN_PARAM_SHAPES = (
    ("cw", (CNN_KSIZE, CNN_KSIZE, 1, CNN_CHANNELS)),
    ("cb", (CNN_CHANNELS,)),
    ("w1", (CNN_POOLED, CNN_HIDDEN)),
    ("b1", (CNN_HIDDEN,)),
    ("w2", (CNN_HIDDEN, NUM_CLASSES)),
    ("b2", (NUM_CLASSES,)),
)


def cnn_apply(params, x):
    """Logits for x[B, 196] (reshaped to NHWC inside)."""
    cw, cb, w1, b1, w2, b2 = params
    b = x.shape[0]
    img = x.reshape(b, IMG_SIDE, IMG_SIDE, 1)
    conv = jax.lax.conv_general_dilated(
        img,
        cw,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    conv = jnp.maximum(conv + cb, 0.0)
    pooled = jax.lax.reduce_window(
        conv,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )
    flat = pooled.reshape(b, CNN_POOLED)
    h = dense(flat, w1, b1, True)
    return dense(h, w2, b2, False)


def cnn_loss(params, x, onehot, wt):
    return softmax_xent(cnn_apply(params, x), onehot, wt)


def cnn_train_step(cw, cb, w1, b1, w2, b2, x, onehot, wt, lr):
    params = (cw, cb, w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(cnn_loss)(params, x, onehot, wt)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new, loss)


def cnn_eval_step(cw, cb, w1, b1, w2, b2, x):
    return (cnn_apply((cw, cb, w1, b1, w2, b2), x),)


# ---------------------------------------------------------------------------
# Batched multi-device train steps: one stacked XLA call per interval
# ---------------------------------------------------------------------------


def make_train_many(step_fn, n_params):
    """vmap a per-device train step over a leading device axis.

    Per-device params and batches map over axis 0 (`[D, ...]`); the
    learning rate stays a scalar broadcast to every device.  Idle device
    slots are padded with all-zero sample weights: `softmax_xent` divides
    by `max(sum(wt), 1)`, so a zero-weight slot produces loss 0 and exactly
    zero gradients — its parameters pass through bit-unchanged.  This is
    the same padding-invariance contract the scalar entry uses per row
    (test_padding_invariance), lifted to whole device slots.
    """
    return jax.vmap(step_fn, in_axes=(0,) * (n_params + 3) + (None,))


# ---------------------------------------------------------------------------
# Batched multi-params eval steps: one stacked XLA call per chunk group
# ---------------------------------------------------------------------------


def make_eval_many(step_fn, n_params):
    """vmap a per-device eval step over a leading device axis, reducing to
    weighted-correct counts on device.

    Each slot carries its own parameter stack, test chunk, one-hot labels
    and per-row weights; the slot's output is `sum(wt * (argmax(logits) ==
    argmax(onehot)))` — the weighted number of correct predictions.  Padded
    rows and whole idle slots carry zero weights, so they contribute
    *exactly* zero to the count (no division is even involved — this is
    the same weight-masking contract the train entries rely on through
    `softmax_xent`'s `max(sum(wt), 1)`, here in its degenerate sum-only
    form).  The host divides the accumulated counts by the true sample
    totals, so a D-slot stack serves D distinct models, or one model over
    D test chunks with the parameters replicated across slots.
    """

    def count_step(*args):
        params, x, onehot, wt = args[:n_params], args[-3], args[-2], args[-1]
        (logits,) = step_fn(*params, x)
        pred = jnp.argmax(logits, axis=-1)
        label = jnp.argmax(onehot, axis=-1)
        return (jnp.sum(wt * (pred == label).astype(jnp.float32)),)

    return jax.vmap(count_step, in_axes=0)


# ---------------------------------------------------------------------------
# Shape specs for AOT lowering (shared with aot.py / manifest.json)
# ---------------------------------------------------------------------------


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def batch_specs():
    """(x, onehot, wt, lr) example specs at the compiled batch size."""
    return (
        _f32((BATCH, IMG_PIXELS)),
        _f32((BATCH, NUM_CLASSES)),
        _f32((BATCH,)),
        _f32(()),
    )


def param_specs(shapes):
    return tuple(_f32(s) for _, s in shapes)


def stacked_param_specs(shapes, d):
    return tuple(_f32((d, *s)) for _, s in shapes)


def stacked_batch_specs(d):
    """(x, onehot, wt, lr) specs with a leading device axis (lr stays scalar)."""
    return (
        _f32((d, BATCH, IMG_PIXELS)),
        _f32((d, BATCH, NUM_CLASSES)),
        _f32((d, BATCH)),
        _f32(()),
    )


def stacked_eval_batch_specs(d):
    """(x, onehot, wt) specs with a leading device axis (no lr for eval)."""
    return (
        _f32((d, BATCH, IMG_PIXELS)),
        _f32((d, BATCH, NUM_CLASSES)),
        _f32((d, BATCH)),
    )


def _train_many_entries():
    """One `<base>_train_many_d<D>` entry per model per compiled tile size."""
    entries = {}
    bases = {
        "mlp_train": (MLP_PARAM_SHAPES, mlp_train_step),
        "cnn_train": (CNN_PARAM_SHAPES, cnn_train_step),
    }
    for base, (shapes, step) in bases.items():
        for d in DEVICE_TILES:
            entries[f"{base}_many_d{d}"] = (
                make_train_many(step, len(shapes)),
                lambda shapes=shapes, d=d: (
                    stacked_param_specs(shapes, d) + stacked_batch_specs(d)
                ),
                {"base": base, "devices": d, "devices_axis": 0},
            )
    return entries


def _eval_many_entries():
    """One `<base>_eval_many_d<D>` entry per model per compiled tile size."""
    entries = {}
    bases = {
        "mlp_eval": (MLP_PARAM_SHAPES, mlp_eval_step),
        "cnn_eval": (CNN_PARAM_SHAPES, cnn_eval_step),
    }
    for base, (shapes, step) in bases.items():
        for d in DEVICE_TILES:
            entries[f"{base}_many_d{d}"] = (
                make_eval_many(step, len(shapes)),
                lambda shapes=shapes, d=d: (
                    stacked_param_specs(shapes, d) + stacked_eval_batch_specs(d)
                ),
                {"base": base, "devices": d, "devices_axis": 0},
            )
    return entries


ENTRY_POINTS = {
    # name -> (fn, example-arg builder, manifest metadata)
    "mlp_train": (
        mlp_train_step,
        lambda: param_specs(MLP_PARAM_SHAPES) + batch_specs(),
        {},
    ),
    "mlp_eval": (
        mlp_eval_step,
        lambda: param_specs(MLP_PARAM_SHAPES) + (_f32((BATCH, IMG_PIXELS)),),
        {},
    ),
    "cnn_train": (
        cnn_train_step,
        lambda: param_specs(CNN_PARAM_SHAPES) + batch_specs(),
        {},
    ),
    "cnn_eval": (
        cnn_eval_step,
        lambda: param_specs(CNN_PARAM_SHAPES) + (_f32((BATCH, IMG_PIXELS)),),
        {},
    ),
    **_train_many_entries(),
    **_eval_many_entries(),
}
