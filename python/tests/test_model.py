"""L2 model contract tests: the ABI the rust trainer relies on."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import common, model


def _init_params(shapes, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _, shape in shapes:
        if len(shape) >= 2:
            fan_in = int(np.prod(shape[:-1]))
            scale = np.sqrt(2.0 / fan_in)
            out.append(jnp.asarray(
                rng.standard_normal(shape).astype(np.float32) * scale))
        else:
            out.append(jnp.zeros(shape, jnp.float32))
    return tuple(out)


def _toy_batch(seed=0, b=common.BATCH):
    """Linearly separable 10-class blobs at the model's input width."""
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((common.NUM_CLASSES, common.IMG_PIXELS))
    labels = rng.integers(0, common.NUM_CLASSES, size=b)
    x = protos[labels] + 0.3 * rng.standard_normal((b, common.IMG_PIXELS))
    onehot = np.eye(common.NUM_CLASSES, dtype=np.float32)[labels]
    wt = np.ones(b, np.float32)
    return (
        jnp.asarray(x.astype(np.float32)),
        jnp.asarray(onehot),
        jnp.asarray(wt),
    )


CASES = [
    ("mlp", model.MLP_PARAM_SHAPES, model.mlp_train_step, model.mlp_eval_step),
    ("cnn", model.CNN_PARAM_SHAPES, model.cnn_train_step, model.cnn_eval_step),
]


@pytest.mark.parametrize("name,shapes,train,evalf", CASES)
def test_train_step_decreases_loss(name, shapes, train, evalf):
    params = _init_params(shapes)
    x, onehot, wt = _toy_batch()
    lr = jnp.float32(0.05)
    losses = []
    for _ in range(12):
        out = train(*params, x, onehot, wt, lr)
        params, loss = out[:-1], out[-1]
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses


@pytest.mark.parametrize("name,shapes,train,evalf", CASES)
def test_padding_invariance(name, shapes, train, evalf):
    """Rows with wt=0 must not change params or loss — this is the contract
    that lets the rust trainer serve any microbatch size with one compiled
    executable."""
    params = _init_params(shapes)
    x, onehot, wt = _toy_batch()
    half = common.BATCH // 2
    wt_half = wt.at[half:].set(0.0)

    out_a = train(*params, x, onehot, wt_half, jnp.float32(0.05))

    # corrupt the masked rows: result must be bit-for-bit unaffected
    x_b = x.at[half:].set(1e3)
    onehot_b = onehot.at[half:].set(0.0)
    out_b = train(*params, x_b, onehot_b, wt_half, jnp.float32(0.05))

    for a, b in zip(out_a, out_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("name,shapes,train,evalf", CASES)
def test_eval_step_shapes(name, shapes, train, evalf):
    params = _init_params(shapes)
    x, _, _ = _toy_batch()
    (logits,) = evalf(*params, x)
    assert logits.shape == (common.BATCH, common.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name,shapes,train,evalf", CASES)
def test_train_then_eval_improves_accuracy(name, shapes, train, evalf):
    params = _init_params(shapes)
    x, onehot, wt = _toy_batch()
    labels = np.argmax(np.asarray(onehot), axis=1)

    def acc():
        (logits,) = evalf(*params, x)
        return float(np.mean(np.argmax(np.asarray(logits), 1) == labels))

    before = acc()
    for _ in range(25):
        out = train(*params, x, onehot, wt, jnp.float32(0.05))
        params = out[:-1]
    after = acc()
    assert after > max(before, 0.5), (before, after)


def test_entry_points_cover_both_models():
    scalar = {"mlp_train", "mlp_eval", "cnn_train", "cnn_eval"}
    many = {
        f"{base}_many_d{d}"
        for base in ("mlp_train", "cnn_train", "mlp_eval", "cnn_eval")
        for d in common.DEVICE_TILES
    }
    assert set(model.ENTRY_POINTS) == scalar | many
    for name, (fn, spec_builder, meta) in model.ENTRY_POINTS.items():
        specs = spec_builder()
        assert all(s.dtype == jnp.float32 for s in specs), name
        if name in many:
            assert meta["devices_axis"] == 0, name
            assert meta["base"] in scalar, name


@pytest.mark.parametrize("name,shapes,train,evalf", CASES)
def test_train_many_matches_scalar_loop(name, shapes, train, evalf):
    """Every device slot of the stacked step must reproduce the scalar
    step on that device's batch — the equivalence contract the rust
    batched train path relies on (tests/batched_equivalence.rs)."""
    d = common.DEVICE_TILES[0]
    many = model.make_train_many(train, len(shapes))
    params = [
        jnp.stack([_init_params(shapes, seed=s)[k] for s in range(d)])
        for k in range(len(shapes))
    ]
    batches = [_toy_batch(seed=100 + s) for s in range(d)]
    x = jnp.stack([b[0] for b in batches])
    onehot = jnp.stack([b[1] for b in batches])
    wt = jnp.stack([b[2] for b in batches])
    lr = jnp.float32(0.05)

    out = many(*params, x, onehot, wt, lr)
    assert out[-1].shape == (d,)
    for s in range(d):
        ref = train(*(p[s] for p in params), x[s], onehot[s], wt[s], lr)
        for a, b in zip(out, ref):
            np.testing.assert_allclose(
                np.asarray(a[s]), np.asarray(b), atol=1e-5
            )


@pytest.mark.parametrize("name,shapes,train,evalf", CASES)
def test_eval_many_matches_scalar_count(name, shapes, train, evalf):
    """Every slot of the stacked eval step must report the same weighted
    correct count the scalar eval step + host argmax produces on that
    slot's chunk — the contract `Trainer::evaluate_many` relies on
    (rust/tests/eval_equivalence.rs)."""
    d = common.DEVICE_TILES[0]
    many = model.make_eval_many(evalf, len(shapes))
    params = [
        jnp.stack([_init_params(shapes, seed=s)[k] for s in range(d)])
        for k in range(len(shapes))
    ]
    batches = [_toy_batch(seed=200 + s) for s in range(d)]
    x = jnp.stack([b[0] for b in batches])
    onehot = jnp.stack([b[1] for b in batches])
    wt = jnp.stack([b[2] for b in batches])

    (counts,) = many(*params, x, onehot, wt)
    assert counts.shape == (d,)
    for s in range(d):
        (logits,) = evalf(*(p[s] for p in params), x[s])
        pred = np.argmax(np.asarray(logits), axis=1)
        label = np.argmax(np.asarray(onehot[s]), axis=1)
        want = float(np.sum(np.asarray(wt[s]) * (pred == label)))
        assert float(counts[s]) == want, (name, s)


@pytest.mark.parametrize("name,shapes,train,evalf", CASES)
def test_eval_many_zero_weight_rows_and_slots(name, shapes, train, evalf):
    """Zero-weight rows and whole zero-weight slots contribute exactly
    zero to the correct count, no matter what garbage their inputs hold —
    how the rust eval path pads partial chunks and idle stack slots."""
    d = common.DEVICE_TILES[0]
    many = model.make_eval_many(evalf, len(shapes))
    params = [
        jnp.stack([_init_params(shapes, seed=s)[k] for s in range(d)])
        for k in range(len(shapes))
    ]
    x_one, onehot_one, wt_one = _toy_batch(seed=9)
    x = jnp.stack([x_one] * d)
    onehot = jnp.stack([onehot_one] * d)
    half = common.BATCH // 2
    idle = 1
    wt_rows = wt_one.at[half:].set(0.0)
    wt = jnp.stack(
        [jnp.zeros_like(wt_one) if s == idle else wt_rows for s in range(d)]
    )
    (counts_a,) = many(*params, x, onehot, wt)

    # corrupt everything the weights mask out: counts must not move
    x_b = x.at[:, half:].set(1e3)
    x_b = x_b.at[idle].set(-1e3)
    (counts_b,) = many(*params, x_b, onehot, wt)

    assert float(counts_a[idle]) == 0.0
    assert float(counts_b[idle]) == 0.0
    np.testing.assert_array_equal(np.asarray(counts_a), np.asarray(counts_b))
    # a live slot counts at most the surviving weight mass
    assert 0.0 <= float(counts_a[0]) <= half


@pytest.mark.parametrize("name,shapes,train,evalf", CASES)
def test_train_many_idle_slot_passthrough(name, shapes, train, evalf):
    """A device slot padded with all-zero sample weights must come back
    bit-identical (zero loss, zero gradient) — this is how the rust
    trainer pads idle devices and exhausted chunk schedules."""
    d = common.DEVICE_TILES[0]
    many = model.make_train_many(train, len(shapes))
    params = [
        jnp.stack([_init_params(shapes, seed=s)[k] for s in range(d)])
        for k in range(len(shapes))
    ]
    x, onehot, wt_one = _toy_batch(seed=3)
    x = jnp.stack([x] * d)
    onehot = jnp.stack([onehot] * d)
    idle = 1
    wt = jnp.stack(
        [jnp.zeros_like(wt_one) if s == idle else wt_one for s in range(d)]
    )

    out = many(*params, x, onehot, wt, jnp.float32(0.05))
    for k, p in enumerate(params):
        assert bool(jnp.all(out[k][idle] == p[idle])), (name, k)
    assert float(out[-1][idle]) == 0.0
    # the live slots did move
    assert bool(jnp.any(out[0][0] != params[0][0]))
