"""AOT pipeline tests: the artifacts the rust runtime will load."""

import json
import os

import pytest

from compile import aot, common


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_all(str(out))
    return str(out), manifest


def test_manifest_constants(built):
    _, manifest = built
    consts = manifest["constants"]
    assert consts["batch"] == common.BATCH
    assert consts["img_pixels"] == common.IMG_SIDE ** 2
    assert consts["num_classes"] == common.NUM_CLASSES
    assert consts["cnn_pooled"] == common.CNN_POOLED


def test_all_entries_emitted(built):
    out, manifest = built
    expected = {"mlp_train", "mlp_eval", "cnn_train", "cnn_eval", "dense_micro"}
    expected |= {
        f"{base}_many_d{d}"
        for base in ("mlp_train", "cnn_train", "mlp_eval", "cnn_eval")
        for d in common.DEVICE_TILES
    }
    assert set(manifest["entries"]) == expected
    for name, entry in manifest["entries"].items():
        path = os.path.join(out, entry["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        # well-formed HLO text module with an ENTRY computation
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_train_entry_abi(built):
    """Input layout: params..., x, onehot, wt, lr; outputs: params..., loss."""
    _, manifest = built
    for name, nparams in (("mlp_train", 4), ("cnn_train", 6)):
        entry = manifest["entries"][name]
        ins, outs = entry["inputs"], entry["outputs"]
        assert len(ins) == nparams + 4
        assert len(outs) == nparams + 1
        # param shapes round-trip through the step unchanged
        for i in range(nparams):
            assert ins[i]["shape"] == outs[i]["shape"], (name, i)
        assert ins[nparams]["shape"] == [common.BATCH, common.IMG_PIXELS]
        assert ins[nparams + 1]["shape"] == [common.BATCH, common.NUM_CLASSES]
        assert ins[nparams + 2]["shape"] == [common.BATCH]
        assert ins[nparams + 3]["shape"] == []   # lr scalar
        assert outs[-1]["shape"] == []           # loss scalar


def test_train_many_entry_abi(built):
    """Stacked layout: params[D,...], x[D,B,P], onehot[D,B,C], wt[D,B], lr
    scalar; outputs params[D,...], loss[D] — plus the sizing metadata the
    rust runtime uses to pick a variant."""
    _, manifest = built
    assert manifest["constants"]["device_tiles"] == list(common.DEVICE_TILES)
    for base, nparams in (("mlp_train", 4), ("cnn_train", 6)):
        scalar = manifest["entries"][base]
        for d in common.DEVICE_TILES:
            entry = manifest["entries"][f"{base}_many_d{d}"]
            assert entry["devices"] == d
            assert entry["devices_axis"] == 0
            assert entry["base"] == base
            ins, outs = entry["inputs"], entry["outputs"]
            assert len(ins) == nparams + 4
            assert len(outs) == nparams + 1
            # every tensor is the scalar entry's with a leading D axis;
            # lr stays scalar, loss becomes [D]
            for i in range(nparams + 3):
                assert ins[i]["shape"] == [d] + scalar["inputs"][i]["shape"]
            assert ins[nparams + 3]["shape"] == []
            for i in range(nparams):
                assert outs[i]["shape"] == [d] + scalar["outputs"][i]["shape"]
            assert outs[-1]["shape"] == [d]


def test_eval_entry_abi(built):
    _, manifest = built
    for name, nparams in (("mlp_eval", 4), ("cnn_eval", 6)):
        entry = manifest["entries"][name]
        assert len(entry["inputs"]) == nparams + 1
        assert entry["outputs"][0]["shape"] == [
            common.BATCH, common.NUM_CLASSES]


def test_eval_many_entry_abi(built):
    """Stacked eval layout: params[D,...], x[D,B,P], onehot[D,B,C],
    wt[D,B]; single output correct[D] — weighted correct counts, one
    scalar per slot (host-side division by the true sample totals)."""
    _, manifest = built
    for base, nparams in (("mlp_eval", 4), ("cnn_eval", 6)):
        scalar = manifest["entries"][base]
        for d in common.DEVICE_TILES:
            entry = manifest["entries"][f"{base}_many_d{d}"]
            assert entry["devices"] == d
            assert entry["devices_axis"] == 0
            assert entry["base"] == base
            ins, outs = entry["inputs"], entry["outputs"]
            assert len(ins) == nparams + 3
            assert len(outs) == 1
            for i in range(nparams + 1):
                assert ins[i]["shape"] == [d] + scalar["inputs"][i]["shape"]
            assert ins[nparams + 1]["shape"] == [
                d, common.BATCH, common.NUM_CLASSES]
            assert ins[nparams + 2]["shape"] == [d, common.BATCH]
            assert outs[0]["shape"] == [d]


def test_manifest_is_valid_json_on_disk(built):
    out, _ = built
    with open(os.path.join(out, "manifest.json")) as f:
        reparsed = json.load(f)
    assert reparsed["format"] == "hlo-text"
