"""Kernel-vs-oracle correctness: the CORE numeric signal of the build.

Hypothesis sweeps shapes (including block-non-divisible ones) and values for
each pallas kernel and asserts allclose against the pure-jnp oracle in
`compile.kernels.ref`.  Gradient paths (the custom VJPs) are checked against
`jax.grad` of the oracle, since the whole point of the custom VJPs is that
autodiff through them must agree with autodiff through plain jnp.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

# property-based sweeps need hypothesis (python/requirements-dev.txt);
# skip this module — not the whole session — where it is absent
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dense import _pick_block, dense, matmul
from compile.kernels.softmax_xent import softmax_xent

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 130),
    k=st.integers(1, 64),
    n=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    got = matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(32, 196, 128), (128, 392, 64), (32, 128, 10)])
def test_matmul_model_shapes(m, k, n):
    """The exact shapes the MLP/CNN use in production."""
    rng = np.random.default_rng(0)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(
        matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5
    )


def test_matmul_empty_dims_fall_back():
    x = jnp.zeros((0, 4), jnp.float32)
    w = jnp.zeros((4, 3), jnp.float32)
    assert matmul(x, w).shape == (0, 3)


def test_pick_block_divides():
    for dim in range(1, 300):
        b = _pick_block(dim, 128)
        assert 1 <= b <= min(dim, 128) and dim % b == 0


# ---------------------------------------------------------------------------
# dense (fwd + custom VJP)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 48),
    n=st.integers(1, 96),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    got = dense(x, w, b, relu)
    want = ref.dense_ref(x, w, b, relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(relu=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_dense_grad_matches_ref_grad(relu, seed):
    """custom_vjp (pallas bwd matmuls) == jax.grad through the jnp oracle."""
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, 8, 12), _rand(rng, 12, 6), _rand(rng, 6)

    def f_kernel(x, w, b):
        return jnp.sum(dense(x, w, b, relu) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.dense_ref(x, w, b, relu) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# softmax_xent (fwd + custom VJP)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 64),
    c=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_matches_ref(b, c, seed):
    rng = np.random.default_rng(seed)
    logits = _rand(rng, b, c) * 3.0
    labels = rng.integers(0, c, size=b)
    onehot = jnp.asarray(np.eye(c, dtype=np.float32)[labels])
    wt = jnp.asarray(rng.integers(0, 2, size=b).astype(np.float32))
    got = softmax_xent(logits, onehot, wt)
    want = ref.softmax_xent_ref(logits, onehot, wt)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_grad_matches_closed_form(seed):
    rng = np.random.default_rng(seed)
    b, c = 16, 10
    logits = _rand(rng, b, c) * 2.0
    labels = rng.integers(0, c, size=b)
    onehot = jnp.asarray(np.eye(c, dtype=np.float32)[labels])
    wt = jnp.asarray(rng.uniform(0, 1, size=b).astype(np.float32))

    g_kernel = jax.grad(lambda l: softmax_xent(l, onehot, wt))(logits)
    g_closed = ref.softmax_xent_grad_ref(logits, onehot, wt)
    g_auto = jax.grad(lambda l: ref.softmax_xent_ref(l, onehot, wt))(logits)
    np.testing.assert_allclose(g_kernel, g_closed, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g_kernel, g_auto, rtol=1e-4, atol=1e-5)


def test_softmax_xent_all_masked_is_finite():
    """wt == 0 everywhere must not divide by zero (denominator clamps to 1)."""
    logits = jnp.ones((4, 10), jnp.float32)
    onehot = jnp.asarray(np.eye(10, dtype=np.float32)[[0, 1, 2, 3]])
    wt = jnp.zeros((4,), jnp.float32)
    assert float(softmax_xent(logits, onehot, wt)) == 0.0


def test_softmax_xent_shift_invariance():
    """Adding a constant to all logits of a row must not change the loss."""
    rng = np.random.default_rng(3)
    logits = _rand(rng, 8, 10)
    onehot = jnp.asarray(np.eye(10, dtype=np.float32)[rng.integers(0, 10, 8)])
    wt = jnp.ones((8,), jnp.float32)
    a = softmax_xent(logits, onehot, wt)
    b = softmax_xent(logits + 100.0, onehot, wt)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
