//! Quickstart: the smallest end-to-end use of the fogml public API.
//!
//! Builds a 6-device fog network with testbed-like costs, runs 30 intervals
//! of network-aware federated learning (movement optimization + local
//! updates + weighted aggregation), and prints the resulting accuracy and
//! cost ledger next to a plain-federated baseline.
//!
//! Run with:
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use fogml::config::{EngineConfig, Method};
use fogml::fed;
use fogml::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // The runtime loads the AOT-compiled XLA artifacts (HLO text) produced
    // once by `make artifacts`; python is not involved from here on.
    let rt = Runtime::load_default()?;

    let cfg = EngineConfig {
        n: 6,
        t_max: 30,
        tau: 5,
        n_train: 2400,
        n_test: 600,
        ..Default::default()
    };

    println!("running network-aware learning ({} devices, T={})...", cfg.n, cfg.t_max);
    let aware = fed::run(&cfg, &rt)?;

    println!("running federated baseline...");
    let federated = fed::run(&cfg.clone().with(|c| c.method = Method::Federated), &rt)?;

    println!();
    println!("                      network-aware    federated");
    println!(
        "accuracy              {:>8.2}%        {:>8.2}%",
        100.0 * aware.accuracy,
        100.0 * federated.accuracy
    );
    println!(
        "total network cost    {:>9.1}        {:>9.1}",
        aware.ledger.total(),
        federated.ledger.total()
    );
    println!(
        "unit cost             {:>9.3}        {:>9.3}",
        aware.ledger.unit_cost(aware.total_collected as f64),
        federated.ledger.unit_cost(federated.total_collected as f64)
    );
    println!(
        "data offloaded        {:>9}        {:>9}",
        aware.movement.offloaded(),
        federated.movement.offloaded()
    );
    let saving = 100.0 * (1.0 - aware.ledger.total() / federated.ledger.total());
    println!();
    println!("network-aware learning saved {saving:.0}% of network cost");
    Ok(())
}
