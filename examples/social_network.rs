//! Privacy-sensitive social-network scenario (§I-A, Theorem 5): devices
//! share data only along trust edges (`c_ij = 0` on trusted links), and the
//! value of offloading grows ~linearly with the spread of computing costs.
//!
//! Demonstrates (i) Theorem 5's eq. (15) against Monte-Carlo on a
//! scale-free trust graph, (ii) Theorem 6's capacity-violation estimate,
//! and (iii) a small-world engine run.
//!
//! ```text
//! make artifacts && cargo run --release --example social_network
//! ```

use fogml::config::{EngineConfig, TopologyKind};
use fogml::fed;
use fogml::movement::theory;
use fogml::runtime::Runtime;
use fogml::topology::generators;
use fogml::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== Theorem 5: value of offloading vs computing-cost range C ==");
    let fracs = theory::scale_free_degree_fracs(2.5, 20);
    println!("C      savings (eq. 15)   savings / C");
    for c in [0.5, 1.0, 2.0, 4.0, 8.0] {
        let s = theory::theorem5_savings(c, &fracs);
        println!("{c:<5}  {s:>16.4}   {:>10.4}", s / c);
    }
    println!("(savings/C constant -> linear in C, as Theorem 5 predicts)");

    println!("\n== Theorem 6: expected capacity violations on the trust graph ==");
    let mut rng = Rng::new(7);
    let graph = generators::scale_free(80, 2, &mut rng);
    let caps: Vec<f64> = (0..400).map(|_| rng.uniform(3.0, 15.0)).collect();
    for d in [2.0, 5.0, 8.0] {
        let expected = theory::theorem6_expected_violations(&graph, d, &caps);
        let simulated = theory::simulate_violations(&graph, d, 1.0, &caps, 2000, &mut rng);
        println!(
            "D={d}: E[violations] formula {expected:.2}, simulation {simulated:.2} (of {} devices)",
            graph.n()
        );
    }

    println!("\n== Engine run on a Watts–Strogatz social topology ==");
    let rt = Runtime::load_default()?;
    let cfg = EngineConfig {
        n: 15,
        topology: TopologyKind::SmallWorld,
        iid: false,
        t_max: 50,
        n_train: 4000,
        n_test: 1000,
        ..Default::default()
    };
    let out = fed::run(&cfg, &rt)?;
    println!("accuracy    {:.2}% (non-iid)", 100.0 * out.accuracy);
    println!(
        "similarity  {:.1}% -> {:.1}% after trust-constrained offloading",
        100.0 * out.similarity.0,
        100.0 * out.similarity.1
    );
    println!(
        "cost        unit {:.3} (process {:.0} / transfer {:.0} / discard {:.0})",
        out.ledger.unit_cost(out.total_collected as f64),
        out.ledger.process,
        out.ledger.transfer,
        out.ledger.discard
    );
    Ok(())
}
