//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Trains the paper's workload — a 10-class image classifier under
//! network-aware federated learning — at the paper's full scale (n = 10
//! devices, T = 100 intervals ≈ 1000 device-interval local updates, τ = 10
//! aggregations) on the SynthDigits corpus, logging the loss curve and the
//! test-accuracy trajectory at every aggregation, plus the complete
//! movement/cost ledger. Proves all three layers compose: Pallas kernels →
//! JAX train step → AOT HLO → rust PJRT runtime → movement optimizer →
//! federated engine.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_training
//! ```

use fogml::config::EngineConfig;
use fogml::fed;
use fogml::runtime::Runtime;
use fogml::util::stats;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let cfg = EngineConfig {
        eval_curve: true,
        iid: false, // the harder, more interesting regime
        ..Default::default()
    };

    println!(
        "e2e: {} devices, T={}, tau={}, {} train / {} test samples, non-iid",
        cfg.n, cfg.t_max, cfg.tau, cfg.n_train, cfg.n_test
    );
    let started = std::time::Instant::now();
    let out = fed::run(&cfg, &rt)?;
    let elapsed = started.elapsed();

    // loss curve: mean per-device training loss per interval
    println!("\n-- training loss (mean over devices, every 5th interval) --");
    for (t, row) in out.per_device_loss.iter().enumerate() {
        if t % 5 != 0 {
            continue;
        }
        let losses: Vec<f64> = row.iter().flatten().map(|&l| l as f64).collect();
        if !losses.is_empty() {
            println!(
                "t={t:>3}  loss {:>6.3} ± {:>5.3}  ({} devices trained)",
                stats::mean(&losses),
                stats::std_dev(&losses),
                losses.len()
            );
        }
    }

    println!("\n-- test accuracy per aggregation --");
    for (t, acc) in &out.accuracy_curve {
        println!("t={t:>3}  {:.2}%", 100.0 * acc);
    }

    println!("\n-- final --");
    println!("accuracy   {:.2}%", 100.0 * out.accuracy);
    println!(
        "costs      process {:.0} / transfer {:.0} / discard {:.0} (unit {:.3})",
        out.ledger.process,
        out.ledger.transfer,
        out.ledger.discard,
        out.ledger.unit_cost(out.total_collected as f64)
    );
    println!(
        "movement   {} collected, {} processed, {} offloaded, {} discarded",
        out.movement.collected(),
        out.movement.processed(),
        out.movement.offloaded(),
        out.movement.discarded()
    );
    println!(
        "similarity {:.1}% -> {:.1}% (offloading mixes non-iid shards)",
        100.0 * out.similarity.0,
        100.0 * out.similarity.1
    );
    println!("wall time  {elapsed:.2?}");

    // sanity gate so CI catches regressions when run as a smoke test
    anyhow::ensure!(out.accuracy > 0.5, "e2e accuracy collapsed");
    let first = out.accuracy_curve.first().map(|&(_, a)| a).unwrap_or(0.0);
    anyhow::ensure!(out.accuracy > first, "no learning progress over aggregations");
    Ok(())
}
