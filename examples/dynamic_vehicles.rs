//! Connected-vehicles scenario (§I-A, §V-E): rapid topology dynamics as
//! vehicles enter and leave sensor range. Sweeps the exit probability and
//! shows the paper's Fig-9 trends — fewer active nodes, less data, more
//! discarding, lower accuracy — plus the actor-based cluster runtime.
//!
//! ```text
//! make artifacts && cargo run --release --example dynamic_vehicles
//! ```

use fogml::config::{Churn, EngineConfig};
use fogml::coordinator::{Cluster, ClusterConfig};
use fogml::fed;
use fogml::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let base = EngineConfig {
        n: 10,
        t_max: 60,
        n_train: 4800,
        n_test: 1000,
        ..Default::default()
    };

    println!("== vehicles leaving coverage: p_exit sweep (p_entry = 2%) ==");
    println!("p_exit  active  data   moved%  unit-cost  accuracy");
    for k in [0usize, 1, 2, 3, 5] {
        let p = k as f64 / 100.0;
        let cfg = base
            .clone()
            .with(|c| c.churn = Some(Churn { p_exit: p, p_entry: 0.02 }));
        let out = fed::run(&cfg, &rt)?;
        let moved = 100.0 * (out.movement.offloaded() + out.movement.discarded()) as f64
            / out.movement.collected().max(1) as f64;
        println!(
            "{k:>4}%   {:>5.1}  {:>5}  {:>5.1}%  {:>9.3}  {:>7.2}%",
            out.mean_active,
            out.total_collected,
            moved,
            out.ledger.unit_cost(out.total_collected as f64),
            100.0 * out.accuracy
        );
    }

    println!("\n== actor-based cluster runtime (leader/worker threads) ==");
    let report = Cluster::run(&ClusterConfig {
        n_devices: 5,
        rounds: 6,
        tau: 5,
        ..Default::default()
    })?;
    for (round, acc) in report.round_accuracy.iter().enumerate() {
        println!("round {round}: {:.2}%", 100.0 * acc);
    }
    println!("per-device processed samples: {:?}", report.device_samples);
    Ok(())
}
