//! Smart-factory scenario (§I-A, Theorem 4): a hierarchical fog network
//! where weak floor sensors offload to a small set of powerful gateway
//! controllers.
//!
//! Demonstrates (i) the hierarchical topology generator driven by measured
//! processing costs, (ii) Theorem 4's closed-form offload/discard fractions
//! vs the convex solver on the same scenario, and (iii) a full engine run
//! over the hierarchy.
//!
//! ```text
//! make artifacts && cargo run --release --example hierarchical_factory
//! ```

use fogml::config::{EngineConfig, TopologyKind};
use fogml::costs::CostSchedule;
use fogml::fed;
use fogml::movement::convex::{self, PgdOptions};
use fogml::movement::problem::{DiscardModel, MovementProblem};
use fogml::movement::theory;
use fogml::runtime::Runtime;
use fogml::topology::generators;

fn main() -> anyhow::Result<()> {
    // --- Theorem 4 on a concrete factory: 4 sensors + 1 gateway ----------
    println!("== Theorem 4: sensors offloading to an edge gateway ==");
    let n_sensors = 4;
    let n = n_sensors + 1;
    let gateway = n_sensors;
    let graph = generators::star(n, gateway);
    let gamma = 60.0;
    let (c_sensor, c_gateway, c_link) = (0.55, 0.10, 0.04);
    let d_rate = 500.0;

    let mut costs = CostSchedule::zeros(n, 2);
    for t in 0..2 {
        for i in 0..n_sensors {
            costs.compute[t][i] = c_sensor + 0.05 * i as f64; // heterogeneous sensors
            costs.error_weight[t][i] = gamma;
            costs.link[t][i * n + gateway] = c_link;
        }
        costs.compute[t][gateway] = c_gateway;
        costs.error_weight[t][gateway] = gamma;
    }
    let mut d = vec![d_rate; n_sensors];
    d.push(0.0);
    let inbound = vec![0.0; n];
    let active = vec![true; n];
    let problem = MovementProblem {
        t: 0,
        graph: &graph,
        active: &active,
        d: &d,
        inbound_prev: &inbound,
        costs: &costs,
        discard_model: DiscardModel::Sqrt,
    };
    let plan = convex::solve(&problem, PgdOptions { iterations: 3000, step0: 0.0 });
    let c_devs: Vec<f64> = (0..n_sensors).map(|i| c_sensor + 0.05 * i as f64).collect();
    let closed = theory::theorem4_closed_form(gamma, &c_devs, c_gateway, c_link, &vec![d_rate; n_sensors]);

    println!("sensor  c_i    r* (thm4)  r* (solver)  s* (thm4)  s* (solver)");
    for i in 0..n_sensors {
        println!(
            "{i:>6}  {:.2}   {:>8.3}  {:>10.3}  {:>8.3}  {:>10.3}",
            c_devs[i],
            closed.r[i],
            plan.r[i],
            closed.s[i],
            plan.s(i, gateway)
        );
    }

    // --- full engine run over a hierarchical fog ---------------------------
    println!("\n== Engine run on the hierarchical topology ==");
    let rt = Runtime::load_default()?;
    let cfg = EngineConfig {
        n: 12,
        topology: TopologyKind::Hierarchical,
        t_max: 50,
        n_train: 4000,
        n_test: 1000,
        ..Default::default()
    };
    let out = fed::run(&cfg, &rt)?;
    println!("accuracy      {:.2}%", 100.0 * out.accuracy);
    println!(
        "cost          process {:.0} / transfer {:.0} / discard {:.0}  (unit {:.3})",
        out.ledger.process,
        out.ledger.transfer,
        out.ledger.discard,
        out.ledger.unit_cost(out.total_collected as f64)
    );
    println!(
        "movement      {:.0}% of data moved (offload or discard)",
        100.0 * (out.movement.offloaded() + out.movement.discarded()) as f64
            / out.movement.collected().max(1) as f64
    );
    // hierarchy limits offload opportunities vs a full mesh (Fig. 8 claim)
    let full = fed::run(&cfg.clone().with(|c| c.topology = TopologyKind::Full), &rt)?;
    println!(
        "vs full mesh  offloaded {} (hier) vs {} (full)",
        out.movement.offloaded(),
        full.movement.offloaded()
    );
    Ok(())
}
