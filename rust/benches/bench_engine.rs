//! Engine-throughput benchmark: serial `fed::run` vs pooled
//! `SimPool::run_many` over identical (config, seed) grids, plus the
//! batched-vs-scalar multi-device comparison.
//!
//! This is the perf trajectory for the session/pool refactor (DESIGN.md
//! §Perf): seed fan-outs of 1, 4 and 8 runs, timed end-to-end (substrate
//! derivation + movement optimization + PJRT training + aggregation), and
//! — since the batched train path landed — single runs at n ∈ {10, 30}
//! with `TrainPath::Scalar` vs `TrainPath::Batched` (§Perf rule 7: the
//! stacked `[D × BATCH]` entry amortizes PJRT dispatch over all devices
//! training in an interval). The `eval` section covers the evaluation
//! subsystem the same way (§Perf rule 8): a full test pass through the
//! scalar chunk loop vs the stacked `*_eval_many_d<D>` entries, and
//! curve-producing runs under the Full vs Subset eval schedules at
//! n ∈ {10, 30}. The `service` section covers the cross-session
//! coalescing scheduler (§Perf rule 10): identical seed fan-outs through
//! K shared services with the classic one-request-at-a-time loop vs the
//! coalescing one, at seeds ∈ {4, 8} and services ∈ {1, 2}. Emits
//! `BENCH_engine.json` (and a copy under `results/bench/`) so later PRs
//! have numbers to beat.

use std::time::Instant;

use fogml::config::{EngineConfig, TrainPath};
use fogml::coordinator::SimPool;
use fogml::experiments::common::seed_sweep;
use fogml::fed;
use fogml::fed::eval::{EvalPath, EvalSchedule, EvalWork};
use fogml::fed::{Substrates, Trainer};
use fogml::runtime::{ModelKind, Runtime};
use fogml::util::json::Json;

const POOL_JOBS: usize = 4;

fn small() -> EngineConfig {
    EngineConfig {
        n: 6,
        t_max: 20,
        tau: 5,
        n_train: 1600,
        n_test: 400,
        ..Default::default()
    }
}

fn runs_per_sec(runs: usize, secs: f64) -> f64 {
    if secs > 0.0 {
        runs as f64 / secs
    } else {
        0.0
    }
}

fn main() {
    let rt = Runtime::load_default().expect("run `make artifacts` first");
    let pool = SimPool::new(POOL_JOBS);

    // warmup: compile the executables on both paths before timing
    let warm = small().with(|c| {
        c.t_max = 5;
        c.n_train = 400;
        c.n_test = 100;
    });
    fed::run(&warm, &rt).expect("serial warmup");
    // warm every pool service (run_many's work-stealing could leave one
    // service cold, putting its XLA compilation inside the timed window)
    pool.warm(&warm).expect("pooled warmup");

    // -- batched vs scalar dispatch at growing device counts --------------
    let mut multi_rows = Vec::new();
    for n in [10usize, 30] {
        let base = small().with(|c| c.n = n);
        // warm both entry variants (scalar + the tile the batched path picks)
        for path in [TrainPath::Scalar, TrainPath::Batched] {
            fed::run(&warm.clone().with(|c| { c.n = n; c.train_path = path; }), &rt)
                .expect("path warmup");
        }
        const REPS: usize = 3;
        let mut secs = [0.0f64; 2];
        for (k, path) in [TrainPath::Scalar, TrainPath::Batched].into_iter().enumerate() {
            let cfg = base.clone().with(|c| c.train_path = path);
            let start = Instant::now();
            for rep in 0..REPS {
                std::hint::black_box(
                    fed::run(&cfg.clone().seeded(1 + rep as u64), &rt).expect("bench run"),
                );
            }
            secs[k] = start.elapsed().as_secs_f64();
        }
        let scalar_rps = runs_per_sec(REPS, secs[0]);
        let batched_rps = runs_per_sec(REPS, secs[1]);
        let speedup = secs[0] / secs[1].max(1e-9);
        println!(
            "engine/n={n:<3} scalar {:>7.2}s ({scalar_rps:.2} runs/s)  \
             batched {:>7.2}s ({batched_rps:.2} runs/s)  speedup {speedup:.2}×",
            secs[0], secs[1]
        );
        multi_rows.push(Json::obj(vec![
            ("n", Json::from(n)),
            ("runs", Json::from(REPS)),
            ("scalar_s", Json::from(secs[0])),
            ("batched_s", Json::from(secs[1])),
            ("scalar_runs_per_sec", Json::from(scalar_rps)),
            ("batched_runs_per_sec", Json::from(batched_rps)),
            ("batched_speedup", Json::from(speedup)),
        ]));
    }

    // -- eval: batched vs scalar full-pass dispatch ------------------------
    // one model scored over the whole test set: the scalar path pays one
    // PJRT call per BATCH chunk, the batched path ceil(chunks / D)
    // stacked calls (DESIGN.md §Perf rule 8)
    let eval_cfg = small().with(|c| {
        c.n_train = 1600;
        c.n_test = 2000;
    });
    let sub = Substrates::derive(&eval_cfg);
    let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).expect("trainer");
    let mut params = rt.init_params(ModelKind::Mlp, 1).expect("init");
    let all_train: Vec<u32> = (0..sub.train.len() as u32).collect();
    trainer
        .train_interval(&mut params, &sub.train, &all_train)
        .expect("train for non-uniform logits");
    let full_test: Vec<u32> = (0..sub.test.len() as u32).collect();
    let mut eval_work = vec![EvalWork {
        params: params.clone(),
        samples: full_test.clone(),
        accuracy: None,
    }];
    // warm both eval entry variants
    trainer.evaluate_subset(&params, &sub.test, &full_test).expect("warm scalar");
    trainer
        .evaluate_many(&rt, &sub.test, &mut eval_work, EvalPath::Batched)
        .expect("warm batched");

    const EVAL_REPS: usize = 10;
    let start = Instant::now();
    for _ in 0..EVAL_REPS {
        std::hint::black_box(
            trainer
                .evaluate_subset(&params, &sub.test, &full_test)
                .expect("scalar eval"),
        );
    }
    let eval_scalar_s = start.elapsed().as_secs_f64();
    let start = Instant::now();
    for _ in 0..EVAL_REPS {
        trainer
            .evaluate_many(&rt, &sub.test, &mut eval_work, EvalPath::Batched)
            .expect("batched eval");
        std::hint::black_box(eval_work[0].accuracy);
    }
    let eval_batched_s = start.elapsed().as_secs_f64();
    let eval_speedup = eval_scalar_s / eval_batched_s.max(1e-9);
    println!(
        "eval/full-pass  scalar {eval_scalar_s:>7.2}s  batched {eval_batched_s:>7.2}s  \
         speedup {eval_speedup:.2}×  ({} samples × {EVAL_REPS} reps)",
        full_test.len()
    );
    let eval_full_pass = Json::obj(vec![
        ("test_samples", Json::from(full_test.len())),
        ("reps", Json::from(EVAL_REPS)),
        ("scalar_s", Json::from(eval_scalar_s)),
        ("batched_s", Json::from(eval_batched_s)),
        ("batched_speedup", Json::from(eval_speedup)),
    ]);

    // -- eval: full vs subset schedule curve cost --------------------------
    // a curve-producing run pays one evaluation per aggregation; the
    // subset schedule cuts each to 1/shards of a test pass
    const SHARDS: usize = 5;
    let mut eval_curve_rows = Vec::new();
    for n in [10usize, 30] {
        let base = small().with(|c| {
            c.n = n;
            c.eval_curve = true;
        });
        const REPS: usize = 3;
        let mut secs = [0.0f64; 2];
        for (k, schedule) in [
            EvalSchedule::Full,
            EvalSchedule::Subset { shards: SHARDS },
        ]
        .into_iter()
        .enumerate()
        {
            let cfg = base.clone().with(|c| c.eval_schedule = schedule);
            fed::run(&cfg, &rt).expect("schedule warmup");
            let start = Instant::now();
            for rep in 0..REPS {
                std::hint::black_box(
                    fed::run(&cfg.clone().seeded(1 + rep as u64), &rt)
                        .expect("curve run"),
                );
            }
            secs[k] = start.elapsed().as_secs_f64();
        }
        let speedup = secs[0] / secs[1].max(1e-9);
        println!(
            "eval/curve n={n:<3} full {:>7.2}s  subset:{SHARDS} {:>7.2}s  \
             run speedup {speedup:.2}×",
            secs[0], secs[1]
        );
        eval_curve_rows.push(Json::obj(vec![
            ("n", Json::from(n)),
            ("runs", Json::from(REPS)),
            ("shards", Json::from(SHARDS)),
            ("full_s", Json::from(secs[0])),
            ("subset_s", Json::from(secs[1])),
            ("subset_speedup", Json::from(speedup)),
        ]));
    }

    // -- service: coalesced vs per-session dispatch through shared
    // services — the cross-session scheduler's reason to exist: with
    // K < jobs services, the classic loop serializes each session's
    // under-filled stack while the coalescer packs them into full
    // largest-tile dispatches (§Perf rule 10)
    let mut service_rows = Vec::new();
    for seeds in [4usize, 8] {
        // multi-trainee intervals so TrainMany requests actually stack
        let cfgs = seed_sweep(&small().with(|c| c.n = 10), seeds);
        for services in [1usize, 2] {
            let shared = SimPool::with_services(POOL_JOBS, services);
            shared.warm(&warm).expect("shared warmup");
            let start = Instant::now();
            std::hint::black_box(shared.run_many(&cfgs).expect("shared run"));
            let shared_s = start.elapsed().as_secs_f64();

            let coalesced = SimPool::coalescing(POOL_JOBS, services);
            coalesced.warm(&warm).expect("coalesced warmup");
            let start = Instant::now();
            std::hint::black_box(coalesced.run_many(&cfgs).expect("coalesced run"));
            let coalesced_s = start.elapsed().as_secs_f64();

            let speedup = shared_s / coalesced_s.max(1e-9);
            println!(
                "service/seeds={seeds:<2} services={services} \
                 per-session {shared_s:>7.2}s  coalesced {coalesced_s:>7.2}s  \
                 speedup {speedup:.2}×"
            );
            service_rows.push(Json::obj(vec![
                ("seeds", Json::from(seeds)),
                ("services", Json::from(services)),
                ("jobs", Json::from(POOL_JOBS)),
                ("per_session_s", Json::from(shared_s)),
                ("coalesced_s", Json::from(coalesced_s)),
                ("coalesced_speedup", Json::from(speedup)),
            ]));
        }
    }

    let mut rows = Vec::new();
    for seeds in [1usize, 4, 8] {
        let cfgs = seed_sweep(&small(), seeds);

        let start = Instant::now();
        for cfg in &cfgs {
            std::hint::black_box(fed::run(cfg, &rt).expect("serial run"));
        }
        let serial_s = start.elapsed().as_secs_f64();

        let start = Instant::now();
        std::hint::black_box(pool.run_many(&cfgs).expect("pooled run"));
        let pooled_s = start.elapsed().as_secs_f64();

        let serial_rps = runs_per_sec(seeds, serial_s);
        let pooled_rps = runs_per_sec(seeds, pooled_s);
        let speedup = if serial_s > 0.0 {
            serial_s / pooled_s.max(1e-9)
        } else {
            0.0
        };
        println!(
            "engine/seeds={seeds:<2} serial {serial_s:>7.2}s ({serial_rps:.2} runs/s)  \
             pooled×{POOL_JOBS} {pooled_s:>7.2}s ({pooled_rps:.2} runs/s)  speedup {speedup:.2}×"
        );
        rows.push(Json::obj(vec![
            ("seeds", Json::from(seeds)),
            ("serial_s", Json::from(serial_s)),
            ("pooled_s", Json::from(pooled_s)),
            ("serial_runs_per_sec", Json::from(serial_rps)),
            ("pooled_runs_per_sec", Json::from(pooled_rps)),
            ("speedup", Json::from(speedup)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::from("bench_engine")),
        ("pool_jobs", Json::from(POOL_JOBS)),
        ("config", Json::obj(vec![
            ("n", Json::from(small().n)),
            ("t_max", Json::from(small().t_max)),
            ("tau", Json::from(small().tau)),
            ("n_train", Json::from(small().n_train)),
        ])),
        ("rows", Json::Arr(rows)),
        ("multi_device", Json::Arr(multi_rows)),
        ("eval", Json::obj(vec![
            ("full_pass", eval_full_pass),
            ("curve", Json::Arr(eval_curve_rows)),
        ])),
        ("service", Json::Arr(service_rows)),
    ]);
    let text = report.to_string();
    std::fs::write("BENCH_engine.json", &text).expect("write BENCH_engine.json");
    if std::fs::create_dir_all("results/bench").is_ok() {
        let _ = std::fs::write("results/bench/BENCH_engine.json", &text);
    }
    println!("wrote BENCH_engine.json");
}
