//! Engine-throughput benchmark: serial `fed::run` vs pooled
//! `SimPool::run_many` over identical (config, seed) grids, plus the
//! batched-vs-scalar multi-device comparison.
//!
//! This is the perf trajectory for the session/pool refactor (DESIGN.md
//! §Perf): seed fan-outs of 1, 4 and 8 runs, timed end-to-end (substrate
//! derivation + movement optimization + PJRT training + aggregation), and
//! — since the batched train path landed — single runs at n ∈ {10, 30}
//! with `TrainPath::Scalar` vs `TrainPath::Batched` (§Perf rule 7: the
//! stacked `[D × BATCH]` entry amortizes PJRT dispatch over all devices
//! training in an interval). The `eval` section covers the evaluation
//! subsystem the same way (§Perf rule 8): a full test pass through the
//! scalar chunk loop vs the stacked `*_eval_many_d<D>` entries, and
//! curve-producing runs under the Full vs Subset eval schedules at
//! n ∈ {10, 30}. The `service` section covers the cross-session
//! coalescing scheduler (§Perf rule 10): identical seed fan-outs through
//! K shared services with the classic one-request-at-a-time loop vs the
//! coalescing one, at seeds ∈ {4, 8} and services ∈ {1, 2}.
//!
//! The `scaling` section is pure CPU — it runs (and the report is
//! written) even when no XLA runtime artifacts are present. It sweeps the
//! movement engine over random-geometric fog topologies at
//! N ∈ {10², 10³, 10⁴, 10⁵} devices with 5% interval churn (§Perf rule
//! 11): the edge-indexed sparse path at every size, the dense n×n path
//! only where its plan still fits (N ≤ 10⁴, ~800 MB), asserting bitwise
//! dense≡sparse agreement wherever both run, and reporting devices/sec
//! plus resident plan bytes (O(E) vs O(n²)). Its `threads` sweep drives
//! the same sparse engine at N ∈ {10³, 10⁴, 10⁵} across solver worker
//! counts {1, 2, 4, 8} (§Perf rule 12: fixed-chunk row passes with
//! serial ascending-order reductions), asserting every thread count
//! reproduces the serial checksum bit-for-bit while reporting the
//! devices/sec scaling.
//!
//! The `participation` section is pure CPU as well (stub compute): it
//! sweeps the device-sampling overlay (§Perf rule 13) over
//! K/N ∈ {0.25, 0.5, 1.0} for both `uniform:K` and `importance:K`
//! schedules, reporting engine runs/sec and per-run train-dispatch
//! counts — the point of sampling is that unsampled devices never reach
//! the compute backend, and the dispatch ratio makes that visible.
//!
//! The `aggregation` section is pure CPU too: the copy-on-write epoch
//! data plane (§Perf rule 14) at N ∈ {10³, 10⁴, 10⁵} devices ×
//! aggregation threads {1, 2, 4, 8}. Each run drives synthetic periods —
//! 10% of devices clone-on-train (`Arc::make_mut`), chunk-parallel
//! `aggregate_chunked`, pointer-bump resync — against a deep-clone-resync
//! reference, asserting every thread count and both resync strategies
//! produce bitwise-identical global parameters, and reporting periods/sec,
//! resident parameter bytes, and parameter bytes deep-copied per period
//! (the COW plane must copy ≥ 5× fewer at N = 10⁵; asserted). Its
//! `session` rows run the real engine (stub compute) with the O(t_max·n)
//! trace state off vs on — scaling benches run untraced.
//!
//! The `shard_io` section is pure CPU too — it times the sweep-sharding
//! I/O path (§Perf rule 9) both ways: a synthetic 4-shard set of
//! 12 000 full `EngineOutput` runs written and reassembled
//! (`load_shard_set`, the merge-bound step) as JSON
//! (`shard_I_of_N.json`, text serde) and as binary (`shard_I_of_N.fsb`,
//! `coordinator::binfmt` raw bit patterns), reporting bytes on disk,
//! runs/sec, and the binary-over-JSON speedups.
//!
//! Emits `BENCH_engine.json` (and a copy under `results/bench/`) so later
//! PRs have numbers to beat.

use std::cell::Cell;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use fogml::config::{EngineConfig, Method, TrainPath};
use fogml::coordinator::shard::{load_shard_set, RunRecord, ShardFile, ShardFormat, ShardSpec};
use fogml::coordinator::SimPool;
use fogml::costs::MovementCosts;
use fogml::experiments::common::seed_sweep;
use fogml::fed;
use fogml::fed::accounting::{IntervalStats, Ledger, MovementTotals};
use fogml::fed::aggregator;
use fogml::fed::eval::{EvalPath, EvalSchedule, EvalWork};
use fogml::fed::session::{run_with, Compute, Params};
use fogml::fed::{EngineOutput, ParticipationSchedule, Substrates, Trainer};
use fogml::movement::{self, convex, DiscardModel, MovementProblem, SolverWorkspace};
use fogml::runtime::{HostTensor, ModelKind, Runtime};
use fogml::topology::generators::random_geometric_with_positions;
use fogml::topology::{ActiveView, ChurnProcess, Graph};
use fogml::util::json::Json;
use fogml::util::rng::Rng;

const POOL_JOBS: usize = 4;

fn small() -> EngineConfig {
    EngineConfig {
        n: 6,
        t_max: 20,
        tau: 5,
        n_train: 1600,
        n_test: 400,
        ..Default::default()
    }
}

fn runs_per_sec(runs: usize, secs: f64) -> f64 {
    if secs > 0.0 {
        runs as f64 / secs
    } else {
        0.0
    }
}

// -- scaling: sparse movement engine at fog-population sizes ----------------

/// Procedural cost oracle for the scaling sweep: O(n) memory where a dense
/// `CostSchedule` would need `T · n²` link entries (hopeless at N = 10⁵).
/// Link costs derive from the random-geometric node positions (longer
/// links are pricier); capacities are unconstrained.
#[derive(Debug)]
struct GeoCosts {
    compute: Vec<f64>,
    error: Vec<f64>,
    pos: Vec<(f64, f64)>,
}

impl MovementCosts for GeoCosts {
    fn c_node(&self, t: usize, i: usize) -> f64 {
        self.compute[i] * (1.0 + 0.1 * (t % 3) as f64)
    }
    fn c_link(&self, _t: usize, i: usize, j: usize) -> f64 {
        let (xi, yi) = self.pos[i];
        let (xj, yj) = self.pos[j];
        2.0 * ((xi - xj).powi(2) + (yi - yj).powi(2)).sqrt()
    }
    fn f(&self, _t: usize, i: usize) -> f64 {
        self.error[i]
    }
    fn cap_node_at(&self, _t: usize, _i: usize) -> f64 {
        f64::INFINITY
    }
    fn cap_link_at(&self, _t: usize, _i: usize, _j: usize) -> f64 {
        f64::INFINITY
    }
}

const SCALING_T: usize = 5;
/// Largest N the dense n×n plan is still benchmarked at (the plan alone is
/// `8 n²` bytes: ~800 MB at 10⁴, 80 GB at 10⁵).
const DENSE_MAX_N: usize = 10_000;

struct ScaleOutcome {
    secs: f64,
    plan_bytes: usize,
    /// Sum of per-interval objectives — exact-equality witness between the
    /// dense and sparse paths (bit-identical solvers ⇒ identical sums).
    checksum: f64,
}

/// Run `SCALING_T` churned movement intervals over `graph` with either
/// backend. Both backends see identical churn and arrival streams (their
/// RNGs are re-seeded per call).
fn scale_run(graph: &Graph, costs: &GeoCosts, sparse: bool, ws: &mut SolverWorkspace) -> ScaleOutcome {
    let n = graph.n();
    let mut churn = ChurnProcess::new(n, 0.05, 0.05);
    let mut churn_rng = Rng::new(7);
    let mut d_rng = Rng::new(9);
    let mut active = ActiveView::all_active(n);
    let mut d = vec![0.0; n];
    let inbound = vec![0.0; n];
    let mut checksum = 0.0;
    let start = Instant::now();
    for t in 0..SCALING_T {
        active.apply(churn.step(&mut churn_rng));
        for x in d.iter_mut() {
            *x = (d_rng.f64() * 20.0).floor();
        }
        let p = MovementProblem {
            t,
            graph,
            active: active.as_slice(),
            d: &d,
            inbound_prev: &inbound,
            costs,
            discard_model: DiscardModel::LinearR,
        };
        if sparse {
            movement::solve_sparse_with(&p, ws);
            checksum += ws.sparse.objective(&p);
        } else {
            movement::solve_with(&p, ws);
            checksum += ws.plan.objective(&p);
        }
    }
    let secs = start.elapsed().as_secs_f64();
    let plan_bytes = if sparse { ws.sparse.heap_bytes() } else { ws.plan.heap_bytes() };
    ScaleOutcome { secs, plan_bytes, checksum }
}

fn scaling_section() -> Json {
    let mut rows = Vec::new();
    for n in [100usize, 1_000, 10_000, 100_000] {
        let mut rng = Rng::new(42);
        // radius targets mean degree ≈ 12, so |E| = O(V) at every size
        let radius = (12.0 / (std::f64::consts::PI * n as f64)).sqrt().min(1.0);
        let (graph, pos) = random_geometric_with_positions(n, radius, &mut rng);
        let costs = GeoCosts {
            compute: (0..n).map(|_| rng.uniform(0.05, 0.6)).collect(),
            error: (0..n).map(|_| rng.uniform(0.2, 0.9)).collect(),
            pos,
        };

        let mut ws = SolverWorkspace::new();
        let sparse = scale_run(&graph, &costs, true, &mut ws);
        let sparse_dps = runs_per_sec(n * SCALING_T, sparse.secs);

        let dense = (n <= DENSE_MAX_N).then(|| scale_run(&graph, &costs, false, &mut ws));
        if let Some(dense) = &dense {
            assert_eq!(
                dense.checksum, sparse.checksum,
                "dense/sparse objective sums diverged at n={n}"
            );
        }
        let (dense_s, dense_bytes, speedup) = match &dense {
            Some(d) => (Json::from(d.secs), Json::from(d.plan_bytes), Json::from(d.secs / sparse.secs.max(1e-9))),
            None => (Json::Null, Json::from(n * n * 8 + n * 8), Json::Null),
        };
        println!(
            "scaling/n={n:<6} edges={:<7} sparse {:>8.3}s ({sparse_dps:.0} devices/s, {} plan bytes)  dense {}",
            graph.num_edges(),
            sparse.secs,
            sparse.plan_bytes,
            match &dense {
                Some(d) => format!("{:.3}s ({} plan bytes, {:.1}× slower)", d.secs, d.plan_bytes, d.secs / sparse.secs.max(1e-9)),
                None => "skipped (plan would not fit)".to_string(),
            }
        );
        rows.push(Json::obj(vec![
            ("n", Json::from(n)),
            ("edges", Json::from(graph.num_edges())),
            ("intervals", Json::from(SCALING_T)),
            ("sparse_s", Json::from(sparse.secs)),
            ("sparse_devices_per_sec", Json::from(sparse_dps)),
            ("sparse_plan_bytes", Json::from(sparse.plan_bytes)),
            ("dense_s", dense_s),
            ("dense_plan_bytes", dense_bytes),
            ("dense_over_sparse", speedup),
        ]));
    }

    // -- threads: row-parallel solver passes at fixed chunk geometry --------
    // same sparse engine, same churned intervals, solver workers swept over
    // {1, 2, 4, 8}: §Perf rule 12 says the chunk layout is a function of n
    // only, so every count must reproduce the serial objective sums
    // bit-for-bit — the sweep measures wall clock and proves invariance
    let mut thread_rows = Vec::new();
    for n in [1_000usize, 10_000, 100_000] {
        let mut rng = Rng::new(44);
        let radius = (12.0 / (std::f64::consts::PI * n as f64)).sqrt().min(1.0);
        let (graph, pos) = random_geometric_with_positions(n, radius, &mut rng);
        let costs = GeoCosts {
            compute: (0..n).map(|_| rng.uniform(0.05, 0.6)).collect(),
            error: (0..n).map(|_| rng.uniform(0.2, 0.9)).collect(),
            pos,
        };
        let mut serial: Option<ScaleOutcome> = None;
        for threads in [1usize, 2, 4, 8] {
            let mut ws = SolverWorkspace::new();
            ws.solver_threads = threads;
            let out = scale_run(&graph, &costs, true, &mut ws);
            let dps = runs_per_sec(n * SCALING_T, out.secs);
            let speedup = match &serial {
                Some(s) => {
                    assert_eq!(
                        s.checksum, out.checksum,
                        "threads={threads} diverged from serial at n={n}"
                    );
                    s.secs / out.secs.max(1e-9)
                }
                None => 1.0,
            };
            println!(
                "scaling/threads n={n:<6} workers={threads}  {:>8.3}s ({dps:.0} devices/s, \
                 {speedup:.2}× vs serial, checksum identical)",
                out.secs
            );
            thread_rows.push(Json::obj(vec![
                ("n", Json::from(n)),
                ("threads", Json::from(threads)),
                ("intervals", Json::from(SCALING_T)),
                ("secs", Json::from(out.secs)),
                ("devices_per_sec", Json::from(dps)),
                ("speedup_vs_serial", Json::from(speedup)),
            ]));
            if serial.is_none() {
                serial = Some(out);
            }
        }
    }

    // PGD (Sqrt model) demo at n = 1000: the convex solver's sparse mirror
    // must match the dense one bitwise and beat it on wall clock
    let n = 1_000;
    let mut rng = Rng::new(43);
    let radius = (12.0 / (std::f64::consts::PI * n as f64)).sqrt();
    let (graph, pos) = random_geometric_with_positions(n, radius, &mut rng);
    let costs = GeoCosts {
        compute: (0..n).map(|_| rng.uniform(0.05, 0.6)).collect(),
        error: (0..n).map(|_| rng.uniform(0.2, 0.9)).collect(),
        pos,
    };
    let d: Vec<f64> = (0..n).map(|_| (rng.f64() * 20.0).floor()).collect();
    let inbound = vec![0.0; n];
    let active = vec![true; n];
    let p = MovementProblem {
        t: 0,
        graph: &graph,
        active: &active,
        d: &d,
        inbound_prev: &inbound,
        costs: &costs,
        discard_model: DiscardModel::Sqrt,
    };
    let opts = convex::PgdOptions { iterations: 60, step0: 0.0, tol: 0.0 };
    let mut ws = SolverWorkspace::new();
    let start = Instant::now();
    convex::solve_sparse_with(&p, opts, &mut ws);
    let pgd_sparse_s = start.elapsed().as_secs_f64();
    let start = Instant::now();
    convex::solve_with(&p, opts, &mut ws);
    let pgd_dense_s = start.elapsed().as_secs_f64();
    assert_eq!(ws.sparse.to_dense(), ws.plan, "PGD dense/sparse plans diverged");
    println!(
        "scaling/pgd n={n} iters=60  sparse {pgd_sparse_s:>7.3}s  dense {pgd_dense_s:>7.3}s  \
         speedup {:.1}×  (plans bit-identical)",
        pgd_dense_s / pgd_sparse_s.max(1e-9)
    );

    Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("threads", Json::Arr(thread_rows)),
        ("pgd_n", Json::from(n)),
        ("pgd_iterations", Json::from(60usize)),
        ("pgd_sparse_s", Json::from(pgd_sparse_s)),
        ("pgd_dense_s", Json::from(pgd_dense_s)),
    ])
}

// -- participation: device-sampling overlay cost (pure CPU) -----------------

/// Arithmetic stub compute (same shape as the session unit tests') with a
/// shared dispatch counter: every non-empty `train_interval` call is one
/// device reaching the backend, so the counter exposes exactly what the
/// sampling overlay is supposed to cut.
struct CountingStub {
    train_dispatches: Rc<Cell<usize>>,
}

impl Compute for CountingStub {
    fn init_params(&self, seed: u64) -> anyhow::Result<Params> {
        Ok(vec![fogml::runtime::HostTensor::new(vec![2], vec![(seed % 97) as f32, 0.0])])
    }

    fn train_interval(
        &self,
        params: &mut Params,
        samples: &[u32],
    ) -> anyhow::Result<Option<f32>> {
        if samples.is_empty() {
            return Ok(None);
        }
        self.train_dispatches.set(self.train_dispatches.get() + 1);
        params[0].data[1] += samples.len() as f32;
        Ok(Some(1.0 / (1.0 + params[0].data[1])))
    }

    fn evaluate(&self, params: &[fogml::runtime::HostTensor]) -> anyhow::Result<f64> {
        Ok((params[0].data[1] as f64 / 1e4).tanh())
    }
}

fn participation_section() -> Json {
    const N: usize = 8;
    const REPS: usize = 20;
    let base = EngineConfig {
        method: Method::NetworkAware,
        n: N,
        t_max: 40,
        tau: 4,
        n_train: 1200,
        n_test: 200,
        ..Default::default()
    };
    // K/N ∈ {1.0, 0.5, 0.25} for both sampled schedules; Full is the
    // K/N = 1.0 reference the dispatch ratios are quoted against
    let schedules = [
        ParticipationSchedule::Full,
        ParticipationSchedule::UniformK { k: N / 2 },
        ParticipationSchedule::UniformK { k: N / 4 },
        ParticipationSchedule::ImportanceK { k: N / 2 },
        ParticipationSchedule::ImportanceK { k: N / 4 },
    ];
    let mut rows = Vec::new();
    let mut full_dispatches = 0usize;
    for s in schedules {
        let cfg = base.clone().with(|c| c.participation = s);
        let sub = Substrates::derive(&cfg);
        let counter = Rc::new(Cell::new(0usize));
        let start = Instant::now();
        for _ in 0..REPS {
            std::hint::black_box(
                run_with(&cfg, &sub, CountingStub { train_dispatches: counter.clone() })
                    .expect("participation bench run"),
            );
        }
        let secs = start.elapsed().as_secs_f64();
        // identical config + substrates every rep ⇒ identical dispatch
        // counts per rep (determinism), so per-run is an exact division
        let per_run = counter.get() / REPS;
        let label = s.label();
        if matches!(s, ParticipationSchedule::Full) {
            full_dispatches = per_run;
        }
        let ratio = per_run as f64 / full_dispatches.max(1) as f64;
        let rps = runs_per_sec(REPS, secs);
        println!(
            "participation/{label:<13} {secs:>7.3}s ({rps:.1} runs/s)  \
             {per_run} train dispatches/run ({ratio:.2}× of full)"
        );
        rows.push(Json::obj(vec![
            ("schedule", Json::from(label)),
            ("n", Json::from(N)),
            ("runs", Json::from(REPS)),
            ("secs", Json::from(secs)),
            ("runs_per_sec", Json::from(rps)),
            ("train_dispatches_per_run", Json::from(per_run)),
            ("dispatch_ratio_vs_full", Json::from(ratio)),
        ]));
    }
    Json::obj(vec![("rows", Json::Arr(rows))])
}

// -- aggregation: COW epoch plane vs deep-clone resync (pure CPU) -----------

/// Per-replica parameter footprint of the synthetic model: one
/// 512-element f32 layer (2 KiB) — small enough that the N = 10⁵
/// deep-clone reference still fits in memory, large enough that the
/// copied-bytes gap dominates the period cost.
const AGG_PARAM_ELEMS: usize = 512;
const AGG_PERIODS: usize = 3;
/// Fraction of devices that train (and therefore unshare) each period.
const AGG_TRAINEE_SHARE: usize = 10;

struct AggOutcome {
    secs: f64,
    /// Parameter bytes deep-copied per period: clone-on-train for the COW
    /// plane, whole-population resync for the clone plane.
    copied_bytes_per_period: usize,
    /// Resident parameter bytes right after the final resync.
    resident_bytes: usize,
    /// Final global parameters — the bitwise witness across thread counts
    /// and between the two resync strategies.
    global: Params,
}

/// Drive `AGG_PERIODS` synthetic aggregation periods over `n` devices:
/// a deterministic 1/`AGG_TRAINEE_SHARE` trainee set perturbs its replica,
/// the trainees aggregate through `aggregate_chunked(threads)`, and the
/// new global resyncs to every device — by pointer bump (`cow`) or by
/// deep clone (the pre-rule-14 plane).
fn agg_run(n: usize, threads: usize, cow: bool) -> AggOutcome {
    let param_bytes = AGG_PARAM_ELEMS * std::mem::size_of::<f32>();
    let init: Params = vec![HostTensor::new(
        vec![AGG_PARAM_ELEMS],
        (0..AGG_PARAM_ELEMS).map(|k| (k as f32 * 0.01).sin()).collect(),
    )];
    let mut copied_total = 0usize;
    let start = Instant::now();
    let mut global = Arc::new(init);
    let mut cow_params: Vec<Arc<Params>> =
        if cow { vec![Arc::clone(&global); n] } else { Vec::new() };
    let mut clone_params: Vec<Params> =
        if cow { Vec::new() } else { vec![(*global).clone(); n] };
    for period in 0..AGG_PERIODS {
        // deterministic, period-shifted trainee set (no wraparound:
        // period < AGG_TRAINEE_SHARE keeps every index distinct)
        let trainees: Vec<usize> = (0..n / AGG_TRAINEE_SHARE)
            .map(|j| j * AGG_TRAINEE_SHARE + period)
            .collect();
        for &i in &trainees {
            let delta = (i as f32 + 1.0) * 1e-4;
            if cow {
                // shared at period start ⇒ make_mut deep-copies exactly once
                let p = Arc::make_mut(&mut cow_params[i]);
                for x in p[0].data.iter_mut() {
                    *x += delta;
                }
                copied_total += param_bytes;
            } else {
                for x in clone_params[i][0].data.iter_mut() {
                    *x += delta;
                }
            }
        }
        let refs: Vec<(&Params, f64)> = trainees
            .iter()
            .map(|&i| {
                let p: &Params =
                    if cow { cow_params[i].as_ref() } else { &clone_params[i] };
                (p, 1.0 + (i % 7) as f64)
            })
            .collect();
        let agg = aggregator::aggregate_chunked(
            &refs,
            threads,
            aggregator::CHUNK_CONTRIBUTORS,
            aggregator::CHUNK_ELEMS,
        )
        .expect("aggregate")
        .expect("positive total weight");
        global = Arc::new(agg);
        if cow {
            for p in cow_params.iter_mut() {
                *p = Arc::clone(&global);
            }
        } else {
            for p in clone_params.iter_mut() {
                p.clone_from(&global);
                copied_total += param_bytes;
            }
        }
    }
    let secs = start.elapsed().as_secs_f64();
    let resident_bytes = if cow {
        // after resync every Arc aliases the single global allocation
        param_bytes + n * std::mem::size_of::<Arc<Params>>()
    } else {
        n * param_bytes
    };
    AggOutcome {
        secs,
        copied_bytes_per_period: copied_total / AGG_PERIODS,
        resident_bytes,
        global: (*global).clone(),
    }
}

fn aggregation_section() -> Json {
    let mut rows = Vec::new();
    for n in [1_000usize, 10_000, 100_000] {
        let cloned = agg_run(n, 1, false);
        let mut serial: Option<AggOutcome> = None;
        for threads in [1usize, 2, 4, 8] {
            let out = agg_run(n, threads, true);
            match &serial {
                Some(s) => assert_eq!(
                    s.global, out.global,
                    "aggregation threads={threads} diverged from serial at n={n}"
                ),
                // same trainee sets, same contributions: the two resync
                // strategies must land on bitwise-identical globals
                None => assert_eq!(
                    cloned.global, out.global,
                    "COW plane diverged from the deep-clone plane at n={n}"
                ),
            }
            let copy_ratio = cloned.copied_bytes_per_period as f64
                / out.copied_bytes_per_period.max(1) as f64;
            if n == 100_000 && threads == 1 {
                assert!(
                    copy_ratio >= 5.0,
                    "COW copied-bytes advantage collapsed at n={n}: {copy_ratio:.1}×"
                );
            }
            let pps = runs_per_sec(AGG_PERIODS, out.secs);
            println!(
                "aggregation/n={n:<6} threads={threads}  cow {:>7.3}s ({pps:.1} periods/s, \
                 {} copied B/period, {} resident B)  cloned {:>7.3}s ({} copied B/period, \
                 {copy_ratio:.1}× more copied)",
                out.secs,
                out.copied_bytes_per_period,
                out.resident_bytes,
                cloned.secs,
                cloned.copied_bytes_per_period,
            );
            rows.push(Json::obj(vec![
                ("n", Json::from(n)),
                ("threads", Json::from(threads)),
                ("periods", Json::from(AGG_PERIODS)),
                ("cow_s", Json::from(out.secs)),
                ("cow_periods_per_sec", Json::from(pps)),
                ("cow_copied_bytes_per_period", Json::from(out.copied_bytes_per_period)),
                ("cow_resident_bytes", Json::from(out.resident_bytes)),
                ("cloned_s", Json::from(cloned.secs)),
                ("cloned_copied_bytes_per_period", Json::from(cloned.copied_bytes_per_period)),
                ("cloned_resident_bytes", Json::from(cloned.resident_bytes)),
                ("cloned_over_cow_copied", Json::from(copy_ratio)),
            ]));
            if serial.is_none() {
                serial = Some(out);
            }
        }
    }

    // engine-in-the-loop rows: the real session state machine over a stub
    // backend with the O(t_max·n) trace state off vs on — scaling runs go
    // untraced; flipping the flag must not change any result field it
    // doesn't own (asserted on accuracy)
    let mut session_rows = Vec::new();
    const SESSION_REPS: usize = 5;
    let base = EngineConfig {
        method: Method::NetworkAware,
        n: 256,
        t_max: 40,
        tau: 4,
        n_train: 1600,
        n_test: 200,
        ..Default::default()
    };
    let sub = Substrates::derive(&base);
    let mut accuracies = Vec::new();
    for trace in [false, true] {
        let cfg = base.clone().with(|c| c.trace = trace);
        let counter = Rc::new(Cell::new(0usize));
        let start = Instant::now();
        let mut last_accuracy = 0.0;
        for _ in 0..SESSION_REPS {
            let out = run_with(&cfg, &sub, CountingStub { train_dispatches: counter.clone() })
                .expect("aggregation session run");
            last_accuracy = out.accuracy;
            std::hint::black_box(&out);
        }
        let secs = start.elapsed().as_secs_f64();
        accuracies.push(last_accuracy);
        let rps = runs_per_sec(SESSION_REPS, secs);
        println!(
            "aggregation/session n={} trace={trace:<5} {secs:>7.3}s ({rps:.1} runs/s)",
            base.n
        );
        session_rows.push(Json::obj(vec![
            ("n", Json::from(base.n)),
            ("t_max", Json::from(base.t_max)),
            ("runs", Json::from(SESSION_REPS)),
            ("trace", Json::Bool(trace)),
            ("secs", Json::from(secs)),
            ("runs_per_sec", Json::from(rps)),
        ]));
    }
    assert_eq!(
        accuracies[0], accuracies[1],
        "trace flag changed the session's accuracy"
    );

    Json::obj(vec![
        ("param_elems", Json::from(AGG_PARAM_ELEMS)),
        ("trainee_share", Json::from(AGG_TRAINEE_SHARE)),
        ("rows", Json::Arr(rows)),
        ("session", Json::Arr(session_rows)),
    ])
}

// -- shard_io: binary vs JSON shard write + merge reassembly ----------------

/// Whole-grid run count of the synthetic shard set (≥ 10⁴ so the text
/// serde cost dominates the JSON path the way a real sweep's does).
const SHARD_IO_RUNS: usize = 12_000;
const SHARD_IO_SHARDS: usize = 4;

/// A representative full `EngineOutput`: a 12-point accuracy curve, 40
/// intervals × 8 devices of optional f32 losses, and 40 interval stats —
/// the shape a curve-producing sweep run actually serializes.
fn synthetic_output(rng: &mut Rng) -> EngineOutput {
    const INTERVALS: usize = 40;
    const DEVICES: usize = 8;
    let mut movement = MovementTotals::default();
    for _ in 0..INTERVALS {
        movement.push(IntervalStats {
            collected: rng.below(200),
            processed: rng.below(200),
            offloaded: rng.below(50),
            discarded: rng.below(20),
        });
    }
    EngineOutput {
        accuracy: rng.f64(),
        accuracy_curve: (0..12).map(|k| (k * 10, rng.f64())).collect(),
        per_device_loss: (0..INTERVALS)
            .map(|_| {
                (0..DEVICES)
                    .map(|_| rng.bool(0.9).then(|| rng.f32()))
                    .collect()
            })
            .collect(),
        ledger: Ledger {
            process: rng.uniform(0.0, 1e4),
            transfer: rng.uniform(0.0, 1e4),
            discard: rng.uniform(0.0, 1e3),
        },
        movement,
        similarity: (rng.f64(), rng.f64()),
        mean_active: rng.uniform(0.0, DEVICES as f64),
        total_collected: rng.below(100_000),
    }
}

/// The full synthetic set: SHARD_IO_SHARDS files jointly covering
/// SHARD_IO_RUNS runs under round-robin ownership, mutually consistent
/// so `load_shard_set` validates them exactly like a real merge would.
fn synthetic_shard_set() -> Vec<ShardFile> {
    let opts = Json::obj(vec![("synthetic", Json::Bool(true))]);
    (1..=SHARD_IO_SHARDS)
        .map(|i| {
            let spec = ShardSpec { index: i, count: SHARD_IO_SHARDS };
            let mut rng = Rng::new(1000 + i as u64);
            let runs = (0..SHARD_IO_RUNS)
                .filter(|&j| spec.owns(j))
                .map(|j| RunRecord {
                    index: j,
                    fingerprint: rng.next_u64(),
                    output: synthetic_output(&mut rng),
                })
                .collect();
            ShardFile {
                experiment: "fig9".to_string(),
                spec,
                total_runs: SHARD_IO_RUNS,
                grid_fingerprint: 0x5EED_F00D_CAFE_D00D,
                opts: opts.clone(),
                runs,
            }
        })
        .collect()
}

struct ShardIoOutcome {
    write_s: f64,
    load_s: f64,
    bytes: u64,
}

fn shard_io_run(files: &[ShardFile], format: ShardFormat) -> ShardIoOutcome {
    let dir = std::env::temp_dir().join(format!(
        "fogml_bench_shard_io_{}_{}",
        std::process::id(),
        format.extension()
    ));
    let _ = std::fs::remove_dir_all(&dir);

    let start = Instant::now();
    for f in files {
        f.save_as(&dir, format).expect("write shard");
    }
    let write_s = start.elapsed().as_secs_f64();
    let bytes: u64 = files
        .iter()
        .map(|f| {
            std::fs::metadata(dir.join(f.spec.file_name(format)))
                .map(|m| m.len())
                .unwrap_or(0)
        })
        .sum();

    // the merge-bound step: read, parse and validate every file, then
    // reassemble the whole grid in canonical order (replaying the driver
    // afterwards costs the same regardless of format)
    let start = Instant::now();
    let set = load_shard_set(&dir).expect("load shard set");
    let load_s = start.elapsed().as_secs_f64();
    assert_eq!(set.runs.len(), SHARD_IO_RUNS, "reassembly lost runs");
    std::hint::black_box(&set);

    let _ = std::fs::remove_dir_all(&dir);
    ShardIoOutcome { write_s, load_s, bytes }
}

fn shard_io_section() -> Json {
    let files = synthetic_shard_set();
    let json = shard_io_run(&files, ShardFormat::Json);
    let bin = shard_io_run(&files, ShardFormat::Binary);
    let write_speedup = json.write_s / bin.write_s.max(1e-9);
    let load_speedup = json.load_s / bin.load_s.max(1e-9);
    let bytes_ratio = json.bytes as f64 / bin.bytes.max(1) as f64;
    println!(
        "shard_io/runs={SHARD_IO_RUNS} shards={SHARD_IO_SHARDS}  \
         json  write {:>6.2}s ({:.0} runs/s)  merge-load {:>6.2}s ({:.0} runs/s)  {} bytes",
        json.write_s,
        runs_per_sec(SHARD_IO_RUNS, json.write_s),
        json.load_s,
        runs_per_sec(SHARD_IO_RUNS, json.load_s),
        json.bytes
    );
    println!(
        "shard_io/runs={SHARD_IO_RUNS} shards={SHARD_IO_SHARDS}  \
         binary write {:>6.2}s ({:.0} runs/s)  merge-load {:>6.2}s ({:.0} runs/s)  {} bytes",
        bin.write_s,
        runs_per_sec(SHARD_IO_RUNS, bin.write_s),
        bin.load_s,
        runs_per_sec(SHARD_IO_RUNS, bin.load_s),
        bin.bytes
    );
    println!(
        "shard_io/binary-over-json  write {write_speedup:.1}×  merge-load {load_speedup:.1}×  \
         size {bytes_ratio:.1}× smaller"
    );
    Json::obj(vec![
        ("total_runs", Json::from(SHARD_IO_RUNS)),
        ("shards", Json::from(SHARD_IO_SHARDS)),
        ("json_write_s", Json::from(json.write_s)),
        ("json_load_s", Json::from(json.load_s)),
        ("json_bytes", Json::from(json.bytes as usize)),
        ("json_write_runs_per_sec", Json::from(runs_per_sec(SHARD_IO_RUNS, json.write_s))),
        ("json_load_runs_per_sec", Json::from(runs_per_sec(SHARD_IO_RUNS, json.load_s))),
        ("binary_write_s", Json::from(bin.write_s)),
        ("binary_load_s", Json::from(bin.load_s)),
        ("binary_bytes", Json::from(bin.bytes as usize)),
        ("binary_write_runs_per_sec", Json::from(runs_per_sec(SHARD_IO_RUNS, bin.write_s))),
        ("binary_load_runs_per_sec", Json::from(runs_per_sec(SHARD_IO_RUNS, bin.load_s))),
        ("binary_write_speedup", Json::from(write_speedup)),
        ("binary_load_speedup", Json::from(load_speedup)),
        ("json_over_binary_bytes", Json::from(bytes_ratio)),
    ])
}

// -- runtime-backed sections (skipped when no XLA artifacts) ----------------

struct RuntimeSections {
    rows: Vec<Json>,
    multi_rows: Vec<Json>,
    eval: Json,
    service_rows: Vec<Json>,
}

fn runtime_sections(rt: &Runtime) -> RuntimeSections {
    let pool = SimPool::new(POOL_JOBS);

    // warmup: compile the executables on both paths before timing
    let warm = small().with(|c| {
        c.t_max = 5;
        c.n_train = 400;
        c.n_test = 100;
    });
    fed::run(&warm, rt).expect("serial warmup");
    // warm every pool service (run_many's work-stealing could leave one
    // service cold, putting its XLA compilation inside the timed window)
    pool.warm(&warm).expect("pooled warmup");

    // -- batched vs scalar dispatch at growing device counts --------------
    let mut multi_rows = Vec::new();
    for n in [10usize, 30] {
        let base = small().with(|c| c.n = n);
        // warm both entry variants (scalar + the tile the batched path picks)
        for path in [TrainPath::Scalar, TrainPath::Batched] {
            fed::run(&warm.clone().with(|c| { c.n = n; c.train_path = path; }), rt)
                .expect("path warmup");
        }
        const REPS: usize = 3;
        let mut secs = [0.0f64; 2];
        for (k, path) in [TrainPath::Scalar, TrainPath::Batched].into_iter().enumerate() {
            let cfg = base.clone().with(|c| c.train_path = path);
            let start = Instant::now();
            for rep in 0..REPS {
                std::hint::black_box(
                    fed::run(&cfg.clone().seeded(1 + rep as u64), rt).expect("bench run"),
                );
            }
            secs[k] = start.elapsed().as_secs_f64();
        }
        let scalar_rps = runs_per_sec(REPS, secs[0]);
        let batched_rps = runs_per_sec(REPS, secs[1]);
        let speedup = secs[0] / secs[1].max(1e-9);
        println!(
            "engine/n={n:<3} scalar {:>7.2}s ({scalar_rps:.2} runs/s)  \
             batched {:>7.2}s ({batched_rps:.2} runs/s)  speedup {speedup:.2}×",
            secs[0], secs[1]
        );
        multi_rows.push(Json::obj(vec![
            ("n", Json::from(n)),
            ("runs", Json::from(REPS)),
            ("scalar_s", Json::from(secs[0])),
            ("batched_s", Json::from(secs[1])),
            ("scalar_runs_per_sec", Json::from(scalar_rps)),
            ("batched_runs_per_sec", Json::from(batched_rps)),
            ("batched_speedup", Json::from(speedup)),
        ]));
    }

    // -- eval: batched vs scalar full-pass dispatch ------------------------
    // one model scored over the whole test set: the scalar path pays one
    // PJRT call per BATCH chunk, the batched path ceil(chunks / D)
    // stacked calls (DESIGN.md §Perf rule 8)
    let eval_cfg = small().with(|c| {
        c.n_train = 1600;
        c.n_test = 2000;
    });
    let sub = Substrates::derive(&eval_cfg);
    let trainer = Trainer::new(rt, ModelKind::Mlp, 0.05).expect("trainer");
    let mut params = rt.init_params(ModelKind::Mlp, 1).expect("init");
    let all_train: Vec<u32> = (0..sub.train.len() as u32).collect();
    trainer
        .train_interval(&mut params, &sub.train, &all_train)
        .expect("train for non-uniform logits");
    let full_test: Vec<u32> = (0..sub.test.len() as u32).collect();
    let mut eval_work = vec![EvalWork {
        params: params.clone(),
        samples: full_test.clone(),
        accuracy: None,
    }];
    // warm both eval entry variants
    trainer.evaluate_subset(&params, &sub.test, &full_test).expect("warm scalar");
    trainer
        .evaluate_many(rt, &sub.test, &mut eval_work, EvalPath::Batched)
        .expect("warm batched");

    const EVAL_REPS: usize = 10;
    let start = Instant::now();
    for _ in 0..EVAL_REPS {
        std::hint::black_box(
            trainer
                .evaluate_subset(&params, &sub.test, &full_test)
                .expect("scalar eval"),
        );
    }
    let eval_scalar_s = start.elapsed().as_secs_f64();
    let start = Instant::now();
    for _ in 0..EVAL_REPS {
        trainer
            .evaluate_many(rt, &sub.test, &mut eval_work, EvalPath::Batched)
            .expect("batched eval");
        std::hint::black_box(eval_work[0].accuracy);
    }
    let eval_batched_s = start.elapsed().as_secs_f64();
    let eval_speedup = eval_scalar_s / eval_batched_s.max(1e-9);
    println!(
        "eval/full-pass  scalar {eval_scalar_s:>7.2}s  batched {eval_batched_s:>7.2}s  \
         speedup {eval_speedup:.2}×  ({} samples × {EVAL_REPS} reps)",
        full_test.len()
    );
    let eval_full_pass = Json::obj(vec![
        ("test_samples", Json::from(full_test.len())),
        ("reps", Json::from(EVAL_REPS)),
        ("scalar_s", Json::from(eval_scalar_s)),
        ("batched_s", Json::from(eval_batched_s)),
        ("batched_speedup", Json::from(eval_speedup)),
    ]);

    // -- eval: full vs subset schedule curve cost --------------------------
    // a curve-producing run pays one evaluation per aggregation; the
    // subset schedule cuts each to 1/shards of a test pass
    const SHARDS: usize = 5;
    let mut eval_curve_rows = Vec::new();
    for n in [10usize, 30] {
        let base = small().with(|c| {
            c.n = n;
            c.eval_curve = true;
        });
        const REPS: usize = 3;
        let mut secs = [0.0f64; 2];
        for (k, schedule) in [
            EvalSchedule::Full,
            EvalSchedule::Subset { shards: SHARDS },
        ]
        .into_iter()
        .enumerate()
        {
            let cfg = base.clone().with(|c| c.eval_schedule = schedule);
            fed::run(&cfg, rt).expect("schedule warmup");
            let start = Instant::now();
            for rep in 0..REPS {
                std::hint::black_box(
                    fed::run(&cfg.clone().seeded(1 + rep as u64), rt)
                        .expect("curve run"),
                );
            }
            secs[k] = start.elapsed().as_secs_f64();
        }
        let speedup = secs[0] / secs[1].max(1e-9);
        println!(
            "eval/curve n={n:<3} full {:>7.2}s  subset:{SHARDS} {:>7.2}s  \
             run speedup {speedup:.2}×",
            secs[0], secs[1]
        );
        eval_curve_rows.push(Json::obj(vec![
            ("n", Json::from(n)),
            ("runs", Json::from(REPS)),
            ("shards", Json::from(SHARDS)),
            ("full_s", Json::from(secs[0])),
            ("subset_s", Json::from(secs[1])),
            ("subset_speedup", Json::from(speedup)),
        ]));
    }

    // -- service: coalesced vs per-session dispatch through shared
    // services — the cross-session scheduler's reason to exist: with
    // K < jobs services, the classic loop serializes each session's
    // under-filled stack while the coalescer packs them into full
    // largest-tile dispatches (§Perf rule 10)
    let mut service_rows = Vec::new();
    for seeds in [4usize, 8] {
        // multi-trainee intervals so TrainMany requests actually stack
        let cfgs = seed_sweep(&small().with(|c| c.n = 10), seeds);
        for services in [1usize, 2] {
            let shared = SimPool::with_services(POOL_JOBS, services);
            shared.warm(&warm).expect("shared warmup");
            let start = Instant::now();
            std::hint::black_box(shared.run_many(&cfgs).expect("shared run"));
            let shared_s = start.elapsed().as_secs_f64();

            let coalesced = SimPool::coalescing(POOL_JOBS, services);
            coalesced.warm(&warm).expect("coalesced warmup");
            let start = Instant::now();
            std::hint::black_box(coalesced.run_many(&cfgs).expect("coalesced run"));
            let coalesced_s = start.elapsed().as_secs_f64();

            let speedup = shared_s / coalesced_s.max(1e-9);
            println!(
                "service/seeds={seeds:<2} services={services} \
                 per-session {shared_s:>7.2}s  coalesced {coalesced_s:>7.2}s  \
                 speedup {speedup:.2}×"
            );
            service_rows.push(Json::obj(vec![
                ("seeds", Json::from(seeds)),
                ("services", Json::from(services)),
                ("jobs", Json::from(POOL_JOBS)),
                ("per_session_s", Json::from(shared_s)),
                ("coalesced_s", Json::from(coalesced_s)),
                ("coalesced_speedup", Json::from(speedup)),
            ]));
        }
    }

    let mut rows = Vec::new();
    for seeds in [1usize, 4, 8] {
        let cfgs = seed_sweep(&small(), seeds);

        let start = Instant::now();
        for cfg in &cfgs {
            std::hint::black_box(fed::run(cfg, rt).expect("serial run"));
        }
        let serial_s = start.elapsed().as_secs_f64();

        let start = Instant::now();
        std::hint::black_box(pool.run_many(&cfgs).expect("pooled run"));
        let pooled_s = start.elapsed().as_secs_f64();

        let serial_rps = runs_per_sec(seeds, serial_s);
        let pooled_rps = runs_per_sec(seeds, pooled_s);
        let speedup = if serial_s > 0.0 {
            serial_s / pooled_s.max(1e-9)
        } else {
            0.0
        };
        println!(
            "engine/seeds={seeds:<2} serial {serial_s:>7.2}s ({serial_rps:.2} runs/s)  \
             pooled×{POOL_JOBS} {pooled_s:>7.2}s ({pooled_rps:.2} runs/s)  speedup {speedup:.2}×"
        );
        rows.push(Json::obj(vec![
            ("seeds", Json::from(seeds)),
            ("serial_s", Json::from(serial_s)),
            ("pooled_s", Json::from(pooled_s)),
            ("serial_runs_per_sec", Json::from(serial_rps)),
            ("pooled_runs_per_sec", Json::from(pooled_rps)),
            ("speedup", Json::from(speedup)),
        ]));
    }

    RuntimeSections {
        rows,
        multi_rows,
        eval: Json::obj(vec![
            ("full_pass", eval_full_pass),
            ("curve", Json::Arr(eval_curve_rows)),
        ]),
        service_rows,
    }
}

fn main() {
    // pure-CPU sections first: they run (and the report is written) even
    // without runtime artifacts
    let scaling = scaling_section();
    let participation = participation_section();
    let aggregation = aggregation_section();
    let shard_io = shard_io_section();

    let runtime = match Runtime::load_default() {
        Ok(rt) => Some(runtime_sections(&rt)),
        Err(e) => {
            println!("runtime unavailable ({e}); skipping engine/eval/service sections");
            None
        }
    };

    let mut fields = vec![
        ("bench", Json::from("bench_engine")),
        ("pool_jobs", Json::from(POOL_JOBS)),
        ("config", Json::obj(vec![
            ("n", Json::from(small().n)),
            ("t_max", Json::from(small().t_max)),
            ("tau", Json::from(small().tau)),
            ("n_train", Json::from(small().n_train)),
        ])),
        ("runtime", Json::from(runtime.is_some())),
        ("scaling", scaling),
        ("participation", participation),
        ("aggregation", aggregation),
        ("shard_io", shard_io),
    ];
    if let Some(rt) = runtime {
        fields.push(("rows", Json::Arr(rt.rows)));
        fields.push(("multi_device", Json::Arr(rt.multi_rows)));
        fields.push(("eval", rt.eval));
        fields.push(("service", Json::Arr(rt.service_rows)));
    }
    let report = Json::obj(fields);
    let text = report.to_string();
    std::fs::write("BENCH_engine.json", &text).expect("write BENCH_engine.json");
    if std::fs::create_dir_all("results/bench").is_ok() {
        let _ = std::fs::write("results/bench/BENCH_engine.json", &text);
    }
    println!("wrote BENCH_engine.json");
}
