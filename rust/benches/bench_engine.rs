//! Engine-throughput benchmark: serial `fed::run` vs pooled
//! `SimPool::run_many` over identical (config, seed) grids, plus the
//! batched-vs-scalar multi-device comparison.
//!
//! This is the perf trajectory for the session/pool refactor (DESIGN.md
//! §Perf): seed fan-outs of 1, 4 and 8 runs, timed end-to-end (substrate
//! derivation + movement optimization + PJRT training + aggregation), and
//! — since the batched train path landed — single runs at n ∈ {10, 30}
//! with `TrainPath::Scalar` vs `TrainPath::Batched` (§Perf rule 7: the
//! stacked `[D × BATCH]` entry amortizes PJRT dispatch over all devices
//! training in an interval). Emits `BENCH_engine.json` (and a copy under
//! `results/bench/`) so later PRs have numbers to beat.

use std::time::Instant;

use fogml::config::{EngineConfig, TrainPath};
use fogml::coordinator::SimPool;
use fogml::experiments::common::seed_sweep;
use fogml::fed;
use fogml::runtime::Runtime;
use fogml::util::json::Json;

const POOL_JOBS: usize = 4;

fn small() -> EngineConfig {
    EngineConfig {
        n: 6,
        t_max: 20,
        tau: 5,
        n_train: 1600,
        n_test: 400,
        ..Default::default()
    }
}

fn runs_per_sec(runs: usize, secs: f64) -> f64 {
    if secs > 0.0 {
        runs as f64 / secs
    } else {
        0.0
    }
}

fn main() {
    let rt = Runtime::load_default().expect("run `make artifacts` first");
    let pool = SimPool::new(POOL_JOBS);

    // warmup: compile the executables on both paths before timing
    let warm = small().with(|c| {
        c.t_max = 5;
        c.n_train = 400;
        c.n_test = 100;
    });
    fed::run(&warm, &rt).expect("serial warmup");
    // warm every pool service (run_many's work-stealing could leave one
    // service cold, putting its XLA compilation inside the timed window)
    pool.warm(&warm).expect("pooled warmup");

    // -- batched vs scalar dispatch at growing device counts --------------
    let mut multi_rows = Vec::new();
    for n in [10usize, 30] {
        let base = small().with(|c| c.n = n);
        // warm both entry variants (scalar + the tile the batched path picks)
        for path in [TrainPath::Scalar, TrainPath::Batched] {
            fed::run(&warm.clone().with(|c| { c.n = n; c.train_path = path; }), &rt)
                .expect("path warmup");
        }
        const REPS: usize = 3;
        let mut secs = [0.0f64; 2];
        for (k, path) in [TrainPath::Scalar, TrainPath::Batched].into_iter().enumerate() {
            let cfg = base.clone().with(|c| c.train_path = path);
            let start = Instant::now();
            for rep in 0..REPS {
                std::hint::black_box(
                    fed::run(&cfg.clone().seeded(1 + rep as u64), &rt).expect("bench run"),
                );
            }
            secs[k] = start.elapsed().as_secs_f64();
        }
        let scalar_rps = runs_per_sec(REPS, secs[0]);
        let batched_rps = runs_per_sec(REPS, secs[1]);
        let speedup = secs[0] / secs[1].max(1e-9);
        println!(
            "engine/n={n:<3} scalar {:>7.2}s ({scalar_rps:.2} runs/s)  \
             batched {:>7.2}s ({batched_rps:.2} runs/s)  speedup {speedup:.2}×",
            secs[0], secs[1]
        );
        multi_rows.push(Json::obj(vec![
            ("n", Json::from(n)),
            ("runs", Json::from(REPS)),
            ("scalar_s", Json::from(secs[0])),
            ("batched_s", Json::from(secs[1])),
            ("scalar_runs_per_sec", Json::from(scalar_rps)),
            ("batched_runs_per_sec", Json::from(batched_rps)),
            ("batched_speedup", Json::from(speedup)),
        ]));
    }

    let mut rows = Vec::new();
    for seeds in [1usize, 4, 8] {
        let cfgs = seed_sweep(&small(), seeds);

        let start = Instant::now();
        for cfg in &cfgs {
            std::hint::black_box(fed::run(cfg, &rt).expect("serial run"));
        }
        let serial_s = start.elapsed().as_secs_f64();

        let start = Instant::now();
        std::hint::black_box(pool.run_many(&cfgs).expect("pooled run"));
        let pooled_s = start.elapsed().as_secs_f64();

        let serial_rps = runs_per_sec(seeds, serial_s);
        let pooled_rps = runs_per_sec(seeds, pooled_s);
        let speedup = if serial_s > 0.0 {
            serial_s / pooled_s.max(1e-9)
        } else {
            0.0
        };
        println!(
            "engine/seeds={seeds:<2} serial {serial_s:>7.2}s ({serial_rps:.2} runs/s)  \
             pooled×{POOL_JOBS} {pooled_s:>7.2}s ({pooled_rps:.2} runs/s)  speedup {speedup:.2}×"
        );
        rows.push(Json::obj(vec![
            ("seeds", Json::from(seeds)),
            ("serial_s", Json::from(serial_s)),
            ("pooled_s", Json::from(pooled_s)),
            ("serial_runs_per_sec", Json::from(serial_rps)),
            ("pooled_runs_per_sec", Json::from(pooled_rps)),
            ("speedup", Json::from(speedup)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::from("bench_engine")),
        ("pool_jobs", Json::from(POOL_JOBS)),
        ("config", Json::obj(vec![
            ("n", Json::from(small().n)),
            ("t_max", Json::from(small().t_max)),
            ("tau", Json::from(small().tau)),
            ("n_train", Json::from(small().n_train)),
        ])),
        ("rows", Json::Arr(rows)),
        ("multi_device", Json::Arr(multi_rows)),
    ]);
    let text = report.to_string();
    std::fs::write("BENCH_engine.json", &text).expect("write BENCH_engine.json");
    if std::fs::create_dir_all("results/bench").is_ok() {
        let _ = std::fs::write("results/bench/BENCH_engine.json", &text);
    }
    println!("wrote BENCH_engine.json");
}
