//! PJRT runtime benchmarks — the per-step cost the whole system pays:
//! compiled train/eval step latency for both models, the standalone pallas
//! dense microkernel, and parameter initialization. L1/L2 perf target from
//! DESIGN.md §Perf is tracked here (JSON history under `results/bench/`).

use fogml::bench::Runner;
use fogml::data::dataset::{IMG_PIXELS, NUM_CLASSES};
use fogml::data::SynthDigits;
use fogml::fed::Trainer;
use fogml::runtime::{HostTensor, ModelKind, Runtime};
use fogml::util::rng::Rng;

fn main() {
    let rt = Runtime::load_default().expect("run `make artifacts` first");
    let mut runner = Runner::new("runtime").with_iters(5, 30);
    let b = rt.batch();

    // dense pallas microkernel
    let micro = rt.executable("dense_micro").unwrap();
    let mut rng = Rng::new(3);
    let x = HostTensor::new(vec![128, IMG_PIXELS], (0..128 * IMG_PIXELS).map(|_| rng.f32()).collect());
    let w = HostTensor::new(vec![IMG_PIXELS, 128], (0..IMG_PIXELS * 128).map(|_| rng.f32()).collect());
    let bias = HostTensor::new(vec![128], (0..128).map(|_| rng.f32()).collect());
    runner.bench("dense_micro_128x196x128", || {
        std::hint::black_box(micro.run(&[x.clone(), w.clone(), bias.clone()]).unwrap());
    });

    let gen = SynthDigits::new(0xF0D5);
    let mut drng = Rng::new(5);
    let (train, test) = gen.train_test(512, 256, &mut drng);

    for kind in [ModelKind::Mlp, ModelKind::Cnn] {
        let trainer = Trainer::new(&rt, kind, 0.05).unwrap();
        let params0 = rt.init_params(kind, 7).unwrap();
        let batch_idx: Vec<u32> = (0..b as u32).collect();

        let mut params = params0.clone();
        runner.bench(&format!("train_step_b{b}/{kind}"), || {
            std::hint::black_box(
                trainer.train_interval(&mut params, &train, &batch_idx).unwrap(),
            );
        });

        runner.bench(&format!("eval_256/{kind}"), || {
            std::hint::black_box(trainer.evaluate(&params0, &test).unwrap());
        });

        runner.bench(&format!("init_params/{kind}"), || {
            std::hint::black_box(rt.init_params(kind, 11).unwrap());
        });
    }

    // aggregation cost (pure host)
    let p1 = rt.init_params(ModelKind::Mlp, 1).unwrap();
    let p2 = rt.init_params(ModelKind::Mlp, 2).unwrap();
    runner.bench("fedavg_aggregate_2xMLP", || {
        std::hint::black_box(
            fogml::fed::aggregator::aggregate(&[(&p1, 3.0), (&p2, 5.0)]).unwrap().unwrap(),
        );
    });

    let _ = NUM_CLASSES;
    runner.write_results().expect("write bench results");
}
