//! End-to-end table benchmarks: one scaled-down engine run per paper table
//! (Tables II–V), timing the complete pipeline — movement optimization,
//! PJRT local updates, aggregation, accounting. `fogml exp tableN`
//! regenerates the full-size numbers; these benches track the wall-clock
//! of the system that produces them.

use fogml::bench::Runner;
use fogml::config::{CapacityPolicy, Churn, EngineConfig, InfoMode, Method};
use fogml::fed;
use fogml::movement::DiscardModel;
use fogml::runtime::Runtime;

fn small() -> EngineConfig {
    EngineConfig {
        n: 6,
        t_max: 20,
        tau: 5,
        n_train: 1600,
        n_test: 400,
        ..Default::default()
    }
}

fn main() {
    let rt = Runtime::load_default().expect("run `make artifacts` first");
    let mut runner = Runner::new("tables").with_iters(1, 5);

    // Table II cell: one methodology comparison point
    runner.bench("table2_cell/network_aware_mlp", || {
        std::hint::black_box(fed::run(&small(), &rt).unwrap());
    });
    runner.bench("table2_cell/federated_mlp", || {
        std::hint::black_box(
            fed::run(&small().with(|c| c.method = Method::Federated), &rt).unwrap(),
        );
    });
    runner.bench("table2_cell/centralized_mlp", || {
        std::hint::black_box(
            fed::run(&small().with(|c| c.method = Method::Centralized), &rt).unwrap(),
        );
    });

    // Table III settings: the costliest variants
    runner.bench("table3_setting/C_estimated", || {
        std::hint::black_box(
            fed::run(&small().with(|c| c.info = InfoMode::Estimated(5)), &rt).unwrap(),
        );
    });
    runner.bench("table3_setting/E_estimated_capped", || {
        std::hint::black_box(
            fed::run(
                &small().with(|c| {
                    c.info = InfoMode::Estimated(5);
                    c.capacity = CapacityPolicy::MeanArrivals;
                }),
                &rt,
            )
            .unwrap(),
        );
    });

    // Table IV row: the convex solver path (the heaviest optimizer)
    runner.bench("table4_row/sqrt_discard_model", || {
        std::hint::black_box(
            fed::run(&small().with(|c| c.discard_model = DiscardModel::Sqrt), &rt).unwrap(),
        );
    });

    // Table V row: dynamic network
    runner.bench("table5_row/dynamic_1pct_churn", || {
        std::hint::black_box(
            fed::run(
                &small().with(|c| c.churn = Some(Churn { p_exit: 0.01, p_entry: 0.01 })),
                &rt,
            )
            .unwrap(),
        );
    });

    runner.write_results().expect("write bench results");
}
