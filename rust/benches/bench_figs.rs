//! End-to-end figure benchmarks: representative sweep points of Figures
//! 5–10 at reduced scale — tracks how engine wall-clock scales with n, ρ,
//! τ and churn, which bounds the cost of regenerating the full figures.

use fogml::bench::Runner;
use fogml::config::{Churn, EngineConfig, TopologyKind};
use fogml::costs::{CostSource, Medium};
use fogml::fed;
use fogml::runtime::Runtime;

fn small() -> EngineConfig {
    EngineConfig {
        n: 6,
        t_max: 20,
        tau: 5,
        n_train: 1600,
        n_test: 400,
        ..Default::default()
    }
}

fn main() {
    let rt = Runtime::load_default().expect("run `make artifacts` first");
    let mut runner = Runner::new("figs").with_iters(1, 5);

    // Fig 5: node-count scaling (largest point dominates the sweep)
    for n in [5usize, 15, 30] {
        runner.bench(&format!("fig5_point/n={n}"), || {
            std::hint::black_box(fed::run(&small().with(|c| c.n = n), &rt).unwrap());
        });
    }

    // Fig 6: connectivity extremes
    for rho in [0.2f64, 1.0] {
        runner.bench(&format!("fig6_point/rho={rho}"), || {
            std::hint::black_box(
                fed::run(&small().with(|c| c.topology = TopologyKind::Random(rho)), &rt)
                    .unwrap(),
            );
        });
    }

    // Fig 7: aggregation period extremes
    for tau in [2usize, 20] {
        runner.bench(&format!("fig7_point/tau={tau}"), || {
            std::hint::black_box(fed::run(&small().with(|c| c.tau = tau), &rt).unwrap());
        });
    }

    // Fig 8: topology × medium
    for (name, topo) in [
        ("social", TopologyKind::SmallWorld),
        ("hierarchical", TopologyKind::Hierarchical),
    ] {
        runner.bench(&format!("fig8_point/{name}_wifi"), || {
            std::hint::black_box(
                fed::run(
                    &small().with(|c| {
                        c.topology = topo;
                        c.cost_source = CostSource::Testbed(Medium::Wifi);
                    }),
                    &rt,
                )
                .unwrap(),
            );
        });
    }

    // Figs 9/10: churn
    runner.bench("fig9_point/p_exit=5pct", || {
        std::hint::black_box(
            fed::run(
                &small().with(|c| c.churn = Some(Churn { p_exit: 0.05, p_entry: 0.02 })),
                &rt,
            )
            .unwrap(),
        );
    });

    runner.write_results().expect("write bench results");
}
