//! Substrate benchmarks: dataset synthesis, partitioning, topology
//! generation, cost traces, queueing closed forms, and the JSON/manifest
//! parser — everything the engine touches outside the PJRT hot path.

use fogml::bench::Runner;
use fogml::costs::traces::{self, Medium};
use fogml::data::{Partitioner, SynthDigits};
use fogml::queueing::{capacity_for_waiting_time, dm1, straggler};
use fogml::topology::generators;
use fogml::util::json::Json;
use fogml::util::rng::Rng;

fn main() {
    let mut runner = Runner::new("substrates").with_iters(2, 10);

    let gen = SynthDigits::new(1);
    runner.bench("dataset_generate_8000", || {
        let mut rng = Rng::new(2);
        std::hint::black_box(gen.generate(8000, &mut rng));
    });

    let mut rng = Rng::new(3);
    let ds = gen.generate(8000, &mut rng);
    runner.bench("partition_noniid_n10_t100", || {
        let mut rng = Rng::new(4);
        let p = Partitioner { n_devices: 10, t_max: 100, iid: false };
        std::hint::black_box(p.partition(&ds, &mut rng));
    });

    runner.bench("topology_scale_free_n100", || {
        let mut rng = Rng::new(5);
        std::hint::black_box(generators::scale_free(100, 2, &mut rng));
    });
    runner.bench("topology_watts_strogatz_n100", || {
        let mut rng = Rng::new(6);
        std::hint::black_box(generators::watts_strogatz(100, 10, 0.3, &mut rng));
    });

    runner.bench("costs_testbed_n50_t100", || {
        let mut rng = Rng::new(7);
        std::hint::black_box(traces::testbed(50, 100, Medium::Lte, &mut rng));
    });

    runner.bench("dm1_capacity_rule_1000x", || {
        for i in 1..=1000 {
            let mu = 0.5 + i as f64 / 500.0;
            std::hint::black_box(capacity_for_waiting_time(mu, 1.0));
        }
    });
    runner.bench("dm1_fixed_point_1000x", || {
        for i in 1..=1000 {
            let lambda = i as f64 / 1001.0;
            std::hint::black_box(dm1::mean_waiting_time(1.0, lambda));
        }
    });
    runner.bench("dm1_simulate_100k_jobs", || {
        let mut rng = Rng::new(8);
        std::hint::black_box(straggler::simulate(1.0, 0.8, 100_000, &mut rng));
    });

    let manifest_text = std::fs::read_to_string("artifacts/manifest.json")
        .expect("run `make artifacts` first");
    runner.bench("json_parse_manifest", || {
        std::hint::black_box(Json::parse(&manifest_text).unwrap());
    });

    runner.write_results().expect("write bench results");
}
