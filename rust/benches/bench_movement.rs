//! Movement-optimizer benchmarks: solver cost as a function of network
//! size for both solver families plus the repair pass. The L3 target from
//! DESIGN.md §Perf: solver time per interval must stay far below a train
//! step (~hundreds of µs), even at n = 50.

use fogml::bench::Runner;
use fogml::costs::{CapacityMode, CostSchedule};
use fogml::movement::convex::{self, PgdOptions};
use fogml::movement::problem::{DiscardModel, MovementProblem};
use fogml::movement::{greedy, repair};
use fogml::topology::generators::fully_connected;
use fogml::util::rng::Rng;

fn random_costs(n: usize, rng: &mut Rng) -> CostSchedule {
    let mut costs = CostSchedule::zeros(n, 2);
    for t in 0..2 {
        for i in 0..n {
            costs.compute[t][i] = rng.f64();
            costs.error_weight[t][i] = 0.5;
            for j in 0..n {
                if i != j {
                    costs.link[t][i * n + j] = rng.f64() * 0.4;
                }
            }
        }
    }
    costs
}

fn main() {
    let mut runner = Runner::new("movement").with_iters(3, 20);
    let mut rng = Rng::new(1);

    for &n in &[10usize, 25, 50] {
        let graph = fully_connected(n);
        let costs = random_costs(n, &mut rng);
        let d: Vec<f64> = (0..n).map(|_| 8.0).collect();
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        runner.bench(&format!("greedy_theorem3/n={n}"), || {
            std::hint::black_box(greedy::solve(&p));
        });

        let p_sqrt = MovementProblem { discard_model: DiscardModel::Sqrt, ..p };
        runner.bench(&format!("convex_pgd_400it/n={n}"), || {
            std::hint::black_box(convex::solve(&p_sqrt, PgdOptions::default()));
        });

        let mut capped = costs.clone();
        capped.set_capacities(CapacityMode::Uniform(8.0));
        let p_cap = MovementProblem { costs: &capped, ..p };
        let base_plan = greedy::solve(&p_cap);
        runner.bench(&format!("repair_pass/n={n}"), || {
            let mut plan = base_plan.clone();
            repair::repair(&p_cap, &mut plan);
            std::hint::black_box(plan);
        });
    }

    runner.write_results().expect("write bench results");
}
