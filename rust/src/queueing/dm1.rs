//! D/M/1 queue closed forms and the Theorem-2 capacity rule.
//!
//! For a D/M/1 queue with deterministic inter-arrival time `1/λ` and
//! exponential service rate `μ` (utilization `λ/μ < 1`), the mean waiting
//! time is `W = δ / (μ (1 - δ))` where `δ` is the smallest root of
//!
//! ```text
//! δ = exp(-μ (1 - δ) / λ)
//! ```
//!
//! **Theorem 2.** To guarantee `W ≤ σ`, set the capacity `C_i` such that
//! `φ(C_i) = σ μ / (1 + σ μ)` where `φ(C)` is the smallest solution of
//! `φ = exp(-μ (1 - φ) / C)`. Inverting the fixed point gives
//! `C = -μ (1 - φ) / ln φ`, which [`capacity_for_waiting_time`] computes
//! directly.

/// Smallest root of `δ = exp(-μ (1 - δ) / λ)` for a stable queue
/// (`λ < μ`); returns 1.0 for an unstable/critical queue.
pub fn delta_fixed_point(mu: f64, lambda: f64) -> f64 {
    assert!(mu > 0.0 && lambda > 0.0);
    if lambda >= mu {
        return 1.0;
    }
    // The map x -> exp(-mu(1-x)/lambda) is increasing and convex on [0,1]
    // with two fixed points; iterating from 0 converges to the smallest.
    let mut x = 0.0f64;
    for _ in 0..200 {
        let next = (-mu * (1.0 - x) / lambda).exp();
        if (next - x).abs() < 1e-14 {
            return next;
        }
        x = next;
    }
    x
}

/// Mean waiting time of the D/M/1 queue; infinite if unstable.
pub fn mean_waiting_time(mu: f64, lambda: f64) -> f64 {
    let delta = delta_fixed_point(mu, lambda);
    if delta >= 1.0 {
        f64::INFINITY
    } else {
        delta / (mu * (1.0 - delta))
    }
}

/// Theorem 2: the largest capacity `C_i` (arrival-rate bound) such that the
/// mean waiting time stays below `sigma` when service is `exp(mu)`.
pub fn capacity_for_waiting_time(mu: f64, sigma: f64) -> f64 {
    assert!(mu > 0.0 && sigma > 0.0);
    let phi = sigma * mu / (1.0 + sigma * mu); // in (0, 1)
    -mu * (1.0 - phi) / phi.ln()
}

/// The link-capacity analog of Theorem 2 (§IV-A1: "network link congestion
/// ... can be handled by choosing the network capacity C_ij(t) analogously").
/// Transfers on link (i, j) queue behind each other with `exp(mu_link)`
/// service (per-datapoint transmission time under fading/retries); the same
/// D/M/1 bound applies, so the per-interval link capacity that keeps mean
/// queueing delay under `sigma` is the same fixed-point inversion.
pub fn link_capacity_for_delay(mu_link: f64, sigma: f64) -> f64 {
    capacity_for_waiting_time(mu_link, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_increasing_in_lambda() {
        let mu = 1.0;
        let mut prev = 0.0;
        for lambda in [0.1, 0.3, 0.5, 0.7, 0.9] {
            let d = delta_fixed_point(mu, lambda);
            assert!(d > prev, "delta not increasing at λ={lambda}");
            assert!(d < 1.0);
            prev = d;
        }
    }

    #[test]
    fn delta_satisfies_fixed_point() {
        for (mu, lambda) in [(1.0, 0.5), (2.0, 1.0), (5.0, 4.0)] {
            let d = delta_fixed_point(mu, lambda);
            let rhs = (-mu * (1.0 - d) / lambda).exp();
            assert!((d - rhs).abs() < 1e-10, "μ={mu} λ={lambda}");
        }
    }

    #[test]
    fn unstable_queue_has_infinite_wait() {
        assert!(mean_waiting_time(1.0, 1.0).is_infinite());
        assert!(mean_waiting_time(1.0, 2.0).is_infinite());
    }

    #[test]
    fn waiting_time_monotone_in_load() {
        let mu = 1.0;
        let mut prev = 0.0;
        for lambda in [0.2, 0.4, 0.6, 0.8, 0.95] {
            let w = mean_waiting_time(mu, lambda);
            assert!(w > prev);
            prev = w;
        }
    }

    #[test]
    fn theorem2_capacity_achieves_sigma() {
        // at the capacity rule's arrival rate, W == σ (up to fp error)
        for (mu, sigma) in [(1.0, 1.0), (2.0, 0.5), (0.7, 2.0)] {
            let c = capacity_for_waiting_time(mu, sigma);
            assert!(c < mu, "capacity must keep the queue stable");
            let w = mean_waiting_time(mu, c);
            assert!(
                (w - sigma).abs() < 1e-6,
                "μ={mu} σ={sigma}: W(C)={w}"
            );
            // any arrival rate below C gives a smaller wait
            let w_less = mean_waiting_time(mu, 0.9 * c);
            assert!(w_less < sigma);
        }
    }

    #[test]
    fn link_capacity_rule_bounds_simulated_delay() {
        // the §IV-A1 link analog: same guarantee on a transfer queue
        let (mu, sigma) = (3.0, 0.4);
        let c = link_capacity_for_delay(mu, sigma);
        assert!(c < mu);
        let w = mean_waiting_time(mu, c);
        assert!((w - sigma).abs() < 1e-6);
    }

    #[test]
    fn theorem2_phi_is_increasing_in_capacity() {
        // φ(C) increasing in C (claimed in the theorem statement)
        let mu = 1.0;
        let mut prev = 0.0;
        for c in [0.2, 0.4, 0.6, 0.8] {
            let phi = delta_fixed_point(mu, c);
            assert!(phi > prev);
            prev = phi;
        }
    }
}
