//! Discrete-event D/M/1 simulator: validates the closed forms in [`super::dm1`]
//! and powers the Theorem-2 validation experiment (`fogml exp theory`).
//!
//! Arrivals are deterministic at rate λ (one datapoint every 1/λ time
//! units); service times are `exp(μ)` — the straggler model. The simulator
//! reports the mean *waiting* time (time in queue, excluding service), the
//! quantity Theorem 2 bounds.

use crate::util::rng::Rng;

/// Result of a simulation run.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    pub mean_wait: f64,
    pub max_wait: f64,
    pub utilization: f64,
}

/// Simulate `n_jobs` deterministic arrivals at rate `lambda` through a
/// single `exp(mu)` server; returns waiting statistics (after discarding a
/// 10% warm-up prefix).
pub fn simulate(mu: f64, lambda: f64, n_jobs: usize, rng: &mut Rng) -> SimResult {
    assert!(mu > 0.0 && lambda > 0.0 && n_jobs > 1);
    let interarrival = 1.0 / lambda;
    let mut server_free_at = 0.0f64;
    let mut waits = Vec::with_capacity(n_jobs);
    let mut busy_time = 0.0f64;
    let mut arrival = 0.0f64;
    for _ in 0..n_jobs {
        let start = server_free_at.max(arrival);
        let wait = start - arrival;
        let service = rng.exponential(mu);
        server_free_at = start + service;
        busy_time += service;
        waits.push(wait);
        arrival += interarrival;
    }
    let warmup = n_jobs / 10;
    let tail = &waits[warmup..];
    SimResult {
        mean_wait: tail.iter().sum::<f64>() / tail.len() as f64,
        max_wait: tail.iter().cloned().fold(0.0, f64::max),
        utilization: busy_time / server_free_at,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queueing::dm1::mean_waiting_time;

    #[test]
    fn simulation_matches_closed_form() {
        let mut rng = Rng::new(42);
        for (mu, lambda) in [(1.0, 0.5), (1.0, 0.8), (2.0, 1.5)] {
            let analytic = mean_waiting_time(mu, lambda);
            let sim = simulate(mu, lambda, 200_000, &mut rng);
            let rel = (sim.mean_wait - analytic).abs() / analytic;
            assert!(
                rel < 0.08,
                "μ={mu} λ={lambda}: sim={} analytic={analytic}",
                sim.mean_wait
            );
        }
    }

    #[test]
    fn utilization_close_to_rho() {
        let mut rng = Rng::new(7);
        let sim = simulate(1.0, 0.6, 100_000, &mut rng);
        assert!((sim.utilization - 0.6).abs() < 0.03, "{}", sim.utilization);
    }

    #[test]
    fn light_load_rarely_waits() {
        let mut rng = Rng::new(8);
        let sim = simulate(10.0, 0.5, 50_000, &mut rng);
        assert!(sim.mean_wait < 0.02, "{}", sim.mean_wait);
    }

    #[test]
    fn theorem2_rule_validated_by_simulation() {
        // capacity from Theorem 2 must empirically keep W under σ
        let mut rng = Rng::new(9);
        let (mu, sigma) = (1.0, 1.0);
        let c = crate::queueing::dm1::capacity_for_waiting_time(mu, sigma);
        let sim = simulate(mu, c, 300_000, &mut rng);
        assert!(
            sim.mean_wait < sigma * 1.08,
            "W={} exceeds σ={sigma}",
            sim.mean_wait
        );
    }
}
