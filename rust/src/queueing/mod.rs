//! Straggler / queueing substrate behind Theorem 2 (§IV-A1).
//!
//! Processing at a device is a D/M/1 queue: deterministic arrivals at rate
//! `G_i(t) ≤ C_i` and exponential service times (`exp(μ)` stragglers, the
//! standard model of [40]). [`dm1`] provides the closed-form waiting time
//! and the Theorem-2 capacity rule; [`straggler`] is a discrete-event
//! simulator used to validate both.

pub mod dm1;
pub mod straggler;

pub use dm1::{capacity_for_waiting_time, mean_waiting_time};
