//! Cost-trace generators: synthetic `U(0,1)` and testbed-like traces.
//!
//! The paper collects `c_i(t)` / `c_ij(t)` from a Raspberry-Pi testbed
//! (gradient-update processing times and Pi→DynamoDB upload times over WiFi
//! or LTE), linearly rescaled to [0, 1] (§V-A). That hardware is not
//! available here, so `testbed` generates traces with the statistical
//! structure the paper's analysis actually relies on:
//!
//! * per-device *speed factors* — a slow device is persistently slow,
//!   giving the cross-device heterogeneity that makes offloading pay off;
//! * **compute–communication correlation** — the paper observes that
//!   devices with faster computation also transmit faster, and credits this
//!   correlation for network-aware learning scoring *better* on testbed
//!   costs than synthetic ones (Table II discussion);
//! * medium-dependent tails — WiFi shows congestion spikes (heavier-tailed
//!   delays, §V-D) while LTE is better regulated.
//!
//! All traces are rescaled to [0, 1] exactly like the paper's.

use crate::costs::model::CostSchedule;
use crate::util::rng::Rng;
use crate::util::stats::rescale_unit;

/// Wireless medium for the testbed-like generator (§V-D, Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Medium {
    /// Cellular: moderate base delay, light tail.
    Lte,
    /// 2.4 GHz WiFi: heavier-tailed congestion (fewer interference
    /// mitigation techniques, §V-D) — larger effective transfer costs.
    Wifi,
}

impl Medium {
    /// Relative magnitude of (rescaled) transfer costs vs processing costs.
    /// On the paper's testbed, uploading a microbatch is considerably
    /// cheaper than computing a gradient update on a Pi — that ratio is
    /// what makes offloading worthwhile at all (Table III shows transfer
    /// cost ≈ ⅓ of processing cost while most data moves). WiFi's
    /// congestion makes its links dearer than LTE's (Fig. 8).
    fn link_scale(self) -> f64 {
        match self {
            Medium::Lte => 0.45,
            Medium::Wifi => 0.65,
        }
    }
}

/// Which cost model an experiment uses (§V-A "network cost and capacity
/// parameters": synthetic vs testbed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostSource {
    /// `c_i(t), c_ij(t) ~ U(0, 1)` i.i.d.
    Synthetic,
    /// Correlated testbed-like traces over the given medium.
    Testbed(Medium),
}

/// Error-weight profile:
///
/// ```text
/// f_i(t) = f0 · (1 − decay · t/T) · (1 − intra_decay · (t mod τ)/τ)
/// ```
///
/// The paper motivates a decreasing `f_i(t)` two ways (§III-C, §V-C3):
/// globally, loss matters less as the model converges over the horizon
/// (`decay`); and *within an aggregation period*, local models converge on
/// their local data, so the marginal value of another datapoint falls
/// until the next synchronization resets it (`intra_decay`). The second
/// term couples the aggregation period τ to the discard behaviour in
/// Fig. 7: longer periods drive `f` lower before each sync, making
/// discarding progressively cost-effective.
#[derive(Debug, Clone, Copy)]
pub struct ErrorWeightProfile {
    pub f0: f64,
    pub decay: f64,
    /// Within-aggregation-period decay (0 disables the τ coupling).
    pub intra_decay: f64,
    /// Multiplier applied to `f_i(t)` in the *optimizer's belief* when the
    /// discard model is the convex `f/√G` (Lemma 1's γ is a
    /// gradient-divergence scale, not a per-datapoint unit cost: with
    /// γ ≈ 2·c·G*^{3/2}, a target of G* ≈ the mean arrival count needs γ
    /// roughly 40× the unit-cost-scale f). The ledger always charges the
    /// unscaled `f`, keeping Table IV's cost columns comparable.
    pub sqrt_gamma_scale: f64,
}

impl Default for ErrorWeightProfile {
    fn default() -> Self {
        // Calibrated so that all three cost components are active in the
        // Table III reproduction: comparable magnitude to the mean of the
        // U(0,1)/testbed unit costs.
        ErrorWeightProfile { f0: 0.80, decay: 0.45, intra_decay: 0.55, sqrt_gamma_scale: 40.0 }
    }
}

/// Generate a schedule for `source` (capacities start unconstrained; apply
/// [`crate::costs::CapacityMode`] afterwards). `tau` is the aggregation
/// period driving the intra-period component of `f_i(t)`.
pub fn generate(
    source: CostSource,
    n: usize,
    t_max: usize,
    tau: usize,
    profile: ErrorWeightProfile,
    rng: &mut Rng,
) -> CostSchedule {
    let mut s = match source {
        CostSource::Synthetic => synthetic(n, t_max, rng),
        CostSource::Testbed(medium) => testbed(n, t_max, medium, rng),
    };
    let tau = tau.max(1);
    for t in 0..t_max {
        let global = 1.0 - profile.decay * t as f64 / t_max.max(1) as f64;
        let intra = 1.0 - profile.intra_decay * (t % tau) as f64 / tau as f64;
        let f_t = profile.f0 * global * intra;
        for i in 0..n {
            s.error_weight[t][i] = f_t;
        }
    }
    s
}

/// Synthetic traces: every `c_i(t)` and `c_ij(t)` i.i.d. `U(0, 1)`.
pub fn synthetic(n: usize, t_max: usize, rng: &mut Rng) -> CostSchedule {
    let mut s = CostSchedule::zeros(n, t_max);
    for t in 0..t_max {
        for i in 0..n {
            s.compute[t][i] = rng.f64();
            for j in 0..n {
                if i != j {
                    s.link[t][i * n + j] = rng.f64();
                }
            }
        }
    }
    s
}

/// Testbed-like traces (see module docs).
pub fn testbed(n: usize, t_max: usize, medium: Medium, rng: &mut Rng) -> CostSchedule {
    let mut s = CostSchedule::zeros(n, t_max);

    // Persistent device speed factors: processing time multiplier.
    let speed: Vec<f64> = (0..n).map(|_| rng.uniform(0.25, 1.0)).collect();

    // Raw (unscaled) processing times: speed * jitter.
    let mut raw_compute = vec![0.0; t_max * n];
    for t in 0..t_max {
        for i in 0..n {
            let jitter = (1.0 + 0.15 * rng.normal()).max(0.05);
            raw_compute[t * n + i] = speed[i] * jitter;
        }
    }

    // Raw transfer times: correlated with the endpoint speeds (fast devices
    // also transmit fast), scaled by the medium's congestion process.
    let (base, tail_sigma) = match medium {
        Medium::Lte => (0.55, 0.20),
        Medium::Wifi => (0.45, 0.65),
    };
    let mut raw_link = vec![0.0; t_max * n * n];
    for t in 0..t_max {
        // network-wide congestion level this interval (log-normal)
        let congestion = (tail_sigma * rng.normal()).exp();
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let endpoint = 0.5 * (speed[i] + speed[j]);
                let jitter = (1.0 + 0.1 * rng.normal()).max(0.05);
                raw_link[t * n * n + i * n + j] = base * endpoint * congestion * jitter;
            }
        }
    }

    // Processing times: linear rescale to [0, 1] exactly like the paper's
    // post-processing. Link times: normalize by the *mean* rather than the
    // max — a max-rescale would let WiFi's rare congestion spikes compress
    // its typical costs below LTE's, inverting the medium ordering the
    // paper measures (Fig. 8); mean-normalization keeps typical WiFi links
    // dearer than LTE while the heavy tail rides far above the mean.
    rescale_unit(&mut raw_compute);
    let link_mean = {
        let nz: Vec<f64> = raw_link.iter().copied().filter(|&v| v > 0.0).collect();
        crate::util::stats::mean(&nz).max(1e-12)
    };
    let target_mean = 0.5 * medium.link_scale();
    for v in raw_link.iter_mut() {
        *v *= target_mean / link_mean;
    }

    for t in 0..t_max {
        for i in 0..n {
            s.compute[t][i] = raw_compute[t * n + i];
            for j in 0..n {
                s.link[t][i * n + j] = raw_link[t * n * n + i * n + j];
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, pearson};

    #[test]
    fn synthetic_in_unit_interval() {
        let mut rng = Rng::new(1);
        let s = synthetic(5, 20, &mut rng);
        for t in 0..20 {
            for i in 0..5 {
                assert!((0.0..1.0).contains(&s.compute[t][i]));
                for j in 0..5 {
                    let c = s.link[t][i * 5 + j];
                    assert!((0.0..1.0).contains(&c));
                    if i == j {
                        assert_eq!(c, 0.0);
                    }
                }
            }
        }
        let all: Vec<f64> = s.compute.iter().flatten().copied().collect();
        assert!((mean(&all) - 0.5).abs() < 0.1);
    }

    #[test]
    fn testbed_compute_comm_correlated() {
        let mut rng = Rng::new(2);
        let n = 10;
        let s = testbed(n, 100, Medium::Lte, &mut rng);
        // per-device mean compute cost vs mean outgoing link cost
        let mut comp = vec![0.0; n];
        let mut comm = vec![0.0; n];
        for t in 0..100 {
            for i in 0..n {
                comp[i] += s.compute[t][i];
                let row: f64 = (0..n).filter(|&j| j != i).map(|j| s.link[t][i * n + j]).sum();
                comm[i] += row / (n - 1) as f64;
            }
        }
        let r = pearson(&comp, &comm);
        assert!(r > 0.5, "expected strong +corr, got {r}");
    }

    #[test]
    fn wifi_heavier_tail_than_lte() {
        let mut rng = Rng::new(3);
        let n = 8;
        let t_max = 200;
        let wifi = testbed(n, t_max, Medium::Wifi, &mut rng);
        let lte = testbed(n, t_max, Medium::Lte, &mut rng);
        let spread = |s: &CostSchedule| {
            let all: Vec<f64> = s.link.iter().flatten().copied().filter(|&x| x > 0.0).collect();
            crate::util::stats::quantile(&all, 0.95) / crate::util::stats::quantile(&all, 0.5).max(1e-9)
        };
        assert!(
            spread(&wifi) > spread(&lte),
            "wifi {} <= lte {}",
            spread(&wifi),
            spread(&lte)
        );
    }

    #[test]
    fn error_weight_decreases_over_time() {
        let mut rng = Rng::new(4);
        let s = generate(
            CostSource::Synthetic,
            4,
            50,
            10,
            ErrorWeightProfile::default(),
            &mut rng,
        );
        assert!(s.f(0, 0) > s.f(49, 0));
        assert!(s.f(49, 0) > 0.0);
    }

    #[test]
    fn error_weight_intra_period_sawtooth() {
        // f dips within each aggregation period and resets at each sync;
        // a longer τ reaches a deeper trough (the Fig-7 coupling)
        let mut rng = Rng::new(5);
        let profile = ErrorWeightProfile::default();
        let s10 = generate(CostSource::Synthetic, 2, 100, 10, profile, &mut Rng::new(5));
        let s50 = generate(CostSource::Synthetic, 2, 100, 50, profile, &mut rng);
        // within period: decreasing
        assert!(s10.f(0, 0) > s10.f(9, 0));
        // reset at sync boundary
        assert!(s10.f(10, 0) > s10.f(9, 0));
        // deeper trough for larger tau (compare trough/peak ratios)
        let ratio10 = s10.f(9, 0) / s10.f(0, 0);
        let ratio50 = s50.f(49, 0) / s50.f(0, 0);
        assert!(ratio50 < ratio10);
    }

    #[test]
    fn wifi_links_dearer_than_lte_on_average() {
        // Fig. 8 ordering: typical WiFi transfer cost above LTE's
        let wifi = testbed(8, 100, Medium::Wifi, &mut Rng::new(6));
        let lte = testbed(8, 100, Medium::Lte, &mut Rng::new(6));
        let avg = |s: &CostSchedule| {
            let nz: Vec<f64> = s.link.iter().flatten().copied().filter(|&x| x > 0.0).collect();
            mean(&nz)
        };
        assert!(avg(&wifi) > avg(&lte), "wifi {} <= lte {}", avg(&wifi), avg(&lte));
    }

    #[test]
    fn deterministic() {
        let a = testbed(6, 30, Medium::Wifi, &mut Rng::new(5));
        let b = testbed(6, 30, Medium::Wifi, &mut Rng::new(5));
        assert_eq!(a.compute, b.compute);
        assert_eq!(a.link, b.link);
    }
}
