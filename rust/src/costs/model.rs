//! The cost/capacity schedule container (§III-A, §III-C).
//!
//! All quantities are per-interval and per-device (or per-link):
//!
//! * `c_i(t)`   — unit processing cost at device i,
//! * `c_ij(t)`  — unit offloading cost on link (i, j),
//! * `f_i(t)`   — error-cost weight (the price of discarding / model loss),
//! * `C_i(t)`   — device compute capacity (datapoints per interval),
//! * `C_ij(t)`  — link capacity (datapoints per interval).
//!
//! The schedule is dense: n ≤ ~50 devices and T ≤ ~200 intervals in every
//! experiment, so `[t][i][j]` storage is at most a few MB and O(1) access
//! keeps the movement optimizer tight.
//!
//! The movement solvers address costs only through the [`MovementCosts`]
//! trait, so scaling runs (N = 10⁵ devices, where a dense `[t][i*n+j]` link
//! table would be 10¹⁰ entries) can plug in procedural O(n)-memory models
//! (see `bench_engine`'s geometric cost model) without touching solver
//! code.

/// Cost/capacity oracle consumed by the movement optimizer. Mirrors the
/// inherent accessors of [`CostSchedule`] (the canonical dense
/// implementation); every method must be pure in `(t, i, j)` so solver
/// passes can re-query freely. `Sync` because the row-parallel solver
/// layer (`util::par`, DESIGN.md §Perf rule 12) queries the oracle
/// from scoped worker threads concurrently.
pub trait MovementCosts: std::fmt::Debug + Sync {
    /// Processing cost `c_i(t)`.
    fn c_node(&self, t: usize, i: usize) -> f64;
    /// Link cost `c_ij(t)`.
    fn c_link(&self, t: usize, i: usize, j: usize) -> f64;
    /// Error weight `f_i(t)`.
    fn f(&self, t: usize, i: usize) -> f64;
    /// Node capacity `C_i(t)` (`f64::INFINITY` when unconstrained).
    fn cap_node_at(&self, t: usize, i: usize) -> f64;
    /// Link capacity `C_ij(t)` (`f64::INFINITY` when unconstrained).
    fn cap_link_at(&self, t: usize, i: usize, j: usize) -> f64;
}

impl MovementCosts for CostSchedule {
    fn c_node(&self, t: usize, i: usize) -> f64 {
        CostSchedule::c_node(self, t, i)
    }
    fn c_link(&self, t: usize, i: usize, j: usize) -> f64 {
        CostSchedule::c_link(self, t, i, j)
    }
    fn f(&self, t: usize, i: usize) -> f64 {
        CostSchedule::f(self, t, i)
    }
    fn cap_node_at(&self, t: usize, i: usize) -> f64 {
        CostSchedule::cap_node_at(self, t, i)
    }
    fn cap_link_at(&self, t: usize, i: usize, j: usize) -> f64 {
        CostSchedule::cap_link_at(self, t, i, j)
    }
}

/// Full cost/capacity schedule over `n` devices and `t_max` intervals.
#[derive(Debug, Clone)]
pub struct CostSchedule {
    pub n: usize,
    pub t_max: usize,
    /// `[t][i]` processing cost per datapoint.
    pub compute: Vec<Vec<f64>>,
    /// `[t][i * n + j]` link cost per datapoint.
    pub link: Vec<Vec<f64>>,
    /// `[t][i]` error-cost weight f_i(t).
    pub error_weight: Vec<Vec<f64>>,
    /// `[t][i]` node capacity (f64::INFINITY when unconstrained).
    pub cap_node: Vec<Vec<f64>>,
    /// `[t][i * n + j]` link capacity (f64::INFINITY when unconstrained).
    pub cap_link: Vec<Vec<f64>>,
}

/// Capacity regimes used by the experiments (§V-A: "when imposed, the
/// capacity constraints are taken as the average data generated per device
/// per time period").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CapacityMode {
    /// No capacity constraints (settings B, C of Table III).
    Unconstrained,
    /// `C_i(t) = C_ij(t) = mean` (settings D, E of Table III).
    Uniform(f64),
}

impl CostSchedule {
    /// All-zero costs, unconstrained capacities.
    pub fn zeros(n: usize, t_max: usize) -> Self {
        CostSchedule {
            n,
            t_max,
            compute: vec![vec![0.0; n]; t_max],
            link: vec![vec![0.0; n * n]; t_max],
            error_weight: vec![vec![0.0; n]; t_max],
            cap_node: vec![vec![f64::INFINITY; n]; t_max],
            cap_link: vec![vec![f64::INFINITY; n * n]; t_max],
        }
    }

    /// Clamp t into the valid range (the optimizer looks ahead to `t+1`,
    /// which at the horizon falls back to the last interval).
    #[inline]
    fn ct(&self, t: usize) -> usize {
        t.min(self.t_max - 1)
    }

    /// Processing cost `c_i(t)`.
    #[inline]
    pub fn c_node(&self, t: usize, i: usize) -> f64 {
        self.compute[self.ct(t)][i]
    }

    /// Link cost `c_ij(t)`.
    #[inline]
    pub fn c_link(&self, t: usize, i: usize, j: usize) -> f64 {
        self.link[self.ct(t)][i * self.n + j]
    }

    /// Error weight `f_i(t)`.
    #[inline]
    pub fn f(&self, t: usize, i: usize) -> f64 {
        self.error_weight[self.ct(t)][i]
    }

    /// Node capacity `C_i(t)`.
    #[inline]
    pub fn cap_node_at(&self, t: usize, i: usize) -> f64 {
        self.cap_node[self.ct(t)][i]
    }

    /// Link capacity `C_ij(t)`.
    #[inline]
    pub fn cap_link_at(&self, t: usize, i: usize, j: usize) -> f64 {
        self.cap_link[self.ct(t)][i * self.n + j]
    }

    /// Apply a capacity mode uniformly over all intervals.
    pub fn set_capacities(&mut self, mode: CapacityMode) {
        let (node_cap, link_cap) = match mode {
            CapacityMode::Unconstrained => (f64::INFINITY, f64::INFINITY),
            CapacityMode::Uniform(c) => (c, c),
        };
        for t in 0..self.t_max {
            for v in self.cap_node[t].iter_mut() {
                *v = node_cap;
            }
            for v in self.cap_link[t].iter_mut() {
                *v = link_cap;
            }
        }
    }

    /// Time-averaged processing cost per device (used e.g. to rank devices
    /// when building the hierarchical topology).
    pub fn mean_compute_per_device(&self) -> Vec<f64> {
        let mut acc = vec![0.0; self.n];
        for t in 0..self.t_max {
            for i in 0..self.n {
                acc[i] += self.compute[t][i];
            }
        }
        for a in acc.iter_mut() {
            *a /= self.t_max as f64;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_accessors() {
        let s = CostSchedule::zeros(3, 5);
        assert_eq!(s.c_node(0, 1), 0.0);
        assert_eq!(s.c_link(4, 1, 2), 0.0);
        assert!(s.cap_node_at(0, 0).is_infinite());
    }

    #[test]
    fn t_clamped_at_horizon() {
        let mut s = CostSchedule::zeros(2, 3);
        s.compute[2][1] = 7.0;
        // t = 5 beyond horizon -> clamps to last interval
        assert_eq!(s.c_node(5, 1), 7.0);
    }

    #[test]
    fn set_capacities_uniform() {
        let mut s = CostSchedule::zeros(2, 2);
        s.set_capacities(CapacityMode::Uniform(8.0));
        assert_eq!(s.cap_node_at(1, 1), 8.0);
        assert_eq!(s.cap_link_at(0, 0, 1), 8.0);
        s.set_capacities(CapacityMode::Unconstrained);
        assert!(s.cap_link_at(0, 0, 1).is_infinite());
    }

    #[test]
    fn mean_compute() {
        let mut s = CostSchedule::zeros(2, 2);
        s.compute[0][0] = 1.0;
        s.compute[1][0] = 3.0;
        assert_eq!(s.mean_compute_per_device(), vec![2.0, 0.0]);
    }
}
