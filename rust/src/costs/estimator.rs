//! Imperfect-information cost estimation (§IV-A, §V-A).
//!
//! In practice the optimizer cannot see future costs/capacities. The paper's
//! scheme: divide the horizon `T` into `L` windows `T_1..T_L`; within window
//! `l`, use the *time-averaged observations from window `l-1`* for every
//! quantity. The first window has no history, so it uses its own averages
//! (bootstrapping — equivalent to a short calibration period before
//! deployment). Settings C and E of Table III run the optimizer on this
//! estimated schedule while the ledger charges *actual* costs.

use crate::costs::model::CostSchedule;

/// Build the estimated schedule seen by the optimizer under imperfect
/// information with `windows` estimation intervals.
pub fn estimate(actual: &CostSchedule, windows: usize) -> CostSchedule {
    let t_max = actual.t_max;
    let n = actual.n;
    let windows = windows.clamp(1, t_max);
    let mut est = CostSchedule::zeros(n, t_max);

    // window boundaries: near-equal partition of 0..t_max
    let bounds: Vec<(usize, usize)> = (0..windows)
        .map(|l| {
            let a = l * t_max / windows;
            let b = ((l + 1) * t_max / windows).max(a + 1);
            (a, b.min(t_max))
        })
        .collect();

    for (l, &(a, b)) in bounds.iter().enumerate() {
        // source window: previous one, or self for the first
        let (sa, sb) = if l == 0 { bounds[0] } else { bounds[l - 1] };
        let span = (sb - sa) as f64;

        // time-averaged values over the source window
        let mut avg_compute = vec![0.0; n];
        let mut avg_link = vec![0.0; n * n];
        let mut avg_f = vec![0.0; n];
        let mut avg_cap_node = vec![0.0; n];
        let mut avg_cap_link = vec![0.0; n * n];
        for t in sa..sb {
            for i in 0..n {
                avg_compute[i] += actual.compute[t][i] / span;
                avg_f[i] += actual.error_weight[t][i] / span;
                avg_cap_node[i] += cap_term(actual.cap_node[t][i], span);
            }
            for e in 0..n * n {
                avg_link[e] += actual.link[t][e] / span;
                avg_cap_link[e] += cap_term(actual.cap_link[t][e], span);
            }
        }

        for t in a..b {
            est.compute[t].copy_from_slice(&avg_compute);
            est.link[t].copy_from_slice(&avg_link);
            est.error_weight[t].copy_from_slice(&avg_f);
            for i in 0..n {
                est.cap_node[t][i] = restore_cap(avg_cap_node[i]);
            }
            for e in 0..n * n {
                est.cap_link[t][e] = restore_cap(avg_cap_link[e]);
            }
        }
    }
    est
}

// Capacities may be infinite; average finite values, keep infinity as a
// sentinel that survives averaging (inf + x = inf).
fn cap_term(cap: f64, span: f64) -> f64 {
    if cap.is_infinite() {
        f64::INFINITY
    } else {
        cap / span
    }
}

fn restore_cap(avg: f64) -> f64 {
    avg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::traces::{synthetic, Medium};
    use crate::util::rng::Rng;
    use crate::util::stats::mean;

    #[test]
    fn estimate_is_piecewise_constant() {
        let mut rng = Rng::new(1);
        let actual = synthetic(4, 20, &mut rng);
        let est = estimate(&actual, 4); // windows of 5
        // within a window all values equal
        for w in 0..4 {
            for t in (w * 5)..(w * 5 + 5) {
                assert_eq!(est.compute[t], est.compute[w * 5]);
            }
        }
    }

    #[test]
    fn windows_lag_by_one() {
        let mut rng = Rng::new(2);
        let mut actual = synthetic(2, 10, &mut rng);
        // paint window 0 (t=0..5) with compute 1.0, window 1 with 3.0
        for t in 0..5 {
            actual.compute[t] = vec![1.0, 1.0];
        }
        for t in 5..10 {
            actual.compute[t] = vec![3.0, 3.0];
        }
        let est = estimate(&actual, 2);
        // window 0 bootstraps from itself, window 1 uses window 0's average
        assert_eq!(est.compute[0][0], 1.0);
        assert_eq!(est.compute[7][0], 1.0);
    }

    #[test]
    fn estimation_error_is_bounded_for_stationary_traces() {
        let mut rng = Rng::new(3);
        let actual = crate::costs::traces::testbed(6, 100, Medium::Lte, &mut rng);
        let est = estimate(&actual, 10);
        // mean absolute deviation should be well under the trace spread
        let mut devs = Vec::new();
        for t in 0..100 {
            for i in 0..6 {
                devs.push((est.compute[t][i] - actual.compute[t][i]).abs());
            }
        }
        assert!(mean(&devs) < 0.25, "MAD={}", mean(&devs));
    }

    #[test]
    fn prop_estimates_bounded_by_source_window() {
        // every estimated value must lie within [min, max] of the window it
        // was averaged from — the estimator can never extrapolate
        crate::prop::for_all("estimator_bounds", 40, |g| {
            let n = g.usize_in(1, 6);
            let t_max = g.usize_in(2, 40);
            let windows = g.usize_in(1, t_max);
            let actual = synthetic(n, t_max, g.rng());
            let est = estimate(&actual, windows);
            let bounds: Vec<(usize, usize)> = (0..windows.clamp(1, t_max))
                .map(|l| {
                    let a = l * t_max / windows.clamp(1, t_max);
                    let b = ((l + 1) * t_max / windows.clamp(1, t_max)).max(a + 1);
                    (a, b.min(t_max))
                })
                .collect();
            for (l, &(a, b)) in bounds.iter().enumerate() {
                let (sa, sb) = if l == 0 { bounds[0] } else { bounds[l - 1] };
                for i in 0..n {
                    let mut lo = f64::INFINITY;
                    let mut hi = f64::NEG_INFINITY;
                    for t in sa..sb {
                        lo = lo.min(actual.compute[t][i]);
                        hi = hi.max(actual.compute[t][i]);
                    }
                    for t in a..b {
                        assert!(
                            est.compute[t][i] >= lo - 1e-9 && est.compute[t][i] <= hi + 1e-9,
                            "estimate escaped window bounds"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn infinite_capacities_survive() {
        let mut rng = Rng::new(4);
        let actual = synthetic(3, 12, &mut rng); // caps = inf by default
        let est = estimate(&actual, 3);
        assert!(est.cap_node_at(7, 1).is_infinite());
        assert!(est.cap_link_at(2, 0, 1).is_infinite());
    }
}
