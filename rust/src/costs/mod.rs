//! Cost and capacity substrate: the `c_i(t)`, `c_ij(t)`, `f_i(t)`,
//! `C_i(t)`, `C_ij(t)` schedules of §III, their generators (synthetic and
//! testbed-like, LTE/WiFi), and the imperfect-information estimator of
//! §IV-A / §V-A.

pub mod estimator;
pub mod model;
pub mod traces;

pub use model::{CapacityMode, CostSchedule, MovementCosts};
pub use traces::{CostSource, Medium};
