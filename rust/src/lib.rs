//! # fogml — Network-Aware Optimization of Distributed Learning for Fog Computing
//!
//! A three-layer Rust + JAX + Pallas reproduction of Wang et al.,
//! *Network-Aware Optimization of Distributed Learning for Fog Computing*
//! (IEEE INFOCOM 2020 / journal extension).
//!
//! The crate is the Layer-3 coordinator: it owns the fog network model
//! (topology, costs, capacities, churn), solves the paper's data-movement
//! optimization (eqs. 5–9) every time interval, schedules local gradient
//! updates through AOT-compiled XLA executables (Layer 2 JAX models built on
//! Layer 1 Pallas kernels), and performs weighted federated aggregation
//! (eq. 4). Python never runs at training time — `make artifacts` lowers the
//! models to HLO text once, and [`runtime`] loads them via PJRT.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — deterministic RNG, statistics, JSON, little-endian
//!   binary I/O ([`util::binio`]), console tables.
//! * [`data`] — SynthDigits dataset + iid/non-iid device partitioning.
//! * [`topology`] — fog graphs (full/ER/Watts–Strogatz/hierarchical/
//!   scale-free/random-geometric), churn deltas ([`topology::ChurnProcess`]),
//!   and the incrementally-maintained active mask ([`topology::ActiveView`]).
//! * [`costs`] — cost/capacity schedules: synthetic, testbed-like, LTE/WiFi;
//!   imperfect-information estimation.
//! * [`queueing`] — D/M/1 straggler model behind Theorem 2.
//! * [`movement`] — the paper's core contribution: the data-movement
//!   optimization and its solvers (Theorem-3 greedy, convex PGD), each with
//!   a bit-identical edge-indexed sparse mirror ([`movement::SparsePlan`],
//!   O(E) memory for million-device topologies, `--movement-backend`),
//!   plus the closed-form theory of Theorems 4–6.
//! * [`runtime`] — PJRT loading/execution of the AOT artifacts.
//! * [`fed`] — federated engine: the session state machine
//!   ([`fed::session`]) over pluggable compute backends, local updates,
//!   evaluation planning ([`fed::eval`]), weighted aggregation, ledger.
//! * [`coordinator`] — thread-based runtime service with a coalescing
//!   request scheduler ([`coordinator::service::ServiceConfig`]:
//!   `--services K` packs concurrent sessions' batched train/eval
//!   requests into shared largest-tile dispatches, partner-invariantly),
//!   the [`coordinator::pool::SimPool`] (config, seed) fan-out,
//!   cross-process sweep sharding ([`coordinator::shard`]: `--shard I/N`
//!   + `fogml merge` reassemble a grid bit-identically across machines,
//!   with shard files in JSON or the compact `.fsb` binary codec
//!   [`coordinator::binfmt`]), and the leader/worker cluster actors.
//! * [`experiments`] — drivers that regenerate every table and figure
//!   (sweeps fan out through the pool via `--jobs N`, and across
//!   processes via `--shard`; see EXPERIMENTS.md for the command ↔
//!   artifact map).

// The solver/topology kernels are explicit index loops over parallel
// arrays (plans, gradients, CSR slices) — the clearest rendering of the
// paper's math, and the form the dense≡sparse identity arguments reason
// about (DESIGN.md §Perf rule 11). Clippy's iterator rewrites obscure the
// cross-array index relationships, so that one style lint is off
// crate-wide; all correctness lints stay on (CI runs
// `clippy --all-targets -- -D warnings` as a hard gate).
#![allow(clippy::needless_range_loop)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod costs;
pub mod data;
pub mod experiments;
pub mod fed;
pub mod movement;
pub mod prop;
pub mod queueing;
pub mod runtime;
pub mod topology;
pub mod util;
