//! Console table printer used by the experiment drivers to reproduce the
//! paper's tables as aligned text (and as CSV under `results/`).

/// A simple column-aligned table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with per-column alignment (left for first column, right for
    /// the rest — matches how the paper prints label + numeric columns).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                if i == 0 {
                    line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
                } else {
                    line.push_str(&format!("{:>width$}", cells[i], width = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// CSV form for results/ output.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with fixed decimals (helper for table rows).
pub fn fnum(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Format a percentage with two decimals, e.g. `92.31%`.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["setting", "acc", "cost"]);
        t.row(vec!["A".into(), pct(0.8972), fnum(1234.0, 0)]);
        t.row(vec!["Blong".into(), pct(0.8981), fnum(578.0, 0)]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("89.72%"));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        // all data lines equal width
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }
}
