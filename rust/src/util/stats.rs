//! Descriptive statistics and small numeric helpers shared by the cost
//! ledger, the experiment drivers, and the theory module.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance; 0.0 for fewer than two samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated quantile, q in [0, 1]. Panics on empty input.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Rescale values linearly onto [0, 1] (the paper's normalization of the
/// testbed processing/communication times, §V-A). A constant slice maps to
/// all zeros.
pub fn rescale_unit(xs: &mut [f64]) {
    if xs.is_empty() {
        return;
    }
    let lo = min(xs);
    let hi = max(xs);
    let span = hi - lo;
    for x in xs.iter_mut() {
        *x = if span > 0.0 { (*x - lo) / span } else { 0.0 };
    }
}

/// Pearson correlation coefficient; 0.0 when either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        cov += (xs[i] - mx) * (ys[i] - my);
        vx += (xs[i] - mx) * (xs[i] - mx);
        vy += (ys[i] - my) * (ys[i] - my);
    }
    if vx == 0.0 || vy == 0.0 {
        0.0
    } else {
        cov / (vx * vy).sqrt()
    }
}

/// Binomial coefficient C(n, k) as f64 (exact for the small n used by the
/// Theorem-5 formula; multiplicative form avoids factorial overflow).
pub fn binomial(n: u64, k: u64) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1.0f64;
    for i in 0..k {
        acc *= (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

/// Welford online mean/variance accumulator (used by the bench harness and
/// long-running ledgers where storing every sample is wasteful).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn rescale_maps_to_unit() {
        let mut xs = [10.0, 20.0, 15.0];
        rescale_unit(&mut xs);
        assert_eq!(xs, [0.0, 1.0, 0.5]);
        let mut c = [3.0, 3.0];
        rescale_unit(&mut c);
        assert_eq!(c, [0.0, 0.0]);
    }

    #[test]
    fn pearson_signs() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(5, 2), 10.0);
        assert_eq!(binomial(10, 0), 1.0);
        assert_eq!(binomial(10, 10), 1.0);
        assert_eq!(binomial(3, 5), 0.0);
        assert!((binomial(20, 10) - 184_756.0).abs() < 1e-6);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 4.0, 2.0, 8.0, 5.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-12);
    }
}
