//! Little-endian binary I/O primitives: a streaming low-allocation writer
//! and a forward-only zero-copy reader.
//!
//! These are the building blocks of the binary shard format
//! ([`crate::coordinator::binfmt`]). The design mirrors the
//! `Utf8JsonReader`/`Utf8JsonWriter` split of forward-only tokenizers:
//!
//! * [`ByteWriter`] wraps any [`std::io::Write`] sink (a `BufWriter<File>`
//!   for streaming file output, a `&mut Vec<u8>` for in-memory scratch)
//!   and appends fixed-width little-endian scalars and length-prefixed
//!   byte runs. No intermediate tree, no `Display` formatting — an `f64`
//!   is eight bytes of its raw bit pattern, so NaN payloads, ±inf, `-0.0`
//!   and subnormals round-trip exactly by construction.
//! * [`ByteReader`] walks a borrowed `&[u8]` buffer front to back. Every
//!   `get_*` advances a cursor; byte runs and strings are returned as
//!   slices **borrowing the input buffer** (zero-copy — the caller copies
//!   only into its final owned structures). Errors carry the byte offset,
//!   like [`crate::util::json::JsonError`] does for text.
//!
//! All multi-byte values are little-endian, matching the wire contract in
//! DESIGN.md §Perf rule 9.

use std::fmt;
use std::io::{self, Write};

/// Streaming little-endian writer over any [`Write`] sink.
pub struct ByteWriter<W: Write> {
    w: W,
    written: u64,
}

impl<W: Write> ByteWriter<W> {
    pub fn new(w: W) -> Self {
        ByteWriter { w, written: 0 }
    }

    /// Total bytes appended so far.
    pub fn written(&self) -> u64 {
        self.written
    }

    /// Finish writing: flush and hand the sink back.
    pub fn into_inner(mut self) -> io::Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }

    pub fn put_bytes(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.w.write_all(bytes)?;
        self.written += bytes.len() as u64;
        Ok(())
    }

    pub fn put_u8(&mut self, x: u8) -> io::Result<()> {
        self.put_bytes(&[x])
    }

    pub fn put_u32(&mut self, x: u32) -> io::Result<()> {
        self.put_bytes(&x.to_le_bytes())
    }

    pub fn put_u64(&mut self, x: u64) -> io::Result<()> {
        self.put_bytes(&x.to_le_bytes())
    }

    /// Raw bit pattern of `x` — the exact value comes back from
    /// [`ByteReader::get_f64`], NaN payload bits included.
    pub fn put_f64(&mut self, x: f64) -> io::Result<()> {
        self.put_u64(x.to_bits())
    }

    /// Length-prefixed byte run: `u32` length then the bytes.
    pub fn put_bytes_lp(&mut self, bytes: &[u8]) -> io::Result<()> {
        let len = u32::try_from(bytes.len()).map_err(|_| {
            io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("byte run of {} bytes exceeds the u32 length prefix", bytes.len()),
            )
        })?;
        self.put_u32(len)?;
        self.put_bytes(bytes)
    }

    /// Length-prefixed UTF-8 string (`u32` byte length then the bytes).
    pub fn put_str_lp(&mut self, s: &str) -> io::Result<()> {
        self.put_bytes_lp(s.as_bytes())
    }
}

/// Error from [`ByteReader`]: what went wrong and at which byte offset
/// (relative to the buffer the reader was created over).
#[derive(Debug, Clone)]
pub struct BinError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for BinError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "binary format error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for BinError {}

/// Forward-only zero-copy reader over a borrowed byte buffer.
#[derive(Debug, Clone)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    /// Offset of `buf[0]` in the original file — keeps error positions
    /// meaningful inside [`ByteReader::sub_reader`] slices.
    base: usize,
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, base: 0, pos: 0 }
    }

    /// Absolute byte offset of the cursor (within the original buffer).
    pub fn pos(&self) -> usize {
        self.base + self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn err(&self, msg: impl Into<String>) -> BinError {
        BinError { pos: self.pos(), msg: msg.into() }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], BinError> {
        if self.remaining() < n {
            return Err(self.err(format!(
                "truncated input: {what} wants {n} bytes, {} remain",
                self.remaining()
            )));
        }
        let slice = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(slice)
    }

    pub fn get_u8(&mut self, what: &str) -> Result<u8, BinError> {
        Ok(self.take(1, what)?[0])
    }

    pub fn get_u32(&mut self, what: &str) -> Result<u32, BinError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self, what: &str) -> Result<u64, BinError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Inverse of [`ByteWriter::put_f64`]: the exact bit pattern.
    pub fn get_f64(&mut self, what: &str) -> Result<f64, BinError> {
        Ok(f64::from_bits(self.get_u64(what)?))
    }

    /// Borrow `n` raw bytes out of the buffer (no copy).
    pub fn get_bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], BinError> {
        self.take(n, what)
    }

    /// Length-prefixed byte run (inverse of [`ByteWriter::put_bytes_lp`]).
    pub fn get_bytes_lp(&mut self, what: &str) -> Result<&'a [u8], BinError> {
        let len = self.get_u32(what)? as usize;
        self.take(len, what)
    }

    /// Length-prefixed UTF-8 string, borrowed from the buffer.
    pub fn get_str_lp(&mut self, what: &str) -> Result<&'a str, BinError> {
        let start = self.pos();
        let bytes = self.get_bytes_lp(what)?;
        std::str::from_utf8(bytes)
            .map_err(|e| BinError { pos: start, msg: format!("{what}: invalid utf-8: {e}") })
    }

    /// Consume `expected` verbatim or error (magic / sentinel checks).
    pub fn expect(&mut self, expected: &[u8], what: &str) -> Result<(), BinError> {
        let start = self.pos();
        let got = self.take(expected.len(), what)?;
        if got != expected {
            return Err(BinError {
                pos: start,
                msg: format!("{what}: expected {expected:02x?}, found {got:02x?}"),
            });
        }
        Ok(())
    }

    /// Split off a forward-only reader over the next `n` bytes (a
    /// length-prefixed record body). The parent cursor skips past them, so
    /// a malformed record cannot desynchronize its successors.
    pub fn sub_reader(&mut self, n: usize, what: &str) -> Result<ByteReader<'a>, BinError> {
        let base = self.pos();
        let slice = self.take(n, what)?;
        Ok(ByteReader { buf: slice, base, pos: 0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        let mut buf = Vec::new();
        let mut w = ByteWriter::new(&mut buf);
        w.put_u8(0xAB).unwrap();
        w.put_u32(0xDEAD_BEEF).unwrap();
        w.put_u64(u64::MAX - 1).unwrap();
        w.put_f64(-0.0).unwrap();
        w.put_str_lp("τ=10").unwrap();
        assert_eq!(w.written(), 1 + 4 + 8 + 8 + 4 + "τ=10".len() as u64);

        let mut r = ByteReader::new(&buf);
        assert_eq!(r.get_u8("a").unwrap(), 0xAB);
        assert_eq!(r.get_u32("b").unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64("c").unwrap(), u64::MAX - 1);
        let z = r.get_f64("d").unwrap();
        assert_eq!(z.to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.get_str_lp("e").unwrap(), "τ=10");
        assert!(r.is_empty());
    }

    #[test]
    fn f64_bit_patterns_survive() {
        // tagged-string JSON flattens these; the binary path must not
        let torture = [
            f64::from_bits(0x7FF8_DEAD_BEEF_CAFE), // NaN with payload
            f64::from_bits(0xFFF0_0000_0000_0001), // signaling-ish NaN
            f64::INFINITY,
            f64::NEG_INFINITY,
            -0.0,
            5e-324, // smallest subnormal
            f64::MIN_POSITIVE,
            0.1 + 0.2,
        ];
        let mut buf = Vec::new();
        let mut w = ByteWriter::new(&mut buf);
        for &x in &torture {
            w.put_f64(x).unwrap();
        }
        let mut r = ByteReader::new(&buf);
        for &x in &torture {
            assert_eq!(r.get_f64("x").unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn truncation_errors_carry_position() {
        let mut buf = Vec::new();
        let mut w = ByteWriter::new(&mut buf);
        w.put_u64(7).unwrap();
        let mut r = ByteReader::new(&buf[..6]);
        let e = r.get_u64("value").unwrap_err();
        assert_eq!(e.pos, 0);
        assert!(e.msg.contains("truncated"), "{e}");
    }

    #[test]
    fn expect_rejects_wrong_magic() {
        let mut r = ByteReader::new(b"NOPE");
        let e = r.expect(b"FGML", "magic").unwrap_err();
        assert!(e.msg.contains("magic"), "{e}");
    }

    #[test]
    fn sub_reader_bounds_and_positions() {
        let mut buf = Vec::new();
        let mut w = ByteWriter::new(&mut buf);
        w.put_u32(2).unwrap(); // record length
        w.put_u8(1).unwrap();
        w.put_u8(2).unwrap();
        w.put_u8(99).unwrap(); // next record's data

        let mut r = ByteReader::new(&buf);
        let n = r.get_u32("len").unwrap() as usize;
        let mut sub = r.sub_reader(n, "record").unwrap();
        assert_eq!(sub.get_u8("a").unwrap(), 1);
        assert_eq!(sub.get_u8("b").unwrap(), 2);
        assert!(sub.is_empty());
        // reading past the sub-slice fails even though the parent has more
        let e = sub.get_u8("c").unwrap_err();
        assert_eq!(e.pos, 6);
        // the parent cursor advanced past the record
        assert_eq!(r.get_u8("next").unwrap(), 99);
    }

    #[test]
    fn oversized_length_prefix_is_rejected_not_wrapped() {
        // 4 GiB string length cannot be represented: writer must error
        // (the reader side is covered by truncation: a huge prefix with a
        // short buffer errors out instead of panicking)
        let mut r = ByteReader::new(&[0xFF, 0xFF, 0xFF, 0xFF, b'x']);
        let e = r.get_bytes_lp("blob").unwrap_err();
        assert!(e.msg.contains("truncated"), "{e}");
    }
}
