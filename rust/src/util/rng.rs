//! Deterministic pseudo-random number generation (SplitMix64 core).
//!
//! Every stochastic element of the reproduction — dataset synthesis, device
//! arrivals, topology wiring, cost traces, churn — draws from this generator
//! so that a single `seed` in the experiment config reproduces a run
//! bit-for-bit. SplitMix64 passes BigCrush, is trivially seedable, and its
//! `split` operation gives independent child streams so subsystems cannot
//! perturb each other's sequences when call orders change.

/// SplitMix64 PRNG with Box–Muller caching for normal deviates.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams for practical purposes.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare_normal: None }
    }

    /// Derive an independent child stream (e.g. one per device).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xDEAD_BEEF_CAFE_F00D)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [a, b).
    pub fn uniform(&mut self, a: f64, b: f64) -> f64 {
        a + (b - a) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    /// Lemire multiply-shift with rejection of the biased tail.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let threshold = n.wrapping_neg() % n;
        loop {
            let wide = (self.next_u64() as u128) * (n as u128);
            if wide as u64 >= threshold {
                return (wide >> 64) as usize;
            }
        }
    }

    /// Bernoulli draw with probability p.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caches the spare deviate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Poisson-distributed count (Knuth's method for small lambda, normal
    /// approximation above 30 — our arrival means are single digits).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let x = self.normal_with(lambda, lambda.sqrt());
            return x.max(0.0).round() as usize;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices drawn from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher–Yates over an index vector
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Uniformly pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_under_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0);
    }

    #[test]
    fn f64_in_unit_interval_and_uniformish() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / 70_000.0;
            assert!((frac - 1.0 / 7.0).abs() < 0.01, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 3.0, 8.0, 40.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lambda)).sum::<usize>() as f64 / n as f64;
            assert!((mean - lambda).abs() < 0.15 * lambda.max(1.0), "lambda={lambda} mean={mean}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        for _ in 0..100 {
            let ks = r.sample_indices(20, 8);
            let mut s = ks.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
            assert!(ks.iter().all(|&k| k < 20));
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
