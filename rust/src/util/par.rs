//! Fixed-chunk deterministic parallel execution layer (DESIGN.md §Perf
//! rules 12 and 14).
//!
//! Born in the movement solvers (`movement::par`, which re-exports this
//! module for compatibility) and promoted crate-wide once the federated
//! data plane grew the same shape of work: every parallel pass partitions
//! its items (device rows, aggregation contributors, tensor elements)
//! into chunks of a **fixed** size. The geometry is a function of the
//! problem size only — **never** of the thread count — and every
//! cross-item reduction (objective terms, G̃/inbound gathers, eq. (4)
//! partial accumulators) is folded into a per-chunk partial and combined
//! serially in ascending chunk order. Workers may execute chunks in any
//! order on any thread; the combine step fixes the float-addition
//! association, so `threads = 1` and `threads = K` produce
//! **bit-identical** results for every K.
//!
//! Below one chunk's worth of items there is exactly one chunk, whose
//! internal term order is exactly the historical serial sweep —
//! paper-scale solves and aggregations (n ≤ 50) and every recorded
//! experiment number replay bitwise.
//!
//! Consumers: the row-parallel movement solvers
//! ([`crate::movement::greedy`], [`crate::movement::convex`],
//! [`crate::movement::repair`], [`crate::movement::sparse`]) and the
//! chunk-parallel federated averaging in [`crate::fed::aggregator`].

use std::ops::Range;

/// Rows per chunk. Matches
/// [`crate::config::MovementBackend::AUTO_THRESHOLD`]: every dense
/// paper-scale problem is a single chunk (historical bits), and by the
/// time a problem spans several chunks it is already on the sparse O(E)
/// backend where per-chunk work amortizes thread handoff.
pub const CHUNK_ROWS: usize = 512;

/// Number of row chunks for `n` rows under `chunk_rows`-row geometry.
pub fn num_chunks(n: usize, chunk_rows: usize) -> usize {
    n.div_ceil(chunk_rows.max(1))
}

/// Row range of chunk `c` (ascending, the combine order).
pub fn chunk_range(c: usize, n: usize, chunk_rows: usize) -> Range<usize> {
    let chunk_rows = chunk_rows.max(1);
    let start = c * chunk_rows;
    start..(start + chunk_rows).min(n)
}

/// Per-chunk scratch for the row-wise simplex projection (the gather /
/// sort / scatter buffers formerly shared serially on the workspace).
/// Contents are fully overwritten per row, so which chunk owns which
/// buffer never affects bits.
#[derive(Debug, Default)]
pub struct ProjBuffers {
    pub(crate) coords: Vec<(Option<usize>, f64)>,
    pub(crate) values: Vec<f64>,
    pub(crate) projected: Vec<f64>,
    pub(crate) scratch: Vec<f64>,
}

/// Run `f(chunk_index, item)` once per item, fanning contiguous blocks of
/// items across at most `threads` scoped workers. With one worker (or one
/// item) everything runs inline on the calling thread in ascending order.
///
/// Determinism contract: `f` must confine its writes to its own item (and
/// the disjoint buffers it holds) and fold cross-row sums into per-item
/// partials — the *caller* combines partials in ascending item order, so
/// scheduling can never reorder float additions.
pub(crate) fn run_chunks<T, F>(threads: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let workers = threads.max(1).min(items.len());
    if workers <= 1 {
        for (c, item) in items.iter_mut().enumerate() {
            f(c, item);
        }
        return;
    }
    let block = items.len().div_ceil(workers);
    std::thread::scope(|scope| {
        for (b, chunk_block) in items.chunks_mut(block).enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (k, item) in chunk_block.iter_mut().enumerate() {
                    f(b * block + k, item);
                }
            });
        }
    });
}

/// Combine per-chunk partial sums serially in ascending chunk order:
/// `((p₀ + p₁) + p₂) + …` — the one association every thread count
/// reproduces. A single chunk returns its partial untouched, so the
/// historical single-accumulator sweep replays exactly.
pub(crate) fn combine(partials: &[f64]) -> f64 {
    let mut it = partials.iter().copied();
    match it.next() {
        None => 0.0,
        Some(first) => it.fold(first, |acc, p| acc + p),
    }
}

/// Split a row-major buffer (`per_row` values per row) into per-chunk
/// mutable row blocks, ascending.
pub(crate) fn split_rows(
    buf: &mut [f64],
    per_row: usize,
    chunk_rows: usize,
) -> impl Iterator<Item = &mut [f64]> {
    buf.chunks_mut((chunk_rows.max(1) * per_row).max(1))
}

/// Split a CSR value buffer into per-chunk mutable blocks at the chunk
/// row boundaries given by `offsets` (length n + 1), ascending.
pub(crate) fn split_csr<'a>(
    values: &'a mut [f64],
    offsets: &[usize],
    n: usize,
    chunk_rows: usize,
) -> Vec<&'a mut [f64]> {
    let nc = num_chunks(n, chunk_rows);
    let mut out = Vec::with_capacity(nc);
    let mut rest = values;
    let mut consumed = 0usize;
    for c in 0..nc {
        let rows = chunk_range(c, n, chunk_rows);
        let end = offsets[rows.end];
        let (head, tail) = rest.split_at_mut(end - consumed);
        out.push(head);
        consumed = end;
        rest = tail;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_is_a_function_of_n_only() {
        assert_eq!(num_chunks(0, CHUNK_ROWS), 0);
        assert_eq!(num_chunks(1, CHUNK_ROWS), 1);
        assert_eq!(num_chunks(CHUNK_ROWS, CHUNK_ROWS), 1);
        assert_eq!(num_chunks(CHUNK_ROWS + 1, CHUNK_ROWS), 2);
        assert_eq!(chunk_range(0, 10, CHUNK_ROWS), 0..10);
        assert_eq!(chunk_range(1, 1000, 512), 512..1000);
        // paper scale is always a single chunk: the historical serial
        // term order replays bitwise at every default-config size
        assert_eq!(num_chunks(50, CHUNK_ROWS), 1);
    }

    #[test]
    fn run_chunks_is_thread_count_invariant() {
        // per-item partials + ascending combine: identical for any K
        let base: Vec<f64> = (0..37).map(|i| 0.1 * i as f64).collect();
        let mut reference: Vec<f64> = base.clone();
        run_chunks(1, &mut reference, |c, v| *v += c as f64);
        for threads in [2, 3, 8, 64] {
            let mut items = base.clone();
            run_chunks(threads, &mut items, |c, v| *v += c as f64);
            assert_eq!(items, reference, "threads={threads}");
        }
        assert_eq!(combine(&reference), {
            let mut acc = reference[0];
            for p in &reference[1..] {
                acc += *p;
            }
            acc
        });
    }

    #[test]
    fn split_helpers_cover_disjointly() {
        let mut buf = vec![0.0; 7 * 3]; // 7 rows, 3 cols, chunk 2 rows
        let blocks: Vec<usize> = split_rows(&mut buf, 3, 2).map(|b| b.len()).collect();
        assert_eq!(blocks, vec![6, 6, 6, 3]);

        let offsets = vec![0, 2, 2, 5, 6, 9];
        let mut vals = vec![0.0; 9];
        let csr = split_csr(&mut vals, &offsets, 5, 2);
        assert_eq!(csr.iter().map(|b| b.len()).collect::<Vec<_>>(), vec![2, 4, 3]);
    }

    #[test]
    fn combine_handles_empty_and_single() {
        assert_eq!(combine(&[]), 0.0);
        assert_eq!(combine(&[0.3]), 0.3);
    }
}
