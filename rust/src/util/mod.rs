//! Small self-contained substrates: deterministic RNG, statistics, a JSON
//! reader/writer, little-endian binary I/O, and a console table printer.
//!
//! These exist because the build environment is fully offline — `rand`,
//! `serde`, `prettytable` etc. are unavailable — and because determinism
//! under a single seed is a hard requirement for reproducing the paper's
//! tables (every experiment is seeded and re-runnable bit-for-bit).

pub mod binio;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;
pub mod table;
