//! Minimal JSON reader/writer.
//!
//! The offline build environment has no `serde`, so this module implements
//! the subset of JSON the system needs: parsing `artifacts/manifest.json`
//! (written by the python AOT pipeline) and emitting experiment results
//! under `results/`. It is a full recursive-descent parser for standard
//! JSON — objects, arrays, strings with escapes, numbers, booleans, null —
//! with precise error positions.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — important for diffable result files.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch) ---------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Builder: object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}' in object"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']' in array"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":{"mlp":{"shape":[32,196]}},"format":"hlo-text","n":10}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"τ=10\"").unwrap(), Json::Str("τ=10".into()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "constants": {"batch": 32, "img_pixels": 196},
          "entries": {
            "mlp_train": {
              "file": "mlp_train.hlo.txt",
              "inputs": [{"dtype": "float32", "shape": [196, 128]}],
              "outputs": [{"dtype": "float32", "shape": []}]
            }
          },
          "format": "hlo-text"
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("constants").unwrap().get("batch").unwrap().as_usize(), Some(32));
        let inputs = v
            .get("entries").unwrap()
            .get("mlp_train").unwrap()
            .get("inputs").unwrap()
            .as_arr().unwrap();
        assert_eq!(inputs[0].get("shape").unwrap().as_arr().unwrap().len(), 2);
    }
}
