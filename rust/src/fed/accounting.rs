//! Cost ledger and movement statistics — the quantities behind the paper's
//! Tables III–V and the cost panels of Figures 5–10.

/// Accumulated network resource costs, charged at **actual** trace values
/// (even when the optimizer planned with estimates).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Ledger {
    /// Σ_t Σ_i G_i(t) c_i(t)
    pub process: f64,
    /// Σ_t Σ_(i,j) D_i(t) s_ij(t) c_ij(t)
    pub transfer: f64,
    /// Σ_t Σ_i f_i(t) D_i(t) r_i(t) — the realized error cost.
    pub discard: f64,
}

impl Ledger {
    pub fn total(&self) -> f64 {
        self.process + self.transfer + self.discard
    }

    /// Total cost normalized by total data generated (the paper's "unit
    /// cost" column).
    pub fn unit_cost(&self, collected: f64) -> f64 {
        if collected > 0.0 {
            self.total() / collected
        } else {
            0.0
        }
    }
}

/// Data-movement counts for one interval.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IntervalStats {
    /// Datapoints collected by active devices this interval.
    pub collected: usize,
    /// Datapoints processed this interval (local keep + inbound arrivals).
    pub processed: usize,
    /// Datapoints sent over links this interval.
    pub offloaded: usize,
    /// Datapoints discarded this interval.
    pub discarded: usize,
}

impl IntervalStats {
    /// Fraction of this interval's collected data that *moved* (offloaded
    /// or discarded) — the paper's "data movement rate" (Fig. 5b etc.).
    pub fn movement_rate(&self) -> Option<f64> {
        if self.collected == 0 {
            None
        } else {
            Some((self.offloaded + self.discarded) as f64 / self.collected as f64)
        }
    }
}

/// Aggregated movement statistics over a run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MovementTotals {
    pub per_interval: Vec<IntervalStats>,
}

impl MovementTotals {
    pub fn push(&mut self, s: IntervalStats) {
        self.per_interval.push(s);
    }

    pub fn collected(&self) -> usize {
        self.per_interval.iter().map(|s| s.collected).sum()
    }

    pub fn processed(&self) -> usize {
        self.per_interval.iter().map(|s| s.processed).sum()
    }

    pub fn offloaded(&self) -> usize {
        self.per_interval.iter().map(|s| s.offloaded).sum()
    }

    pub fn discarded(&self) -> usize {
        self.per_interval.iter().map(|s| s.discarded).sum()
    }

    /// Fraction of all collected data eventually processed (Fig. 5a's
    /// "process ratio"). Offloaded data that is processed downstream counts
    /// once, at its processing interval.
    pub fn processed_ratio(&self) -> f64 {
        let c = self.collected();
        if c == 0 {
            0.0
        } else {
            self.processed() as f64 / c as f64
        }
    }

    /// Fraction of all collected data discarded (Fig. 5a's "discard ratio").
    pub fn discarded_ratio(&self) -> f64 {
        let c = self.collected();
        if c == 0 {
            0.0
        } else {
            self.discarded() as f64 / c as f64
        }
    }

    /// (mean, min, max) of the per-interval movement rate (Fig. 5b shading).
    pub fn movement_rate_stats(&self) -> (f64, f64, f64) {
        let rates: Vec<f64> = self
            .per_interval
            .iter()
            .filter_map(IntervalStats::movement_rate)
            .collect();
        if rates.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        (
            crate::util::stats::mean(&rates),
            crate::util::stats::min(&rates),
            crate::util::stats::max(&rates),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_totals() {
        let l = Ledger { process: 300.0, transfer: 120.0, discard: 140.0 };
        assert_eq!(l.total(), 560.0);
        assert!((l.unit_cost(4000.0) - 0.14).abs() < 1e-12);
        assert_eq!(l.unit_cost(0.0), 0.0);
    }

    #[test]
    fn movement_totals_ratios() {
        let mut m = MovementTotals::default();
        m.push(IntervalStats { collected: 100, processed: 60, offloaded: 30, discarded: 10 });
        m.push(IntervalStats { collected: 0, processed: 30, offloaded: 0, discarded: 0 });
        assert_eq!(m.collected(), 100);
        assert_eq!(m.processed(), 90);
        assert!((m.processed_ratio() - 0.9).abs() < 1e-12);
        assert!((m.discarded_ratio() - 0.1).abs() < 1e-12);
        let (mean, min, max) = m.movement_rate_stats();
        // only the first interval has collected > 0: rate = 0.4
        assert_eq!((mean, min, max), (0.4, 0.4, 0.4));
    }
}
