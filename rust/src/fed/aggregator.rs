//! Weighted federated averaging — eq. (4) of the paper:
//!
//! ```text
//! w(k) = Σ_i H_i(kτ) · w_i(kτ) / Σ_i H_i(kτ)
//! ```
//!
//! where `H_i` is the number of datapoints device i processed since the
//! last aggregation. Devices that processed more data carry more weight,
//! consistent with the empirical-loss objective (1). Under importance
//! sampling (`fed::participation`) the session pre-scales each sampled
//! device's `H_i` by `1 / π_i` — the Horvitz–Thompson correction — before
//! it reaches this function; the normalization below is otherwise
//! unchanged.
//!
//! # Chunk-parallel evaluation (DESIGN.md §Perf rule 14)
//!
//! The averaging sum is evaluated on the crate-wide fixed-chunk layer
//! ([`crate::util::par`]): contributors split into fixed
//! [`CHUNK_CONTRIBUTORS`]-entry chunks, each chunk folds its own partial
//! accumulator with the historical serial `axpy` chain, and the partials
//! are combined serially in ascending chunk order. Chunk geometry depends
//! on the contributor count only — never the thread count — so
//! `--solver-threads K` is bit-invariant for every K, and with ≤ 512
//! contributors (every paper-scale run) there is exactly **one** chunk
//! whose internal term order replays the historical serial sweep bitwise.
//! On the single-chunk path, large-tensor models additionally fan the
//! *element* axis across threads in [`CHUNK_ELEMS`]-element blocks; each
//! element's accumulation chain visits contributors in the same ascending
//! order regardless of blocking, so that axis is bit-neutral by
//! construction.

use anyhow::{bail, Result};

use crate::runtime::HostTensor;
use crate::util::par;

/// Model parameters: one tensor per layer, positionally matching the AOT
/// entry's leading inputs.
pub type Params = Vec<HostTensor>;

/// Contributors per chunk. Matches [`par::CHUNK_ROWS`] (and therefore
/// [`crate::config::MovementBackend::AUTO_THRESHOLD`]): every paper-scale
/// aggregation is a single chunk — historical bits — and by the time a
/// period has thousands of contributors, per-chunk axpy work amortizes
/// the thread handoff.
pub const CHUNK_CONTRIBUTORS: usize = 512;

/// Elements per block when the single-chunk path fans the element axis
/// across threads (64 KiB of f32 per block — large enough that a block is
/// worth a thread, small enough that MLP-scale layers still split).
pub const CHUNK_ELEMS: usize = 1 << 14;

fn zeros_like(p: &Params) -> Params {
    p.iter().map(|t| HostTensor::zeros(t.shape.clone())).collect()
}

/// Aggregate `(params, weight)` contributions (serial entry point —
/// exactly [`aggregate_chunked`] at one thread and default geometry).
///
/// Contract (pinned by the unit tests below):
/// * any non-finite weight (NaN or ±∞) is an error — a poisoned weight
///   must abort the run, never silently corrupt the global model;
/// * contributions with weight ≤ 0 are ignored;
/// * `Ok(None)` when no positive weight remains (empty input or all-zero
///   weights) — the paper keeps the previous global model in that case.
pub fn aggregate(contributions: &[(&Params, f64)]) -> Result<Option<Params>> {
    aggregate_chunked(contributions, 1, CHUNK_CONTRIBUTORS, CHUNK_ELEMS)
}

/// [`aggregate`] with explicit thread count and chunk geometry.
///
/// Determinism contract: the result is a function of `contributions`,
/// `chunk_contributors`, and `chunk_elems` only — **never** of `threads`.
/// At the default geometry a single chunk (≤ 512 contributors) replays
/// the historical serial axpy chain bitwise, and `chunk_elems` is
/// bit-neutral at every value (per-element accumulation order is
/// independent of element blocking). Both invariances are pinned by
/// `tests/aggregation.rs`.
pub fn aggregate_chunked(
    contributions: &[(&Params, f64)],
    threads: usize,
    chunk_contributors: usize,
    chunk_elems: usize,
) -> Result<Option<Params>> {
    if let Some((i, &(_, h))) =
        contributions.iter().enumerate().find(|&(_, &(_, h))| !h.is_finite())
    {
        bail!("aggregate: non-finite weight {h} for contribution {i}");
    }
    let n = contributions.len();
    let nc = par::num_chunks(n, chunk_contributors);
    // Chunked weight total: per-chunk serial sums combined ascending. A
    // single chunk is exactly the historical `iter().sum()` fold
    // (0.0 + h₀ + h₁ + …), so the normalizer — and with it every per-
    // contributor `w` — replays bitwise at paper scale.
    let mut h_partials = vec![0.0f64; nc];
    par::run_chunks(threads, &mut h_partials, |c, out| {
        let range = par::chunk_range(c, n, chunk_contributors);
        *out = contributions[range].iter().map(|&(_, h)| h).sum();
    });
    let total = par::combine(&h_partials);
    if total <= 0.0 {
        return Ok(None);
    }
    let Some(&(first, _)) = contributions.iter().find(|&&(_, h)| h > 0.0) else {
        return Ok(None);
    };
    if nc <= 1 {
        // historical term order; threads (if any) fan the element axis,
        // which cannot reorder any single element's accumulation chain
        let mut acc = zeros_like(first);
        accumulate_elem_blocks(&mut acc, contributions, total, threads, chunk_elems);
        return Ok(Some(acc));
    }
    // Per-chunk partial accumulators: each chunk runs the serial axpy
    // chain over its own contributors (None when the chunk has no
    // positive weight), then partials combine serially ascending —
    // `((p₀ + p₁) + p₂) + …`, the one association every thread count
    // reproduces.
    let mut partials: Vec<Option<Params>> = vec![None; nc];
    par::run_chunks(threads, &mut partials, |c, out| {
        let range = par::chunk_range(c, n, chunk_contributors);
        let mut acc: Option<Params> = None;
        for &(params, h) in &contributions[range] {
            if h <= 0.0 {
                continue;
            }
            let w = (h / total) as f32;
            let acc = acc.get_or_insert_with(|| zeros_like(params));
            for (a, p) in acc.iter_mut().zip(params) {
                a.axpy(w, p);
            }
        }
        *out = acc;
    });
    let mut acc: Option<Params> = None;
    for partial in partials.into_iter().flatten() {
        match &mut acc {
            None => acc = Some(partial),
            Some(acc) => {
                for (a, p) in acc.iter_mut().zip(&partial) {
                    a.axpy(1.0, p);
                }
            }
        }
    }
    Ok(acc) // total > 0 guarantees at least one Some partial
}

/// One element block of the accumulator a worker owns exclusively.
struct ElemBlock<'a> {
    layer: usize,
    start: usize,
    data: &'a mut [f32],
}

/// Single-chunk accumulation with the element axis split into
/// `chunk_elems`-element blocks fanned across `threads`. Every element's
/// op sequence is `a += w·p` over positive contributors ascending —
/// identical to the serial [`HostTensor::axpy`] chain for any blocking.
fn accumulate_elem_blocks(
    acc: &mut Params,
    contributions: &[(&Params, f64)],
    total: f64,
    threads: usize,
    chunk_elems: usize,
) {
    let chunk_elems = chunk_elems.max(1);
    let mut blocks: Vec<ElemBlock> = Vec::new();
    for (layer, t) in acc.iter_mut().enumerate() {
        let mut start = 0usize;
        for data in t.data.chunks_mut(chunk_elems) {
            let len = data.len();
            blocks.push(ElemBlock { layer, start, data });
            start += len;
        }
    }
    par::run_chunks(threads, &mut blocks, |_, b| {
        for &(params, h) in contributions {
            if h <= 0.0 {
                continue;
            }
            let w = (h / total) as f32;
            let src = &params[b.layer].data[b.start..b.start + b.data.len()];
            for (a, p) in b.data.iter_mut().zip(src) {
                *a += w * p;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(v: f32) -> Params {
        vec![HostTensor::new(vec![2], vec![v, 2.0 * v])]
    }

    #[test]
    fn weighted_mean() {
        let a = p(1.0);
        let b = p(4.0);
        // H_a = 3, H_b = 1 -> w = (3*1 + 1*4)/4 = 1.75
        let agg = aggregate(&[(&a, 3.0), (&b, 1.0)]).unwrap().unwrap();
        assert!((agg[0].data[0] - 1.75).abs() < 1e-6);
        assert!((agg[0].data[1] - 3.5).abs() < 1e-6);
    }

    #[test]
    fn zero_weight_contributions_ignored() {
        let a = p(1.0);
        let b = p(100.0);
        let agg = aggregate(&[(&a, 2.0), (&b, 0.0)]).unwrap().unwrap();
        assert_eq!(agg[0].data, vec![1.0, 2.0]);
    }

    #[test]
    fn no_contributors_returns_none() {
        let a = p(1.0);
        // all-zero weights and the empty list both mean "keep the
        // previous global model" — Ok(None), not an error
        assert!(aggregate(&[(&a, 0.0)]).unwrap().is_none());
        assert!(aggregate(&[]).unwrap().is_none());
        let b = p(2.0);
        assert!(aggregate(&[(&a, 0.0), (&b, 0.0)]).unwrap().is_none());
    }

    #[test]
    fn single_contributor_identity() {
        let a = p(3.0);
        let agg = aggregate(&[(&a, 5.0)]).unwrap().unwrap();
        assert_eq!(agg[0].data, a[0].data);
    }

    #[test]
    fn non_finite_weights_are_rejected() {
        let a = p(1.0);
        let b = p(2.0);
        // a single NaN poisons the whole aggregation — even alongside
        // healthy contributions, and regardless of sign conventions
        let err = aggregate(&[(&a, 1.0), (&b, f64::NAN)]).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        assert!(aggregate(&[(&a, f64::INFINITY)]).is_err());
        assert!(aggregate(&[(&a, f64::NEG_INFINITY), (&b, 1.0)]).is_err());

        // the chunked path keeps the poisoned-weight contract even when
        // the NaN lands in a late chunk
        let owned: Vec<Params> = (0..5).map(|i| p(i as f32)).collect();
        let mut refs: Vec<(&Params, f64)> = owned.iter().map(|q| (q, 1.0)).collect();
        refs[4].1 = f64::NAN;
        assert!(aggregate_chunked(&refs, 2, 2, CHUNK_ELEMS).is_err());
    }

    #[test]
    fn chunked_is_thread_and_elem_block_invariant() {
        let owned: Vec<Params> = (0..11).map(|i| p(0.3 * i as f32 - 1.0)).collect();
        let refs: Vec<(&Params, f64)> =
            owned.iter().enumerate().map(|(i, q)| (q, (i % 4) as f64)).collect();
        let serial = aggregate(&refs).unwrap().unwrap();
        for chunk in [2, 3, CHUNK_CONTRIBUTORS] {
            let base = aggregate_chunked(&refs, 1, chunk, CHUNK_ELEMS).unwrap().unwrap();
            for threads in [2, 4, 7] {
                for elems in [1, 3, CHUNK_ELEMS] {
                    let out =
                        aggregate_chunked(&refs, threads, chunk, elems).unwrap().unwrap();
                    assert_eq!(
                        out[0].data, base[0].data,
                        "chunk={chunk} threads={threads} elems={elems}"
                    );
                }
            }
            // 11 contributors fit one default chunk: that geometry must
            // replay the serial entry point bitwise
            if chunk == CHUNK_CONTRIBUTORS {
                assert_eq!(base[0].data, serial[0].data);
            }
        }
    }

    #[test]
    fn chunked_none_when_positive_weight_is_absent() {
        let owned: Vec<Params> = (0..7).map(|i| p(i as f32)).collect();
        let refs: Vec<(&Params, f64)> = owned.iter().map(|q| (q, 0.0)).collect();
        assert!(aggregate_chunked(&refs, 4, 2, 3).unwrap().is_none());
    }
}
