//! Weighted federated averaging — eq. (4) of the paper:
//!
//! ```text
//! w(k) = Σ_i H_i(kτ) · w_i(kτ) / Σ_i H_i(kτ)
//! ```
//!
//! where `H_i` is the number of datapoints device i processed since the
//! last aggregation. Devices that processed more data carry more weight,
//! consistent with the empirical-loss objective (1).

use crate::runtime::HostTensor;

/// Model parameters: one tensor per layer, positionally matching the AOT
/// entry's leading inputs.
pub type Params = Vec<HostTensor>;

/// Aggregate `(params, weight)` contributions. Contributions with zero
/// weight are ignored; returns `None` if no weight at all (the paper keeps
/// the previous global model in that case).
pub fn aggregate(contributions: &[(&Params, f64)]) -> Option<Params> {
    let total: f64 = contributions.iter().map(|&(_, h)| h).sum();
    if total <= 0.0 {
        return None;
    }
    let first = contributions.iter().find(|&&(_, h)| h > 0.0)?.0;
    let mut acc: Params = first
        .iter()
        .map(|t| HostTensor::zeros(t.shape.clone()))
        .collect();
    for &(params, h) in contributions {
        if h <= 0.0 {
            continue;
        }
        let w = (h / total) as f32;
        for (a, p) in acc.iter_mut().zip(params) {
            a.axpy(w, p);
        }
    }
    Some(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(v: f32) -> Params {
        vec![HostTensor::new(vec![2], vec![v, 2.0 * v])]
    }

    #[test]
    fn weighted_mean() {
        let a = p(1.0);
        let b = p(4.0);
        // H_a = 3, H_b = 1 -> w = (3*1 + 1*4)/4 = 1.75
        let agg = aggregate(&[(&a, 3.0), (&b, 1.0)]).unwrap();
        assert!((agg[0].data[0] - 1.75).abs() < 1e-6);
        assert!((agg[0].data[1] - 3.5).abs() < 1e-6);
    }

    #[test]
    fn zero_weight_contributions_ignored() {
        let a = p(1.0);
        let b = p(100.0);
        let agg = aggregate(&[(&a, 2.0), (&b, 0.0)]).unwrap();
        assert_eq!(agg[0].data, vec![1.0, 2.0]);
    }

    #[test]
    fn no_contributors_returns_none() {
        let a = p(1.0);
        assert!(aggregate(&[(&a, 0.0)]).is_none());
        assert!(aggregate(&[]).is_none());
    }

    #[test]
    fn single_contributor_identity() {
        let a = p(3.0);
        let agg = aggregate(&[(&a, 5.0)]).unwrap();
        assert_eq!(agg[0].data, a[0].data);
    }
}
