//! Weighted federated averaging — eq. (4) of the paper:
//!
//! ```text
//! w(k) = Σ_i H_i(kτ) · w_i(kτ) / Σ_i H_i(kτ)
//! ```
//!
//! where `H_i` is the number of datapoints device i processed since the
//! last aggregation. Devices that processed more data carry more weight,
//! consistent with the empirical-loss objective (1). Under importance
//! sampling (`fed::participation`) the session pre-scales each sampled
//! device's `H_i` by `1 / π_i` — the Horvitz–Thompson correction — before
//! it reaches this function; the normalization below is otherwise
//! unchanged.

use anyhow::{bail, Result};

use crate::runtime::HostTensor;

/// Model parameters: one tensor per layer, positionally matching the AOT
/// entry's leading inputs.
pub type Params = Vec<HostTensor>;

/// Aggregate `(params, weight)` contributions.
///
/// Contract (pinned by the unit tests below):
/// * any non-finite weight (NaN or ±∞) is an error — a poisoned weight
///   must abort the run, never silently corrupt the global model;
/// * contributions with weight ≤ 0 are ignored;
/// * `Ok(None)` when no positive weight remains (empty input or all-zero
///   weights) — the paper keeps the previous global model in that case.
pub fn aggregate(contributions: &[(&Params, f64)]) -> Result<Option<Params>> {
    if let Some((i, &(_, h))) =
        contributions.iter().enumerate().find(|&(_, &(_, h))| !h.is_finite())
    {
        bail!("aggregate: non-finite weight {h} for contribution {i}");
    }
    let total: f64 = contributions.iter().map(|&(_, h)| h).sum();
    if total <= 0.0 {
        return Ok(None);
    }
    let Some(&(first, _)) = contributions.iter().find(|&&(_, h)| h > 0.0) else {
        return Ok(None);
    };
    let mut acc: Params = first
        .iter()
        .map(|t| HostTensor::zeros(t.shape.clone()))
        .collect();
    for &(params, h) in contributions {
        if h <= 0.0 {
            continue;
        }
        let w = (h / total) as f32;
        for (a, p) in acc.iter_mut().zip(params) {
            a.axpy(w, p);
        }
    }
    Ok(Some(acc))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(v: f32) -> Params {
        vec![HostTensor::new(vec![2], vec![v, 2.0 * v])]
    }

    #[test]
    fn weighted_mean() {
        let a = p(1.0);
        let b = p(4.0);
        // H_a = 3, H_b = 1 -> w = (3*1 + 1*4)/4 = 1.75
        let agg = aggregate(&[(&a, 3.0), (&b, 1.0)]).unwrap().unwrap();
        assert!((agg[0].data[0] - 1.75).abs() < 1e-6);
        assert!((agg[0].data[1] - 3.5).abs() < 1e-6);
    }

    #[test]
    fn zero_weight_contributions_ignored() {
        let a = p(1.0);
        let b = p(100.0);
        let agg = aggregate(&[(&a, 2.0), (&b, 0.0)]).unwrap().unwrap();
        assert_eq!(agg[0].data, vec![1.0, 2.0]);
    }

    #[test]
    fn no_contributors_returns_none() {
        let a = p(1.0);
        // all-zero weights and the empty list both mean "keep the
        // previous global model" — Ok(None), not an error
        assert!(aggregate(&[(&a, 0.0)]).unwrap().is_none());
        assert!(aggregate(&[]).unwrap().is_none());
        let b = p(2.0);
        assert!(aggregate(&[(&a, 0.0), (&b, 0.0)]).unwrap().is_none());
    }

    #[test]
    fn single_contributor_identity() {
        let a = p(3.0);
        let agg = aggregate(&[(&a, 5.0)]).unwrap().unwrap();
        assert_eq!(agg[0].data, a[0].data);
    }

    #[test]
    fn non_finite_weights_are_rejected() {
        let a = p(1.0);
        let b = p(2.0);
        // a single NaN poisons the whole aggregation — even alongside
        // healthy contributions, and regardless of sign conventions
        let err = aggregate(&[(&a, 1.0), (&b, f64::NAN)]).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        assert!(aggregate(&[(&a, f64::INFINITY)]).is_err());
        assert!(aggregate(&[(&a, f64::NEG_INFINITY), (&b, 1.0)]).is_err());
    }
}
