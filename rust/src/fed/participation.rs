//! Per-period device participation sampling — the train-side twin of the
//! eval-subset trick (DESIGN.md §Perf rule 13).
//!
//! Instead of training every active device every interval, a
//! [`ParticipationSchedule`] selects `K` of the devices active at the
//! start of each aggregation period. The paper's offloading primitive
//! turns the unselected devices into *offload-only sources*: a
//! [`ParticipationCosts`] wrapper zeroes their processing capacity in the
//! movement problem, so their collected data flows toward sampled
//! neighbors (or is discarded, per the cost trade-off) rather than
//! silently vanishing. The aggregator keeps the period average unbiased
//! by Horvitz–Thompson reweighting: each sampled device's eq. (4) weight
//! is scaled by `1 / π_i`, the inverse of its inclusion probability.
//!
//! Determinism contract: the sampler draws from its own domain-separated
//! stream (`seed ^ PARTICIPATION_SALT`, like the eval planner's
//! `EVAL_PLAN_SALT`), so enabling it cannot perturb the load-bearing RNG
//! split order of [`crate::fed::session::Substrates::derive`] — and the
//! `Full` default materializes no state at all, which is what guarantees
//! bit-identity with the pre-subsystem engine (`tests/participation.rs`).
//!
//! The `1 / π_i` weight scales feed straight into the chunk-parallel
//! aggregator ([`crate::fed::aggregator::aggregate_chunked`], DESIGN.md
//! §Perf rule 14): the session pre-scales each sampled device's `H_i`
//! before aggregation, so the reweighting is invariant to the aggregate's
//! chunk/thread geometry for free — the weights are inputs to the fixed
//! geometry, never participants in its reduction order.

use anyhow::{anyhow, bail, Result};

use crate::costs::MovementCosts;
use crate::util::rng::Rng;

/// Which devices participate in each aggregation period (CLI
/// `--participation`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParticipationSchedule {
    /// Every active device trains every interval — the historical
    /// behavior, bit-identical to the pre-subsystem engine.
    #[default]
    Full,
    /// `k` devices drawn uniformly without replacement from the devices
    /// active at each period start (`π_i = k / m`, equal reweighting).
    UniformK { k: usize },
    /// `k` devices drawn with probability proportional to an importance
    /// score (collected data volume over believed processing cost), with
    /// per-device `1 / π_i` reweighting in the aggregator.
    ImportanceK { k: usize },
}

impl ParticipationSchedule {
    /// Parse `full`, `uniform:K` or `importance:K` (K ≥ 1).
    pub fn parse(s: &str) -> Result<Self> {
        let lower = s.to_ascii_lowercase();
        if lower == "full" {
            return Ok(ParticipationSchedule::Full);
        }
        let Some((kind, kstr)) = lower.split_once(':') else {
            bail!("unknown participation schedule '{s}' (want full|uniform:K|importance:K)");
        };
        let k: usize =
            kstr.parse().map_err(|e| anyhow!("--participation {kind}:{kstr}: {e}"))?;
        if k < 1 {
            bail!("participation schedule needs at least 1 device per period (got {k})");
        }
        match kind {
            "uniform" => Ok(ParticipationSchedule::UniformK { k }),
            "importance" => Ok(ParticipationSchedule::ImportanceK { k }),
            _ => bail!("unknown participation schedule '{s}' (want full|uniform:K|importance:K)"),
        }
    }

    /// Canonical string form — the inverse of [`ParticipationSchedule::parse`].
    /// Recorded in the shard opts blob as an identity field, so shard sets
    /// produced under different schedules refuse to merge.
    pub fn label(&self) -> String {
        match self {
            ParticipationSchedule::Full => "full".to_string(),
            ParticipationSchedule::UniformK { k } => format!("uniform:{k}"),
            ParticipationSchedule::ImportanceK { k } => format!("importance:{k}"),
        }
    }
}

/// Domain-separation constant for the participation draws: the sampler
/// owns `Rng::new(seed ^ PARTICIPATION_SALT)` so the schedule cannot
/// perturb any other seeded stream (distinct from the eval planner's
/// `EVAL_PLAN_SALT`).
const PARTICIPATION_SALT: u64 = 0x5A3D_91C7_0B6E_F24D;

/// Per-run sampling state: which devices participate in the current
/// aggregation period, and the Horvitz–Thompson multiplier (`1 / π_i`)
/// applied to each sampled device's aggregation weight. One instance
/// lives in the session (`None` under `Full`), re-resolved at every
/// period start over the then-active devices.
#[derive(Debug, Clone)]
pub struct ParticipationState {
    schedule: ParticipationSchedule,
    rng: Rng,
    /// Whether device `i` participates this period. Devices entering
    /// mid-period stay unsampled until the next resolution (they would be
    /// unsynced and excluded from the aggregate anyway).
    pub sampled: Vec<bool>,
    /// `1 / π_i` for sampled devices, `1.0` otherwise.
    pub weight_scale: Vec<f64>,
    /// Degenerate period (`Full`-equivalent): `k` covered every active
    /// device, so the whole sampling machinery — cost wrapper, train
    /// gate, reweighting — is bypassed and the period is bitwise the
    /// pre-subsystem engine.
    pub full_period: bool,
}

impl ParticipationState {
    /// Materialize sampling state for a run of `n` devices. Returns
    /// `None` under `Full`: the absence of state (not a disabled flag) is
    /// what pins the default to the pre-subsystem code path.
    pub fn new(schedule: ParticipationSchedule, n: usize, seed: u64) -> Option<ParticipationState> {
        if schedule == ParticipationSchedule::Full {
            return None;
        }
        Some(ParticipationState {
            schedule,
            rng: Rng::new(seed ^ PARTICIPATION_SALT),
            sampled: vec![true; n],
            weight_scale: vec![1.0; n],
            full_period: true,
        })
    }

    /// Draw the participant set for the period starting now. `active` is
    /// the post-churn activity mask; `score` supplies the importance score
    /// of an active device (ignored under `UniformK`, must be finite and
    /// positive to carry weight — degenerate scores fall back to uniform
    /// mass).
    ///
    /// When `k` covers every active device the period degrades to `Full`
    /// **exactly** and no RNG output is consumed, so alternating
    /// degenerate and sampled periods cannot shift later draws.
    pub fn resolve_period(&mut self, active: &[bool], mut score: impl FnMut(usize) -> f64) {
        let n = active.len();
        debug_assert_eq!(n, self.sampled.len());
        for i in 0..n {
            self.sampled[i] = false;
            self.weight_scale[i] = 1.0;
        }
        let ids: Vec<usize> = (0..n).filter(|&i| active[i]).collect();
        let m = ids.len();
        let k = match self.schedule {
            ParticipationSchedule::Full => m,
            ParticipationSchedule::UniformK { k } | ParticipationSchedule::ImportanceK { k } => k,
        };
        if k >= m {
            self.full_period = true;
            for &i in &ids {
                self.sampled[i] = true;
            }
            return;
        }
        self.full_period = false;
        match self.schedule {
            ParticipationSchedule::Full => unreachable!("Full materializes no state"),
            ParticipationSchedule::UniformK { k } => {
                let scale = m as f64 / k as f64;
                for slot in self.rng.sample_indices(m, k) {
                    let i = ids[slot];
                    self.sampled[i] = true;
                    self.weight_scale[i] = scale;
                }
            }
            ParticipationSchedule::ImportanceK { k } => {
                let scores: Vec<f64> = ids
                    .iter()
                    .map(|&i| {
                        let s = score(i);
                        if s.is_finite() && s > 0.0 {
                            s
                        } else {
                            0.0
                        }
                    })
                    .collect();
                let pi = inclusion_probabilities(&scores, k);
                self.systematic_pps(&ids, &pi, k);
            }
        }
    }

    /// Systematic probability-proportional-to-size draw: one uniform `u`
    /// selects the units whose cumulative-`π` interval contains a point of
    /// `{u, u+1, …, u+k-1}` (valid because `Σ π_i = k` and every
    /// `π_i ≤ 1`, so each unit is hit at most once). A single RNG output
    /// per period keeps the stream advance schedule-independent.
    fn systematic_pps(&mut self, ids: &[usize], pi: &[f64], k: usize) {
        let u = self.rng.f64();
        let mut cum = 0.0;
        let mut next = 0usize;
        for (slot, &i) in ids.iter().enumerate() {
            let hi = cum + pi[slot];
            while next < k && (u + next as f64) < hi {
                next += 1;
                if !self.sampled[i] {
                    self.sampled[i] = true;
                    self.weight_scale[i] = 1.0 / pi[slot];
                }
            }
            cum = hi;
        }
        // float-drift backstop: if accumulated rounding starved a target,
        // top up deterministically with the largest unsampled π
        let mut selected = ids.iter().filter(|&&i| self.sampled[i]).count();
        while selected < k {
            let Some((slot, &i)) = ids
                .iter()
                .enumerate()
                .filter(|&(_, &i)| !self.sampled[i])
                .max_by(|a, b| pi[a.0].partial_cmp(&pi[b.0]).unwrap())
            else {
                break;
            };
            self.sampled[i] = true;
            self.weight_scale[i] = 1.0 / pi[slot].max(f64::MIN_POSITIVE);
            selected += 1;
        }
    }
}

/// Horvitz–Thompson inclusion probabilities for a size-`k`
/// without-replacement PPS draw: `π_i = k·s_i / Σs`, iteratively capping
/// units that exceed 1 (they enter with certainty) and re-solving over the
/// rest, so `Σ π_i = k` exactly. All-zero score vectors fall back to
/// uniform mass (every unit equally likely).
fn inclusion_probabilities(scores: &[f64], k: usize) -> Vec<f64> {
    let m = scores.len();
    debug_assert!(k < m);
    let total: f64 = scores.iter().sum();
    let uniform = vec![1.0; m];
    let scores = if total > 0.0 { scores } else { &uniform[..] };
    let mut pi = vec![0.0; m];
    let mut capped = vec![false; m];
    let mut k_rem = k;
    loop {
        let total: f64 = (0..m).filter(|&i| !capped[i]).map(|i| scores[i]).sum();
        if k_rem == 0 || total <= 0.0 {
            for i in (0..m).filter(|&i| !capped[i]) {
                pi[i] = 0.0;
            }
            break;
        }
        let mut newly = 0usize;
        for i in 0..m {
            if capped[i] {
                continue;
            }
            let p = k_rem as f64 * scores[i] / total;
            if p >= 1.0 {
                capped[i] = true;
                pi[i] = 1.0;
                newly += 1;
            } else {
                pi[i] = p;
            }
        }
        if newly == 0 {
            break;
        }
        k_rem -= newly;
    }
    pi
}

/// Capacity-zero view of a cost oracle for unsampled devices: costs and
/// link/error terms pass through untouched, but an unsampled device's
/// node capacity reads as 0, so the movement solver can only route its
/// collected data outward (offload to a sampled neighbor or discard per
/// the cost trade-off) — the "offload-only source" of the device-sampling
/// papers. The mask is the period's participant set for both the `t` and
/// `t+1` oracle queries; data already in flight toward a device that the
/// *next* period leaves unsampled is discarded (and charged) by the train
/// gate instead.
#[derive(Debug)]
pub struct ParticipationCosts<'a> {
    pub inner: &'a dyn MovementCosts,
    pub sampled: &'a [bool],
}

impl MovementCosts for ParticipationCosts<'_> {
    fn c_node(&self, t: usize, i: usize) -> f64 {
        self.inner.c_node(t, i)
    }
    fn c_link(&self, t: usize, i: usize, j: usize) -> f64 {
        self.inner.c_link(t, i, j)
    }
    fn f(&self, t: usize, i: usize) -> f64 {
        self.inner.f(t, i)
    }
    fn cap_node_at(&self, t: usize, i: usize) -> f64 {
        if self.sampled[i] {
            self.inner.cap_node_at(t, i)
        } else {
            0.0
        }
    }
    fn cap_link_at(&self, t: usize, i: usize, j: usize) -> f64 {
        self.inner.cap_link_at(t, i, j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;

    #[test]
    fn schedule_parses() {
        assert_eq!(ParticipationSchedule::parse("full").unwrap(), ParticipationSchedule::Full);
        assert_eq!(
            ParticipationSchedule::parse("Uniform:3").unwrap(),
            ParticipationSchedule::UniformK { k: 3 }
        );
        assert_eq!(
            ParticipationSchedule::parse("importance:8").unwrap(),
            ParticipationSchedule::ImportanceK { k: 8 }
        );
        assert!(ParticipationSchedule::parse("uniform:0").is_err());
        assert!(ParticipationSchedule::parse("uniform").is_err());
        assert!(ParticipationSchedule::parse("uniform:x").is_err());
        assert!(ParticipationSchedule::parse("topk:3").is_err());
        assert_eq!(ParticipationSchedule::default(), ParticipationSchedule::Full);
    }

    #[test]
    fn label_round_trips() {
        for s in [
            ParticipationSchedule::Full,
            ParticipationSchedule::UniformK { k: 4 },
            ParticipationSchedule::ImportanceK { k: 7 },
        ] {
            assert_eq!(ParticipationSchedule::parse(&s.label()).unwrap(), s);
        }
    }

    #[test]
    fn full_materializes_no_state() {
        assert!(ParticipationState::new(ParticipationSchedule::Full, 8, 1).is_none());
        assert!(ParticipationState::new(ParticipationSchedule::UniformK { k: 2 }, 8, 1).is_some());
    }

    #[test]
    fn uniform_draws_exactly_k_active_devices() {
        let mut st =
            ParticipationState::new(ParticipationSchedule::UniformK { k: 3 }, 10, 42).unwrap();
        let mut active = vec![true; 10];
        active[2] = false;
        active[7] = false;
        for _ in 0..50 {
            st.resolve_period(&active, |_| 1.0);
            assert!(!st.full_period);
            let picked: Vec<usize> = (0..10).filter(|&i| st.sampled[i]).collect();
            assert_eq!(picked.len(), 3);
            assert!(picked.iter().all(|&i| active[i]), "{picked:?}");
            for &i in &picked {
                // π = k/m = 3/8 -> scale = 8/3
                assert!((st.weight_scale[i] - 8.0 / 3.0).abs() < 1e-12);
            }
            for i in (0..10).filter(|&i| !st.sampled[i]) {
                assert_eq!(st.weight_scale[i], 1.0);
            }
        }
    }

    #[test]
    fn uniform_marginals_match_inclusion_probability() {
        let (n, k, periods) = (10usize, 3usize, 4000usize);
        let mut st =
            ParticipationState::new(ParticipationSchedule::UniformK { k }, n, 7).unwrap();
        let active = vec![true; n];
        let mut hits = vec![0usize; n];
        for _ in 0..periods {
            st.resolve_period(&active, |_| 1.0);
            for i in 0..n {
                hits[i] += usize::from(st.sampled[i]);
            }
        }
        let expect = k as f64 / n as f64;
        for (i, &h) in hits.iter().enumerate() {
            let freq = h as f64 / periods as f64;
            assert!((freq - expect).abs() < 0.03, "device {i}: freq={freq} expect={expect}");
        }
    }

    #[test]
    fn sampler_is_seed_deterministic() {
        let mk = || {
            ParticipationState::new(ParticipationSchedule::ImportanceK { k: 4 }, 12, 99).unwrap()
        };
        let (mut a, mut b) = (mk(), mk());
        let active = vec![true; 12];
        for _ in 0..20 {
            a.resolve_period(&active, |i| 1.0 + i as f64);
            b.resolve_period(&active, |i| 1.0 + i as f64);
            assert_eq!(a.sampled, b.sampled);
            assert_eq!(a.weight_scale, b.weight_scale);
        }
        let mut c =
            ParticipationState::new(ParticipationSchedule::ImportanceK { k: 4 }, 12, 100).unwrap();
        let mut diverged = false;
        for _ in 0..20 {
            a.resolve_period(&active, |i| 1.0 + i as f64);
            c.resolve_period(&active, |i| 1.0 + i as f64);
            diverged |= a.sampled != c.sampled;
        }
        assert!(diverged, "different seeds never diverged");
    }

    #[test]
    fn degenerate_k_covers_all_and_consumes_no_rng() {
        let schedule = ParticipationSchedule::UniformK { k: 3 };
        let mut with_degenerate = ParticipationState::new(schedule, 8, 5).unwrap();
        let mut without = ParticipationState::new(schedule, 8, 5).unwrap();
        let all = vec![true; 8];
        let mut few = vec![false; 8];
        few[1] = true;
        few[4] = true;

        // k >= m: full-period degradation, everyone active is in
        with_degenerate.resolve_period(&few, |_| 1.0);
        assert!(with_degenerate.full_period);
        assert_eq!(
            (0..8).filter(|&i| with_degenerate.sampled[i]).collect::<Vec<_>>(),
            vec![1, 4]
        );
        assert!(with_degenerate.weight_scale.iter().all(|&w| w == 1.0));

        // ...and it must not have advanced the RNG: the next sampled
        // period matches a state that never saw the degenerate one
        with_degenerate.resolve_period(&all, |_| 1.0);
        without.resolve_period(&all, |_| 1.0);
        assert!(!with_degenerate.full_period);
        assert_eq!(with_degenerate.sampled, without.sampled);
    }

    #[test]
    fn inclusion_probabilities_sum_to_k_and_cap_at_one() {
        let pi = inclusion_probabilities(&[1.0, 1.0, 1.0, 1.0], 2);
        assert!(pi.iter().all(|&p| (p - 0.5).abs() < 1e-12));

        // one dominant score: capped at 1, remainder spread over the rest
        let pi = inclusion_probabilities(&[100.0, 1.0, 1.0, 1.0, 1.0], 2);
        assert_eq!(pi[0], 1.0);
        for &p in &pi[1..] {
            assert!((p - 0.25).abs() < 1e-12, "{pi:?}");
        }
        let sum: f64 = pi.iter().sum();
        assert!((sum - 2.0).abs() < 1e-9, "{pi:?}");

        // all-zero scores fall back to uniform
        let pi = inclusion_probabilities(&[0.0, 0.0, 0.0], 2);
        assert!(pi.iter().all(|&p| (p - 2.0 / 3.0).abs() < 1e-12), "{pi:?}");
    }

    #[test]
    fn importance_draws_exactly_k_and_prefers_high_scores() {
        let n = 12;
        let mut st =
            ParticipationState::new(ParticipationSchedule::ImportanceK { k: 4 }, n, 21).unwrap();
        let active = vec![true; n];
        let mut hits = vec![0usize; n];
        let periods = 2000;
        for _ in 0..periods {
            st.resolve_period(&active, |i| if i < 4 { 8.0 } else { 1.0 });
            let picked = (0..n).filter(|&i| st.sampled[i]).count();
            assert_eq!(picked, 4);
            for i in 0..n {
                if st.sampled[i] {
                    hits[i] += 1;
                    assert!(st.weight_scale[i] >= 1.0 - 1e-12, "scale under 1: {}", st.weight_scale[i]);
                }
            }
        }
        let hot = hits[..4].iter().sum::<usize>() as f64 / 4.0;
        let cold = hits[4..].iter().sum::<usize>() as f64 / (n - 4) as f64;
        assert!(hot > 2.0 * cold, "hot={hot} cold={cold}");
    }

    #[test]
    fn participation_costs_zero_unsampled_node_capacity_only() {
        let mut sched = CostSchedule::zeros(3, 2);
        for t in 0..2 {
            for i in 0..3 {
                sched.compute[t][i] = 1.5;
                sched.error_weight[t][i] = 2.5;
                sched.cap_node[t][i] = 10.0;
                for j in 0..3 {
                    sched.link[t][i * 3 + j] = 0.5;
                    sched.cap_link[t][i * 3 + j] = 20.0;
                }
            }
        }
        let sampled = vec![true, false, true];
        let wrapped = ParticipationCosts { inner: &sched, sampled: &sampled };
        for t in 0..2 {
            assert_eq!(wrapped.cap_node_at(t, 0), 10.0);
            assert_eq!(wrapped.cap_node_at(t, 1), 0.0);
            assert_eq!(wrapped.cap_node_at(t, 2), 10.0);
            for i in 0..3 {
                assert_eq!(wrapped.c_node(t, i), 1.5);
                assert_eq!(wrapped.f(t, i), 2.5);
                for j in 0..3 {
                    assert_eq!(wrapped.c_link(t, i, j), 0.5);
                    assert_eq!(wrapped.cap_link_at(t, i, j), 20.0);
                }
            }
        }
    }
}
