//! Local-update executor: runs the AOT-compiled train/eval steps over a
//! device's processed data `G_i(t)` (eq. 3 of the paper).
//!
//! A single compiled executable serves any workload size: microbatches are
//! padded to the compiled `BATCH` with zero per-sample weights (the padding
//! provably does not affect loss or gradients — enforced by the python test
//! `test_padding_invariance`), and workloads larger than `BATCH` are
//! chunked into successive gradient steps.

use anyhow::Result;

use crate::data::dataset::{Dataset, IMG_PIXELS, NUM_CLASSES};
use crate::runtime::model::Executable;
use crate::runtime::{HostTensor, ModelKind, Runtime};

/// Train/eval executor bound to one model kind.
pub struct Trainer {
    train_exe: std::rc::Rc<Executable>,
    eval_exe: std::rc::Rc<Executable>,
    pub kind: ModelKind,
    pub lr: f32,
    pub batch: usize,
    // reusable input buffers (hot path: no per-step allocation)
    x_buf: std::cell::RefCell<Vec<f32>>,
    y_buf: std::cell::RefCell<Vec<f32>>,
    w_buf: std::cell::RefCell<Vec<f32>>,
}

impl Trainer {
    pub fn new(rt: &Runtime, kind: ModelKind, lr: f32) -> Result<Trainer> {
        let batch = rt.batch();
        Ok(Trainer {
            train_exe: rt.executable(kind.train_entry())?,
            eval_exe: rt.executable(kind.eval_entry())?,
            kind,
            lr,
            batch,
            x_buf: std::cell::RefCell::new(vec![0.0; batch * IMG_PIXELS]),
            y_buf: std::cell::RefCell::new(vec![0.0; batch * NUM_CLASSES]),
            w_buf: std::cell::RefCell::new(vec![0.0; batch]),
        })
    }

    /// One interval of local updates on the given samples: successive
    /// gradient steps over `BATCH`-sized chunks (the last chunk padded with
    /// zero weights). Updates `params` in place; returns the
    /// sample-weighted mean loss, or `None` for an empty workload.
    ///
    /// Hot path: parameters are converted to XLA literals once, stay
    /// literal-resident across all chunks (each step's outputs feed the
    /// next step's inputs without host round-trips), and are materialized
    /// back into `HostTensor`s only at the end (DESIGN.md §Perf).
    pub fn train_interval(
        &self,
        params: &mut Vec<HostTensor>,
        ds: &Dataset,
        samples: &[u32],
    ) -> Result<Option<f32>> {
        if samples.is_empty() {
            return Ok(None);
        }
        let n_params = self.kind.num_params();
        let mut lit_params: Vec<xla::Literal> =
            params.iter().map(HostTensor::to_literal).collect::<Result<_>>()?;
        let lr = HostTensor::scalar(self.lr).to_literal()?;

        let mut loss_acc = 0.0f64;
        for chunk in samples.chunks(self.batch) {
            let (x, y, w) = self.fill_batch(ds, chunk);
            let (xl, yl, wl) = (x.to_literal()?, y.to_literal()?, w.to_literal()?);
            let mut inputs: Vec<&xla::Literal> = lit_params.iter().collect();
            inputs.extend([&xl, &yl, &wl, &lr]);
            let mut out = self.train_exe.run_literals(&inputs)?;
            let loss = out[n_params].to_vec::<f32>()?[0];
            loss_acc += loss as f64 * chunk.len() as f64;
            out.truncate(n_params);
            lit_params = out;
        }
        *params = lit_params.iter().map(HostTensor::from_literal).collect::<Result<_>>()?;
        Ok(Some((loss_acc / samples.len() as f64) as f32))
    }

    /// Test-set accuracy of `params` (argmax over logits, computed host-side).
    pub fn evaluate(&self, params: &[HostTensor], ds: &Dataset) -> Result<f64> {
        let all: Vec<u32> = (0..ds.len() as u32).collect();
        self.evaluate_subset(params, ds, &all)
    }

    /// Accuracy over an index subset.
    pub fn evaluate_subset(
        &self,
        params: &[HostTensor],
        ds: &Dataset,
        samples: &[u32],
    ) -> Result<f64> {
        if samples.is_empty() {
            return Ok(0.0);
        }
        // parameters converted once and shared (by reference) across chunks
        let lit_params: Vec<xla::Literal> =
            params.iter().map(HostTensor::to_literal).collect::<Result<_>>()?;
        let mut correct = 0usize;
        for chunk in samples.chunks(self.batch) {
            let (x, _, _) = self.fill_batch(ds, chunk);
            let xl = x.to_literal()?;
            let mut inputs: Vec<&xla::Literal> = lit_params.iter().collect();
            inputs.push(&xl);
            let out = self.eval_exe.run_literals(&inputs)?;
            let logits = out[0].to_vec::<f32>()?;
            for (row, &idx) in chunk.iter().enumerate() {
                let offs = row * NUM_CLASSES;
                let pred = (0..NUM_CLASSES)
                    .max_by(|&a, &b| {
                        logits[offs + a].partial_cmp(&logits[offs + b]).unwrap()
                    })
                    .unwrap();
                if pred == ds.labels[idx as usize] as usize {
                    correct += 1;
                }
            }
        }
        Ok(correct as f64 / samples.len() as f64)
    }

    /// Fill (x, onehot, wt) tensors for a chunk, zero-padding to `batch`.
    fn fill_batch(&self, ds: &Dataset, chunk: &[u32]) -> (HostTensor, HostTensor, HostTensor) {
        let b = self.batch;
        let mut x = self.x_buf.borrow_mut();
        let mut y = self.y_buf.borrow_mut();
        let mut w = self.w_buf.borrow_mut();
        x.iter_mut().for_each(|v| *v = 0.0);
        y.iter_mut().for_each(|v| *v = 0.0);
        w.iter_mut().for_each(|v| *v = 0.0);
        for (row, &idx) in chunk.iter().enumerate() {
            let img = ds.image(idx as usize);
            x[row * IMG_PIXELS..(row + 1) * IMG_PIXELS].copy_from_slice(img);
            y[row * NUM_CLASSES + ds.labels[idx as usize] as usize] = 1.0;
            w[row] = 1.0;
        }
        (
            HostTensor::new(vec![b, IMG_PIXELS], x.clone()),
            HostTensor::new(vec![b, NUM_CLASSES], y.clone()),
            HostTensor::new(vec![b], w.clone()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::SynthDigits;
    use crate::util::rng::Rng;

    fn setup() -> (Runtime, Dataset, Dataset) {
        let rt = Runtime::load_default().expect("run `make artifacts` first");
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(11);
        let (train, test) = gen.train_test(2000, 500, &mut rng);
        (rt, train, test)
    }

    #[test]
    fn training_beats_chance_and_improves() {
        let (rt, train, test) = setup();
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).unwrap();
        let mut params = rt.init_params(ModelKind::Mlp, 3).unwrap();
        let before = trainer.evaluate(&params, &test).unwrap();

        let all: Vec<u32> = (0..train.len() as u32).collect();
        let mut first_loss = None;
        let mut last_loss = 0.0;
        for epoch in 0..3 {
            let loss = trainer
                .train_interval(&mut params, &train, &all)
                .unwrap()
                .unwrap();
            if epoch == 0 {
                first_loss = Some(loss);
            }
            last_loss = loss;
        }
        let after = trainer.evaluate(&params, &test).unwrap();
        assert!(after > 0.5, "accuracy {after} not above chance enough");
        assert!(after > before + 0.2, "no improvement: {before} -> {after}");
        assert!(last_loss < first_loss.unwrap());
    }

    #[test]
    fn empty_interval_is_noop() {
        let (rt, train, _) = setup();
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.01).unwrap();
        let mut params = rt.init_params(ModelKind::Mlp, 4).unwrap();
        let snapshot = params.clone();
        assert!(trainer.train_interval(&mut params, &train, &[]).unwrap().is_none());
        assert_eq!(params[0].data, snapshot[0].data);
    }

    #[test]
    fn partial_batch_trains() {
        let (rt, train, _) = setup();
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).unwrap();
        let mut params = rt.init_params(ModelKind::Mlp, 5).unwrap();
        let snapshot = params.clone();
        // 5 samples << batch 32
        let loss = trainer
            .train_interval(&mut params, &train, &[0, 1, 2, 3, 4])
            .unwrap()
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_ne!(params[0].data, snapshot[0].data);
    }
}
