//! Local-update executor: runs the AOT-compiled train/eval steps over a
//! device's processed data `G_i(t)` (eq. 3 of the paper).
//!
//! A single compiled executable serves any workload size: microbatches are
//! padded to the compiled `BATCH` with zero per-sample weights (the padding
//! provably does not affect loss or gradients — enforced by the python test
//! `test_padding_invariance`), and workloads larger than `BATCH` are
//! chunked into successive gradient steps.
//!
//! For multi-device intervals, [`Trainer::train_interval_many`] stacks all
//! devices' chunk schedules into lock-stepped `[D × BATCH]` executions of a
//! batched `*_train_many_d<D>` entry (one PJRT dispatch per step for the
//! whole fleet instead of one per device). Devices whose schedules run out
//! early — and idle pad slots of a partially-filled stack — get all-zero
//! sample weights, which the same padding invariance turns into exact
//! no-ops (loss 0, zero gradient). See DESIGN.md §Perf rule 7.
//!
//! Slot packing is **origin-agnostic**: the general entry points
//! ([`Trainer::train_interval_units`], [`Trainer::evaluate_units`]) take
//! [`TrainUnit`]/[`EvalUnit`] lists where every slot carries its own
//! dataset reference, so one stacked dispatch can mix work from multiple
//! sessions (the coalescing runtime-service scheduler, DESIGN.md §Perf
//! rule 10). The single-session methods are thin wrappers that tag every
//! slot with the same dataset. [`TileFill`] picks the tile policy:
//! `Smallest` (per-session default) or `Largest` (the coalescer's
//! partner-invariance contract).
//!
//! Evaluation mirrors the split: [`Trainer::evaluate_subset`] is the
//! scalar one-call-per-chunk path, [`Trainer::evaluate_many`] stacks
//! (params, chunk) slots through the batched `*_eval_many_d<D>` entries
//! (§Perf rule 8), with zero-weight pad slots contributing exactly zero
//! correct predictions.

use std::cell::RefCell;

use anyhow::Result;

use crate::data::dataset::{Dataset, IMG_PIXELS, NUM_CLASSES};
use crate::fed::eval::{EvalPath, EvalUnit, EvalWork};
use crate::runtime::model::Executable;
use crate::runtime::{literal_from_slice, HostTensor, ModelKind, Runtime};

/// One device's slice of a batched training interval: the trainer consumes
/// `samples`, updates `params` in place and reports the device's
/// sample-weighted mean loss (None when `samples` is empty).
///
/// `params` is an *owned* private copy for the duration of the dispatch:
/// the session's device state is `Arc`-shared copy-on-write (DESIGN.md
/// §Perf rule 14), and the dispatch path materializes (unwrap-or-clone)
/// each trainee's params into its slot before the call, re-wrapping them
/// afterwards — so a trainer may mutate slots freely without ever
/// touching the shared epoch allocation.
#[derive(Debug, Default)]
pub struct DeviceWork {
    pub params: Vec<HostTensor>,
    pub samples: Vec<u32>,
    pub loss: Option<f32>,
}

/// A batched train work unit from any origin: the dataset its chunks stage
/// from plus the device work, updated in place. The cross-session
/// generalization of a `&mut [DeviceWork]` slice — every slot of a stacked
/// dispatch can come from a different session's dataset (DESIGN.md §Perf
/// rule 10).
pub struct TrainUnit<'a> {
    pub ds: &'a Dataset,
    pub work: &'a mut DeviceWork,
}

/// Tile-selection policy for the batched `*_many_d<D>` entries.
///
/// Routing through a different compiled tile is a perf decision with the
/// rule-7/8 equivalence tolerances, never a semantic one — but *within*
/// one policy, a slot's result is a pure function of the slot input, which
/// is what makes `Largest` the coalescing scheduler's bit-stability
/// contract (§Perf rule 10).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TileFill {
    /// Smallest compiled tile `D >= slots` per dispatch — the per-session
    /// default (least padding).
    #[default]
    Smallest,
    /// Always the largest compiled tile: every slot executes through the
    /// same executable no matter how many co-scheduled slots share the
    /// dispatch, so results are invariant to partner sessions.
    Largest,
}

/// Dispatch plan for `n` slots over the compiled tile sizes: each entry is
/// `(slots, tile)` — how many live slots the dispatch carries and which
/// compiled tile it requests. Pure (unit-tested without a runtime); empty
/// when `n == 0` or no tiles are compiled (callers fall back to the scalar
/// path).
pub fn plan_tiles(n: usize, tiles: &[usize], fill: TileFill) -> Vec<(usize, usize)> {
    let Some(&max) = tiles.last() else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut left = n;
    while left > 0 {
        let slots = left.min(max);
        let tile = match fill {
            TileFill::Smallest => {
                tiles.iter().copied().find(|&t| t >= slots).unwrap_or(max)
            }
            TileFill::Largest => max,
        };
        out.push((slots, tile));
        left -= slots;
    }
    out
}

/// Reusable staging buffers for the batched path (sized on first use to the
/// largest device tile a session actually selects; resident afterwards).
#[derive(Debug, Default)]
struct ManyScratch {
    x: Vec<f32>,
    y: Vec<f32>,
    w: Vec<f32>,
    stack: Vec<f32>,
    counts: Vec<usize>,
    loss: Vec<f64>,
}

/// Train/eval executor bound to one model kind.
pub struct Trainer {
    train_exe: std::rc::Rc<Executable>,
    eval_exe: std::rc::Rc<Executable>,
    pub kind: ModelKind,
    pub lr: f32,
    pub batch: usize,
    // reusable input buffers (hot path: no per-step allocation)
    x_buf: RefCell<Vec<f32>>,
    y_buf: RefCell<Vec<f32>>,
    w_buf: RefCell<Vec<f32>>,
    many: RefCell<ManyScratch>,
}

impl Trainer {
    pub fn new(rt: &Runtime, kind: ModelKind, lr: f32) -> Result<Trainer> {
        let batch = rt.batch();
        Ok(Trainer {
            train_exe: rt.executable(kind.train_entry())?,
            eval_exe: rt.executable(kind.eval_entry())?,
            kind,
            lr,
            batch,
            x_buf: RefCell::new(vec![0.0; batch * IMG_PIXELS]),
            y_buf: RefCell::new(vec![0.0; batch * NUM_CLASSES]),
            w_buf: RefCell::new(vec![0.0; batch]),
            many: RefCell::new(ManyScratch::default()),
        })
    }

    /// One interval of local updates on the given samples: successive
    /// gradient steps over `BATCH`-sized chunks (the last chunk padded with
    /// zero weights). Updates `params` in place; returns the
    /// sample-weighted mean loss, or `None` for an empty workload.
    ///
    /// Hot path: parameters are converted to XLA literals once, stay
    /// literal-resident across all chunks (each step's outputs feed the
    /// next step's inputs without host round-trips), and are materialized
    /// back into `HostTensor`s only at the end (DESIGN.md §Perf).
    pub fn train_interval(
        &self,
        params: &mut Vec<HostTensor>,
        ds: &Dataset,
        samples: &[u32],
    ) -> Result<Option<f32>> {
        if samples.is_empty() {
            return Ok(None);
        }
        let n_params = self.kind.num_params();
        let mut lit_params: Vec<xla::Literal> =
            params.iter().map(HostTensor::to_literal).collect::<Result<_>>()?;
        let lr = HostTensor::scalar(self.lr).to_literal()?;

        let mut loss_acc = 0.0f64;
        for chunk in samples.chunks(self.batch) {
            let (xl, yl, wl) = self.stage_chunk(ds, chunk)?;
            let mut inputs: Vec<&xla::Literal> = lit_params.iter().collect();
            inputs.extend([&xl, &yl, &wl, &lr]);
            let mut out = self.train_exe.run_literals(&inputs)?;
            let loss = out[n_params].to_vec::<f32>()?[0];
            loss_acc += loss as f64 * chunk.len() as f64;
            out.truncate(n_params);
            lit_params = out;
        }
        *params = lit_params.iter().map(HostTensor::from_literal).collect::<Result<_>>()?;
        Ok(Some((loss_acc / samples.len() as f64) as f32))
    }

    /// One interval of local updates for several devices of *one* session
    /// in lock-step (every slot stages from the same dataset). Thin
    /// wrapper over [`Trainer::train_interval_units`] with the
    /// per-session `Smallest` tile policy — bit-identical to the
    /// pre-coalescing behavior.
    pub fn train_interval_many(
        &self,
        rt: &Runtime,
        ds: &Dataset,
        work: &mut [DeviceWork],
    ) -> Result<()> {
        let mut units: Vec<TrainUnit> =
            work.iter_mut().map(|w| TrainUnit { ds, work: w }).collect();
        self.train_interval_units(rt, &mut units, TileFill::Smallest)
    }

    /// One interval of local updates for any mix of work units in
    /// lock-step: stacked `[D × BATCH]` executions of the batched train
    /// entry, with the stacked parameters literal-resident across all
    /// steps (exactly like the scalar path, amortized over D slots).
    /// Units are split into dispatches by [`plan_tiles`] under `fill`;
    /// idle slots are padded with zero sample weights. Each slot stages
    /// its chunks from its own dataset, so one dispatch can carry several
    /// sessions' devices (§Perf rule 10). Falls back to per-unit scalar
    /// dispatch when the loaded artifacts predate the batched entries.
    pub fn train_interval_units(
        &self,
        rt: &Runtime,
        units: &mut [TrainUnit],
        fill: TileFill,
    ) -> Result<()> {
        for u in units.iter_mut() {
            u.work.loss = None;
        }
        let todo: Vec<usize> =
            (0..units.len()).filter(|&i| !units[i].work.samples.is_empty()).collect();
        if todo.is_empty() {
            return Ok(());
        }
        let plan = plan_tiles(todo.len(), &rt.manifest.device_tiles, fill);
        if plan.is_empty() {
            return self.train_units_fallback(&todo, units);
        }
        let mut lo = 0usize;
        for (slots, tile) in plan {
            let group = &todo[lo..lo + slots];
            lo += slots;
            match rt.train_many_executable(self.kind, tile)? {
                Some((d, exe)) => self.train_group(&exe, d, group, units)?,
                // tiles advertised but entries missing (hand-pruned
                // artifact set): stay correct via the scalar path
                None => self.train_units_fallback(group, units)?,
            }
        }
        Ok(())
    }

    fn train_units_fallback(&self, group: &[usize], units: &mut [TrainUnit]) -> Result<()> {
        for &i in group {
            let u = &mut units[i];
            u.work.loss = self.train_interval(&mut u.work.params, u.ds, &u.work.samples)?;
        }
        Ok(())
    }

    /// Drive one slot group through the sized batched entry: lock-step
    /// count is the longest chunk schedule in the group; shorter schedules
    /// ride along with zero weights (exact no-ops per padding invariance).
    fn train_group(
        &self,
        exe: &Executable,
        d: usize,
        group: &[usize],
        units: &mut [TrainUnit],
    ) -> Result<()> {
        let n_params = self.kind.num_params();
        let b = self.batch;
        let steps = group
            .iter()
            .map(|&i| units[i].work.samples.len().div_ceil(b))
            .max()
            .unwrap_or(0);
        if steps == 0 {
            return Ok(());
        }

        let mut ms = self.many.borrow_mut();
        let ManyScratch { x, y, w, stack, counts, loss } = &mut *ms;

        // stack per-slot params into [d, ...] literals; pad slots zero
        let mut lit_params: Vec<xla::Literal> = Vec::with_capacity(n_params);
        for p in 0..n_params {
            let shape = units[group[0]].work.params[p].shape.clone();
            let plen: usize = shape.iter().product();
            stack.clear();
            stack.resize(d * plen, 0.0);
            for (slot, &i) in group.iter().enumerate() {
                stack[slot * plen..(slot + 1) * plen]
                    .copy_from_slice(&units[i].work.params[p].data);
            }
            let mut stacked_shape = Vec::with_capacity(shape.len() + 1);
            stacked_shape.push(d);
            stacked_shape.extend_from_slice(&shape);
            lit_params.push(literal_from_slice(&stacked_shape, stack)?);
        }
        let lr = HostTensor::scalar(self.lr).to_literal()?;

        x.resize(d * b * IMG_PIXELS, 0.0);
        y.resize(d * b * NUM_CLASSES, 0.0);
        w.resize(d * b, 0.0);
        counts.clear();
        counts.resize(group.len(), 0);
        loss.clear();
        loss.resize(group.len(), 0.0);

        for step in 0..steps {
            x.fill(0.0);
            y.fill(0.0);
            w.fill(0.0);
            for (slot, &i) in group.iter().enumerate() {
                let samples = &units[i].work.samples;
                let lo = step * b;
                counts[slot] = 0;
                if lo >= samples.len() {
                    continue; // schedule exhausted: zero-weight no-op slot
                }
                let chunk = &samples[lo..(lo + b).min(samples.len())];
                counts[slot] = chunk.len();
                stage_rows(
                    &mut x[slot * b * IMG_PIXELS..(slot + 1) * b * IMG_PIXELS],
                    &mut y[slot * b * NUM_CLASSES..(slot + 1) * b * NUM_CLASSES],
                    &mut w[slot * b..(slot + 1) * b],
                    units[i].ds,
                    chunk,
                );
            }
            let xl = literal_from_slice(&[d, b, IMG_PIXELS], x)?;
            let yl = literal_from_slice(&[d, b, NUM_CLASSES], y)?;
            let wl = literal_from_slice(&[d, b], w)?;
            let mut inputs: Vec<&xla::Literal> = lit_params.iter().collect();
            inputs.extend([&xl, &yl, &wl, &lr]);
            let mut out = exe.run_literals(&inputs)?;
            let losses = out[n_params].to_vec::<f32>()?;
            for (slot, &c) in counts.iter().enumerate() {
                if c > 0 {
                    loss[slot] += losses[slot] as f64 * c as f64;
                }
            }
            out.truncate(n_params);
            lit_params = out;
        }

        // materialize the final stacked params back into each slot
        // (straight from the literal's data — no intermediate HostTensor)
        for (p, lit) in lit_params.iter().enumerate() {
            let full = lit.to_vec::<f32>()?;
            let plen = full.len() / d;
            for (slot, &i) in group.iter().enumerate() {
                units[i].work.params[p]
                    .data
                    .copy_from_slice(&full[slot * plen..(slot + 1) * plen]);
            }
        }
        for (slot, &i) in group.iter().enumerate() {
            units[i].work.loss =
                Some((loss[slot] / units[i].work.samples.len() as f64) as f32);
        }
        Ok(())
    }

    /// Test-set accuracy of `params` (argmax over logits, computed host-side).
    pub fn evaluate(&self, params: &[HostTensor], ds: &Dataset) -> Result<f64> {
        let all: Vec<u32> = (0..ds.len() as u32).collect();
        self.evaluate_subset(params, ds, &all)
    }

    /// Accuracy over an index subset (one PJRT call per chunk — the
    /// scalar eval path, and the reference side of
    /// `tests/eval_equivalence.rs`).
    pub fn evaluate_subset(
        &self,
        params: &[HostTensor],
        ds: &Dataset,
        samples: &[u32],
    ) -> Result<f64> {
        if samples.is_empty() {
            return Ok(0.0);
        }
        // parameters converted once and shared (by reference) across chunks
        let lit_params: Vec<xla::Literal> =
            params.iter().map(HostTensor::to_literal).collect::<Result<_>>()?;
        let mut correct = 0usize;
        for chunk in samples.chunks(self.batch) {
            correct += self.count_chunk(ds, chunk, &lit_params)?;
        }
        Ok(correct as f64 / samples.len() as f64)
    }

    /// Correct predictions in one chunk through the scalar eval entry
    /// (host-side argmax over the returned logits).
    fn count_chunk(
        &self,
        ds: &Dataset,
        chunk: &[u32],
        lit_params: &[xla::Literal],
    ) -> Result<usize> {
        let xl = {
            let mut x = self.x_buf.borrow_mut();
            x.fill(0.0);
            for (row, &idx) in chunk.iter().enumerate() {
                x[row * IMG_PIXELS..(row + 1) * IMG_PIXELS]
                    .copy_from_slice(ds.image(idx as usize));
            }
            literal_from_slice(&[self.batch, IMG_PIXELS], &x)?
        };
        let mut inputs: Vec<&xla::Literal> = lit_params.iter().collect();
        inputs.push(&xl);
        let out = self.eval_exe.run_literals(&inputs)?;
        let logits = out[0].to_vec::<f32>()?;
        let mut correct = 0usize;
        for (row, &idx) in chunk.iter().enumerate() {
            let offs = row * NUM_CLASSES;
            let pred = (0..NUM_CLASSES)
                .max_by(|&a, &b| {
                    logits[offs + a].partial_cmp(&logits[offs + b]).unwrap()
                })
                .unwrap();
            if pred == ds.labels[idx as usize] as usize {
                correct += 1;
            }
        }
        Ok(correct)
    }

    /// Score a batch of one session's evaluation work units (every unit
    /// reads the same test set), honoring `path`. Thin wrapper over
    /// [`Trainer::evaluate_units`] with the per-session `Smallest` tile
    /// policy — bit-identical to the pre-coalescing behavior.
    /// `EvalPath::Scalar` — and artifact sets predating the batched eval
    /// entries — fall back to [`Trainer::evaluate_subset`] per unit,
    /// which is bit-identical to the pre-subsystem behavior.
    pub fn evaluate_many(
        &self,
        rt: &Runtime,
        ds: &Dataset,
        work: &mut [EvalWork],
        path: EvalPath,
    ) -> Result<()> {
        let b = self.batch;
        let n_units: usize =
            work.iter().map(|w| w.samples.len().div_ceil(b)).sum();
        let batched = match path {
            EvalPath::Scalar => false,
            EvalPath::Batched => true,
            EvalPath::Auto => n_units > 1,
        };
        if !batched {
            for w in work.iter_mut() {
                w.accuracy = None;
            }
            return self.eval_many_fallback(ds, work);
        }
        let mut units: Vec<EvalUnit> =
            work.iter_mut().map(|w| EvalUnit { ds, work: w }).collect();
        self.evaluate_units(rt, &mut units, TileFill::Smallest)
    }

    /// Score eval work units from any mix of origins, stacking
    /// `BATCH`-sized chunks across the device axis of the batched
    /// `*_eval_many_d<D>` entries: every slot carries one (params, chunk)
    /// pair — distinct models, or one model replicated over many chunks —
    /// and comes back as a weighted-correct count, so a full test pass
    /// costs `ceil(chunks / D)` PJRT dispatches instead of `chunks`
    /// (DESIGN.md §Perf rule 8). Each slot stages from its own unit's
    /// dataset, so one dispatch can carry several sessions' evaluations
    /// (§Perf rule 10); `fill` picks the tile policy.
    ///
    /// The stacked parameters are literal-resident across consecutive
    /// groups with the same slot→unit mapping (the common case: one model
    /// evaluated over a long chunk run). Idle pad slots carry all-zero
    /// sample weights, so they contribute exactly zero correct
    /// predictions. Artifact sets predating the batched eval entries fall
    /// back to the scalar path per unit.
    pub fn evaluate_units(
        &self,
        rt: &Runtime,
        units: &mut [EvalUnit],
        fill: TileFill,
    ) -> Result<()> {
        for u in units.iter_mut() {
            u.work.accuracy = None;
        }
        let b = self.batch;
        // flatten every unit into (unit, chunk offset) slots
        let slots: Vec<(usize, usize)> = units
            .iter()
            .enumerate()
            .flat_map(|(i, u)| {
                (0..u.work.samples.len().div_ceil(b)).map(move |c| (i, c * b))
            })
            .collect();
        let plan = plan_tiles(slots.len(), &rt.manifest.device_tiles, fill);
        if plan.is_empty() && !slots.is_empty() {
            // no compiled tiles at all: scalar per unit
            for u in units.iter_mut() {
                u.work.accuracy =
                    Some(self.evaluate_subset(&u.work.params, u.ds, &u.work.samples)?);
            }
            return Ok(());
        }

        let n_params = self.kind.num_params();
        let mut correct = vec![0f64; units.len()];
        // per-unit scalar literals, built lazily for per-group fallback
        let mut scalar_lits: Vec<Option<Vec<xla::Literal>>> =
            units.iter().map(|_| None).collect();

        let mut ms = self.many.borrow_mut();
        let ManyScratch { x, y, w: wt, stack, .. } = &mut *ms;
        let mut lit_params: Vec<xla::Literal> = Vec::new();
        let mut lit_key: (usize, Vec<usize>) = (0, Vec::new());

        let mut cursor = 0usize;
        for (count, tile) in plan {
            let group = &slots[cursor..cursor + count];
            cursor += count;
            let Some((d, exe)) = rt.eval_many_executable(self.kind, tile)?
            else {
                // this tile's entries missing (hand-pruned artifact set):
                // stay correct via the scalar path for the group
                for &(i, lo) in group {
                    if scalar_lits[i].is_none() {
                        scalar_lits[i] = Some(
                            units[i]
                                .work
                                .params
                                .iter()
                                .map(HostTensor::to_literal)
                                .collect::<Result<_>>()?,
                        );
                    }
                    let samples = &units[i].work.samples;
                    let chunk = &samples[lo..(lo + b).min(samples.len())];
                    correct[i] += self.count_chunk(
                        units[i].ds,
                        chunk,
                        scalar_lits[i].as_ref().unwrap(),
                    )? as f64;
                }
                continue;
            };

            // stack per-slot params; reuse the literals when this group's
            // slot→unit mapping matches the previous group's
            let items: Vec<usize> = group.iter().map(|&(i, _)| i).collect();
            if lit_params.is_empty() || lit_key.0 != d || lit_key.1 != items {
                lit_params.clear();
                for p in 0..n_params {
                    let shape = units[items[0]].work.params[p].shape.clone();
                    let plen: usize = shape.iter().product();
                    stack.clear();
                    stack.resize(d * plen, 0.0);
                    for (slot, &i) in items.iter().enumerate() {
                        stack[slot * plen..(slot + 1) * plen]
                            .copy_from_slice(&units[i].work.params[p].data);
                    }
                    let mut stacked_shape = Vec::with_capacity(shape.len() + 1);
                    stacked_shape.push(d);
                    stacked_shape.extend_from_slice(&shape);
                    lit_params.push(literal_from_slice(&stacked_shape, stack)?);
                }
                lit_key = (d, items);
            }

            x.resize(d * b * IMG_PIXELS, 0.0);
            y.resize(d * b * NUM_CLASSES, 0.0);
            wt.resize(d * b, 0.0);
            x.fill(0.0);
            y.fill(0.0);
            wt.fill(0.0);
            for (slot, &(i, lo)) in group.iter().enumerate() {
                let samples = &units[i].work.samples;
                let chunk = &samples[lo..(lo + b).min(samples.len())];
                stage_rows(
                    &mut x[slot * b * IMG_PIXELS..(slot + 1) * b * IMG_PIXELS],
                    &mut y[slot * b * NUM_CLASSES..(slot + 1) * b * NUM_CLASSES],
                    &mut wt[slot * b..(slot + 1) * b],
                    units[i].ds,
                    chunk,
                );
            }
            let xl = literal_from_slice(&[d, b, IMG_PIXELS], x)?;
            let yl = literal_from_slice(&[d, b, NUM_CLASSES], y)?;
            let wl = literal_from_slice(&[d, b], wt)?;
            let mut inputs: Vec<&xla::Literal> = lit_params.iter().collect();
            inputs.extend([&xl, &yl, &wl]);
            let out = exe.run_literals(&inputs)?;
            let counts = out[0].to_vec::<f32>()?;
            for (slot, &(i, _)) in group.iter().enumerate() {
                correct[i] += counts[slot] as f64;
            }
        }

        for (i, u) in units.iter_mut().enumerate() {
            u.work.accuracy = Some(if u.work.samples.is_empty() {
                0.0
            } else {
                correct[i] / u.work.samples.len() as f64
            });
        }
        Ok(())
    }

    /// Scalar execution of an eval work list (the pre-subsystem behavior,
    /// unit by unit).
    fn eval_many_fallback(&self, ds: &Dataset, work: &mut [EvalWork]) -> Result<()> {
        for w in work.iter_mut() {
            w.accuracy = Some(self.evaluate_subset(&w.params, ds, &w.samples)?);
        }
        Ok(())
    }

    /// Stage one chunk into the reusable (x, onehot, wt) buffers and build
    /// the input literals straight from the borrowed buffers — no
    /// intermediate `HostTensor` clone per chunk (DESIGN.md §Perf).
    fn stage_chunk(
        &self,
        ds: &Dataset,
        chunk: &[u32],
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        let b = self.batch;
        let mut x = self.x_buf.borrow_mut();
        let mut y = self.y_buf.borrow_mut();
        let mut w = self.w_buf.borrow_mut();
        x.fill(0.0);
        y.fill(0.0);
        w.fill(0.0);
        stage_rows(&mut x, &mut y, &mut w, ds, chunk);
        Ok((
            literal_from_slice(&[b, IMG_PIXELS], &x)?,
            literal_from_slice(&[b, NUM_CLASSES], &y)?,
            literal_from_slice(&[b], &w)?,
        ))
    }
}

/// Copy a chunk's images, one-hot labels and unit weights into the leading
/// rows of pre-zeroed staging slices (shared by the scalar path and each
/// device slot of the batched path).
fn stage_rows(x: &mut [f32], y: &mut [f32], w: &mut [f32], ds: &Dataset, chunk: &[u32]) {
    for (row, &idx) in chunk.iter().enumerate() {
        x[row * IMG_PIXELS..(row + 1) * IMG_PIXELS]
            .copy_from_slice(ds.image(idx as usize));
        y[row * NUM_CLASSES + ds.labels[idx as usize] as usize] = 1.0;
        w[row] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::SynthDigits;
    use crate::fed::eval::EvalPath;
    use crate::util::rng::Rng;

    fn setup() -> Option<(Runtime, Dataset, Dataset)> {
        let rt = crate::runtime::test_runtime()?;
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(11);
        let (train, test) = gen.train_test(2000, 500, &mut rng);
        Some((rt, train, test))
    }

    // -- pure tile planning (no runtime needed) -----------------------------

    #[test]
    fn plan_tiles_smallest_matches_legacy_grouping() {
        let tiles = [4usize, 8, 16, 32];
        // n <= max tile: one dispatch through the smallest fitting tile
        assert_eq!(plan_tiles(1, &tiles, TileFill::Smallest), vec![(1, 4)]);
        assert_eq!(plan_tiles(4, &tiles, TileFill::Smallest), vec![(4, 4)]);
        assert_eq!(plan_tiles(5, &tiles, TileFill::Smallest), vec![(5, 8)]);
        assert_eq!(plan_tiles(17, &tiles, TileFill::Smallest), vec![(17, 32)]);
        // n > max tile: chunks of the max tile, remainder smallest-fitted
        assert_eq!(
            plan_tiles(35, &tiles, TileFill::Smallest),
            vec![(32, 32), (3, 4)]
        );
        assert_eq!(
            plan_tiles(70, &tiles, TileFill::Smallest),
            vec![(32, 32), (32, 32), (6, 8)]
        );
    }

    #[test]
    fn plan_tiles_largest_is_partner_invariant() {
        let tiles = [4usize, 8, 16, 32];
        // every dispatch requests the same (largest) tile regardless of
        // slot count — the per-slot executable never varies with partners
        for n in [1usize, 3, 8, 32, 33, 100] {
            let plan = plan_tiles(n, &tiles, TileFill::Largest);
            assert!(plan.iter().all(|&(_, t)| t == 32), "{plan:?}");
            assert_eq!(plan.iter().map(|&(s, _)| s).sum::<usize>(), n);
            assert!(plan.iter().all(|&(s, _)| s <= 32));
        }
    }

    #[test]
    fn plan_tiles_degenerate_cases() {
        assert!(plan_tiles(0, &[4, 8], TileFill::Smallest).is_empty());
        assert!(plan_tiles(5, &[], TileFill::Smallest).is_empty());
        assert!(plan_tiles(5, &[], TileFill::Largest).is_empty());
        // single compiled tile
        assert_eq!(plan_tiles(5, &[4], TileFill::Smallest), vec![(4, 4), (1, 4)]);
        assert_eq!(plan_tiles(5, &[4], TileFill::Largest), vec![(4, 4), (1, 4)]);
    }

    // -- runtime-backed (skip under the pure-CPU xla stub) ------------------

    #[test]
    fn training_beats_chance_and_improves() {
        let Some((rt, train, test)) = setup() else { return };
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).unwrap();
        let mut params = rt.init_params(ModelKind::Mlp, 3).unwrap();
        let before = trainer.evaluate(&params, &test).unwrap();

        let all: Vec<u32> = (0..train.len() as u32).collect();
        let mut first_loss = None;
        let mut last_loss = 0.0;
        for epoch in 0..3 {
            let loss = trainer
                .train_interval(&mut params, &train, &all)
                .unwrap()
                .unwrap();
            if epoch == 0 {
                first_loss = Some(loss);
            }
            last_loss = loss;
        }
        let after = trainer.evaluate(&params, &test).unwrap();
        assert!(after > 0.5, "accuracy {after} not above chance enough");
        assert!(after > before + 0.2, "no improvement: {before} -> {after}");
        assert!(last_loss < first_loss.unwrap());
    }

    #[test]
    fn empty_interval_is_noop() {
        let Some((rt, train, _)) = setup() else { return };
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.01).unwrap();
        let mut params = rt.init_params(ModelKind::Mlp, 4).unwrap();
        let snapshot = params.clone();
        assert!(trainer.train_interval(&mut params, &train, &[]).unwrap().is_none());
        assert_eq!(params[0].data, snapshot[0].data);
    }

    #[test]
    fn partial_batch_trains() {
        let Some((rt, train, _)) = setup() else { return };
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).unwrap();
        let mut params = rt.init_params(ModelKind::Mlp, 5).unwrap();
        let snapshot = params.clone();
        // 5 samples << batch 32
        let loss = trainer
            .train_interval(&mut params, &train, &[0, 1, 2, 3, 4])
            .unwrap()
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_ne!(params[0].data, snapshot[0].data);
    }

    /// The batched path must reproduce the scalar path per device: ledger
    /// equivalence is exact elsewhere; params and losses agree within the
    /// tolerance documented in DESIGN.md §Perf rule 7.
    #[test]
    fn batched_interval_matches_scalar() {
        let Some((rt, train, _)) = setup() else { return };
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).unwrap();
        // ragged workloads: different sizes, one spanning multiple chunks,
        // one empty (must come back loss=None, params untouched)
        let sample_sets: Vec<Vec<u32>> = vec![
            (0..70).collect(),
            (100..117).collect(),
            Vec::new(),
            (200..232).collect(),
            (300..305).collect(),
        ];
        let mut work: Vec<DeviceWork> = sample_sets
            .iter()
            .enumerate()
            .map(|(k, s)| DeviceWork {
                params: rt.init_params(ModelKind::Mlp, 40 + k as u64).unwrap(),
                samples: s.clone(),
                loss: None,
            })
            .collect();
        let mut scalar_params: Vec<_> =
            work.iter().map(|w| w.params.clone()).collect();

        trainer.train_interval_many(&rt, &train, &mut work).unwrap();

        for (k, w) in work.iter().enumerate() {
            let loss = trainer
                .train_interval(&mut scalar_params[k], &train, &sample_sets[k])
                .unwrap();
            match (loss, w.loss) {
                (None, None) => {
                    assert_eq!(w.params[0].data, scalar_params[k][0].data);
                }
                (Some(ls), Some(lb)) => {
                    assert!(
                        (ls - lb).abs() <= 1e-5 * (1.0 + ls.abs()),
                        "device {k}: loss {ls} vs {lb}"
                    );
                    for (p, (a, b)) in
                        w.params.iter().zip(&scalar_params[k]).enumerate()
                    {
                        let max_diff = a
                            .data
                            .iter()
                            .zip(&b.data)
                            .map(|(x, y)| (x - y).abs())
                            .fold(0f32, f32::max);
                        assert!(
                            max_diff <= 1e-4,
                            "device {k} param {p}: max diff {max_diff}"
                        );
                    }
                }
                other => panic!("device {k}: loss mismatch {other:?}"),
            }
        }
    }

    /// Cross-origin units: the same slot input must produce bit-identical
    /// results under `TileFill::Largest` no matter which partner slots
    /// (from another dataset) share the dispatch — the coalescing
    /// scheduler's §Perf rule 10 contract at the trainer level.
    #[test]
    fn largest_fill_units_are_partner_invariant() {
        let Some((rt, train, test)) = setup() else { return };
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).unwrap();
        let samples: Vec<u32> = (0..50).collect();
        let mk = |seed: u64| DeviceWork {
            params: rt.init_params(ModelKind::Mlp, seed).unwrap(),
            samples: samples.clone(),
            loss: None,
        };

        // alone: one unit through the largest tile
        let mut alone = mk(77);
        {
            let mut units = vec![TrainUnit { ds: &train, work: &mut alone }];
            trainer
                .train_interval_units(&rt, &mut units, TileFill::Largest)
                .unwrap();
        }

        // with partners: same unit packed beside units from ANOTHER
        // dataset (`test` doubles as a second session's train split here)
        let mut together = mk(77);
        let mut partner_a = mk(78);
        let mut partner_b = DeviceWork {
            params: rt.init_params(ModelKind::Mlp, 79).unwrap(),
            samples: (0..90).collect(), // longer schedule: extra lock-steps
            loss: None,
        };
        {
            let mut units = vec![
                TrainUnit { ds: &test, work: &mut partner_a },
                TrainUnit { ds: &train, work: &mut together },
                TrainUnit { ds: &test, work: &mut partner_b },
            ];
            trainer
                .train_interval_units(&rt, &mut units, TileFill::Largest)
                .unwrap();
        }

        assert_eq!(alone.loss, together.loss, "loss not partner-invariant");
        for (p, (a, b)) in alone.params.iter().zip(&together.params).enumerate() {
            assert_eq!(a.data, b.data, "param {p} not partner-invariant");
        }

        // and the eval twin: a unit's accuracy is invariant to partners
        let full: Vec<u32> = (0..test.len() as u32).collect();
        let mut ew_alone = EvalWork {
            params: alone.params.clone(),
            samples: full.clone(),
            accuracy: None,
        };
        {
            let mut units = vec![EvalUnit { ds: &test, work: &mut ew_alone }];
            trainer.evaluate_units(&rt, &mut units, TileFill::Largest).unwrap();
        }
        let mut ew_together = EvalWork {
            params: alone.params.clone(),
            samples: full.clone(),
            accuracy: None,
        };
        let mut ew_partner = EvalWork {
            params: partner_a.params.clone(),
            samples: (0..200).collect(),
            accuracy: None,
        };
        {
            let mut units = vec![
                EvalUnit { ds: &train, work: &mut ew_partner },
                EvalUnit { ds: &test, work: &mut ew_together },
            ];
            trainer.evaluate_units(&rt, &mut units, TileFill::Largest).unwrap();
        }
        assert_eq!(ew_alone.accuracy, ew_together.accuracy);
    }

    /// Batched eval must agree with the scalar path per work item within
    /// the DESIGN.md §Perf rule 7 accuracy tolerance, across ragged
    /// sample sets (multi-chunk, partial-chunk, empty) and distinct
    /// parameter sets — including a unit count past the largest tile.
    #[test]
    fn batched_eval_matches_scalar() {
        let Some((rt, train, test)) = setup() else { return };
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).unwrap();
        // lightly train one model so logits are not near-uniform
        let mut trained = rt.init_params(ModelKind::Mlp, 21).unwrap();
        let all: Vec<u32> = (0..train.len() as u32).collect();
        trainer.train_interval(&mut trained, &train, &all).unwrap();

        let max_tile = *rt.manifest.device_tiles.last().unwrap();
        let full: Vec<u32> = (0..test.len() as u32).collect();
        let sample_sets: Vec<Vec<u32>> = vec![
            full.clone(),
            full.clone(),
            (0..17).collect(),
            Vec::new(),
            (100..260).collect(),
        ];
        // the unit total must exceed the largest tile so the group split
        // (and the stacked-literal rebuild across groups) is exercised
        let units: usize =
            sample_sets.iter().map(|s| s.len().div_ceil(rt.batch())).sum();
        assert!(units > max_tile, "{units} units <= tile {max_tile}");
        let mut work: Vec<EvalWork> = sample_sets
            .iter()
            .enumerate()
            .map(|(k, s)| EvalWork {
                params: if k == 0 {
                    trained.clone()
                } else {
                    rt.init_params(ModelKind::Mlp, 60 + k as u64).unwrap()
                },
                samples: s.clone(),
                accuracy: None,
            })
            .collect();

        trainer
            .evaluate_many(&rt, &test, &mut work, EvalPath::Batched)
            .unwrap();
        for (k, w) in work.iter().enumerate() {
            let scalar = trainer
                .evaluate_subset(&w.params, &test, &sample_sets[k])
                .unwrap();
            let batched = w.accuracy.unwrap();
            if sample_sets[k].is_empty() {
                assert_eq!(batched, 0.0, "item {k}");
            }
            assert!(
                (scalar - batched).abs() <= 5e-3,
                "item {k}: scalar {scalar} vs batched {batched}"
            );
        }

        // the scalar route through evaluate_many is bit-identical to
        // evaluate_subset (it IS evaluate_subset per unit)
        let mut scalar_work: Vec<EvalWork> = sample_sets
            .iter()
            .zip(&work)
            .map(|(s, w)| EvalWork {
                params: w.params.clone(),
                samples: s.clone(),
                accuracy: None,
            })
            .collect();
        trainer
            .evaluate_many(&rt, &test, &mut scalar_work, EvalPath::Scalar)
            .unwrap();
        for (k, w) in scalar_work.iter().enumerate() {
            let want = trainer
                .evaluate_subset(&w.params, &test, &sample_sets[k])
                .unwrap();
            assert_eq!(w.accuracy.unwrap(), want, "item {k}");
        }
    }

    /// Auto routing: a single sub-chunk unit takes the scalar path (no
    /// tile padding for one call), everything larger stacks — both must
    /// produce accuracies, and the single-unit case bit-matches scalar.
    #[test]
    fn eval_auto_single_chunk_is_scalar_exact() {
        let Some((rt, _train, test)) = setup() else { return };
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).unwrap();
        let params = rt.init_params(ModelKind::Mlp, 2).unwrap();
        let small: Vec<u32> = (0..20).collect();
        let mut work = vec![EvalWork {
            params: params.clone(),
            samples: small.clone(),
            accuracy: None,
        }];
        trainer
            .evaluate_many(&rt, &test, &mut work, EvalPath::Auto)
            .unwrap();
        let want = trainer.evaluate_subset(&params, &test, &small).unwrap();
        assert_eq!(work[0].accuracy.unwrap(), want);
    }

    /// More devices than the largest compiled tile: the trainer must split
    /// into several stacked executions and still update every device.
    #[test]
    fn batched_interval_splits_oversized_groups() {
        let Some((rt, train, _)) = setup() else { return };
        let trainer = Trainer::new(&rt, ModelKind::Mlp, 0.05).unwrap();
        let max_tile = *rt.manifest.device_tiles.last().unwrap();
        let n = max_tile + 3;
        let mut work: Vec<DeviceWork> = (0..n)
            .map(|k| DeviceWork {
                params: rt.init_params(ModelKind::Mlp, 7).unwrap(),
                samples: vec![(k % 64) as u32, (k % 64) as u32 + 1],
                loss: None,
            })
            .collect();
        let before = work[0].params[0].data.clone();
        trainer.train_interval_many(&rt, &train, &mut work).unwrap();
        for (k, w) in work.iter().enumerate() {
            assert!(w.loss.unwrap().is_finite(), "device {k}");
            assert_ne!(w.params[0].data, before, "device {k} did not train");
        }
    }
}
