//! Evaluation planning and batched evaluation work units — the eval-side
//! mirror of the PR-2 train-path refactor (DESIGN.md §Perf rule 8).
//!
//! Two orthogonal knobs govern how a session evaluates:
//!
//! * **What** to evaluate per curve point — [`EvalSchedule`]: the classic
//!   full test pass per aggregation, or a seeded [`EvalSchedule::Subset`]
//!   rotation of deterministic test shards (≈`shards`× cheaper curves at
//!   matched noise; the shard rotation covers the whole test set every
//!   `shards` aggregations, so curve bias averages out across points).
//!   [`EvalPlan`] materializes the schedule for one run.
//! * **How** to execute it — [`EvalPath`]: one PJRT call per `BATCH`
//!   chunk (`Scalar`, bit-identical to the pre-subsystem `eval_curve`),
//!   or chunks stacked into `[D × BATCH]` executions of the batched
//!   `*_eval_many_d<D>` entries (`Batched`; `Auto` picks stacking
//!   whenever more than one chunk is in flight).
//!
//! [`EvalWork`] is the transport unit (the eval twin of
//! [`crate::fed::trainer::DeviceWork`]): one parameter set plus the test
//! indices to score it on. A work list travels through
//! [`crate::fed::session::Compute::evaluate_many`] — a scalar loop by
//! default, stacked on PJRT-backed backends, and one `EvalMany` service
//! round-trip per call for pooled sessions.

use anyhow::{anyhow, bail, Result};

use crate::data::dataset::Dataset;
use crate::fed::session::{Compute, Params};
use crate::runtime::HostTensor;
use crate::util::rng::Rng;

/// Which test samples a curve point evaluates (CLI `--eval-schedule`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvalSchedule {
    /// Full test pass at every aggregation — the historical behavior.
    #[default]
    Full,
    /// Rotate over `shards` seeded, disjoint test shards, one per curve
    /// point: each evaluation costs `1/shards` of a full pass; every
    /// sample is still visited once per `shards` aggregations.
    Subset { shards: usize },
}

impl EvalSchedule {
    /// Default shard count for a bare `subset` (≈5× cheaper curves).
    pub const DEFAULT_SHARDS: usize = 5;

    /// Parse `full`, `subset` or `subset:K` (K ≥ 2).
    pub fn parse(s: &str) -> Result<Self> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "full" => Ok(EvalSchedule::Full),
            "subset" => Ok(EvalSchedule::Subset { shards: Self::DEFAULT_SHARDS }),
            _ => match lower.strip_prefix("subset:") {
                Some(k) => {
                    let shards: usize = k
                        .parse()
                        .map_err(|e| anyhow::anyhow!("--eval-schedule subset:{k}: {e}"))?;
                    if shards < 2 {
                        bail!("subset schedule needs at least 2 shards (got {shards})");
                    }
                    Ok(EvalSchedule::Subset { shards })
                }
                None => bail!("unknown eval schedule '{s}' (want full|subset|subset:K)"),
            },
        }
    }
}

/// Which execution path evaluation takes (CLI `--eval-path`), mirroring
/// [`crate::config::TrainPath`] for the train side. Routing is a perf
/// decision: batched and scalar agree within the DESIGN.md §Perf rule 7
/// accuracy tolerance (`tests/eval_equivalence.rs`). Unlike the train
/// side, the *default* is `Scalar`: curves are reported artifacts, and
/// the scalar path keeps them bit-identical to the pre-subsystem
/// `eval_curve` under unchanged configs — stacking is one flag away.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvalPath {
    /// Stacked whenever an evaluation spans more than one `BATCH` chunk,
    /// scalar otherwise.
    Auto,
    /// Always stack chunks into the batched `*_eval_many_d<D>` entry.
    Batched,
    /// One PJRT call per chunk — bit-identical to the pre-subsystem
    /// `eval_curve` (the default), and the reference side of
    /// `tests/eval_equivalence.rs`.
    #[default]
    Scalar,
}

impl EvalPath {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(EvalPath::Auto),
            "batched" => Ok(EvalPath::Batched),
            "scalar" => Ok(EvalPath::Scalar),
            other => bail!("unknown eval path '{other}' (want auto|batched|scalar)"),
        }
    }
}

/// One evaluation work unit: score `params` over the test indices in
/// `samples`. The eval twin of [`crate::fed::trainer::DeviceWork`] — the
/// executor fills `accuracy` (`Some(0.0)` for an empty sample list, like
/// `Trainer::evaluate_subset`).
#[derive(Debug, Default)]
pub struct EvalWork {
    pub params: Vec<HostTensor>,
    pub samples: Vec<u32>,
    pub accuracy: Option<f64>,
}

/// A batched eval work unit from any origin: the test set its chunks
/// stage from plus the work, scored in place. The eval twin of
/// [`crate::fed::trainer::TrainUnit`] — one stacked dispatch can mix
/// evaluations from several sessions' test sets (the coalescing
/// runtime-service scheduler, DESIGN.md §Perf rule 10).
pub struct EvalUnit<'a> {
    pub ds: &'a Dataset,
    pub work: &'a mut EvalWork,
}

/// A run's materialized evaluation schedule: which test indices each
/// curve point scores. Derived deterministically from `(schedule, n_test,
/// seed)` alone, so serial and pooled runs of the same config share the
/// exact same shards (`tests/determinism.rs`).
#[derive(Debug, Clone)]
pub struct EvalPlan {
    shards: Vec<Vec<u32>>,
}

/// Domain-separation constant for the shard shuffle: the plan draws from
/// its own `Rng::new(seed ^ EVAL_PLAN_SALT)` stream so introducing the
/// planner does not perturb the load-bearing RNG split order of
/// [`crate::fed::session::Substrates::derive`].
const EVAL_PLAN_SALT: u64 = 0xE7A1_5C0F_D157_0BEB;

impl EvalPlan {
    /// Materialize a schedule over a test set of `n_test` samples.
    ///
    /// `Subset` shards are a seeded permutation of the test indices cut
    /// into `shards` near-equal slices (sizes differ by at most one), so
    /// every index appears in exactly one shard and each shard is an
    /// unbiased sample of the test distribution.
    pub fn new(schedule: EvalSchedule, n_test: usize, seed: u64) -> EvalPlan {
        let shards = match schedule {
            EvalSchedule::Full => vec![(0..n_test as u32).collect()],
            EvalSchedule::Subset { shards } => {
                let mut idx: Vec<u32> = (0..n_test as u32).collect();
                let mut rng = Rng::new(seed ^ EVAL_PLAN_SALT);
                rng.shuffle(&mut idx);
                let k = shards.max(1).min(n_test.max(1));
                // near-equal contiguous slices of the permutation
                let base = n_test / k;
                let extra = n_test % k;
                let mut out = Vec::with_capacity(k);
                let mut lo = 0usize;
                for s in 0..k {
                    let len = base + usize::from(s < extra);
                    let mut shard = idx[lo..lo + len].to_vec();
                    // sorted within the shard: chunk staging walks the
                    // dataset in index order (cache-friendlier, and the
                    // accuracy is order-invariant)
                    shard.sort_unstable();
                    out.push(shard);
                    lo += len;
                }
                out
            }
        };
        EvalPlan { shards }
    }

    /// Whether this plan evaluates the full test set at every point.
    pub fn is_full(&self) -> bool {
        self.shards.len() == 1
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The test indices the `k`-th curve point scores (rotating through
    /// the shards).
    pub fn shard(&self, k: usize) -> &[u32] {
        &self.shards[k % self.shards.len()]
    }
}

/// Score one curve point through a [`Compute`] backend: the `k`-th shard
/// of the plan against `global`, in a single `evaluate_many` dispatch
/// (one `EvalMany` round-trip on pooled backends). The parameters are
/// swapped into the reusable `work` buffer for the duration of the call
/// — no per-point clone. Like the train dispatch, the swap-back runs on
/// the error path too, but a failed service round-trip loses the
/// in-flight params; the error aborts the run.
pub fn curve_point<C: Compute>(
    compute: &C,
    plan: &EvalPlan,
    path: EvalPath,
    work: &mut Vec<EvalWork>,
    global: &mut Params,
    k: usize,
) -> Result<f64> {
    if work.is_empty() {
        work.push(EvalWork::default());
    }
    let w = &mut work[0];
    w.samples.clear();
    w.samples.extend_from_slice(plan.shard(k));
    w.accuracy = None;
    std::mem::swap(&mut w.params, global);
    let res = compute.evaluate_many(&mut work[..1], path);
    std::mem::swap(&mut work[0].params, global);
    res?;
    work[0]
        .accuracy
        .ok_or_else(|| anyhow!("evaluate_many left accuracy unset"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parses() {
        assert_eq!(EvalSchedule::parse("full").unwrap(), EvalSchedule::Full);
        assert_eq!(
            EvalSchedule::parse("Subset").unwrap(),
            EvalSchedule::Subset { shards: EvalSchedule::DEFAULT_SHARDS }
        );
        assert_eq!(
            EvalSchedule::parse("subset:4").unwrap(),
            EvalSchedule::Subset { shards: 4 }
        );
        assert!(EvalSchedule::parse("subset:1").is_err());
        assert!(EvalSchedule::parse("subset:x").is_err());
        assert!(EvalSchedule::parse("half").is_err());
        assert_eq!(EvalSchedule::default(), EvalSchedule::Full);
    }

    #[test]
    fn path_parses() {
        assert_eq!(EvalPath::parse("auto").unwrap(), EvalPath::Auto);
        assert_eq!(EvalPath::parse("Batched").unwrap(), EvalPath::Batched);
        assert_eq!(EvalPath::parse("scalar").unwrap(), EvalPath::Scalar);
        assert!(EvalPath::parse("vectorized").is_err());
        // Scalar by default: reported curves stay bit-identical across
        // releases unless stacking is explicitly requested
        assert_eq!(EvalPath::default(), EvalPath::Scalar);
    }

    #[test]
    fn full_plan_is_identity() {
        let plan = EvalPlan::new(EvalSchedule::Full, 100, 7);
        assert!(plan.is_full());
        assert_eq!(plan.num_shards(), 1);
        let all: Vec<u32> = (0..100).collect();
        for k in 0..5 {
            assert_eq!(plan.shard(k), &all[..]);
        }
    }

    #[test]
    fn subset_plan_partitions_and_rotates() {
        let n = 103;
        let k = 5;
        let plan = EvalPlan::new(EvalSchedule::Subset { shards: k }, n, 42);
        assert!(!plan.is_full());
        assert_eq!(plan.num_shards(), k);
        // disjoint cover of 0..n with near-equal sizes
        let mut seen: Vec<u32> = Vec::new();
        for s in 0..k {
            let shard = plan.shard(s);
            assert!(shard.len() == n / k || shard.len() == n / k + 1, "{}", shard.len());
            assert!(shard.windows(2).all(|w| w[0] < w[1]), "shard not sorted");
            seen.extend_from_slice(shard);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..n as u32).collect::<Vec<_>>());
        // rotation wraps
        assert_eq!(plan.shard(k), plan.shard(0));
        assert_eq!(plan.shard(2 * k + 3), plan.shard(3));
    }

    #[test]
    fn subset_plan_is_seed_deterministic() {
        let a = EvalPlan::new(EvalSchedule::Subset { shards: 4 }, 200, 9);
        let b = EvalPlan::new(EvalSchedule::Subset { shards: 4 }, 200, 9);
        let c = EvalPlan::new(EvalSchedule::Subset { shards: 4 }, 200, 10);
        for s in 0..4 {
            assert_eq!(a.shard(s), b.shard(s));
        }
        assert!((0..4).any(|s| a.shard(s) != c.shard(s)));
    }

    #[test]
    fn degenerate_sizes_stay_sane() {
        // more shards than samples: clamp to one sample per shard
        let plan = EvalPlan::new(EvalSchedule::Subset { shards: 8 }, 3, 1);
        assert_eq!(plan.num_shards(), 3);
        // empty test set: a single empty shard, never a panic
        let empty = EvalPlan::new(EvalSchedule::Subset { shards: 4 }, 0, 1);
        assert_eq!(empty.num_shards(), 1);
        assert!(empty.shard(0).is_empty());
        let full_empty = EvalPlan::new(EvalSchedule::Full, 0, 1);
        assert!(full_empty.shard(7).is_empty());
    }
}
