//! Federated learning engine: local updates (eq. 3), weighted aggregation
//! (eq. 4), movement-integrated time-interval loop, cost accounting and
//! data-similarity metrics.
//!
//! The loop itself lives in [`session`] as an explicit state machine over a
//! pluggable [`session::Compute`] backend; [`engine`] is the thin
//! single-threaded compatibility wrapper ([`run`]); [`eval`] owns the
//! evaluation subsystem (schedules, plans and batched eval work units).

pub mod accounting;
pub mod aggregator;
pub mod engine;
pub mod eval;
pub mod participation;
pub mod session;
pub mod similarity;
pub mod trainer;

pub use accounting::{IntervalStats, Ledger, MovementTotals};
pub use engine::{run, EngineOutput};
pub use eval::{EvalPath, EvalPlan, EvalSchedule, EvalUnit, EvalWork};
pub use participation::{ParticipationCosts, ParticipationSchedule, ParticipationState};
pub use session::{Compute, LocalCompute, Session, SessionState, Substrates};
pub use trainer::{DeviceWork, TileFill, TrainUnit, Trainer};
