//! Federated learning engine: local updates (eq. 3), weighted aggregation
//! (eq. 4), movement-integrated time-interval loop, cost accounting and
//! data-similarity metrics.

pub mod accounting;
pub mod aggregator;
pub mod engine;
pub mod similarity;
pub mod trainer;

pub use accounting::{IntervalStats, Ledger, MovementTotals};
pub use engine::{run, EngineOutput};
pub use trainer::Trainer;
