//! Compatibility wrapper over the session-based engine.
//!
//! Historically this module held the full ~500-line time-interval loop of
//! §III. That loop now lives in [`crate::fed::session`] as an explicit
//! state machine ([`Session`](crate::fed::session::Session)) with
//! preallocated per-interval workspaces, trainable through any
//! [`Compute`](crate::fed::session::Compute) backend — the borrowed
//! single-thread [`Trainer`] here, or the runtime-service handle used by
//! [`crate::coordinator::pool::SimPool`] for parallel (config, seed)
//! fan-out.
//!
//! `run` keeps its original signature: one call = one experiment run (one
//! cell of a paper table, one point of a figure). With
//! `TrainPath::Scalar` it is bit-identical to the pre-session engine
//! under the same seed; the default `TrainPath::Auto` routes
//! multi-trainee intervals through the stacked multi-device entry, which
//! is equivalent within the tolerance documented in DESIGN.md §Perf
//! rule 7 (`tests/batched_equivalence.rs`).

use anyhow::Result;

use crate::config::EngineConfig;
use crate::fed::session::{self, LocalCompute, Substrates};
use crate::fed::trainer::Trainer;
use crate::runtime::Runtime;

pub use crate::fed::session::{EngineOutput, TASK_SEED};

/// Run one experiment on the calling thread's runtime (the classic
/// single-threaded fast path).
pub fn run(cfg: &EngineConfig, rt: &Runtime) -> Result<EngineOutput> {
    let sub = Substrates::derive(cfg);
    let trainer = Trainer::new(rt, cfg.model, cfg.lr)?;
    let compute = LocalCompute { rt, trainer: &trainer, train: &sub.train, test: &sub.test };
    session::run_with(cfg, &sub, compute)
}
