//! The network-aware federated learning engine: the full time-interval loop
//! of §III integrating data collection, the movement optimization (§III-C),
//! local gradient updates (eq. 3), weighted aggregation (eq. 4), cost
//! accounting, and §V-E churn semantics.
//!
//! One call to [`run`] = one experiment run (one cell of a paper table, one
//! point of a figure).
//!
//! Churn semantics (worst case, §V-E): an exiting device loses the local
//! updates it accumulated since the last aggregation (it "cannot transmit
//! its local update results prior to exiting"); a re-entering device
//! participates in data collection and movement immediately, but trains
//! and contributes only after it re-synchronizes at the end of the ongoing
//! aggregation period.

use anyhow::Result;

use crate::config::{CapacityPolicy, Churn, EngineConfig, InfoMode, Method, TopologyKind};
use crate::costs::{estimator, traces, CapacityMode, CostSchedule};
use crate::data::dataset::Dataset;
use crate::data::{Partitioner, SynthDigits};
use crate::fed::accounting::{IntervalStats, Ledger, MovementTotals};
use crate::fed::aggregator;
use crate::fed::similarity;
use crate::fed::trainer::Trainer;
use crate::movement::{self, MovementPlan, MovementProblem};
use crate::runtime::{HostTensor, Runtime};
use crate::topology::{generators, ChurnProcess, Graph};
use crate::util::rng::Rng;

/// Everything an experiment driver needs from one run.
#[derive(Debug, Clone)]
pub struct EngineOutput {
    /// Final test accuracy of the global model.
    pub accuracy: f64,
    /// Test accuracy after each aggregation `(t, acc)` (if `eval_curve`).
    pub accuracy_curve: Vec<(usize, f64)>,
    /// Per-interval, per-device training loss (None when the device did
    /// not train that interval) — Fig. 4a.
    pub per_device_loss: Vec<Vec<Option<f32>>>,
    pub ledger: Ledger,
    pub movement: MovementTotals,
    /// Mean pairwise label similarity (before movement, after movement) —
    /// Fig. 4b.
    pub similarity: (f64, f64),
    /// Mean active devices per interval (Table V / Figs. 9–10).
    pub mean_active: f64,
    /// Total datapoints collected by active devices.
    pub total_collected: usize,
}

/// Fixed generator seed for the SynthDigits class prototypes: the *task*
/// is identical across all experiments; per-run seeds control sampling,
/// partitioning, costs, topology and churn.
const TASK_SEED: u64 = 0xF0D5;

/// Run one experiment.
pub fn run(cfg: &EngineConfig, rt: &Runtime) -> Result<EngineOutput> {
    let mut root = Rng::new(cfg.seed);
    let mut data_rng = root.split();
    let mut topo_rng = root.split();
    let mut cost_rng = root.split();
    let mut churn_rng = root.split();
    let init_seed = root.next_u64();

    // --- substrates --------------------------------------------------------
    let gen = SynthDigits::new(TASK_SEED);
    let (train, test) = gen.train_test(cfg.n_train, cfg.n_test, &mut data_rng);
    let arrivals = Partitioner { n_devices: cfg.n, t_max: cfg.t_max, iid: cfg.iid }
        .partition(&train, &mut data_rng);

    let mut actual_costs = traces::generate(
        cfg.cost_source,
        cfg.n,
        cfg.t_max,
        cfg.tau,
        cfg.error_profile,
        &mut cost_rng,
    );
    if let CapacityPolicy::MeanArrivals = cfg.capacity {
        actual_costs.set_capacities(CapacityMode::Uniform(cfg.mean_arrivals()));
    }
    let mut belief_costs: CostSchedule = match cfg.info {
        InfoMode::Perfect => actual_costs.clone(),
        InfoMode::Estimated(w) => estimator::estimate(&actual_costs, w),
    };
    if cfg.discard_model == crate::movement::DiscardModel::Sqrt {
        // γ-rescaling for the convex error model (see ErrorWeightProfile)
        for t in 0..cfg.t_max {
            for i in 0..cfg.n {
                belief_costs.error_weight[t][i] *= cfg.error_profile.sqrt_gamma_scale;
            }
        }
    }

    let graph = build_topology(cfg, &actual_costs, &mut topo_rng);
    let mut churn = match cfg.churn {
        Some(Churn { p_exit, p_entry }) => ChurnProcess::new(cfg.n, p_exit, p_entry),
        None => ChurnProcess::static_network(cfg.n),
    };

    let trainer = Trainer::new(rt, cfg.model, cfg.lr)?;
    let mut global: Vec<HostTensor> = rt.init_params(cfg.model, init_seed)?;

    match cfg.method {
        Method::Centralized => run_centralized(cfg, rt, &trainer, global, &train, &test, &arrivals),
        _ => run_distributed(
            cfg,
            &trainer,
            &mut global,
            &train,
            &test,
            &arrivals,
            &actual_costs,
            &belief_costs,
            &graph,
            &mut churn,
            &mut churn_rng,
        ),
    }
}

fn build_topology(cfg: &EngineConfig, costs: &CostSchedule, rng: &mut Rng) -> Graph {
    match cfg.topology {
        TopologyKind::Full => generators::fully_connected(cfg.n),
        TopologyKind::Random(rho) => generators::erdos_renyi(cfg.n, rho, rng),
        TopologyKind::SmallWorld => {
            generators::watts_strogatz(cfg.n, (cfg.n / 5).max(2), 0.3, rng)
        }
        TopologyKind::Hierarchical => {
            generators::hierarchical(cfg.n, &costs.mean_compute_per_device(), rng)
        }
        TopologyKind::ScaleFree => generators::scale_free(cfg.n, 2, rng),
    }
}

/// Centralized baseline: all collected data is processed at one server;
/// no movement, no network costs (accuracy comparison only, Table II).
fn run_centralized(
    cfg: &EngineConfig,
    _rt: &Runtime,
    trainer: &Trainer,
    mut params: Vec<HostTensor>,
    train: &Dataset,
    test: &Dataset,
    arrivals: &crate::data::Arrivals,
) -> Result<EngineOutput> {
    let mut per_device_loss = vec![vec![None; cfg.n]; cfg.t_max];
    let mut collected = 0usize;
    let mut curve = Vec::new();
    for t in 0..cfg.t_max {
        let mut batch: Vec<u32> = Vec::new();
        for i in 0..cfg.n {
            batch.extend(&arrivals.schedule[i][t]);
        }
        collected += batch.len();
        if let Some(loss) = trainer.train_interval(&mut params, train, &batch)? {
            per_device_loss[t][0] = Some(loss);
        }
        if cfg.eval_curve && (t + 1) % cfg.tau == 0 {
            curve.push((t + 1, trainer.evaluate(&params, test)?));
        }
    }
    let accuracy = trainer.evaluate(&params, test)?;
    Ok(EngineOutput {
        accuracy,
        accuracy_curve: curve,
        per_device_loss,
        ledger: Ledger::default(),
        movement: MovementTotals::default(),
        similarity: (1.0, 1.0),
        mean_active: cfg.n as f64,
        total_collected: collected,
    })
}

#[allow(clippy::too_many_arguments)]
fn run_distributed(
    cfg: &EngineConfig,
    trainer: &Trainer,
    global: &mut Vec<HostTensor>,
    train: &Dataset,
    test: &Dataset,
    arrivals: &crate::data::Arrivals,
    actual_costs: &CostSchedule,
    belief_costs: &CostSchedule,
    graph: &Graph,
    churn: &mut ChurnProcess,
    churn_rng: &mut Rng,
) -> Result<EngineOutput> {
    let n = cfg.n;
    let mut device_params: Vec<Vec<HostTensor>> = vec![global.clone(); n];
    let mut synced = vec![true; n];
    let mut h = vec![0f64; n]; // datapoints processed since last aggregation
    let mut inbound: Vec<Vec<u32>> = vec![Vec::new(); n]; // received last interval
    let mut per_device_loss = vec![vec![None; n]; cfg.t_max];
    let mut ledger = Ledger::default();
    let mut movement_totals = MovementTotals::default();
    let mut curve = Vec::new();

    // similarity bookkeeping: collected vs processed label multisets
    let mut collected_per_device: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut processed_per_device: Vec<Vec<u32>> = vec![Vec::new(); n];

    for t in 0..cfg.t_max {
        // --- churn ----------------------------------------------------------
        let entered = churn.step(churn_rng);
        for &i in &entered {
            synced[i] = false;
            h[i] = 0.0;
        }
        let active: Vec<bool> = churn.active().to_vec();

        // a device that exited loses unsent updates: reset its weight
        for i in 0..n {
            if !active[i] {
                h[i] = 0.0;
            }
        }

        // --- data collection --------------------------------------------------
        let mut new_data: Vec<Vec<u32>> = (0..n)
            .map(|i| if active[i] { arrivals.schedule[i][t].clone() } else { Vec::new() })
            .collect();
        for (i, samples) in new_data.iter().enumerate() {
            collected_per_device[i].extend(samples);
        }

        // --- movement optimization --------------------------------------------
        let d: Vec<f64> = new_data.iter().map(|s| s.len() as f64).collect();
        let inbound_counts: Vec<f64> = inbound.iter().map(|s| s.len() as f64).collect();
        let restricted = graph.restrict(&active);
        let plan = match cfg.method {
            Method::NetworkAware => {
                let problem = MovementProblem {
                    t,
                    graph: &restricted,
                    active: &active,
                    d: &d,
                    inbound_prev: &inbound_counts,
                    costs: belief_costs,
                    discard_model: cfg.discard_model,
                };
                movement::solve(&problem)
            }
            Method::Federated => MovementPlan::keep_all(n),
            Method::Centralized => unreachable!(),
        };

        // --- materialize the plan into integer sample movements ---------------
        let mut pending: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut stats = IntervalStats::default();
        for i in 0..n {
            let samples = std::mem::take(&mut new_data[i]);
            stats.collected += samples.len();
            if samples.is_empty() {
                continue;
            }
            let alloc = apportion(&plan, i, samples.len());
            let mut cursor = 0usize;
            // kept locally
            let keep = &samples[cursor..cursor + alloc.keep];
            cursor += alloc.keep;
            // offloads, ascending j (deterministic)
            for &(j, count) in &alloc.offloads {
                let sent = &samples[cursor..cursor + count];
                cursor += count;
                pending[j].extend_from_slice(sent);
                stats.offloaded += count;
                ledger.transfer += count as f64 * actual_costs.c_link(t, i, j);
            }
            // discards
            let dropped = samples.len() - cursor;
            stats.discarded += dropped;
            ledger.discard += dropped as f64 * actual_costs.f(t, i);
            // local processing queue = kept + inbound from last interval
            new_data[i] = keep.to_vec();
        }

        // --- local updates -----------------------------------------------------
        for i in 0..n {
            let mut workload = std::mem::take(&mut inbound[i]);
            workload.extend(&new_data[i]);
            if workload.is_empty() || !active[i] {
                // inactive devices drop their queue (worst case: data at an
                // exited device is unreachable); its discard cost is charged
                // since the network loses those points.
                if !workload.is_empty() && !active[i] {
                    ledger.discard += workload.len() as f64 * actual_costs.f(t, i);
                    stats.discarded += workload.len();
                }
                continue;
            }
            stats.processed += workload.len();
            ledger.process += workload.len() as f64 * actual_costs.c_node(t, i);
            processed_per_device[i].extend(&workload);
            if synced[i] {
                if let Some(loss) = trainer.train_interval(&mut device_params[i], train, &workload)? {
                    per_device_loss[t][i] = Some(loss);
                    h[i] += workload.len() as f64;
                }
            }
            // unsynced devices process data (it is consumed) but their stale
            // update cannot be used — the processed points still count
            // toward resource usage, not toward aggregation weight.
        }
        inbound = pending;
        movement_totals.push(stats);

        // --- aggregation ---------------------------------------------------------
        if (t + 1) % cfg.tau == 0 {
            let contributions: Vec<(&Vec<HostTensor>, f64)> = (0..n)
                .filter(|&i| active[i] && synced[i])
                .map(|i| (&device_params[i], h[i]))
                .collect();
            if let Some(new_global) = aggregator::aggregate(&contributions) {
                *global = new_global;
            }
            for i in 0..n {
                if active[i] {
                    device_params[i] = global.clone();
                    synced[i] = true;
                }
                h[i] = 0.0;
            }
            if cfg.eval_curve {
                curve.push((t + 1, trainer.evaluate(global, test)?));
            }
        }
    }

    let accuracy = trainer.evaluate(global, test)?;
    let sim_before =
        similarity::mean_similarity(&similarity::label_histograms(train, &collected_per_device));
    let sim_after =
        similarity::mean_similarity(&similarity::label_histograms(train, &processed_per_device));
    let total_collected = movement_totals.collected();

    Ok(EngineOutput {
        accuracy,
        accuracy_curve: curve,
        per_device_loss,
        ledger,
        movement: movement_totals,
        similarity: (sim_before, sim_after),
        mean_active: churn.mean_active(),
        total_collected,
    })
}

/// Integer apportionment of `count` samples to a device's plan row by the
/// largest-remainder method (keep / offload-per-neighbor / discard).
struct Allocation {
    keep: usize,
    /// (target, count), ascending target id.
    offloads: Vec<(usize, usize)>,
}

fn apportion(plan: &MovementPlan, i: usize, count: usize) -> Allocation {
    let n = plan.n;
    // options: 0 = keep, 1..=n = offload to j-1, n+1 = discard
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(n + 2);
    fracs.push((0, plan.s(i, i)));
    for j in 0..n {
        if j != i && plan.s(i, j) > 0.0 {
            fracs.push((j + 1, plan.s(i, j)));
        }
    }
    fracs.push((n + 1, plan.r[i]));

    let total: f64 = fracs.iter().map(|&(_, f)| f).sum();
    if total <= 0.0 {
        // degenerate all-zero row (e.g. from an inactive device): discard
        return Allocation { keep: 0, offloads: Vec::new() };
    }
    let norm = total;
    let mut counts: Vec<(usize, usize, f64)> = fracs
        .iter()
        .map(|&(opt, f)| {
            let exact = f / norm * count as f64;
            (opt, exact.floor() as usize, exact - exact.floor())
        })
        .collect();
    let assigned: usize = counts.iter().map(|&(_, c, _)| c).sum();
    let mut remaining = count - assigned;
    // largest remainders get the leftover units
    let mut order: Vec<usize> = (0..counts.len()).collect();
    order.sort_by(|&a, &b| counts[b].2.partial_cmp(&counts[a].2).unwrap());
    for &k in &order {
        if remaining == 0 {
            break;
        }
        counts[k].1 += 1;
        remaining -= 1;
    }

    let mut alloc = Allocation { keep: 0, offloads: Vec::new() };
    for (opt, c, _) in counts {
        if c == 0 {
            continue;
        }
        if opt == 0 {
            alloc.keep = c;
        } else if opt <= plan.n {
            alloc.offloads.push((opt - 1, c));
        }
        // discard = remainder, implicit
    }
    alloc.offloads.sort_unstable();
    alloc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_from_rows(n: usize, rows: Vec<(Vec<f64>, f64)>) -> MovementPlan {
        let mut plan = MovementPlan::keep_all(n);
        for (i, (s_row, r)) in rows.into_iter().enumerate() {
            for j in 0..n {
                plan.set_s(i, j, s_row[j]);
            }
            plan.r[i] = r;
        }
        plan
    }

    #[test]
    fn apportion_integral_plan() {
        let plan = plan_from_rows(2, vec![(vec![0.0, 1.0], 0.0), (vec![0.0, 1.0], 0.0)]);
        let a = apportion(&plan, 0, 7);
        assert_eq!(a.keep, 0);
        assert_eq!(a.offloads, vec![(1, 7)]);
    }

    #[test]
    fn apportion_fractional_sums_to_count() {
        let plan = plan_from_rows(
            3,
            vec![
                (vec![0.5, 0.3, 0.0], 0.2),
                (vec![0.0, 1.0, 0.0], 0.0),
                (vec![0.0, 0.0, 1.0], 0.0),
            ],
        );
        for count in [1usize, 2, 3, 10, 17] {
            let a = apportion(&plan, 0, count);
            let offloaded: usize = a.offloads.iter().map(|&(_, c)| c).sum();
            assert!(a.keep + offloaded <= count);
            // exact proportions within 1 unit each
            assert!((a.keep as f64 - 0.5 * count as f64).abs() <= 1.0);
        }
    }

    #[test]
    fn apportion_empty_row_discards_everything() {
        // all-zero row (inactive device shape) normalizes to discard
        let plan = plan_from_rows(2, vec![(vec![0.0, 0.0], 0.0), (vec![0.0, 1.0], 0.0)]);
        let a = apportion(&plan, 0, 5);
        assert_eq!(a.keep, 0);
        assert!(a.offloads.is_empty());
    }

    /// Property: apportionment conserves the sample count and tracks the
    /// fractional plan within one unit per option.
    #[test]
    fn prop_apportion_conserves_and_tracks() {
        crate::prop::for_all("apportion", 150, |g| {
            let n = g.usize_in(2, 6);
            let count = g.usize_in(0, 40);
            // random simplex row for device 0
            let mut fracs = g.vec_f64(n + 1, 0.0, 1.0); // s_00..s_0(n-1), r_0
            let total: f64 = fracs.iter().sum();
            for f in fracs.iter_mut() {
                *f /= total.max(1e-12);
            }
            let mut plan = MovementPlan::keep_all(n);
            for j in 0..n {
                plan.set_s(0, j, fracs[j]);
            }
            plan.r[0] = fracs[n];

            let a = apportion(&plan, 0, count);
            let offloaded: usize = a.offloads.iter().map(|&(_, c)| c).sum();
            assert!(a.keep + offloaded <= count);
            // per-option counts within 1 of the exact proportion
            assert!((a.keep as f64 - fracs[0] * count as f64).abs() <= 1.0 + 1e-9);
            for &(j, c) in &a.offloads {
                assert!(j != 0 && j < n);
                assert!((c as f64 - fracs[j] * count as f64).abs() <= 1.0 + 1e-9);
            }
            // implied discard also within 1
            let discard = count - a.keep - offloaded;
            assert!((discard as f64 - fracs[n] * count as f64).abs() <= 1.0 + 1e-9);
        });
    }
}
