//! Label-overlap data similarity between devices (Fig. 4b).
//!
//! Percent similarity between devices i and j is the multiset label overlap
//! `s_ij = |Y_i ∩ Y_j| / min(|Y_i|, |Y_j|)` where `Y_i` is the multiset of
//! labels at device i (§V-B1), averaged over all pairs that hold data. The
//! paper computes this before offloading (on collected data `D_i`) and
//! after (on processed data `G_i`) to show that movement makes non-iid
//! local datasets more alike.

use crate::data::dataset::{Dataset, NUM_CLASSES};

/// Per-device label histograms for arbitrary sample-index lists.
pub fn label_histograms(ds: &Dataset, per_device: &[Vec<u32>]) -> Vec<[usize; NUM_CLASSES]> {
    per_device
        .iter()
        .map(|idxs| {
            let mut h = [0usize; NUM_CLASSES];
            for &i in idxs {
                h[ds.labels[i as usize] as usize] += 1;
            }
            h
        })
        .collect()
}

/// Multiset-overlap similarity between two label histograms.
pub fn pair_similarity(a: &[usize; NUM_CLASSES], b: &[usize; NUM_CLASSES]) -> Option<f64> {
    let na: usize = a.iter().sum();
    let nb: usize = b.iter().sum();
    if na == 0 || nb == 0 {
        return None;
    }
    let overlap: usize = a.iter().zip(b).map(|(&x, &y)| x.min(y)).sum();
    Some(overlap as f64 / na.min(nb) as f64)
}

/// Mean pairwise similarity over all device pairs holding data.
pub fn mean_similarity(hists: &[[usize; NUM_CLASSES]]) -> f64 {
    let mut acc = 0.0;
    let mut count = 0usize;
    for i in 0..hists.len() {
        for j in (i + 1)..hists.len() {
            if let Some(s) = pair_similarity(&hists[i], &hists[j]) {
                acc += s;
                count += 1;
            }
        }
    }
    if count == 0 {
        0.0
    } else {
        acc / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::SynthDigits;
    use crate::util::rng::Rng;

    #[test]
    fn identical_histograms_similarity_one() {
        let a = [5, 5, 0, 0, 0, 0, 0, 0, 0, 0];
        assert_eq!(pair_similarity(&a, &a), Some(1.0));
    }

    #[test]
    fn disjoint_histograms_similarity_zero() {
        let a = [5, 0, 0, 0, 0, 0, 0, 0, 0, 0];
        let b = [0, 5, 0, 0, 0, 0, 0, 0, 0, 0];
        assert_eq!(pair_similarity(&a, &b), Some(0.0));
    }

    #[test]
    fn empty_devices_skipped() {
        let a = [1, 0, 0, 0, 0, 0, 0, 0, 0, 0];
        let empty = [0usize; NUM_CLASSES];
        assert_eq!(pair_similarity(&a, &empty), None);
        assert_eq!(mean_similarity(&[a, empty, a]), 1.0);
    }

    #[test]
    fn offloading_between_disjoint_devices_raises_similarity() {
        // device 0 holds labels {0..4}, device 1 holds {5..9}; moving half
        // of device 0's data to device 1 must increase mean similarity.
        let gen = SynthDigits::new(1);
        let mut rng = Rng::new(2);
        let ds = gen.generate(400, &mut rng);
        let mut dev0: Vec<u32> = Vec::new();
        let mut dev1: Vec<u32> = Vec::new();
        for (i, &l) in ds.labels.iter().enumerate() {
            if l < 5 {
                dev0.push(i as u32);
            } else {
                dev1.push(i as u32);
            }
        }
        let before = mean_similarity(&label_histograms(&ds, &[dev0.clone(), dev1.clone()]));
        let moved: Vec<u32> = dev0.split_off(dev0.len() / 2);
        let mut dev1_after = dev1.clone();
        dev1_after.extend(moved);
        let after = mean_similarity(&label_histograms(&ds, &[dev0, dev1_after]));
        assert!(after > before, "before={before} after={after}");
        assert_eq!(before, 0.0);
    }
}
