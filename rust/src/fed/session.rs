//! The session-based federated engine: the time-interval loop of §III as an
//! explicit state machine.
//!
//! [`crate::fed::run`] used to be a ~500-line monolith that interleaved
//! substrate derivation, churn, data collection, movement optimization,
//! training and aggregation in one function body. This module splits it into
//!
//! * [`Substrates`] — everything derived from an [`EngineConfig`] before the
//!   loop starts (datasets, arrival schedules, cost traces, topology, churn
//!   process). Pure CPU work, no runtime needed, bit-deterministic per seed.
//! * [`Compute`] — the training backend. [`LocalCompute`] borrows a
//!   [`Trainer`] for the classic single-threaded fast path;
//!   [`crate::coordinator::RuntimeHandle`] implements it over the
//!   runtime-service thread so sessions can run from any worker thread
//!   (see [`crate::coordinator::pool::SimPool`]).
//! * [`Session`] — the loop itself, decomposed into
//!   [`Session::step_churn`], [`Session::step_collect`],
//!   [`Session::step_movement`], [`Session::step_train`] and
//!   [`Session::step_aggregate`], with all per-interval buffers preallocated
//!   in an interval workspace (no per-`t` `Vec` churn in the
//!   movement-materialization and training loops; see DESIGN.md §Perf).
//!
//! Churn semantics (worst case, §V-E): an exiting device loses the local
//! updates it accumulated since the last aggregation (it "cannot transmit
//! its local update results prior to exiting"); a re-entering device
//! participates in data collection and movement immediately, but trains
//! and contributes only after it re-synchronizes at the end of the ongoing
//! aggregation period.

use std::sync::{Arc, OnceLock};

use anyhow::Result;

use crate::config::{
    CapacityPolicy, Churn, EngineConfig, InfoMode, Method, MovementBackend, TopologyKind,
    TrainPath,
};
use crate::costs::{estimator, traces, CapacityMode, CostSchedule, MovementCosts};
use crate::data::dataset::Dataset;
use crate::data::{Arrivals, Partitioner, SynthDigits};
use crate::fed::accounting::{IntervalStats, Ledger, MovementTotals};
use crate::fed::aggregator;
use crate::fed::eval::{self, EvalPath, EvalPlan, EvalWork};
use crate::fed::participation::{ParticipationCosts, ParticipationState};
use crate::fed::similarity;
use crate::fed::trainer::{DeviceWork, Trainer};
use crate::movement::{self, MovementPlan, MovementProblem, SolverWorkspace, SparsePlan};
use crate::runtime::{HostTensor, Runtime};
use crate::topology::{generators, ActiveView, ChurnProcess, Graph};
use crate::util::rng::Rng;

/// Model parameters as one tensor per layer.
pub type Params = Vec<HostTensor>;

/// Everything an experiment driver needs from one run.
///
/// `Default` exists for the sweep-sharding placeholder path
/// ([`crate::coordinator::shard::SweepCtx::run_many`] returns zeroed
/// outputs for runs another shard owns) — a default output never feeds a
/// real artifact.
#[derive(Debug, Clone, Default)]
pub struct EngineOutput {
    /// Final test accuracy of the global model.
    pub accuracy: f64,
    /// Test accuracy after each aggregation `(t, acc)` (if `eval_curve`).
    pub accuracy_curve: Vec<(usize, f64)>,
    /// Per-interval, per-device training loss (None when the device did
    /// not train that interval) — Fig. 4a. Empty when
    /// `EngineConfig::trace` is off (the dense rows are O(t_max·n)).
    pub per_device_loss: Vec<Vec<Option<f32>>>,
    pub ledger: Ledger,
    pub movement: MovementTotals,
    /// Mean pairwise label similarity (before movement, after movement) —
    /// Fig. 4b. The `(0.0, 0.0)` sentinel when `EngineConfig::trace` is
    /// off (similarity is derived from the per-device sample logs).
    pub similarity: (f64, f64),
    /// Mean active devices per interval (Table V / Figs. 9–10).
    pub mean_active: f64,
    /// Total datapoints collected by active devices.
    pub total_collected: usize,
}

/// Fixed generator seed for the SynthDigits class prototypes: the *task*
/// is identical across all experiments; per-run seeds control sampling,
/// partitioning, costs, topology and churn.
pub const TASK_SEED: u64 = 0xF0D5;

static TASK_GENERATOR: OnceLock<SynthDigits> = OnceLock::new();

/// The fixed-task SynthDigits generator: because [`TASK_SEED`] never
/// varies, the class prototypes are derived once per process and shared
/// read-only by every session and [`crate::coordinator::pool::SimPool`]
/// worker (per-run sampling noise still flows through each run's own RNG).
pub fn task_generator() -> &'static SynthDigits {
    TASK_GENERATOR.get_or_init(|| SynthDigits::new(TASK_SEED))
}

/// The training backend a [`Session`] schedules local updates through.
///
/// Two implementations exist: [`LocalCompute`] (borrowed [`Trainer`] on the
/// current thread — the classic `fed::run` path) and
/// [`crate::coordinator::RuntimeHandle`] (message-passing to the
/// runtime-service thread — the [`crate::coordinator::pool::SimPool`]
/// path). Both must be deterministic: the same parameters and samples must
/// produce bit-identical updates, which is what makes pooled and serial
/// runs interchangeable (see `tests/determinism.rs`).
pub trait Compute {
    /// Seeded parameter initialization for the session's model.
    fn init_params(&self, seed: u64) -> Result<Params>;
    /// One interval of local updates over `samples`; updates `params` in
    /// place and returns the sample-weighted mean loss (None if empty).
    fn train_interval(&self, params: &mut Params, samples: &[u32]) -> Result<Option<f32>>;
    /// One interval of local updates for several devices at once. The
    /// default implementation dispatches scalar [`Compute::train_interval`]
    /// calls in device order; PJRT-backed implementations override it to
    /// stack all devices into lock-stepped `[D × BATCH]` executions of the
    /// batched train entry (DESIGN.md §Perf rule 7). Either way the result
    /// must be deterministic in the work list alone.
    fn train_interval_many(&self, work: &mut [DeviceWork]) -> Result<()> {
        for w in work.iter_mut() {
            w.loss = self.train_interval(&mut w.params, &w.samples)?;
        }
        Ok(())
    }
    /// Test-set accuracy of `params`.
    fn evaluate(&self, params: &[HostTensor]) -> Result<f64>;
    /// Accuracy of `params` over an explicit test-index subset. The
    /// default falls back to the full pass — correct for index-unaware
    /// stub backends (their evaluate ignores the test set anyway);
    /// dataset-backed implementations must override it.
    fn evaluate_subset(&self, params: &[HostTensor], samples: &[u32]) -> Result<f64> {
        let _ = samples;
        self.evaluate(params)
    }
    /// Score a batch of evaluation work units in one dispatch. The
    /// default is a scalar loop over [`Compute::evaluate_subset`] in work
    /// order — so `StubCompute`-style backends are trivially
    /// path-invariant — and ignores `path`; PJRT-backed implementations
    /// honor it by stacking chunks into `[D × BATCH]` executions of the
    /// batched eval entry (DESIGN.md §Perf rule 8). Either way the result
    /// must be deterministic in the work list alone.
    fn evaluate_many(&self, work: &mut [EvalWork], path: EvalPath) -> Result<()> {
        let _ = path;
        for w in work.iter_mut() {
            w.accuracy = Some(self.evaluate_subset(&w.params, &w.samples)?);
        }
        Ok(())
    }
}

/// Direct, single-threaded backend: borrows the runtime and trainer of the
/// calling thread. This is the fast path `fed::run` uses.
pub struct LocalCompute<'a> {
    pub rt: &'a Runtime,
    pub trainer: &'a Trainer,
    pub train: &'a Dataset,
    pub test: &'a Dataset,
}

impl Compute for LocalCompute<'_> {
    fn init_params(&self, seed: u64) -> Result<Params> {
        self.rt.init_params(self.trainer.kind, seed)
    }

    fn train_interval(&self, params: &mut Params, samples: &[u32]) -> Result<Option<f32>> {
        self.trainer.train_interval(params, self.train, samples)
    }

    fn train_interval_many(&self, work: &mut [DeviceWork]) -> Result<()> {
        self.trainer.train_interval_many(self.rt, self.train, work)
    }

    fn evaluate(&self, params: &[HostTensor]) -> Result<f64> {
        self.trainer.evaluate(params, self.test)
    }

    fn evaluate_subset(&self, params: &[HostTensor], samples: &[u32]) -> Result<f64> {
        self.trainer.evaluate_subset(params, self.test, samples)
    }

    fn evaluate_many(&self, work: &mut [EvalWork], path: EvalPath) -> Result<()> {
        self.trainer.evaluate_many(self.rt, self.test, work, path)
    }
}

/// Everything a run derives from its [`EngineConfig`] before the loop
/// starts. Derivation is pure CPU work: a pooled worker can build this
/// concurrently with other runs, then register the datasets with the
/// runtime service and stream the loop through a [`Compute`] handle.
#[derive(Debug, Clone)]
pub struct Substrates {
    pub train: Dataset,
    pub test: Dataset,
    pub arrivals: Arrivals,
    /// Ground-truth cost/capacity schedule (the ledger always charges this).
    pub actual_costs: CostSchedule,
    /// What the optimizer believes (equals `actual_costs` under perfect
    /// information).
    pub belief_costs: CostSchedule,
    pub graph: Graph,
    /// Initial churn process state (cloned into each session).
    pub churn: ChurnProcess,
    /// Churn RNG stream (cloned into each session).
    pub churn_rng: Rng,
    /// Seed for parameter initialization.
    pub init_seed: u64,
}

impl Substrates {
    /// Derive all substrates from the config. The RNG split order below is
    /// load-bearing: it must stay exactly as in the original engine so that
    /// every seed reproduces the pre-refactor numbers bit-for-bit.
    pub fn derive(cfg: &EngineConfig) -> Substrates {
        let mut root = Rng::new(cfg.seed);
        let mut data_rng = root.split();
        let mut topo_rng = root.split();
        let mut cost_rng = root.split();
        let churn_rng = root.split();
        let init_seed = root.next_u64();

        // the fixed-seed class prototypes are derived once per process and
        // shared across all runs (per-run sampling stays on data_rng)
        let gen = task_generator();
        let (train, test) = gen.train_test(cfg.n_train, cfg.n_test, &mut data_rng);
        let arrivals = Partitioner { n_devices: cfg.n, t_max: cfg.t_max, iid: cfg.iid }
            .partition(&train, &mut data_rng);

        let mut actual_costs = traces::generate(
            cfg.cost_source,
            cfg.n,
            cfg.t_max,
            cfg.tau,
            cfg.error_profile,
            &mut cost_rng,
        );
        if let CapacityPolicy::MeanArrivals = cfg.capacity {
            actual_costs.set_capacities(CapacityMode::Uniform(cfg.mean_arrivals()));
        }
        let mut belief_costs: CostSchedule = match cfg.info {
            InfoMode::Perfect => actual_costs.clone(),
            InfoMode::Estimated(w) => estimator::estimate(&actual_costs, w),
        };
        if cfg.discard_model == crate::movement::DiscardModel::Sqrt {
            // γ-rescaling for the convex error model (see ErrorWeightProfile)
            for t in 0..cfg.t_max {
                for i in 0..cfg.n {
                    belief_costs.error_weight[t][i] *= cfg.error_profile.sqrt_gamma_scale;
                }
            }
        }

        let graph = build_topology(cfg, &actual_costs, &mut topo_rng);
        let churn = match cfg.churn {
            Some(Churn { p_exit, p_entry }) => ChurnProcess::new(cfg.n, p_exit, p_entry),
            None => ChurnProcess::static_network(cfg.n),
        };

        Substrates {
            train,
            test,
            arrivals,
            actual_costs,
            belief_costs,
            graph,
            churn,
            churn_rng,
            init_seed,
        }
    }
}

fn build_topology(cfg: &EngineConfig, costs: &CostSchedule, rng: &mut Rng) -> Graph {
    match cfg.topology {
        TopologyKind::Full => generators::fully_connected(cfg.n),
        TopologyKind::Random(rho) => generators::erdos_renyi(cfg.n, rho, rng),
        TopologyKind::SmallWorld => {
            generators::watts_strogatz(cfg.n, (cfg.n / 5).max(2), 0.3, rng)
        }
        TopologyKind::Hierarchical => {
            generators::hierarchical(cfg.n, &costs.mean_compute_per_device(), rng)
        }
        TopologyKind::ScaleFree => generators::scale_free(cfg.n, 2, rng),
    }
}

/// The mutable learning state of a running session: what a checkpoint of
/// the distributed system would have to contain.
///
/// Model state is copy-on-write (DESIGN.md §Perf rule 14): `global` and
/// every `device_params[i]` are `Arc<Params>`, so a period-end resync is
/// n pointer bumps — all synced replicas *share* the global allocation —
/// and only devices that actually train materialize a private copy
/// (`Arc::make_mut` / unwrap-or-clone in the dispatch paths). Resident
/// model memory is O(trainees·|params|), not O(n·|params|), and the
/// shared epoch is bit-identical to the historical clone-per-device
/// storage because synced replicas were equal by construction.
pub struct SessionState {
    /// Global model parameters (updated at each aggregation).
    pub global: Arc<Params>,
    /// Per-device local parameters (synced devices alias `global`).
    pub device_params: Vec<Arc<Params>>,
    /// Whether device i holds a model synchronized with the current
    /// aggregation period (re-entering devices wait for the next one).
    pub synced: Vec<bool>,
    /// Datapoints processed since the last aggregation (eq. 4 weight).
    pub h: Vec<f64>,
    /// Data offloaded *to* each device last interval, processed this one.
    pub inbound: Vec<Vec<u32>>,
    pub ledger: Ledger,
    pub movement: MovementTotals,
    pub per_device_loss: Vec<Vec<Option<f32>>>,
    pub curve: Vec<(usize, f64)>,
    /// Label multiset collected per device (similarity "before").
    pub collected_per_device: Vec<Vec<u32>>,
    /// Label multiset processed per device (similarity "after").
    pub processed_per_device: Vec<Vec<u32>>,
}

impl SessionState {
    fn new(cfg: &EngineConfig, global: Params) -> SessionState {
        let n = cfg.n;
        let global = Arc::new(global);
        SessionState {
            // every device starts synced: n pointer bumps, one allocation
            device_params: vec![Arc::clone(&global); n],
            global,
            synced: vec![true; n],
            h: vec![0.0; n],
            inbound: vec![Vec::new(); n],
            ledger: Ledger::default(),
            movement: MovementTotals::default(),
            per_device_loss: if cfg.trace {
                vec![vec![None; n]; cfg.t_max]
            } else {
                Vec::new()
            },
            curve: Vec::new(),
            collected_per_device: vec![Vec::new(); n],
            processed_per_device: vec![Vec::new(); n],
        }
    }
}

/// Preallocated per-interval buffers, reused across all `t` (DESIGN.md
/// §Perf): the hot loops never allocate per interval — churn flips bits in
/// `active` in place, and the movement solvers reuse `solver`'s plans and
/// scratch (warm-start clones are the opt-in exception).
struct IntervalWorkspace {
    /// Incrementally-maintained active mask (flipped per churn delta
    /// instead of recopied from the churn process every interval).
    active: ActiveView,
    /// Collected-this-interval sample queues (after movement: the kept
    /// prefix only).
    new_data: Vec<Vec<u32>>,
    /// Samples offloaded this interval, delivered next interval (swapped
    /// with `SessionState::inbound` at the end of `step_train`).
    pending: Vec<Vec<u32>>,
    d: Vec<f64>,
    inbound_counts: Vec<f64>,
    workload: Vec<u32>,
    /// Device index of each deferred trainee this interval (parallel to
    /// the leading entries of `train_work`).
    trainee_ids: Vec<usize>,
    /// Deferred per-trainee workloads: `step_train` collects them first,
    /// then dispatches all of them scalar or batched (sample buffers are
    /// reused across intervals on the local path).
    train_work: Vec<DeviceWork>,
    solver: SolverWorkspace,
    apportion: ApportionScratch,
    stats: IntervalStats,
}

impl IntervalWorkspace {
    fn new(n: usize) -> IntervalWorkspace {
        IntervalWorkspace {
            // matches the churn process's all-active start (§V-E)
            active: ActiveView::all_active(n),
            new_data: vec![Vec::new(); n],
            pending: vec![Vec::new(); n],
            d: Vec::with_capacity(n),
            inbound_counts: Vec::with_capacity(n),
            workload: Vec::new(),
            trainee_ids: Vec::with_capacity(n),
            train_work: Vec::new(),
            solver: SolverWorkspace::new(),
            apportion: ApportionScratch::default(),
            stats: IntervalStats::default(),
        }
    }
}

/// One distributed run as an explicit state machine. Construct with
/// [`Session::new`], drive with [`Session::run`] (or step manually for
/// tests and future schedulers).
pub struct Session<'a, C: Compute> {
    pub cfg: &'a EngineConfig,
    sub: &'a Substrates,
    compute: C,
    churn: ChurnProcess,
    churn_rng: Rng,
    /// Concrete plan representation for this run (`cfg.movement_backend`
    /// resolved against `cfg.n`).
    backend: MovementBackend,
    pub state: SessionState,
    ws: IntervalWorkspace,
    /// Which test shard each curve point scores (Full = the whole set);
    /// only materialized when the run produces a curve.
    eval_plan: Option<EvalPlan>,
    /// Reusable single-slot buffer for curve evaluations.
    eval_work: Vec<EvalWork>,
    /// Per-period device sampling state (`cfg.participation`); `None`
    /// under the `Full` default, which is what pins the default to the
    /// pre-subsystem code path bit-for-bit (DESIGN.md §Perf rule 13).
    participation: Option<ParticipationState>,
}

impl<'a, C: Compute> Session<'a, C> {
    pub fn new(cfg: &'a EngineConfig, sub: &'a Substrates, compute: C) -> Result<Session<'a, C>> {
        let global = compute.init_params(sub.init_seed)?;
        let mut ws = IntervalWorkspace::new(cfg.n);
        ws.solver.warm_start = cfg.warm_start;
        ws.solver.solver_threads = cfg
            .solver_threads
            .resolve(cfg.n, crate::coordinator::pool::worker_share());
        Ok(Session {
            cfg,
            sub,
            compute,
            churn: sub.churn.clone(),
            churn_rng: sub.churn_rng.clone(),
            backend: cfg.movement_backend.resolve(cfg.n),
            state: SessionState::new(cfg, global),
            ws,
            eval_plan: cfg
                .eval_curve
                .then(|| EvalPlan::new(cfg.eval_schedule, sub.test.len(), cfg.seed)),
            eval_work: Vec::new(),
            participation: ParticipationState::new(cfg.participation, cfg.n, cfg.seed),
        })
    }

    /// Advance the churn process and reset state for exits/entries: a
    /// re-entering device is present but unsynchronized; an exited device
    /// loses the updates it could not transmit.
    ///
    /// Only the interval's churn **delta** is touched — O(|Δ|) instead of
    /// O(n): flipping the active view per delta reproduces the full mask
    /// copy exactly, and zeroing `h` for exits only is equivalent to the
    /// old every-inactive-device sweep because a device's `h` can only
    /// become nonzero while it is active (so it is already 0 for devices
    /// that stayed inactive).
    ///
    /// At each aggregation-period start (`t % τ == 0`) the participation
    /// sampler — when one exists — draws the period's participant set over
    /// the post-churn active devices, so `k >= n_active` periods degrade
    /// to `Full` exactly.
    pub fn step_churn(&mut self, t: usize) {
        let delta = self.churn.step(&mut self.churn_rng);
        for &i in &delta.entered {
            self.state.synced[i] = false;
            self.state.h[i] = 0.0;
        }
        for &i in &delta.exited {
            self.state.h[i] = 0.0;
            // an exited device's uncollected queue is gone; clearing here
            // (instead of the old every-device sweep in step_collect)
            // keeps the invariant that inactive devices always hold empty
            // queues, so the active-id sweeps below can skip them
            self.ws.new_data[i].clear();
        }
        self.ws.active.apply(delta);
        if t % self.cfg.tau == 0 {
            if let Some(p) = self.participation.as_mut() {
                let arrivals = &self.sub.arrivals;
                let costs = &self.sub.belief_costs;
                let t_end = (t + self.cfg.tau).min(self.cfg.t_max);
                // importance score: the data volume the device will collect
                // this period, discounted by its believed mean processing
                // cost — devices holding much cheap-to-process data matter
                // most (both score inputs are substrate-deterministic)
                p.resolve_period(self.ws.active.as_slice(), |i| {
                    let volume: usize =
                        (t..t_end).map(|s| arrivals.schedule[i][s].len()).sum();
                    let span = (t_end - t).max(1) as f64;
                    let mean_cost: f64 =
                        (t..t_end).map(|s| costs.c_node(s, i)).sum::<f64>() / span;
                    (1.0 + volume as f64) / (1.0 + mean_cost.max(0.0))
                });
            }
        }
    }

    /// Materialize this interval's arrivals `D_i(t)` for active devices.
    ///
    /// O(n_active), not O(n): inactive devices always hold empty queues
    /// (`step_churn` clears on exit, nothing refills while inactive), so
    /// sweeping the active-id list reproduces the historical full scan —
    /// which only ever cleared already-empty queues elsewhere — exactly.
    pub fn step_collect(&mut self, t: usize) {
        let IntervalWorkspace { active, new_data, .. } = &mut self.ws;
        for &i in active.ids() {
            new_data[i].clear();
            new_data[i].extend_from_slice(&self.sub.arrivals.schedule[i][t]);
            if self.cfg.trace {
                self.state.collected_per_device[i].extend_from_slice(&new_data[i]);
            }
        }
    }

    /// Solve the movement problem (eqs. 5–9) for this interval and
    /// materialize the fractional plan into integer sample movements:
    /// kept prefixes stay in the local queues, offloads land in `pending`
    /// (delivered next interval), the rest is discarded and charged.
    pub fn step_movement(&mut self, t: usize) {
        let n = self.cfg.n;
        self.ws.d.clear();
        self.ws.d.extend(self.ws.new_data.iter().map(|s| s.len() as f64));
        self.ws.inbound_counts.clear();
        self.ws.inbound_counts.extend(self.state.inbound.iter().map(|s| s.len() as f64));

        let use_sparse =
            self.cfg.method == Method::NetworkAware && self.backend == MovementBackend::Sparse;
        match self.cfg.method {
            Method::NetworkAware => {
                // Under a sampling period, unsampled devices become
                // offload-only sources: a capacity-zero view of the belief
                // oracle forces the solver to route their collections to
                // sampled neighbors or discard them (never a cost
                // override — 0 × ∞ hazards live that way). Full periods
                // skip the wrapper entirely, keeping the historical
                // problem construction bit-for-bit.
                let sampling = self
                    .participation
                    .as_ref()
                    .filter(|p| !p.full_period)
                    .map(|p| ParticipationCosts {
                        inner: &self.sub.belief_costs,
                        sampled: &p.sampled,
                    });
                let costs: &dyn MovementCosts = match &sampling {
                    Some(wrapped) => wrapped,
                    None => &self.sub.belief_costs,
                };
                // The solvers filter on the active mask themselves, and the
                // base graph's adjacency is natively sorted, so solving over
                // (base graph, mask) is bit-identical to the historical
                // per-interval `Graph::restrict` — without rebuilding the
                // topology every interval (O(V + E) saved per t).
                let problem = MovementProblem {
                    t,
                    graph: &self.sub.graph,
                    active: self.ws.active.as_slice(),
                    d: &self.ws.d,
                    inbound_prev: &self.ws.inbound_counts,
                    costs,
                    discard_model: self.cfg.discard_model,
                };
                if use_sparse {
                    movement::solve_sparse_with(&problem, &mut self.ws.solver);
                } else {
                    movement::solve_with(&problem, &mut self.ws.solver);
                }
            }
            Method::Federated => self.ws.solver.plan.reset_keep_all(n),
            Method::Centralized => unreachable!("centralized runs bypass Session"),
        }

        // materialization sweep over the active-id list (O(n_active)):
        // inactive devices always hold empty queues, and the historical
        // full 0..n scan `continue`d on them without a float op, so the
        // restricted sweep is bit-identical
        self.ws.stats = IntervalStats::default();
        let IntervalWorkspace { active, new_data, pending, apportion, solver, stats, .. } =
            &mut self.ws;
        for &i in active.ids() {
            let count = new_data[i].len();
            stats.collected += count;
            if count == 0 {
                continue;
            }
            let keep = if use_sparse {
                apportion_sparse_into(&solver.sparse, i, count, apportion)
            } else {
                apportion_into(&solver.plan, i, count, apportion)
            };
            // offloads, ascending j (deterministic)
            let mut cursor = keep;
            for &(j, sent) in &apportion.offloads {
                pending[j].extend_from_slice(&new_data[i][cursor..cursor + sent]);
                cursor += sent;
                stats.offloaded += sent;
                self.state.ledger.transfer +=
                    sent as f64 * self.sub.actual_costs.c_link(t, i, j);
            }
            let dropped = count - cursor;
            stats.discarded += dropped;
            self.state.ledger.discard += dropped as f64 * self.sub.actual_costs.f(t, i);
            // local processing queue = kept prefix (+ inbound, in step_train)
            new_data[i].truncate(keep);
        }
    }

    /// Run local gradient updates (eq. 3) on every active, synchronized
    /// device's workload (inbound from last interval + kept collection),
    /// then rotate the pending offloads into the inbound queues.
    ///
    /// Workloads are collected first and dispatched together so that —
    /// when more than one device trains and `cfg.train_path` allows it —
    /// all of them execute as stacked `[D × BATCH]` steps through
    /// [`Compute::train_interval_many`] (one PJRT dispatch per lock-step
    /// for the whole interval instead of one per device per chunk).
    pub fn step_train(&mut self, t: usize) -> Result<()> {
        let n = self.cfg.n;
        // devices a sampling period benched: they neither process nor
        // train — whatever still reaches their queue (cross-period
        // offloads in flight, mid-period entrants) is lost like data at
        // an exited device
        let unsampled = |p: &Option<ParticipationState>, i: usize| {
            matches!(p, Some(p) if !p.full_period && !p.sampled[i])
        };
        self.ws.trainee_ids.clear();
        for i in 0..n {
            self.ws.workload.clear();
            self.ws.workload.extend_from_slice(&self.state.inbound[i]);
            self.state.inbound[i].clear();
            self.ws.workload.extend_from_slice(&self.ws.new_data[i]);
            let benched = !self.ws.active[i] || unsampled(&self.participation, i);
            if self.ws.workload.is_empty() || benched {
                // inactive devices drop their queue (worst case: data at an
                // exited device is unreachable); its discard cost is charged
                // since the network loses those points.
                if !self.ws.workload.is_empty() && benched {
                    self.state.ledger.discard +=
                        self.ws.workload.len() as f64 * self.sub.actual_costs.f(t, i);
                    self.ws.stats.discarded += self.ws.workload.len();
                }
                continue;
            }
            self.ws.stats.processed += self.ws.workload.len();
            self.state.ledger.process +=
                self.ws.workload.len() as f64 * self.sub.actual_costs.c_node(t, i);
            if self.cfg.trace {
                self.state.processed_per_device[i].extend_from_slice(&self.ws.workload);
            }
            if self.state.synced[i] {
                let slot = self.ws.trainee_ids.len();
                self.ws.trainee_ids.push(i);
                if self.ws.train_work.len() <= slot {
                    self.ws.train_work.push(DeviceWork::default());
                }
                let w = &mut self.ws.train_work[slot];
                w.samples.clear();
                w.samples.extend_from_slice(&self.ws.workload);
                w.loss = None;
            }
            // unsynced devices process data (it is consumed) but their stale
            // update cannot be used — the processed points still count
            // toward resource usage, not toward aggregation weight.
        }
        self.dispatch_train(t)?;
        // offloads sent this interval become next interval's inbound; the
        // drained inbound vectors become next interval's pending buffers.
        std::mem::swap(&mut self.state.inbound, &mut self.ws.pending);
        self.state.movement.push(self.ws.stats);
        Ok(())
    }

    /// Dispatch the interval's deferred trainees: batched when the config
    /// allows it (Auto requires >1 trainee), scalar otherwise. Both paths
    /// apply losses and aggregation weights in device order.
    fn dispatch_train(&mut self, t: usize) -> Result<()> {
        let k = self.ws.trainee_ids.len();
        if k == 0 {
            return Ok(());
        }
        let batched = match self.cfg.train_path {
            TrainPath::Scalar => false,
            TrainPath::Batched => true,
            TrainPath::Auto => k > 1,
        };
        if batched {
            // params move into the work list for the duration of the call:
            // a trainee still sharing the epoch allocation clones here
            // (clone-on-train — the only place a synced replica ever
            // copies), an already-private replica unwraps with zero copy.
            // The rewrap-back runs on the error path too, but a failed
            // service round-trip (RuntimeHandle) loses the in-flight
            // params — the error aborts the run, so the session must not
            // be stepped further after a dispatch failure.
            for (slot, &i) in self.ws.trainee_ids.iter().enumerate() {
                let arc = std::mem::take(&mut self.state.device_params[i]);
                self.ws.train_work[slot].params =
                    Arc::try_unwrap(arc).unwrap_or_else(|shared| (*shared).clone());
            }
            let res = self.compute.train_interval_many(&mut self.ws.train_work[..k]);
            for (slot, &i) in self.ws.trainee_ids.iter().enumerate() {
                self.state.device_params[i] =
                    Arc::new(std::mem::take(&mut self.ws.train_work[slot].params));
            }
            res?;
            for (slot, &i) in self.ws.trainee_ids.iter().enumerate() {
                if let Some(loss) = self.ws.train_work[slot].loss {
                    if self.cfg.trace {
                        self.state.per_device_loss[t][i] = Some(loss);
                    }
                    self.state.h[i] += self.ws.train_work[slot].samples.len() as f64;
                }
            }
        } else {
            for (slot, &i) in self.ws.trainee_ids.iter().enumerate() {
                // make_mut = clone-on-train: the first interval after a
                // resync copies the shared epoch params once; later
                // intervals find the Arc unique and mutate in place
                if let Some(loss) = self.compute.train_interval(
                    Arc::make_mut(&mut self.state.device_params[i]),
                    &self.ws.train_work[slot].samples,
                )? {
                    if self.cfg.trace {
                        self.state.per_device_loss[t][i] = Some(loss);
                    }
                    self.state.h[i] += self.ws.train_work[slot].samples.len() as f64;
                }
            }
        }
        Ok(())
    }

    /// Weighted federated averaging (eq. 4) every τ intervals; re-syncs all
    /// active devices to the new global model.
    pub fn step_aggregate(&mut self, t: usize) -> Result<()> {
        if (t + 1) % self.cfg.tau != 0 {
            return Ok(());
        }
        // Horvitz–Thompson correction under a sampling period: each
        // sampled device's eq. (4) weight is its processed count scaled by
        // 1/π_i, so the weighted average stays unbiased for the full-
        // participation aggregate. Full periods multiply by nothing at
        // all — the historical weights, bit-for-bit.
        let scale = |i: usize| match &self.participation {
            Some(p) if !p.full_period => self.state.h[i] * p.weight_scale[i],
            _ => self.state.h[i],
        };
        // active-id sweep: the historical 0..n filter visited the same
        // devices in the same ascending order
        let contributions: Vec<(&Params, f64)> = self
            .ws
            .active
            .ids()
            .iter()
            .copied()
            .filter(|&i| self.state.synced[i])
            .map(|i| (self.state.device_params[i].as_ref(), scale(i)))
            .collect();
        // fixed 512-contributor chunks, partials combined ascending: one
        // chunk at paper scale replays the serial axpy chain bitwise, and
        // the result is invariant to the worker count (§Perf rule 14)
        let new_global = aggregator::aggregate_chunked(
            &contributions,
            self.ws.solver.solver_threads,
            aggregator::CHUNK_CONTRIBUTORS,
            aggregator::CHUNK_ELEMS,
        )?;
        if let Some(g) = new_global {
            self.state.global = Arc::new(g);
        }
        // Curve point before the resync: the freshly-aggregated global is
        // still uniquely owned, so make_mut hands the evaluator a mutable
        // view without copying (after the pointer bumps it would have to
        // deep-clone). Bit-neutral reordering — the resync below touches
        // no evaluator input and the evaluator touches no resync state.
        if let Some(plan) = &self.eval_plan {
            // through the eval planner: the k-th shard of the schedule, in
            // one evaluate_many dispatch (one EvalMany round-trip per
            // curve point on pooled backends)
            let k = self.state.curve.len();
            let acc = eval::curve_point(
                &self.compute,
                plan,
                self.cfg.eval_path,
                &mut self.eval_work,
                Arc::make_mut(&mut self.state.global),
                k,
            )?;
            self.state.curve.push((t + 1, acc));
        }
        // O(n_active) pointer-bump resync: every active device re-shares
        // the epoch allocation instead of deep-cloning it (the historical
        // O(n·|params|) wall this PR removes). Inactive devices keep
        // whatever stale replica they exited with — as before.
        for &i in self.ws.active.ids() {
            self.state.device_params[i] = Arc::clone(&self.state.global);
            self.state.synced[i] = true;
        }
        for h in self.state.h.iter_mut() {
            *h = 0.0;
        }
        Ok(())
    }

    /// Drive all intervals and produce the run's output.
    pub fn run(mut self) -> Result<EngineOutput> {
        for t in 0..self.cfg.t_max {
            self.step_churn(t);
            self.step_collect(t);
            self.step_movement(t);
            self.step_train(t)?;
            self.step_aggregate(t)?;
        }
        self.finish()
    }

    /// Final evaluation and similarity metrics.
    pub fn finish(self) -> Result<EngineOutput> {
        let accuracy = self.compute.evaluate(&self.state.global)?;
        // similarity is derived entirely from the per-device trace logs;
        // with tracing off they are empty and the summary is reported as
        // the (0.0, 0.0) sentinel instead of a misleading number
        let (sim_before, sim_after) = if self.cfg.trace {
            (
                similarity::mean_similarity(&similarity::label_histograms(
                    &self.sub.train,
                    &self.state.collected_per_device,
                )),
                similarity::mean_similarity(&similarity::label_histograms(
                    &self.sub.train,
                    &self.state.processed_per_device,
                )),
            )
        } else {
            (0.0, 0.0)
        };
        let total_collected = self.state.movement.collected();
        Ok(EngineOutput {
            accuracy,
            accuracy_curve: self.state.curve,
            per_device_loss: self.state.per_device_loss,
            ledger: self.state.ledger,
            movement: self.state.movement,
            similarity: (sim_before, sim_after),
            mean_active: self.churn.mean_active(),
            total_collected,
        })
    }
}

/// Run one experiment on already-derived substrates through any backend.
/// Dispatches centralized runs to the no-network baseline loop.
pub fn run_with<C: Compute>(
    cfg: &EngineConfig,
    sub: &Substrates,
    compute: C,
) -> Result<EngineOutput> {
    match cfg.method {
        Method::Centralized => run_centralized(cfg, sub, &compute),
        _ => Session::new(cfg, sub, compute)?.run(),
    }
}

/// Centralized baseline: all collected data is processed at one server;
/// no movement, no network costs (accuracy comparison only, Table II).
fn run_centralized<C: Compute>(
    cfg: &EngineConfig,
    sub: &Substrates,
    compute: &C,
) -> Result<EngineOutput> {
    let mut params = compute.init_params(sub.init_seed)?;
    let mut per_device_loss = if cfg.trace {
        vec![vec![None; cfg.n]; cfg.t_max]
    } else {
        Vec::new()
    };
    let mut collected = 0usize;
    let mut curve = Vec::new();
    let mut batch: Vec<u32> = Vec::new();
    let eval_plan = cfg
        .eval_curve
        .then(|| EvalPlan::new(cfg.eval_schedule, sub.test.len(), cfg.seed));
    let mut eval_work = Vec::new();
    for t in 0..cfg.t_max {
        batch.clear();
        for i in 0..cfg.n {
            batch.extend(&sub.arrivals.schedule[i][t]);
        }
        collected += batch.len();
        if let Some(loss) = compute.train_interval(&mut params, &batch)? {
            if cfg.trace {
                per_device_loss[t][0] = Some(loss);
            }
        }
        if let (Some(plan), true) = (&eval_plan, (t + 1) % cfg.tau == 0) {
            let k = curve.len();
            let acc = eval::curve_point(
                compute,
                plan,
                cfg.eval_path,
                &mut eval_work,
                &mut params,
                k,
            )?;
            curve.push((t + 1, acc));
        }
    }
    let accuracy = compute.evaluate(&params)?;
    Ok(EngineOutput {
        accuracy,
        accuracy_curve: curve,
        per_device_loss,
        ledger: Ledger::default(),
        movement: MovementTotals::default(),
        // one server sees everything: similarity is 1 by definition, but
        // the untraced sentinel stays consistent with Session::finish
        similarity: if cfg.trace { (1.0, 1.0) } else { (0.0, 0.0) },
        mean_active: cfg.n as f64,
        total_collected: collected,
    })
}

/// Reusable scratch for [`apportion_into`] (one call per device per
/// interval — preallocating avoids four `Vec`s per call).
#[derive(Debug, Default)]
pub struct ApportionScratch {
    fracs: Vec<(usize, f64)>,
    counts: Vec<(usize, usize, f64)>,
    order: Vec<usize>,
    /// `(target, count)` ascending by target id — valid after a call.
    pub offloads: Vec<(usize, usize)>,
}

/// Integer apportionment of `count` samples to device `i`'s plan row by the
/// largest-remainder method (keep / offload-per-neighbor / discard).
/// Returns the kept count; offloads land in `ws.offloads`; the implicit
/// remainder is discarded.
pub fn apportion_into(
    plan: &MovementPlan,
    i: usize,
    count: usize,
    ws: &mut ApportionScratch,
) -> usize {
    let n = plan.n;
    // options: 0 = keep, 1..=n = offload to j-1, n+1 = discard
    ws.fracs.clear();
    ws.fracs.push((0, plan.s(i, i)));
    for j in 0..n {
        if j != i && plan.s(i, j) > 0.0 {
            ws.fracs.push((j + 1, plan.s(i, j)));
        }
    }
    ws.fracs.push((n + 1, plan.r[i]));
    apportion_fracs(n, count, ws)
}

/// Sparse mirror of [`apportion_into`]: gathers the same option sequence —
/// keep, then nonzero offload targets ascending (the dense `j = 0..n` scan
/// only ever sees nonzeros on stored edges), then discard — so the
/// largest-remainder assignment, including its stable tie-breaks, is
/// identical to the dense path on equal plans.
pub fn apportion_sparse_into(
    sp: &SparsePlan,
    i: usize,
    count: usize,
    ws: &mut ApportionScratch,
) -> usize {
    let n = sp.n;
    ws.fracs.clear();
    ws.fracs.push((0, sp.local[i]));
    for e in sp.offsets[i]..sp.offsets[i + 1] {
        if sp.s_edge[e] > 0.0 {
            ws.fracs.push((sp.targets[e] + 1, sp.s_edge[e]));
        }
    }
    ws.fracs.push((n + 1, sp.discard[i]));
    apportion_fracs(n, count, ws)
}

/// Shared tail of the apportionment: largest-remainder assignment over the
/// gathered `ws.fracs` option list.
fn apportion_fracs(n: usize, count: usize, ws: &mut ApportionScratch) -> usize {
    ws.offloads.clear();
    let total: f64 = ws.fracs.iter().map(|&(_, f)| f).sum();
    if total <= 0.0 {
        // degenerate all-zero row (e.g. from an inactive device): discard
        return 0;
    }
    let norm = total;
    ws.counts.clear();
    ws.counts.extend(ws.fracs.iter().map(|&(opt, f)| {
        let exact = f / norm * count as f64;
        (opt, exact.floor() as usize, exact - exact.floor())
    }));
    let assigned: usize = ws.counts.iter().map(|&(_, c, _)| c).sum();
    let mut remaining = count - assigned;
    // largest remainders get the leftover units (stable sort: ties keep
    // option order, matching the pre-refactor engine exactly)
    let ApportionScratch { counts, order, offloads, .. } = ws;
    order.clear();
    order.extend(0..counts.len());
    order.sort_by(|&a, &b| counts[b].2.partial_cmp(&counts[a].2).unwrap());
    for &k in order.iter() {
        if remaining == 0 {
            break;
        }
        counts[k].1 += 1;
        remaining -= 1;
    }

    let mut keep = 0usize;
    for &(opt, c, _) in counts.iter() {
        if c == 0 {
            continue;
        }
        if opt == 0 {
            keep = c;
        } else if opt <= n {
            offloads.push((opt - 1, c));
        }
        // discard = remainder, implicit
    }
    offloads.sort_unstable();
    keep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fed::eval::EvalSchedule;

    // -- apportionment ------------------------------------------------------

    struct Allocation {
        keep: usize,
        offloads: Vec<(usize, usize)>,
    }

    fn apportion(plan: &MovementPlan, i: usize, count: usize) -> Allocation {
        let mut ws = ApportionScratch::default();
        let keep = apportion_into(plan, i, count, &mut ws);
        Allocation { keep, offloads: ws.offloads.clone() }
    }

    fn plan_from_rows(n: usize, rows: Vec<(Vec<f64>, f64)>) -> MovementPlan {
        let mut plan = MovementPlan::keep_all(n);
        for (i, (s_row, r)) in rows.into_iter().enumerate() {
            for j in 0..n {
                plan.set_s(i, j, s_row[j]);
            }
            plan.r[i] = r;
        }
        plan
    }

    #[test]
    fn apportion_integral_plan() {
        let plan = plan_from_rows(2, vec![(vec![0.0, 1.0], 0.0), (vec![0.0, 1.0], 0.0)]);
        let a = apportion(&plan, 0, 7);
        assert_eq!(a.keep, 0);
        assert_eq!(a.offloads, vec![(1, 7)]);
    }

    #[test]
    fn apportion_fractional_sums_to_count() {
        let plan = plan_from_rows(
            3,
            vec![
                (vec![0.5, 0.3, 0.0], 0.2),
                (vec![0.0, 1.0, 0.0], 0.0),
                (vec![0.0, 0.0, 1.0], 0.0),
            ],
        );
        for count in [1usize, 2, 3, 10, 17] {
            let a = apportion(&plan, 0, count);
            let offloaded: usize = a.offloads.iter().map(|&(_, c)| c).sum();
            assert!(a.keep + offloaded <= count);
            // exact proportions within 1 unit each
            assert!((a.keep as f64 - 0.5 * count as f64).abs() <= 1.0);
        }
    }

    #[test]
    fn apportion_empty_row_discards_everything() {
        // all-zero row (inactive device shape) normalizes to discard
        let plan = plan_from_rows(2, vec![(vec![0.0, 0.0], 0.0), (vec![0.0, 1.0], 0.0)]);
        let a = apportion(&plan, 0, 5);
        assert_eq!(a.keep, 0);
        assert!(a.offloads.is_empty());
    }

    #[test]
    fn apportion_scratch_reuse_is_stateless() {
        // reusing one scratch across calls must not leak previous results
        let plan_a = plan_from_rows(2, vec![(vec![0.0, 1.0], 0.0), (vec![0.0, 1.0], 0.0)]);
        let plan_b = plan_from_rows(2, vec![(vec![1.0, 0.0], 0.0), (vec![0.0, 1.0], 0.0)]);
        let mut ws = ApportionScratch::default();
        let keep_a = apportion_into(&plan_a, 0, 9, &mut ws);
        assert_eq!((keep_a, ws.offloads.as_slice()), (0, &[(1usize, 9usize)][..]));
        let keep_b = apportion_into(&plan_b, 0, 9, &mut ws);
        assert_eq!((keep_b, ws.offloads.len()), (9, 0));
    }

    /// Property: apportionment conserves the sample count and tracks the
    /// fractional plan within one unit per option.
    #[test]
    fn prop_apportion_conserves_and_tracks() {
        crate::prop::for_all("apportion", 150, |g| {
            let n = g.usize_in(2, 6);
            let count = g.usize_in(0, 40);
            // random simplex row for device 0
            let mut fracs = g.vec_f64(n + 1, 0.0, 1.0); // s_00..s_0(n-1), r_0
            let total: f64 = fracs.iter().sum();
            for f in fracs.iter_mut() {
                *f /= total.max(1e-12);
            }
            let mut plan = MovementPlan::keep_all(n);
            for j in 0..n {
                plan.set_s(0, j, fracs[j]);
            }
            plan.r[0] = fracs[n];

            let a = apportion(&plan, 0, count);
            let offloaded: usize = a.offloads.iter().map(|&(_, c)| c).sum();
            assert!(a.keep + offloaded <= count);
            // per-option counts within 1 of the exact proportion
            assert!((a.keep as f64 - fracs[0] * count as f64).abs() <= 1.0 + 1e-9);
            for &(j, c) in &a.offloads {
                assert!(j != 0 && j < n);
                assert!((c as f64 - fracs[j] * count as f64).abs() <= 1.0 + 1e-9);
            }
            // implied discard also within 1
            let discard = count - a.keep - offloaded;
            assert!((discard as f64 - fracs[n] * count as f64).abs() <= 1.0 + 1e-9);
        });
    }

    // -- session loop with a stub backend (no PJRT needed) ------------------

    /// Deterministic fake backend: "parameters" are a single 2-element
    /// tensor; training accumulates the sample count. Lets the session's
    /// bookkeeping (churn, movement, accounting, aggregation) be tested
    /// without XLA artifacts.
    struct StubCompute;

    impl Compute for StubCompute {
        fn init_params(&self, seed: u64) -> Result<Params> {
            Ok(vec![HostTensor::new(vec![2], vec![(seed % 97) as f32, 0.0])])
        }

        fn train_interval(&self, params: &mut Params, samples: &[u32]) -> Result<Option<f32>> {
            if samples.is_empty() {
                return Ok(None);
            }
            params[0].data[1] += samples.len() as f32;
            Ok(Some(1.0 / (1.0 + params[0].data[1])))
        }

        fn evaluate(&self, params: &[HostTensor]) -> Result<f64> {
            Ok((params[0].data[1] as f64 / 1e4).tanh())
        }
    }

    fn stub_cfg(method: Method) -> EngineConfig {
        EngineConfig {
            method,
            n: 5,
            t_max: 12,
            tau: 4,
            n_train: 600,
            n_test: 120,
            ..Default::default()
        }
    }

    #[test]
    fn session_conserves_datapoints() {
        let cfg = stub_cfg(Method::NetworkAware);
        let sub = Substrates::derive(&cfg);
        let out = run_with(&cfg, &sub, StubCompute).unwrap();
        let m = &out.movement;
        assert!(m.collected() > 0, "nothing collected");
        // every point ends somewhere: processed + discarded never exceeds
        // collected (offloads still in flight at T are the only gap)
        assert!(m.processed() + m.discarded() <= m.collected());
        assert!(m.collected() - (m.processed() + m.discarded()) <= cfg.n * 64);
        assert!(out.ledger.process >= 0.0 && out.ledger.transfer >= 0.0);
        assert_eq!(out.per_device_loss.len(), cfg.t_max);
        assert_eq!(out.per_device_loss[0].len(), cfg.n);
        assert_eq!(out.total_collected, m.collected());
    }

    #[test]
    fn session_is_deterministic() {
        let cfg = stub_cfg(Method::NetworkAware).with(|c| {
            c.churn = Some(Churn { p_exit: 0.1, p_entry: 0.1 });
        });
        let sub = Substrates::derive(&cfg);
        let a = run_with(&cfg, &sub, StubCompute).unwrap();
        let b = run_with(&cfg, &sub, StubCompute).unwrap();
        // and from independently re-derived substrates
        let c = run_with(&cfg, &Substrates::derive(&cfg), StubCompute).unwrap();
        for other in [&b, &c] {
            assert_eq!(a.accuracy, other.accuracy);
            assert_eq!(a.ledger, other.ledger);
            assert_eq!(a.movement.per_interval, other.movement.per_interval);
            assert_eq!(a.per_device_loss, other.per_device_loss);
            assert_eq!(a.similarity, other.similarity);
            assert_eq!(a.mean_active, other.mean_active);
        }
    }

    /// All three dispatch modes must agree bit-for-bit through a backend
    /// whose `train_interval_many` is the default scalar loop: routing is
    /// a perf decision, never a semantic one.
    #[test]
    fn train_path_routing_is_semantically_invisible() {
        let base = stub_cfg(Method::NetworkAware).with(|c| {
            c.churn = Some(Churn { p_exit: 0.1, p_entry: 0.1 });
        });
        let sub = Substrates::derive(&base);
        let outs: Vec<EngineOutput> = [TrainPath::Auto, TrainPath::Batched, TrainPath::Scalar]
            .into_iter()
            .map(|p| {
                let cfg = base.clone().with(|c| c.train_path = p);
                run_with(&cfg, &sub, StubCompute).unwrap()
            })
            .collect();
        for other in &outs[1..] {
            assert_eq!(outs[0].accuracy, other.accuracy);
            assert_eq!(outs[0].per_device_loss, other.per_device_loss);
            assert_eq!(outs[0].ledger, other.ledger);
            assert_eq!(outs[0].movement.per_interval, other.movement.per_interval);
        }
    }

    /// Dense and sparse movement backends must be bit-for-bit identical
    /// through the whole session loop — same ledgers, same losses, same
    /// sample movements — under churn and for every discard model
    /// (DESIGN.md §Perf rule 11).
    #[test]
    fn movement_backend_routing_is_semantically_invisible() {
        use crate::movement::DiscardModel;
        for model in [DiscardModel::LinearR, DiscardModel::LinearG, DiscardModel::Sqrt] {
            let base = stub_cfg(Method::NetworkAware).with(|c| {
                c.discard_model = model;
                c.topology = crate::config::TopologyKind::Random(0.5);
                c.churn = Some(Churn { p_exit: 0.15, p_entry: 0.15 });
            });
            let sub = Substrates::derive(&base);
            let outs: Vec<EngineOutput> =
                [MovementBackend::Dense, MovementBackend::Sparse, MovementBackend::Auto]
                    .into_iter()
                    .map(|b| {
                        let cfg = base.clone().with(|c| c.movement_backend = b);
                        run_with(&cfg, &sub, StubCompute).unwrap()
                    })
                    .collect();
            for other in &outs[1..] {
                assert_eq!(outs[0].accuracy, other.accuracy, "{model:?}");
                assert_eq!(outs[0].per_device_loss, other.per_device_loss, "{model:?}");
                assert_eq!(outs[0].ledger, other.ledger, "{model:?}");
                assert_eq!(
                    outs[0].movement.per_interval, other.movement.per_interval,
                    "{model:?}"
                );
                assert_eq!(outs[0].similarity, other.similarity, "{model:?}");
            }
        }
    }

    /// `--solver-threads` is a pure execution knob: whole-session outputs
    /// are bit-for-bit identical across worker counts (and to the Auto
    /// default), for every discard model, on both plan backends
    /// (DESIGN.md §Perf rule 12).
    #[test]
    fn solver_threads_routing_is_semantically_invisible() {
        use crate::config::SolverThreads;
        use crate::movement::DiscardModel;
        for model in [DiscardModel::LinearR, DiscardModel::LinearG, DiscardModel::Sqrt] {
            for backend in [MovementBackend::Dense, MovementBackend::Sparse] {
                let base = stub_cfg(Method::NetworkAware).with(|c| {
                    c.discard_model = model;
                    c.movement_backend = backend;
                    c.topology = crate::config::TopologyKind::Random(0.5);
                    c.churn = Some(Churn { p_exit: 0.15, p_entry: 0.15 });
                });
                let sub = Substrates::derive(&base);
                let outs: Vec<EngineOutput> = [
                    SolverThreads::Auto,
                    SolverThreads::Fixed(1),
                    SolverThreads::Fixed(2),
                    SolverThreads::Fixed(4),
                ]
                .into_iter()
                .map(|st| {
                    let cfg = base.clone().with(|c| c.solver_threads = st);
                    run_with(&cfg, &sub, StubCompute).unwrap()
                })
                .collect();
                for other in &outs[1..] {
                    assert_eq!(outs[0].accuracy, other.accuracy, "{model:?}/{backend:?}");
                    assert_eq!(outs[0].ledger, other.ledger, "{model:?}/{backend:?}");
                    assert_eq!(
                        outs[0].movement.per_interval, other.movement.per_interval,
                        "{model:?}/{backend:?}"
                    );
                    assert_eq!(outs[0].similarity, other.similarity, "{model:?}/{backend:?}");
                }
            }
        }
    }

    /// Warm starts change PGD trajectories but must keep the session sound:
    /// datapoints stay conserved, runs stay deterministic, and the flag has
    /// zero effect on greedy (closed-form) models.
    #[test]
    fn warm_start_conserves_and_is_inert_for_greedy() {
        use crate::movement::DiscardModel;
        // greedy models: warm start must be a bitwise no-op
        let base = stub_cfg(Method::NetworkAware).with(|c| {
            c.churn = Some(Churn { p_exit: 0.1, p_entry: 0.1 });
        });
        let sub = Substrates::derive(&base);
        let cold = run_with(&base, &sub, StubCompute).unwrap();
        let warm_cfg = base.clone().with(|c| c.warm_start = true);
        let warm = run_with(&warm_cfg, &sub, StubCompute).unwrap();
        assert_eq!(cold.ledger, warm.ledger);
        assert_eq!(cold.movement.per_interval, warm.movement.per_interval);

        // convex model: warm-started runs stay conserved + deterministic
        let sqrt_cfg = base.clone().with(|c| {
            c.discard_model = DiscardModel::Sqrt;
            c.warm_start = true;
        });
        let sub = Substrates::derive(&sqrt_cfg);
        let a = run_with(&sqrt_cfg, &sub, StubCompute).unwrap();
        let b = run_with(&sqrt_cfg, &sub, StubCompute).unwrap();
        assert_eq!(a.ledger, b.ledger);
        assert_eq!(a.movement.per_interval, b.movement.per_interval);
        let m = &a.movement;
        assert!(m.processed() + m.discarded() <= m.collected());
    }

    /// Eval schedules and paths must never touch anything but the curve:
    /// through a backend whose evaluate ignores the sample subset (the
    /// trait defaults), every (schedule, path) combination is bit-for-bit
    /// identical — scheduling is a cost decision, never a semantic one
    /// for the learning loop itself.
    #[test]
    fn eval_schedule_routing_is_semantically_invisible() {
        let base = stub_cfg(Method::NetworkAware).with(|c| {
            c.eval_curve = true;
            c.churn = Some(Churn { p_exit: 0.1, p_entry: 0.1 });
        });
        let sub = Substrates::derive(&base);
        let mut outs = Vec::new();
        for schedule in [EvalSchedule::Full, EvalSchedule::Subset { shards: 3 }] {
            for path in [EvalPath::Auto, EvalPath::Batched, EvalPath::Scalar] {
                let cfg = base.clone().with(|c| {
                    c.eval_schedule = schedule;
                    c.eval_path = path;
                });
                outs.push(run_with(&cfg, &sub, StubCompute).unwrap());
            }
        }
        assert_eq!(outs[0].accuracy_curve.len(), base.t_max / base.tau);
        for other in &outs[1..] {
            assert_eq!(outs[0].accuracy, other.accuracy);
            assert_eq!(outs[0].accuracy_curve, other.accuracy_curve);
            assert_eq!(outs[0].per_device_loss, other.per_device_loss);
            assert_eq!(outs[0].ledger, other.ledger);
            assert_eq!(outs[0].movement.per_interval, other.movement.per_interval);
        }
    }

    /// The session issues exactly one `evaluate_many` dispatch per curve
    /// point — the contract that makes a pooled run cost one `EvalMany`
    /// round-trip per point instead of one `evaluate` per chunk/device.
    #[test]
    fn one_eval_dispatch_per_curve_point() {
        use std::cell::Cell;
        struct CountingCompute<'a> {
            many: &'a Cell<usize>,
        }
        impl Compute for CountingCompute<'_> {
            fn init_params(&self, seed: u64) -> Result<Params> {
                StubCompute.init_params(seed)
            }
            fn train_interval(
                &self,
                params: &mut Params,
                samples: &[u32],
            ) -> Result<Option<f32>> {
                StubCompute.train_interval(params, samples)
            }
            fn evaluate(&self, params: &[HostTensor]) -> Result<f64> {
                StubCompute.evaluate(params)
            }
            fn evaluate_many(
                &self,
                work: &mut [EvalWork],
                _path: EvalPath,
            ) -> Result<()> {
                self.many.set(self.many.get() + 1);
                for w in work.iter_mut() {
                    w.accuracy = Some(self.evaluate(&w.params)?);
                }
                Ok(())
            }
        }

        let cfg = stub_cfg(Method::NetworkAware).with(|c| {
            c.eval_curve = true;
            c.eval_schedule = EvalSchedule::Subset { shards: 2 };
        });
        let sub = Substrates::derive(&cfg);
        let counter = Cell::new(0);
        let points = cfg.t_max / cfg.tau;
        let out =
            run_with(&cfg, &sub, CountingCompute { many: &counter }).unwrap();
        assert_eq!(out.accuracy_curve.len(), points);
        assert_eq!(counter.get(), points, "one evaluate_many dispatch per point");
    }

    /// Under a batched train path the session issues exactly one
    /// `train_interval_many` dispatch per interval with trainees — the
    /// contract the coalescing runtime-service scheduler builds on: one
    /// `TrainMany` request per session-interval is what the service can
    /// pack across sessions (DESIGN.md §Perf rule 10).
    #[test]
    fn one_train_dispatch_per_interval() {
        use std::cell::Cell;
        struct CountingCompute<'a> {
            many: &'a Cell<usize>,
        }
        impl Compute for CountingCompute<'_> {
            fn init_params(&self, seed: u64) -> Result<Params> {
                StubCompute.init_params(seed)
            }
            fn train_interval(
                &self,
                params: &mut Params,
                samples: &[u32],
            ) -> Result<Option<f32>> {
                StubCompute.train_interval(params, samples)
            }
            fn train_interval_many(&self, work: &mut [DeviceWork]) -> Result<()> {
                self.many.set(self.many.get() + 1);
                for w in work.iter_mut() {
                    w.loss = self.train_interval(&mut w.params, &w.samples)?;
                }
                Ok(())
            }
            fn evaluate(&self, params: &[HostTensor]) -> Result<f64> {
                StubCompute.evaluate(params)
            }
        }

        let cfg = stub_cfg(Method::NetworkAware)
            .with(|c| c.train_path = TrainPath::Batched);
        let sub = Substrates::derive(&cfg);
        let counter = Cell::new(0);
        let out = run_with(&cfg, &sub, CountingCompute { many: &counter }).unwrap();
        // every interval with at least one trainee dispatched exactly once
        let training_intervals = out
            .per_device_loss
            .iter()
            .filter(|row| row.iter().any(Option::is_some))
            .count();
        assert!(training_intervals > 0);
        assert_eq!(counter.get(), training_intervals);
    }

    /// The centralized baseline routes its curve through the same planner.
    #[test]
    fn centralized_curve_goes_through_planner() {
        let cfg = stub_cfg(Method::Centralized).with(|c| {
            c.eval_curve = true;
            c.eval_schedule = EvalSchedule::Subset { shards: 2 };
        });
        let sub = Substrates::derive(&cfg);
        let out = run_with(&cfg, &sub, StubCompute).unwrap();
        assert_eq!(out.accuracy_curve.len(), cfg.t_max / cfg.tau);
        // stub evaluate is monotone in trained volume: curve non-decreasing
        assert!(out
            .accuracy_curve
            .windows(2)
            .all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn federated_session_moves_nothing() {
        let cfg = stub_cfg(Method::Federated);
        let sub = Substrates::derive(&cfg);
        let out = run_with(&cfg, &sub, StubCompute).unwrap();
        assert_eq!(out.movement.offloaded(), 0);
        assert_eq!(out.movement.discarded(), 0);
        assert_eq!(out.movement.processed(), out.movement.collected());
        assert_eq!(out.ledger.transfer, 0.0);
        assert_eq!(out.ledger.discard, 0.0);
    }

    #[test]
    fn churn_reduces_active_devices() {
        let static_cfg = stub_cfg(Method::NetworkAware);
        let churn_cfg = static_cfg
            .clone()
            .with(|c| c.churn = Some(Churn { p_exit: 0.25, p_entry: 0.05 }));
        let s = run_with(&static_cfg, &Substrates::derive(&static_cfg), StubCompute).unwrap();
        let d = run_with(&churn_cfg, &Substrates::derive(&churn_cfg), StubCompute).unwrap();
        assert_eq!(s.mean_active, static_cfg.n as f64);
        assert!(d.mean_active < s.mean_active);
        assert!(d.total_collected < s.total_collected);
    }

    #[test]
    fn centralized_session_has_no_network_costs() {
        let cfg = stub_cfg(Method::Centralized);
        let sub = Substrates::derive(&cfg);
        let out = run_with(&cfg, &sub, StubCompute).unwrap();
        assert_eq!(out.ledger.total(), 0.0);
        assert_eq!(out.movement.collected(), 0);
        assert!(out.total_collected > 0);
        assert_eq!(out.mean_active, cfg.n as f64);
    }

    #[test]
    fn stepwise_equals_run() {
        let cfg = stub_cfg(Method::NetworkAware);
        let sub = Substrates::derive(&cfg);
        let whole = run_with(&cfg, &sub, StubCompute).unwrap();

        let mut session = Session::new(&cfg, &sub, StubCompute).unwrap();
        for t in 0..cfg.t_max {
            session.step_churn(t);
            session.step_collect(t);
            session.step_movement(t);
            session.step_train(t).unwrap();
            session.step_aggregate(t).unwrap();
        }
        let stepped = session.finish().unwrap();
        assert_eq!(whole.accuracy, stepped.accuracy);
        assert_eq!(whole.ledger, stepped.ledger);
        assert_eq!(whole.movement.per_interval, stepped.movement.per_interval);
    }

    /// The trace flag is pure observability (DESIGN.md §Perf rule 14):
    /// everything the learning loop computes is bit-identical with it
    /// off; only the recorded trace state (loss rows, similarity) and the
    /// O(t_max·n) allocation behind it disappear.
    #[test]
    fn trace_flag_is_observation_only() {
        for method in [Method::NetworkAware, Method::Federated, Method::Centralized] {
            let on = stub_cfg(method).with(|c| {
                c.eval_curve = true;
                c.churn =
                    (method != Method::Centralized).then_some(Churn { p_exit: 0.1, p_entry: 0.1 });
            });
            let off = on.clone().with(|c| c.trace = false);
            let sub = Substrates::derive(&on);
            let a = run_with(&on, &sub, StubCompute).unwrap();
            let b = run_with(&off, &sub, StubCompute).unwrap();
            assert_eq!(a.accuracy, b.accuracy, "{method:?}");
            assert_eq!(a.accuracy_curve, b.accuracy_curve, "{method:?}");
            assert_eq!(a.ledger, b.ledger, "{method:?}");
            assert_eq!(a.movement.per_interval, b.movement.per_interval, "{method:?}");
            assert_eq!(a.total_collected, b.total_collected, "{method:?}");
            assert!(!a.per_device_loss.is_empty(), "{method:?}");
            assert!(b.per_device_loss.is_empty(), "{method:?}");
            assert_eq!(b.similarity, (0.0, 0.0), "{method:?}");
        }
    }

    /// Period-end resync is pointer bumps, not clones: after an
    /// aggregation every active device aliases the global allocation, and
    /// mid-period only the devices that actually trained hold private
    /// copies (§Perf rule 14; `tests/aggregation.rs` proves the aliasing
    /// never leaks a trainee's mutation).
    #[test]
    fn resync_shares_the_epoch_allocation() {
        let cfg = stub_cfg(Method::NetworkAware);
        let sub = Substrates::derive(&cfg);
        let mut session = Session::new(&cfg, &sub, StubCompute).unwrap();
        // initial state: one allocation, n + 1 handles
        for p in &session.state.device_params {
            assert!(Arc::ptr_eq(p, &session.state.global));
        }
        for t in 0..cfg.tau {
            session.step_churn(t);
            session.step_collect(t);
            session.step_movement(t);
            session.step_train(t).unwrap();
            if t + 1 < cfg.tau {
                // mid-period: exactly the devices that have trained so far
                // have diverged from the shared epoch
                for (i, p) in session.state.device_params.iter().enumerate() {
                    let trained = session.state.h[i] > 0.0;
                    assert_eq!(!Arc::ptr_eq(p, &session.state.global), trained, "device {i}");
                }
            }
            session.step_aggregate(t).unwrap();
        }
        // period end: everyone re-shares the (new) epoch allocation
        for (i, p) in session.state.device_params.iter().enumerate() {
            assert!(Arc::ptr_eq(p, &session.state.global), "device {i} not resynced");
        }
    }
}
