//! `SimPool`: parallel fan-out of independent (config, seed) engine runs.
//!
//! The paper's evaluation (§V) is built from dozens of independent runs —
//! every table cell and figure point averages several seeds, and every
//! sweep walks a parameter grid. Those runs share nothing but the compiled
//! XLA executables, so they parallelize perfectly: the pool keeps a small
//! set of [`RuntimeService`] threads (each owning a PJRT runtime and a
//! compile cache) and streams queued [`EngineConfig`]s through worker
//! threads that derive substrates, register their datasets, and drive a
//! [`Session`](crate::fed::session::Session) against a service handle.
//!
//! Determinism: a run's output depends only on its config (substrate
//! derivation is seeded; XLA CPU execution is deterministic), never on
//! which worker or service executed it or in which order. `jobs = 1`
//! therefore reproduces the serial `fed::run` numbers bit-for-bit, and
//! `jobs = N` reproduces `jobs = 1` (see `tests/determinism.rs`).
//!
//! Shared services are the headline scale-out shape since the coalescing
//! scheduler landed: [`SimPool::coalescing`] keeps `K < jobs` service
//! threads whose schedulers pack concurrent sessions' `TrainMany`/
//! `EvalMany` requests into shared largest-tile dispatches (CLI
//! `--services K`; DESIGN.md §Perf rule 10). Outputs stay invariant to
//! the partner sessions, the service count and the job count — only the
//! default per-worker-service pool ([`SimPool::new`]) is additionally
//! bit-identical to serial `fed::run` (coalesced runs agree with it
//! within the §Perf rule 7/8 tolerances, because the tile policy
//! differs).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::config::EngineConfig;
use crate::coordinator::service::{RuntimeService, ServiceClient, ServiceConfig};
use crate::fed::session::{self, EngineOutput, Substrates};

thread_local! {
    /// How many pool workers (including this one) share the machine, seen
    /// from the current thread: 1 on the serial path and on every thread
    /// that is not a pool worker; the worker count inside `run_many`
    /// fan-outs. `SolverThreads::Auto` divides `available_parallelism()`
    /// by this share so concurrent sessions don't oversubscribe cores.
    /// Deliberately NOT part of `EngineConfig`: it only gates *how many*
    /// workers the (bit-invariant) fixed-chunk passes use — the
    /// row-parallel movement solvers (§Perf rule 12) and the
    /// chunk-parallel federated average (§Perf rule 14) — never what
    /// they compute, so a per-invocation `--jobs` value must not
    /// perturb config fingerprints.
    static POOL_SHARE: Cell<usize> = const { Cell::new(1) };
}

/// The current thread's pool share (≥ 1). See [`POOL_SHARE`].
pub fn worker_share() -> usize {
    POOL_SHARE.with(|s| s.get().max(1))
}

fn set_worker_share(share: usize) {
    POOL_SHARE.with(|s| s.set(share.max(1)));
}

/// A pool of engine workers over shared runtime services.
pub struct SimPool {
    jobs: usize,
    services: Vec<RuntimeService>,
}

impl SimPool {
    /// A pool running up to `jobs` concurrent runs, with one runtime
    /// service per worker (maximum training parallelism; each service
    /// compiles its own executables once).
    pub fn new(jobs: usize) -> SimPool {
        let jobs = jobs.max(1);
        Self::with_services(jobs, jobs)
    }

    /// Explicit service count with the classic (non-coalescing)
    /// scheduler: `services < jobs` makes workers share service threads
    /// (less memory and compilation, but training requests serialize per
    /// service). Kept for bit-compatibility with pre-scheduler releases;
    /// the shared-service shape you normally want is
    /// [`SimPool::coalescing`].
    pub fn with_services(jobs: usize, services: usize) -> SimPool {
        Self::with_service_config(jobs, services, ServiceConfig::default())
    }

    /// `K` shared **coalescing** services (CLI `--services K`): each
    /// service's scheduler drains its queue and packs concurrent
    /// sessions' batched requests into shared largest-tile dispatches, so
    /// under-filled per-session stacks merge into full ones instead of
    /// serializing. Outputs are invariant to `jobs`, `services` and the
    /// co-scheduled partners (`tests/determinism.rs`).
    pub fn coalescing(jobs: usize, services: usize) -> SimPool {
        Self::with_service_config(jobs, services, ServiceConfig::coalescing())
    }

    /// The general constructor: `jobs` workers over `services` service
    /// threads, each spawned with `cfg`.
    pub fn with_service_config(jobs: usize, services: usize, cfg: ServiceConfig) -> SimPool {
        let jobs = jobs.max(1);
        let services = services.clamp(1, jobs);
        SimPool {
            jobs,
            services: (0..services).map(|_| RuntimeService::spawn_with(cfg)).collect(),
        }
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Run one config end-to-end against a service: derive substrates,
    /// register the datasets, drive the session, release the datasets.
    pub fn run_one(client: &ServiceClient, cfg: &EngineConfig) -> Result<EngineOutput> {
        let sub = Substrates::derive(cfg);
        let ds = client.register_dataset(sub.train.clone(), sub.test.clone())?;
        let handle = client.bind(cfg.model, cfg.lr, ds);
        let out = session::run_with(cfg, &sub, handle);
        client.unregister_dataset(ds);
        out
    }

    /// Run `cfg` once on *every* service in the pool — e.g. to force each
    /// service's XLA compilation before a timed measurement (`run_many`'s
    /// work-stealing gives no such guarantee).
    pub fn warm(&self, cfg: &EngineConfig) -> Result<()> {
        for svc in &self.services {
            Self::run_one(&svc.client(), cfg)?;
        }
        Ok(())
    }

    /// Run every config, up to `jobs` at a time, and return the outputs in
    /// input order. The first failed run aborts with its error (remaining
    /// in-flight runs finish their current request and are discarded).
    pub fn run_many(&self, cfgs: &[EngineConfig]) -> Result<Vec<EngineOutput>> {
        if cfgs.is_empty() {
            return Ok(Vec::new());
        }
        let workers = self.jobs.min(cfgs.len());
        if workers <= 1 {
            let client = self.services[0].client();
            return cfgs.iter().map(|cfg| Self::run_one(&client, cfg)).collect();
        }

        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<EngineOutput>>>> =
            cfgs.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for w in 0..workers {
                let client = self.services[w % self.services.len()].client();
                let next = &next;
                let slots = &slots;
                scope.spawn(move || {
                    set_worker_share(workers);
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= cfgs.len() {
                            break;
                        }
                        let out = Self::run_one(&client, &cfgs[i]);
                        let failed = out.is_err();
                        *slots[i].lock().unwrap() = Some(out);
                        if failed {
                            // drain the queue so sibling workers stop early
                            next.store(cfgs.len(), Ordering::Relaxed);
                            break;
                        }
                    }
                });
            }
        });

        let mut outs = Vec::with_capacity(cfgs.len());
        for slot in slots {
            match slot.into_inner().unwrap() {
                Some(res) => outs.push(res?),
                None => return Err(anyhow!("pooled run aborted before completion")),
            }
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    fn tiny(seed: u64) -> EngineConfig {
        EngineConfig {
            method: Method::NetworkAware,
            n: 4,
            t_max: 10,
            tau: 5,
            n_train: 400,
            n_test: 100,
            seed,
            ..Default::default()
        }
    }

    /// Pooled outputs must arrive in input order and match a serial rerun
    /// of the same configs bit-for-bit.
    #[test]
    fn pool_preserves_order_and_determinism() {
        if !crate::runtime::backend_available() {
            return;
        }
        let cfgs: Vec<EngineConfig> = (1..=4).map(tiny).collect();
        let pool = SimPool::new(2);
        let pooled = pool.run_many(&cfgs).expect("pooled runs");
        let serial_pool = SimPool::new(1);
        let serial = serial_pool.run_many(&cfgs).expect("serial runs");
        assert_eq!(pooled.len(), cfgs.len());
        for (a, b) in pooled.iter().zip(&serial) {
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.ledger, b.ledger);
            assert_eq!(a.movement.per_interval, b.movement.per_interval);
        }
        // different seeds actually produce different runs
        assert!(pooled.windows(2).any(|w| w[0].accuracy != w[1].accuracy
            || w[0].ledger != w[1].ledger));
    }
}
