//! Device actors + aggregation server: the deployment-shaped federated
//! cluster (one thread per device, one server thread, PJRT behind the
//! runtime service).
//!
//! Protocol per aggregation period (eq. 3/4 of the paper):
//! 1. the server broadcasts the global parameters to every device actor;
//! 2. each device runs τ intervals of local updates on its own arrival
//!    schedule (train requests are serialized by the runtime service, but
//!    actors overlap their bookkeeping and message handling);
//! 3. devices report `(w_i, H_i)`; the server computes the weighted average
//!    and the next round begins.
//!
//! This module exists to prove the system composes as an actual
//! distributed-shaped runtime; the measurement-focused experiments run the
//! [`crate::fed::session`] engine instead — single-threaded via
//! [`crate::fed::run`], or fanned out via [`crate::coordinator::pool::SimPool`].

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::service::{Params, RuntimeHandle, RuntimeService};

use crate::data::Partitioner;
use crate::fed::aggregator;
use crate::runtime::ModelKind;
use crate::util::rng::Rng;

/// Cluster configuration (a deliberately small subset of
/// [`crate::config::EngineConfig`] — the cluster demonstrates topology-free
/// federated rounds; movement optimization lives in the engine).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub model: ModelKind,
    pub n_devices: usize,
    pub rounds: usize,
    /// Local intervals per round (τ).
    pub tau: usize,
    pub lr: f32,
    pub iid: bool,
    pub n_train: usize,
    pub n_test: usize,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            model: ModelKind::Mlp,
            n_devices: 4,
            rounds: 5,
            tau: 5,
            lr: 0.05,
            iid: true,
            n_train: 2000,
            n_test: 500,
            seed: 1,
        }
    }
}

/// Result of a cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Test accuracy after each round.
    pub round_accuracy: Vec<f64>,
    /// Total datapoints processed per device.
    pub device_samples: Vec<usize>,
}

enum ToDevice {
    /// Round broadcast. The epoch params are shared copy-on-write
    /// (DESIGN.md §Perf rule 14): the server sends n pointer bumps and
    /// each actor materializes its private copy on its *own* thread, in
    /// parallel — instead of the server deep-cloning |params| n times per
    /// round before any device lifts a finger.
    Round { params: Arc<Params>, round: usize },
    Stop,
}

struct FromDevice {
    device: usize,
    params: Params,
    processed: f64,
}

/// A running federated cluster.
pub struct Cluster;

impl Cluster {
    /// Build the workloads, spawn the service + device actors, run all
    /// rounds, and return the accuracy trajectory.
    pub fn run(cfg: &ClusterConfig) -> Result<ClusterReport> {
        // shared fixed-task prototypes (derived once per process)
        let gen = crate::fed::session::task_generator();
        let mut rng = Rng::new(cfg.seed);
        let (train, test) = gen.train_test(cfg.n_train, cfg.n_test, &mut rng);
        let t_max = cfg.rounds * cfg.tau;
        let arrivals = Partitioner { n_devices: cfg.n_devices, t_max, iid: cfg.iid }
            .partition(&train, &mut rng);

        let mut svc = RuntimeService::spawn(cfg.model, cfg.lr, train.clone(), test.clone());
        let handle = svc.handle();
        let global = handle.init_params(cfg.seed ^ 0xA11CE)?;

        // spawn device actors
        let (result_tx, result_rx): (Sender<FromDevice>, Receiver<FromDevice>) = channel();
        let mut device_txs = Vec::new();
        let mut joins = Vec::new();
        for dev in 0..cfg.n_devices {
            let (tx, rx): (Sender<ToDevice>, Receiver<ToDevice>) = channel();
            device_txs.push(tx);
            let schedule: Vec<Vec<u32>> = arrivals.schedule[dev].clone();
            let handle = handle.clone();
            let results = result_tx.clone();
            let tau = cfg.tau;
            joins.push(std::thread::Builder::new().name(format!("fogml-dev{dev}")).spawn(
                move || {
                    device_actor(dev, rx, results, handle, schedule, tau);
                },
            )?);
        }
        drop(result_tx);

        // server loop
        let mut global = Arc::new(global);
        let mut round_accuracy = Vec::with_capacity(cfg.rounds);
        let mut device_samples = vec![0usize; cfg.n_devices];
        for round in 0..cfg.rounds {
            for tx in &device_txs {
                tx.send(ToDevice::Round { params: Arc::clone(&global), round })
                    .map_err(|_| anyhow!("device actor died"))?;
            }
            let mut contributions: Vec<(Params, f64)> = Vec::with_capacity(cfg.n_devices);
            for _ in 0..cfg.n_devices {
                let msg = result_rx
                    .recv()
                    .map_err(|_| anyhow!("device actors all gone"))?;
                device_samples[msg.device] += msg.processed as usize;
                contributions.push((msg.params, msg.processed));
            }
            let refs: Vec<(&Params, f64)> =
                contributions.iter().map(|(p, h)| (p, *h)).collect();
            if let Some(agg) = aggregator::aggregate(&refs)? {
                global = Arc::new(agg);
            }
            round_accuracy.push(handle.evaluate((*global).clone())?);
        }

        for tx in &device_txs {
            let _ = tx.send(ToDevice::Stop);
        }
        for j in joins {
            let _ = j.join();
        }
        svc.shutdown();
        Ok(ClusterReport { round_accuracy, device_samples })
    }
}

/// One device actor: waits for the round broadcast, runs τ intervals of
/// local updates on its schedule, reports back (w_i, H_i).
fn device_actor(
    device: usize,
    rx: Receiver<ToDevice>,
    results: Sender<FromDevice>,
    handle: RuntimeHandle,
    schedule: Vec<Vec<u32>>,
    tau: usize,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            ToDevice::Round { params, round } => {
                // clone off the shared epoch on the actor's own thread
                // (try_unwrap succeeds — zero copy — only if every other
                // holder already dropped its handle)
                let mut params = Arc::try_unwrap(params).unwrap_or_else(|p| (*p).clone());
                let mut processed = 0f64;
                for step in 0..tau {
                    let t = round * tau + step;
                    let samples = schedule.get(t).cloned().unwrap_or_default();
                    if samples.is_empty() {
                        continue;
                    }
                    processed += samples.len() as f64;
                    match handle.train(params, samples) {
                        Ok((np, _)) => params = np,
                        Err(_) => return, // service gone: exit actor
                    }
                }
                if results.send(FromDevice { device, params, processed }).is_err() {
                    return;
                }
            }
            ToDevice::Stop => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Full actor-based federated run: accuracy must climb well above
    /// chance and every device must have contributed.
    #[test]
    fn cluster_round_trip_learns() {
        if !crate::runtime::backend_available() {
            return;
        }
        let cfg = ClusterConfig { rounds: 4, ..Default::default() };
        let report = Cluster::run(&cfg).expect("cluster run");
        assert_eq!(report.round_accuracy.len(), 4);
        let final_acc = *report.round_accuracy.last().unwrap();
        assert!(final_acc > 0.5, "final accuracy {final_acc}");
        for (dev, &n) in report.device_samples.iter().enumerate() {
            assert!(n > 0, "device {dev} processed nothing");
        }
        // later rounds should not be (much) worse than the first
        assert!(final_acc + 0.05 >= report.round_accuracy[0]);
    }
}
