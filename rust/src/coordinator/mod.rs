//! Thread-based leader/worker coordination.
//!
//! `xla::PjRtClient` is `Rc`-based and thread-confined, so all PJRT
//! execution lives on a dedicated **runtime-service thread**; device actors
//! and the aggregation server communicate with it (and each other) over
//! `std::sync::mpsc` channels. This mirrors the paper's deployment shape —
//! devices compute local updates, a server aggregates every τ intervals —
//! while keeping the simulation engine (`fed::engine`) free to use the
//! faster single-threaded direct path.
//!
//! * [`service`] — the runtime-service thread and its typed handle.
//! * [`cluster`] — device actors + aggregation server wired together.

pub mod cluster;
pub mod service;

pub use cluster::{Cluster, ClusterConfig, ClusterReport};
pub use service::{RuntimeHandle, RuntimeService};
