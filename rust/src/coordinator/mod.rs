//! Thread-based leader/worker coordination.
//!
//! `xla::PjRtClient` is `Rc`-based and thread-confined, so all PJRT
//! execution lives on dedicated **runtime-service threads**; device actors,
//! the aggregation server, and pool workers communicate with them (and each
//! other) over `std::sync::mpsc` channels. This mirrors the paper's
//! deployment shape — devices compute local updates, a server aggregates
//! every τ intervals — while keeping the simulation engine
//! (`fed::session`) free to use the faster single-threaded direct path.
//!
//! * [`service`] — the runtime-service thread: model/dataset-agnostic,
//!   with a raw [`ServiceClient`] and a bound [`RuntimeHandle`] that
//!   implements [`crate::fed::session::Compute`]. Its loop is a
//!   **coalescing scheduler** ([`ServiceConfig`]): when enabled, pending
//!   `TrainMany`/`EvalMany` requests from different sessions pack into
//!   shared largest-tile dispatches (DESIGN.md §Perf rule 10).
//! * [`pool`] — [`SimPool`]: parallel fan-out of independent
//!   (config, seed) engine runs across worker threads — each with its own
//!   service ([`SimPool::new`]) or over `K` shared coalescing services
//!   ([`SimPool::coalescing`], CLI `--services K`).
//! * [`shard`] — cross-process sweep sharding: [`SweepCtx`] splits one
//!   experiment grid across N `fogml` processes (`--shard I/N`) and
//!   `fogml merge` reassembles bit-identical results.
//! * [`binfmt`] — the binary shard wire format (`shard_I_of_N.fsb`):
//!   streaming little-endian writer + forward-only zero-copy reader,
//!   raw f64 bit patterns instead of JSON text (`--shard-format binary`).
//! * [`cluster`] — device actors + aggregation server wired together.

pub mod binfmt;
pub mod cluster;
pub mod pool;
pub mod service;
pub mod shard;

pub use cluster::{Cluster, ClusterConfig, ClusterReport};
pub use pool::SimPool;
pub use service::{DatasetId, RuntimeHandle, RuntimeService, ServiceClient, ServiceConfig};
pub use shard::{ShardFormat, ShardSpec, SweepCtx};
