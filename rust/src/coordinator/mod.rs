//! Thread-based leader/worker coordination.
//!
//! `xla::PjRtClient` is `Rc`-based and thread-confined, so all PJRT
//! execution lives on dedicated **runtime-service threads**; device actors,
//! the aggregation server, and pool workers communicate with them (and each
//! other) over `std::sync::mpsc` channels. This mirrors the paper's
//! deployment shape — devices compute local updates, a server aggregates
//! every τ intervals — while keeping the simulation engine
//! (`fed::session`) free to use the faster single-threaded direct path.
//!
//! * [`service`] — the runtime-service thread: model/dataset-agnostic,
//!   with a raw [`ServiceClient`] and a bound [`RuntimeHandle`] that
//!   implements [`crate::fed::session::Compute`].
//! * [`pool`] — [`SimPool`]: parallel fan-out of independent
//!   (config, seed) engine runs across worker threads.
//! * [`shard`] — cross-process sweep sharding: [`SweepCtx`] splits one
//!   experiment grid across N `fogml` processes (`--shard I/N`) and
//!   `fogml merge` reassembles bit-identical results.
//! * [`cluster`] — device actors + aggregation server wired together.

pub mod cluster;
pub mod pool;
pub mod service;
pub mod shard;

pub use cluster::{Cluster, ClusterConfig, ClusterReport};
pub use pool::SimPool;
pub use service::{DatasetId, RuntimeHandle, RuntimeService, ServiceClient};
pub use shard::{ShardSpec, SweepCtx};
