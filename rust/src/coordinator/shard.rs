//! Cross-process sweep sharding: split one experiment's (config, seed)
//! grid across N `fogml` processes and merge the results exactly.
//!
//! The paper's evaluation is built from grids of independent engine runs
//! (every table cell and figure point averages several seeds).
//! [`crate::coordinator::SimPool`] parallelizes those runs *within* one
//! process; this module shards them *across* processes or machines:
//!
//! ```text
//! machine 1:  fogml exp table3 --shard 1/4 --out shards   # runs 0,4,8,…
//! machine 2:  fogml exp table3 --shard 2/4 --out shards   # runs 1,5,9,…
//! machine 3:  fogml exp table3 --shard 3/4 --out shards   # runs 2,6,10,…
//! machine 4:  fogml exp table3 --shard 4/4 --out shards   # runs 3,7,11,…
//! anywhere:   fogml merge shards --out results            # ≡ serial run
//! ```
//!
//! # The determinism / merge contract
//!
//! 1. **Canonical expansion order.** A driver's grid is the sequence of
//!    configs it passes to [`SweepCtx::run_many`], concatenated in call
//!    order. Drivers are deterministic functions of their options, so
//!    every process — shard 1, shard N, the merge — enumerates the exact
//!    same sequence and assigns each run the same global index.
//! 2. **Round-robin assignment.** Run `j` belongs to shard
//!    `(j mod N) + 1`. Shards are disjoint by construction and their
//!    union is the full grid, so completeness is checkable without any
//!    coordination between processes.
//! 3. **Fingerprints.** Every run records a fingerprint of its config
//!    (FNV-1a 64 over the canonical [`Debug`] encoding); the shard file
//!    additionally records the whole-grid fingerprint (the per-run
//!    fingerprints folded in order). [`load_shard_set`] refuses to mix
//!    files from different grids, and the merge replay re-fingerprints
//!    every config it expands against the recorded value — options or
//!    code drift between shard time and merge time fails loudly instead
//!    of silently mislabeling rows.
//! 4. **Exact reassembly.** [`SimPool::run_many`] returns outputs in
//!    input order regardless of worker scheduling (the pool's
//!    determinism contract), and the shard files round-trip every float
//!    exactly in **both** on-disk formats — JSON (`shard_I_of_N.json`,
//!    Rust's shortest-roundtrip formatting plus tagged-string escapes)
//!    and binary (`shard_I_of_N.fsb`, raw f64 bit patterns through
//!    [`crate::coordinator::binfmt`]) — so a merge's tables and curve
//!    CSVs are **byte-identical** to an unsharded serial run whichever
//!    format the shards used (`tests/shard_merge.rs`).
//!
//! [`SweepCtx`] is the mechanism: drivers route both their engine runs
//! and their output (tables, CSVs, console lines) through it, and the
//! context either executes everything (run mode), executes only its
//! shard and writes `shard_I_of_N.{json,fsb}` instead of artifacts
//! (shard mode; [`ShardFormat`] picks the extension via
//! `--shard-format`), or replays recorded outputs and emits the real
//! artifacts (merge mode). [`ShardFile::load`] auto-detects the format
//! per file by content, so `fogml merge` never needs a format flag —
//! but [`load_shard_set`] still refuses mixed-format sets (convert with
//! `fogml shard convert` first).

use std::cell::RefCell;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::EngineConfig;
use crate::coordinator::binfmt;
use crate::coordinator::SimPool;
use crate::fed::accounting::{IntervalStats, Ledger, MovementTotals};
use crate::fed::EngineOutput;
use crate::util::json::Json;
use crate::util::table::Table;

/// Version stamp written into every JSON shard file; [`load_shard_set`]
/// rejects files from incompatible future formats. (The binary format
/// carries its own version — [`binfmt::BINARY_FORMAT_VERSION`].)
pub const SHARD_FORMAT_VERSION: usize = 1;

/// On-disk encoding of a shard file. JSON is the debug/interop default;
/// binary ([`crate::coordinator::binfmt`]) is the opt-in fast path for
/// large sweeps. Both round-trip every float exactly and merge
/// byte-identically — the choice is pure I/O cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardFormat {
    /// `shard_I_of_N.json` — human-readable, tagged-string float escapes.
    #[default]
    Json,
    /// `shard_I_of_N.fsb` — length-prefixed little-endian, raw f64 bits.
    Binary,
}

impl ShardFormat {
    /// Parse the CLI form: `--shard-format json|binary`.
    pub fn parse(s: &str) -> Result<ShardFormat> {
        match s {
            "json" => Ok(ShardFormat::Json),
            "binary" | "fsb" => Ok(ShardFormat::Binary),
            other => bail!("--shard-format wants json|binary, got '{other}'"),
        }
    }

    /// The file extension this format writes (no leading dot).
    pub fn extension(&self) -> &'static str {
        match self {
            ShardFormat::Json => "json",
            ShardFormat::Binary => "fsb",
        }
    }
}

impl std::fmt::Display for ShardFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardFormat::Json => write!(f, "json"),
            ShardFormat::Binary => write!(f, "binary"),
        }
    }
}

/// Which slice of the grid this process runs: `--shard I/N` (1-based
/// index `I`, total shard count `N`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// 1-based shard index `I` (`1 ≤ I ≤ N`).
    pub index: usize,
    /// Total number of shards `N`.
    pub count: usize,
}

impl ShardSpec {
    /// Parse the CLI form `I/N` (e.g. `2/4`).
    pub fn parse(s: &str) -> Result<ShardSpec> {
        let (i, n) = s
            .split_once('/')
            .ok_or_else(|| anyhow!("--shard wants I/N (e.g. 2/4), got '{s}'"))?;
        let index: usize =
            i.trim().parse().map_err(|e| anyhow!("--shard index '{i}': {e}"))?;
        let count: usize =
            n.trim().parse().map_err(|e| anyhow!("--shard count '{n}': {e}"))?;
        if count == 0 {
            bail!("--shard count must be at least 1 (got {s})");
        }
        if index == 0 || index > count {
            bail!("--shard index must be in 1..={count} (got {index})");
        }
        Ok(ShardSpec { index, count })
    }

    /// Round-robin ownership: does this shard execute global run `j`?
    pub fn owns(&self, run: usize) -> bool {
        run % self.count == self.index - 1
    }

    /// The file this shard serializes to: `shard_I_of_N.json` or
    /// `shard_I_of_N.fsb` depending on `format`.
    pub fn file_name(&self, format: ShardFormat) -> String {
        format!("shard_{}_of_{}.{}", self.index, self.count, format.extension())
    }

    /// Inverse of [`ShardSpec::file_name`]; `None` when `name` is not a
    /// shard file.
    ///
    /// Strict by design: only *canonical* names round-trip. Anything a
    /// human or an editor derives from one — `shard_1_of_2.json.bak`,
    /// `shard_1_of_2.json~`, `.#shard_1_of_2.json`, `shard_01_of_2.json`
    /// (leading zeros), `shard_+1_of_2.json` — returns `None`, so stray
    /// files sitting next to a shard set are ignored instead of
    /// poisoning [`load_shard_set`]'s validation.
    pub fn parse_file_name(name: &str) -> Option<(ShardSpec, ShardFormat)> {
        let rest = name.strip_prefix("shard_")?;
        let (rest, format) = if let Some(r) = rest.strip_suffix(".json") {
            (r, ShardFormat::Json)
        } else if let Some(r) = rest.strip_suffix(".fsb") {
            (r, ShardFormat::Binary)
        } else {
            return None;
        };
        let (i, n) = rest.split_once("_of_")?;
        let spec = ShardSpec { index: i.parse().ok()?, count: n.parse().ok()? };
        // re-format and compare: rejects non-canonical spellings that
        // usize::parse would accept ("+1", "01", …) in one stroke
        (spec.index >= 1 && spec.index <= spec.count && spec.file_name(format) == name)
            .then_some((spec, format))
    }
}

impl std::fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

// ---------------------------------------------------------------------------
// Fingerprints
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv1a(mut hash: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(FNV_PRIME);
    }
    hash
}

/// Deterministic fingerprint of a config: FNV-1a 64 over the canonical
/// `Debug` encoding (covers every field, including floats via their
/// shortest-roundtrip representation). Identical across processes and
/// platforms for identical configs; any drift in options, base config or
/// the `EngineConfig` definition itself changes the value — which is
/// exactly what the merge validation wants to catch.
pub fn config_fingerprint(cfg: &EngineConfig) -> u64 {
    fnv1a(FNV_OFFSET, format!("{cfg:?}").as_bytes())
}

fn fingerprint_to_json(fp: u64) -> Json {
    // u64 does not fit losslessly in a JSON number (f64) — hex string
    Json::Str(format!("{fp:016x}"))
}

fn fingerprint_from_json(j: &Json, what: &str) -> Result<u64> {
    let s = j.as_str().ok_or_else(|| anyhow!("{what}: expected hex string"))?;
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("{what}: '{s}': {e}"))
}

// ---------------------------------------------------------------------------
// EngineOutput <-> JSON (exact float round-trip)
// ---------------------------------------------------------------------------

/// Encode a float so parsing returns the identical value: finite values
/// use JSON numbers (Rust's shortest-roundtrip formatting on both
/// sides); non-finite values and negative zero (which the writer's
/// integer shortcut would flatten to `0`) fall back to tagged strings.
fn json_f64(x: f64) -> Json {
    if x == 0.0 && x.is_sign_negative() {
        Json::Str("-0".into())
    } else if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        Json::Str("NaN".into())
    } else if x > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

fn f64_from(j: &Json, what: &str) -> Result<f64> {
    match j {
        Json::Num(x) => Ok(*x),
        Json::Str(s) => match s.as_str() {
            "NaN" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "-0" => Ok(-0.0),
            other => bail!("{what}: unexpected float string '{other}'"),
        },
        other => bail!("{what}: expected number, got {other}"),
    }
}

fn field<'a>(j: &'a Json, key: &str, what: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("{what}: missing field '{key}'"))
}

fn usize_from(j: &Json, what: &str) -> Result<usize> {
    j.as_usize().ok_or_else(|| anyhow!("{what}: expected integer"))
}

/// Serialize one run's full [`EngineOutput`] (every field an averaging
/// driver can consume, including curves and per-device losses).
pub fn output_to_json(o: &EngineOutput) -> Json {
    Json::obj(vec![
        ("accuracy", json_f64(o.accuracy)),
        (
            "accuracy_curve",
            Json::Arr(
                o.accuracy_curve
                    .iter()
                    .map(|(t, a)| Json::Arr(vec![Json::from(*t), json_f64(*a)]))
                    .collect(),
            ),
        ),
        (
            "per_device_loss",
            Json::Arr(
                o.per_device_loss
                    .iter()
                    .map(|row| {
                        Json::Arr(
                            row.iter()
                                .map(|l| match l {
                                    None => Json::Null,
                                    Some(x) => json_f64(*x as f64),
                                })
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        ),
        (
            "ledger",
            Json::obj(vec![
                ("process", json_f64(o.ledger.process)),
                ("transfer", json_f64(o.ledger.transfer)),
                ("discard", json_f64(o.ledger.discard)),
            ]),
        ),
        (
            "movement",
            Json::Arr(
                o.movement
                    .per_interval
                    .iter()
                    .map(|s| {
                        Json::Arr(vec![
                            Json::from(s.collected),
                            Json::from(s.processed),
                            Json::from(s.offloaded),
                            Json::from(s.discarded),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "similarity",
            Json::Arr(vec![json_f64(o.similarity.0), json_f64(o.similarity.1)]),
        ),
        ("mean_active", json_f64(o.mean_active)),
        ("total_collected", Json::from(o.total_collected)),
    ])
}

/// Inverse of [`output_to_json`]. Exact: every float parses back to the
/// identical value (`f32` losses round-trip through `f64` losslessly).
pub fn output_from_json(j: &Json) -> Result<EngineOutput> {
    const W: &str = "shard run output";
    let mut accuracy_curve = Vec::new();
    for p in field(j, "accuracy_curve", W)?.as_arr().unwrap_or(&[]) {
        let pair = p.as_arr().ok_or_else(|| anyhow!("{W}: curve point not a pair"))?;
        if pair.len() != 2 {
            bail!("{W}: curve point not a (t, acc) pair");
        }
        accuracy_curve.push((usize_from(&pair[0], W)?, f64_from(&pair[1], W)?));
    }
    let mut per_device_loss = Vec::new();
    for row in field(j, "per_device_loss", W)?.as_arr().unwrap_or(&[]) {
        let row = row.as_arr().ok_or_else(|| anyhow!("{W}: loss row not an array"))?;
        let mut out_row = Vec::with_capacity(row.len());
        for l in row {
            out_row.push(match l {
                Json::Null => None,
                other => Some(f64_from(other, W)? as f32),
            });
        }
        per_device_loss.push(out_row);
    }
    let ledger_j = field(j, "ledger", W)?;
    let ledger = Ledger {
        process: f64_from(field(ledger_j, "process", W)?, W)?,
        transfer: f64_from(field(ledger_j, "transfer", W)?, W)?,
        discard: f64_from(field(ledger_j, "discard", W)?, W)?,
    };
    let mut movement = MovementTotals::default();
    for s in field(j, "movement", W)?.as_arr().unwrap_or(&[]) {
        let q = s.as_arr().ok_or_else(|| anyhow!("{W}: interval not an array"))?;
        if q.len() != 4 {
            bail!("{W}: interval stats want 4 counts, got {}", q.len());
        }
        movement.push(IntervalStats {
            collected: usize_from(&q[0], W)?,
            processed: usize_from(&q[1], W)?,
            offloaded: usize_from(&q[2], W)?,
            discarded: usize_from(&q[3], W)?,
        });
    }
    let sim = field(j, "similarity", W)?
        .as_arr()
        .ok_or_else(|| anyhow!("{W}: similarity not a pair"))?;
    if sim.len() != 2 {
        bail!("{W}: similarity wants 2 values");
    }
    Ok(EngineOutput {
        accuracy: f64_from(field(j, "accuracy", W)?, W)?,
        accuracy_curve,
        per_device_loss,
        ledger,
        movement,
        similarity: (f64_from(&sim[0], W)?, f64_from(&sim[1], W)?),
        mean_active: f64_from(field(j, "mean_active", W)?, W)?,
        total_collected: usize_from(field(j, "total_collected", W)?, W)?,
    })
}

// ---------------------------------------------------------------------------
// Shard files
// ---------------------------------------------------------------------------

/// One recorded run: its global grid index, config fingerprint, and full
/// output.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// 0-based position in the canonical expansion order.
    pub index: usize,
    /// [`config_fingerprint`] of the config that produced this run.
    pub fingerprint: u64,
    /// The run's complete result.
    pub output: EngineOutput,
}

/// One serialized shard: the subset of a grid's runs owned by
/// `spec.index`, plus everything needed to validate a merge.
#[derive(Debug, Clone)]
pub struct ShardFile {
    /// Which experiment driver produced the grid (`table3`, `fig9`, …).
    pub experiment: String,
    /// This file's position in the shard set.
    pub spec: ShardSpec,
    /// Size of the *whole* grid (across all shards).
    pub total_runs: usize,
    /// Per-run fingerprints folded in canonical order — identical in
    /// every file of a consistent shard set.
    pub grid_fingerprint: u64,
    /// The driver options the grid was expanded under (opaque blob owned
    /// by `experiments::ExpOptions`; must agree across the set).
    pub opts: Json,
    /// The runs this shard owns, in canonical order.
    pub runs: Vec<RunRecord>,
}

impl ShardFile {
    /// Serialize to the versioned on-disk JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::from("fogml-shard")),
            ("version", Json::from(SHARD_FORMAT_VERSION)),
            ("experiment", Json::from(self.experiment.as_str())),
            (
                "shard",
                Json::obj(vec![
                    ("index", Json::from(self.spec.index)),
                    ("count", Json::from(self.spec.count)),
                ]),
            ),
            ("total_runs", Json::from(self.total_runs)),
            ("grid_fingerprint", fingerprint_to_json(self.grid_fingerprint)),
            ("opts", self.opts.clone()),
            (
                "runs",
                Json::Arr(
                    self.runs
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("index", Json::from(r.index)),
                                ("config_fingerprint", fingerprint_to_json(r.fingerprint)),
                                ("output", output_to_json(&r.output)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse and validate one shard file body.
    pub fn from_json(j: &Json) -> Result<ShardFile> {
        const W: &str = "shard file";
        match field(j, "kind", W)?.as_str() {
            Some("fogml-shard") => {}
            other => bail!("{W}: not a fogml shard file (kind = {other:?})"),
        }
        let version = usize_from(field(j, "version", W)?, W)?;
        if version != SHARD_FORMAT_VERSION {
            bail!("{W}: unsupported format version {version} (this build reads {SHARD_FORMAT_VERSION})");
        }
        let shard_j = field(j, "shard", W)?;
        let spec = ShardSpec {
            index: usize_from(field(shard_j, "index", W)?, W)?,
            count: usize_from(field(shard_j, "count", W)?, W)?,
        };
        let total_runs = usize_from(field(j, "total_runs", W)?, W)?;
        let mut runs = Vec::new();
        for r in field(j, "runs", W)?.as_arr().unwrap_or(&[]) {
            runs.push(RunRecord {
                index: usize_from(field(r, "index", W)?, W)?,
                fingerprint: fingerprint_from_json(
                    field(r, "config_fingerprint", W)?,
                    "config_fingerprint",
                )?,
                output: output_from_json(field(r, "output", W)?)?,
            });
        }
        let file = ShardFile {
            experiment: field(j, "experiment", W)?
                .as_str()
                .ok_or_else(|| anyhow!("{W}: experiment not a string"))?
                .to_string(),
            spec,
            total_runs,
            grid_fingerprint: fingerprint_from_json(
                field(j, "grid_fingerprint", W)?,
                "grid_fingerprint",
            )?,
            opts: field(j, "opts", W)?.clone(),
            runs,
        };
        file.validate()?;
        Ok(file)
    }

    /// Semantic validation shared by both on-disk formats (the JSON
    /// parser and [`binfmt::read_shard`] call this after structural
    /// decoding): shard position sanity, run indices in range, and
    /// round-robin ownership of every record.
    pub fn validate(&self) -> Result<()> {
        const W: &str = "shard file";
        let spec = self.spec;
        if spec.count == 0 || spec.index == 0 || spec.index > spec.count {
            bail!("{W}: invalid shard position {}/{}", spec.index, spec.count);
        }
        for r in &self.runs {
            if r.index >= self.total_runs {
                bail!(
                    "{W}: run index {} out of range (total_runs = {})",
                    r.index,
                    self.total_runs
                );
            }
            if !spec.owns(r.index) {
                bail!(
                    "{W}: run {} does not belong to shard {spec} under round-robin assignment — the file was tampered with or mislabeled",
                    r.index
                );
            }
        }
        Ok(())
    }

    /// Write to `dir/shard_I_of_N.json` (creating `dir` if needed) and
    /// return the path. JSON shorthand for [`ShardFile::save_as`].
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        self.save_as(dir, ShardFormat::Json)
    }

    /// Write to `dir/shard_I_of_N.{json,fsb}` in `format` (creating
    /// `dir` if needed) and return the path. The binary path streams
    /// through a `BufWriter` — no full-file text buffer is built.
    pub fn save_as(&self, dir: &Path, format: ShardFormat) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating shard dir {}", dir.display()))?;
        let path = dir.join(self.spec.file_name(format));
        match format {
            ShardFormat::Json => {
                std::fs::write(&path, self.to_json().to_string())
                    .with_context(|| format!("writing {}", path.display()))?;
            }
            ShardFormat::Binary => {
                let f = std::fs::File::create(&path)
                    .with_context(|| format!("creating {}", path.display()))?;
                binfmt::write_shard(std::io::BufWriter::new(f), self)
                    .with_context(|| format!("writing {}", path.display()))?;
            }
        }
        Ok(path)
    }

    /// Read and validate `path`, auto-detecting the format from the
    /// file's leading bytes (binary magic vs JSON text) — the extension
    /// is advisory, the content decides.
    pub fn load(path: &Path) -> Result<ShardFile> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if binfmt::is_binary(&bytes) {
            return binfmt::read_shard(&bytes)
                .with_context(|| format!("parsing {}", path.display()));
        }
        let text = std::str::from_utf8(&bytes)
            .map_err(|e| anyhow!("{}: neither binary shard magic nor UTF-8 JSON: {e}", path.display()))?;
        let j = Json::parse(text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("parsing {}", path.display()))
    }
}

/// A complete, validated shard set loaded from one directory: every shard
/// present, mutually consistent, and jointly covering the whole grid.
#[derive(Debug, Clone)]
pub struct ShardSet {
    /// The experiment the grid belongs to.
    pub experiment: String,
    /// The recorded driver-options blob (agreed on by every file).
    pub opts: Json,
    /// Shard count `N` of the set.
    pub count: usize,
    /// All runs of the grid, reassembled in canonical order
    /// (`runs[j].index == j` for every `j`).
    pub runs: Vec<RunRecord>,
}

/// Enumerate recognized shard files under `dir`, sorted by shard index.
/// Only canonical names qualify (`shard_I_of_N.json` / `shard_I_of_N.fsb`
/// — [`ShardSpec::parse_file_name`]); backups, editor temp files and
/// anything else sitting in the directory are ignored. Shared by
/// [`load_shard_set`] and `fogml shard convert`.
pub fn discover_shard_files(dir: &Path) -> Result<Vec<(ShardSpec, ShardFormat, PathBuf)>> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading shard dir {}", dir.display()))?;
    let mut files: Vec<(ShardSpec, ShardFormat, PathBuf)> = Vec::new();
    for e in entries {
        let e = e?;
        if !e.file_type()?.is_file() {
            continue; // e.g. a directory that happens to carry a shard name
        }
        let name = e.file_name();
        if let Some((spec, format)) = name.to_str().and_then(ShardSpec::parse_file_name) {
            files.push((spec, format, e.path()));
        }
    }
    files.sort_by_key(|(spec, _, _)| spec.index);
    Ok(files)
}

/// Load every `shard_I_of_N.{json,fsb}` under `dir` and validate the
/// set: exactly one file per shard 1..=N, no mixed shard counts, no
/// mixed formats, identical experiment/options/total/grid-fingerprint
/// everywhere, and a run for every grid index. Any violation is a hard
/// error naming the offender — a merge must never silently proceed from
/// an incomplete or mixed set.
pub fn load_shard_set(dir: &Path) -> Result<ShardSet> {
    let files = discover_shard_files(dir)?;
    if files.is_empty() {
        bail!(
            "no shard files (shard_I_of_N.json or shard_I_of_N.fsb) found in {}",
            dir.display()
        );
    }
    let count = files[0].0.count;
    if let Some((spec, _, path)) = files.iter().find(|(s, _, _)| s.count != count) {
        bail!(
            "mixed shard sets in {}: found both /{} and /{} files (e.g. {})",
            dir.display(),
            count,
            spec.count,
            path.display()
        );
    }
    let format = files[0].1;
    if let Some((_, other, path)) = files.iter().find(|(_, f, _)| *f != format) {
        bail!(
            "mixed shard formats in {}: found both .{} and .{} files (e.g. {}) — normalize with `fogml shard convert` before merging",
            dir.display(),
            format.extension(),
            other.extension(),
            path.display()
        );
    }
    let missing: Vec<usize> =
        (1..=count).filter(|i| !files.iter().any(|(s, _, _)| s.index == *i)).collect();
    if !missing.is_empty() {
        bail!(
            "incomplete shard set in {}: missing shard(s) {:?} of {count}",
            dir.display(),
            missing
        );
    }

    let mut experiment: Option<String> = None;
    let mut opts: Option<Json> = None;
    let mut total: Option<usize> = None;
    let mut grid: Option<u64> = None;
    let mut slots: Vec<Option<RunRecord>> = Vec::new();
    for (spec, _, path) in &files {
        let f = ShardFile::load(path)?;
        if f.spec != *spec {
            bail!(
                "{}: file body claims shard {} but the file name says {spec}",
                path.display(),
                f.spec
            );
        }
        match &experiment {
            None => experiment = Some(f.experiment.clone()),
            Some(e) if *e != f.experiment => bail!(
                "{}: experiment '{}' disagrees with the rest of the set ('{e}')",
                path.display(),
                f.experiment
            ),
            Some(_) => {}
        }
        match &opts {
            None => opts = Some(f.opts.clone()),
            Some(o) if *o != f.opts => bail!(
                "{}: recorded options disagree with the rest of the set",
                path.display()
            ),
            Some(_) => {}
        }
        match total {
            None => {
                total = Some(f.total_runs);
                slots = (0..f.total_runs).map(|_| None).collect();
            }
            Some(t) if t != f.total_runs => bail!(
                "{}: total_runs {} disagrees with the rest of the set ({t})",
                path.display(),
                f.total_runs
            ),
            Some(_) => {}
        }
        match grid {
            None => grid = Some(f.grid_fingerprint),
            Some(g) if g != f.grid_fingerprint => bail!(
                "{}: grid fingerprint {:016x} does not match the rest of the set ({:016x}) — the shards were produced from different grids or options",
                path.display(),
                f.grid_fingerprint,
                g
            ),
            Some(_) => {}
        }
        for rec in f.runs {
            if slots[rec.index].is_some() {
                bail!("{}: duplicate record for run {}", path.display(), rec.index);
            }
            slots[rec.index] = Some(rec);
        }
    }
    let total = total.unwrap_or(0);
    let missing_runs: Vec<usize> =
        slots.iter().enumerate().filter(|(_, s)| s.is_none()).map(|(j, _)| j).collect();
    if !missing_runs.is_empty() {
        bail!(
            "shard set in {} is missing {} of {total} runs (first missing: run {}) — a shard file was truncated",
            dir.display(),
            missing_runs.len(),
            missing_runs[0]
        );
    }
    Ok(ShardSet {
        experiment: experiment.unwrap_or_default(),
        opts: opts.unwrap_or(Json::Null),
        count,
        runs: slots.into_iter().map(|s| s.unwrap()).collect(),
    })
}

// ---------------------------------------------------------------------------
// SweepCtx — the driver-facing execution + output sink
// ---------------------------------------------------------------------------

struct ShardState {
    /// Next global run index (== runs enumerated so far).
    next: usize,
    /// Per-run fingerprints folded in canonical order.
    grid: u64,
    /// Owned runs recorded so far.
    records: Vec<RunRecord>,
}

struct MergeState {
    /// Next record to replay.
    cursor: usize,
    /// The full grid in canonical order (from [`load_shard_set`]).
    runs: Vec<RunRecord>,
}

enum Mode {
    /// Execute everything, emit artifacts — the classic behavior.
    Full,
    /// Execute only the owned round-robin subset, suppress artifacts,
    /// record results for a later merge.
    Shard { spec: ShardSpec, state: RefCell<ShardState> },
    /// Execute nothing: replay recorded outputs (validating fingerprints
    /// run by run) and emit artifacts exactly as a serial run would.
    Merge { state: RefCell<MergeState> },
}

/// The execution and output context every pooled experiment driver runs
/// against. Encapsulates the three sweep modes (full / shard / merge) so
/// driver code is written once: drivers request engine runs through
/// [`SweepCtx::run_many`] and route every artifact through
/// [`SweepCtx::emit_table`] / [`SweepCtx::emit_raw`] /
/// [`SweepCtx::say`]; the mode decides what actually executes and what
/// actually gets written (module docs have the full contract).
pub struct SweepCtx<'a> {
    pool: &'a SimPool,
    mode: Mode,
}

impl<'a> SweepCtx<'a> {
    /// Full mode: run the whole grid through `pool`, emit everything.
    pub fn full(pool: &'a SimPool) -> SweepCtx<'a> {
        SweepCtx { pool, mode: Mode::Full }
    }

    /// Shard mode: run only `spec`'s round-robin subset through `pool`,
    /// suppress artifacts, record results for [`SweepCtx::write_shard_file`].
    pub fn sharded(pool: &'a SimPool, spec: ShardSpec) -> SweepCtx<'a> {
        SweepCtx {
            pool,
            mode: Mode::Shard {
                spec,
                state: RefCell::new(ShardState {
                    next: 0,
                    grid: FNV_OFFSET,
                    records: Vec::new(),
                }),
            },
        }
    }

    /// Merge mode: replay `runs` (a complete grid from
    /// [`load_shard_set`]) instead of executing; emit everything. Call
    /// [`SweepCtx::finish_merge`] after the driver returns.
    pub fn merged(pool: &'a SimPool, runs: Vec<RunRecord>) -> SweepCtx<'a> {
        SweepCtx {
            pool,
            mode: Mode::Merge { state: RefCell::new(MergeState { cursor: 0, runs }) },
        }
    }

    /// True in shard mode — artifacts and console output are suppressed.
    pub fn is_sharded(&self) -> bool {
        matches!(self.mode, Mode::Shard { .. })
    }

    /// Run `cfgs` (one grid segment, in canonical order) and return their
    /// outputs in input order.
    ///
    /// * Full mode: all of them, via [`SimPool::run_many`].
    /// * Shard mode: only the owned subset executes (still pooled, still
    ///   in order); unowned positions return placeholder
    ///   [`EngineOutput::default`]s, which is sound because shard mode
    ///   suppresses every artifact derived from them.
    /// * Merge mode: nothing executes; recorded outputs are replayed in
    ///   grid order after re-validating each config's fingerprint.
    pub fn run_many(&self, cfgs: &[EngineConfig]) -> Result<Vec<EngineOutput>> {
        match &self.mode {
            Mode::Full => self.pool.run_many(cfgs),
            Mode::Shard { spec, state } => {
                let (start, fps) = {
                    let mut st = state.borrow_mut();
                    let start = st.next;
                    st.next += cfgs.len();
                    let mut fps = Vec::with_capacity(cfgs.len());
                    for cfg in cfgs {
                        let fp = config_fingerprint(cfg);
                        st.grid = fnv1a(st.grid, &fp.to_le_bytes());
                        fps.push(fp);
                    }
                    (start, fps)
                };
                let owned: Vec<usize> = (0..cfgs.len())
                    .filter(|k| spec.owns(start + k))
                    .collect();
                let owned_cfgs: Vec<EngineConfig> =
                    owned.iter().map(|&k| cfgs[k].clone()).collect();
                let outs = self.pool.run_many(&owned_cfgs)?;
                let mut results = vec![EngineOutput::default(); cfgs.len()];
                let mut st = state.borrow_mut();
                for (&k, out) in owned.iter().zip(outs) {
                    st.records.push(RunRecord {
                        index: start + k,
                        fingerprint: fps[k],
                        output: out.clone(),
                    });
                    results[k] = out;
                }
                Ok(results)
            }
            Mode::Merge { state } => {
                let mut st = state.borrow_mut();
                let mut outs = Vec::with_capacity(cfgs.len());
                for cfg in cfgs {
                    let j = st.cursor;
                    let rec = st.runs.get(j).ok_or_else(|| {
                        anyhow!(
                            "merge replay expanded run {j} but the shard set only recorded {} runs — the driver or its options drifted since sharding",
                            st.runs.len()
                        )
                    })?;
                    let fp = config_fingerprint(cfg);
                    if fp != rec.fingerprint {
                        bail!(
                            "run {j}: expanded config fingerprint {fp:016x} != recorded {:016x} — the shard files were produced from a different grid (options, base config, or code revision)",
                            rec.fingerprint
                        );
                    }
                    outs.push(rec.output.clone());
                    st.cursor += 1;
                }
                Ok(outs)
            }
        }
    }

    /// Print `table` and persist `<out_dir>/<name>.csv` — suppressed in
    /// shard mode (the merge regenerates it from the reassembled grid).
    pub fn emit_table(&self, table: &Table, out_dir: &str, name: &str) -> Result<()> {
        if self.is_sharded() {
            return Ok(());
        }
        table.print();
        std::fs::create_dir_all(out_dir)?;
        std::fs::write(format!("{out_dir}/{name}.csv"), table.to_csv())?;
        Ok(())
    }

    /// Write raw CSV lines to `<out_dir>/<name>.csv` — suppressed in
    /// shard mode.
    pub fn emit_raw(&self, lines: &str, out_dir: &str, name: &str) -> Result<()> {
        if self.is_sharded() {
            return Ok(());
        }
        std::fs::create_dir_all(out_dir)?;
        std::fs::write(format!("{out_dir}/{name}.csv"), lines)?;
        Ok(())
    }

    /// Console narration (`println!`) — suppressed in shard mode, where
    /// the values being narrated are partial.
    pub fn say(&self, line: &str) {
        if !self.is_sharded() {
            println!("{line}");
        }
    }

    /// Shard-mode epilogue: serialize the recorded subset (plus grid
    /// metadata and the caller-supplied `opts` blob) to
    /// `dir/shard_I_of_N.{json,fsb}` per `format`. Errors outside shard
    /// mode.
    pub fn write_shard_file(
        &self,
        experiment: &str,
        opts: Json,
        dir: &Path,
        format: ShardFormat,
    ) -> Result<PathBuf> {
        match &self.mode {
            Mode::Shard { spec, state } => {
                let mut st = state.borrow_mut();
                let file = ShardFile {
                    experiment: experiment.to_string(),
                    spec: *spec,
                    total_runs: st.next,
                    grid_fingerprint: st.grid,
                    opts,
                    runs: std::mem::take(&mut st.records),
                };
                file.save_as(dir, format)
            }
            _ => bail!("write_shard_file called outside shard mode"),
        }
    }

    /// Merge-mode epilogue: verify the replay consumed every recorded
    /// run (a shorter-than-recorded expansion means driver drift and
    /// must not pass silently). Errors outside merge mode.
    pub fn finish_merge(&self) -> Result<()> {
        match &self.mode {
            Mode::Merge { state } => {
                let st = state.borrow();
                if st.cursor != st.runs.len() {
                    bail!(
                        "merge replay consumed {} of {} recorded runs — the driver or its options drifted since sharding",
                        st.cursor,
                        st.runs.len()
                    );
                }
                Ok(())
            }
            _ => bail!("finish_merge called outside merge mode"),
        }
    }

    /// How many runs this context has recorded so far: the owned subset
    /// in shard mode, the replayed count in merge mode, 0 in full mode
    /// (nothing is recorded there). Diagnostic only.
    pub fn runs_owned(&self) -> usize {
        match &self.mode {
            Mode::Full => 0,
            Mode::Shard { state, .. } => state.borrow().records.len(),
            Mode::Merge { state } => state.borrow().cursor,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_spec_parse_and_ownership() {
        let s = ShardSpec::parse("2/4").unwrap();
        assert_eq!(s, ShardSpec { index: 2, count: 4 });
        assert!(ShardSpec::parse("0/4").is_err());
        assert!(ShardSpec::parse("5/4").is_err());
        assert!(ShardSpec::parse("1/0").is_err());
        assert!(ShardSpec::parse("nope").is_err());
        // round-robin: shard 2 of 4 owns 1, 5, 9, …
        assert!(!s.owns(0));
        assert!(s.owns(1));
        assert!(!s.owns(2));
        assert!(s.owns(5));
        // the full set partitions every index exactly once
        for j in 0..20 {
            let owners = (1..=4)
                .filter(|&i| ShardSpec { index: i, count: 4 }.owns(j))
                .count();
            assert_eq!(owners, 1, "run {j} must have exactly one owner");
        }
    }

    #[test]
    fn file_name_round_trip() {
        let s = ShardSpec { index: 3, count: 8 };
        assert_eq!(s.file_name(ShardFormat::Json), "shard_3_of_8.json");
        assert_eq!(s.file_name(ShardFormat::Binary), "shard_3_of_8.fsb");
        for format in [ShardFormat::Json, ShardFormat::Binary] {
            assert_eq!(
                ShardSpec::parse_file_name(&s.file_name(format)),
                Some((s, format))
            );
        }
        assert_eq!(ShardSpec::parse_file_name("table3.csv"), None);
        assert_eq!(ShardSpec::parse_file_name("shard_9_of_8.json"), None);
    }

    #[test]
    fn parse_file_name_ignores_unrelated_and_noncanonical_names() {
        // derived / editor noise next to a real shard set must not parse
        for name in [
            "shard_1_of_2.json.bak",
            "shard_1_of_2.json~",
            "shard_1_of_2.json.swp",
            ".#shard_1_of_2.json",
            "#shard_1_of_2.json#",
            "shard_1_of_2.fsb.partial",
            "shard_1_of_2",
            "shard_1_of_2.csv",
            // non-canonical spellings usize::parse would happily accept
            "shard_01_of_2.json",
            "shard_1_of_02.json",
            "shard_+1_of_2.json",
            "shard_1_of_+2.fsb",
            "shard_ 1_of_2.json",
            "shard_0_of_2.json",
            "shard_3_of_2.fsb",
        ] {
            assert_eq!(ShardSpec::parse_file_name(name), None, "{name} must not parse");
        }
    }

    #[test]
    fn shard_format_parse_and_extension() {
        assert_eq!(ShardFormat::parse("json").unwrap(), ShardFormat::Json);
        assert_eq!(ShardFormat::parse("binary").unwrap(), ShardFormat::Binary);
        assert_eq!(ShardFormat::parse("fsb").unwrap(), ShardFormat::Binary);
        assert!(ShardFormat::parse("msgpack").is_err());
        assert_eq!(ShardFormat::default(), ShardFormat::Json);
        assert_eq!(ShardFormat::Json.extension(), "json");
        assert_eq!(ShardFormat::Binary.extension(), "fsb");
    }

    #[test]
    fn config_fingerprint_is_field_sensitive() {
        let a = EngineConfig::default();
        let b = a.clone().with(|c| c.n = 11);
        let c = a.clone().seeded(2);
        assert_eq!(config_fingerprint(&a), config_fingerprint(&a.clone()));
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c));
    }

    #[test]
    fn non_finite_floats_round_trip() {
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.1 + 0.2, -1e-17, -0.0] {
            let j = json_f64(x);
            let text = j.to_string();
            let back = f64_from(&Json::parse(&text).unwrap(), "t").unwrap();
            if x.is_nan() {
                assert!(back.is_nan());
            } else {
                assert_eq!(back.to_bits(), x.to_bits(), "exact round-trip for {x}");
            }
        }
    }
}
