//! The runtime-service thread: owns the (thread-confined) PJRT runtime and
//! serves train/eval requests from any number of actor or pool-worker
//! threads.
//!
//! The service is model- and dataset-agnostic: callers register
//! `(train, test)` dataset pairs (one per in-flight run) and address every
//! request with an explicit [`ModelKind`]/learning-rate/[`DatasetId`]
//! triple. [`Trainer`]s are built lazily per `(model, lr)` and cached for
//! the lifetime of the thread, so the expensive XLA compilation happens
//! once per entry point no matter how many runs stream through.
//!
//! Two client views exist:
//! * [`ServiceClient`] — the raw cloneable handle with the full addressed
//!   API (what [`crate::coordinator::pool::SimPool`] workers use);
//! * [`RuntimeHandle`] — a client bound to one `(model, lr, dataset)`
//!   context. It keeps the original positional `train/evaluate/init_params`
//!   API used by the [`crate::coordinator::cluster`] actors, and implements
//!   [`crate::fed::session::Compute`] so a whole engine session can run
//!   against the service from any thread.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::data::dataset::Dataset;
use crate::fed::eval::{EvalPath, EvalWork};
use crate::fed::session::Compute;
use crate::fed::trainer::{DeviceWork, Trainer};
use crate::runtime::{HostTensor, ModelKind, Runtime};

/// Model parameters as they travel between threads.
pub type Params = Vec<HostTensor>;

/// Handle to a `(train, test)` dataset pair registered with the service.
pub type DatasetId = usize;

enum Request {
    Register {
        train: Dataset,
        test: Dataset,
        reply: Sender<DatasetId>,
    },
    Unregister {
        id: DatasetId,
    },
    Train {
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: Params,
        samples: Vec<u32>,
        reply: Sender<Result<(Params, Option<f32>)>>,
    },
    TrainMany {
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        work: Vec<DeviceWork>,
        reply: Sender<Result<Vec<DeviceWork>>>,
    },
    Evaluate {
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: Params,
        reply: Sender<Result<f64>>,
    },
    EvalMany {
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        work: Vec<EvalWork>,
        path: EvalPath,
        reply: Sender<Result<Vec<EvalWork>>>,
    },
    InitParams {
        kind: ModelKind,
        seed: u64,
        reply: Sender<Result<Params>>,
    },
    Shutdown,
}

/// Cloneable, unbound handle to the runtime-service thread.
#[derive(Clone)]
pub struct ServiceClient {
    tx: Sender<Request>,
}

/// A [`ServiceClient`] bound to one `(model, lr, dataset)` context.
#[derive(Clone)]
pub struct RuntimeHandle {
    client: ServiceClient,
    kind: ModelKind,
    lr: f32,
    ds: DatasetId,
}

/// The service itself (join handle + control).
pub struct RuntimeService {
    client: ServiceClient,
    default_handle: Option<RuntimeHandle>,
    join: Option<JoinHandle<()>>,
}

/// Thread-local state of the service loop: the (lazily loaded) runtime,
/// the dataset registry, and the per-(model, lr) trainer cache.
struct ServiceState {
    /// `None` until the first compute request: idle services (e.g. pool
    /// workers an experiment never exercises) cost one parked thread, not
    /// a PJRT client.
    rt: Option<Result<Runtime>>,
    datasets: HashMap<DatasetId, (Dataset, Dataset)>,
    next_id: DatasetId,
    trainers: HashMap<(ModelKind, u32), Trainer>,
}

impl ServiceState {
    fn runtime(&mut self) -> Result<&Runtime> {
        self.rt
            .get_or_insert_with(Runtime::load_default)
            .as_ref()
            .map_err(|e| anyhow!("runtime load failed: {e:#}"))
    }

    fn dataset(&self, id: DatasetId) -> Result<&(Dataset, Dataset)> {
        self.datasets
            .get(&id)
            .ok_or_else(|| anyhow!("dataset {id} not registered (or already dropped)"))
    }

    /// Build and cache the trainer for a `(model, lr)` pair if it does not
    /// exist yet. The lr is part of the key bit-exactly.
    fn ensure_trainer(&mut self, kind: ModelKind, lr: f32) -> Result<()> {
        let key = (kind, lr.to_bits());
        if !self.trainers.contains_key(&key) {
            let trainer = Trainer::new(self.runtime()?, kind, lr)?;
            self.trainers.insert(key, trainer);
        }
        Ok(())
    }

    fn handle_train(
        &mut self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        mut params: Params,
        samples: &[u32],
    ) -> Result<(Params, Option<f32>)> {
        // look up the dataset first so a stale id errors before compiling
        self.dataset(ds)?;
        self.ensure_trainer(kind, lr)?;
        let trainer = &self.trainers[&(kind, lr.to_bits())];
        let train_ds = &self.datasets[&ds].0;
        let loss = trainer.train_interval(&mut params, train_ds, samples)?;
        Ok((params, loss))
    }

    /// Batched interval: all devices' updates execute as stacked
    /// `[D × BATCH]` steps on the service thread (one queue round-trip and
    /// one PJRT dispatch per lock-step for the whole fleet).
    fn handle_train_many(
        &mut self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        mut work: Vec<DeviceWork>,
    ) -> Result<Vec<DeviceWork>> {
        self.dataset(ds)?;
        self.ensure_trainer(kind, lr)?;
        let rt = match self.rt.as_ref() {
            Some(Ok(rt)) => rt,
            _ => return Err(anyhow!("runtime unavailable after trainer build")),
        };
        let trainer = &self.trainers[&(kind, lr.to_bits())];
        let train_ds = &self.datasets[&ds].0;
        trainer.train_interval_many(rt, train_ds, &mut work)?;
        Ok(work)
    }

    fn handle_evaluate(
        &mut self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: &Params,
    ) -> Result<f64> {
        self.dataset(ds)?;
        self.ensure_trainer(kind, lr)?;
        let trainer = &self.trainers[&(kind, lr.to_bits())];
        let test_ds = &self.datasets[&ds].1;
        trainer.evaluate(params, test_ds)
    }

    /// Batched evaluation: the whole work list scores on the service
    /// thread — one queue round-trip per `evaluate_many` call (i.e. one
    /// per curve point for pooled sessions), with stacked `[D × BATCH]`
    /// execution unless `path` forces the scalar chunks.
    fn handle_eval_many(
        &mut self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        mut work: Vec<EvalWork>,
        path: EvalPath,
    ) -> Result<Vec<EvalWork>> {
        self.dataset(ds)?;
        self.ensure_trainer(kind, lr)?;
        let rt = match self.rt.as_ref() {
            Some(Ok(rt)) => rt,
            _ => return Err(anyhow!("runtime unavailable after trainer build")),
        };
        let trainer = &self.trainers[&(kind, lr.to_bits())];
        let test_ds = &self.datasets[&ds].1;
        trainer.evaluate_many(rt, test_ds, &mut work, path)?;
        Ok(work)
    }
}

impl RuntimeService {
    /// Spawn a model/dataset-agnostic service thread. Register datasets and
    /// bind handles through [`RuntimeService::client`].
    pub fn spawn_shared() -> RuntimeService {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let join = std::thread::Builder::new()
            .name("fogml-runtime".into())
            .spawn(move || service_loop(rx))
            .expect("spawn runtime service");
        RuntimeService {
            client: ServiceClient { tx },
            default_handle: None,
            join: Some(join),
        }
    }

    /// Spawn the service pre-bound to one model/lr/dataset context — the
    /// original single-tenant API the cluster actors use.
    pub fn spawn(kind: ModelKind, lr: f32, train_ds: Dataset, test_ds: Dataset) -> RuntimeService {
        let mut svc = Self::spawn_shared();
        let ds = svc
            .client
            .register_dataset(train_ds, test_ds)
            .expect("register default datasets");
        svc.default_handle = Some(svc.client.bind(kind, lr, ds));
        svc
    }

    /// The raw, unbound client (register datasets, address any model).
    pub fn client(&self) -> ServiceClient {
        self.client.clone()
    }

    /// The default bound handle (only for services created via
    /// [`RuntimeService::spawn`]).
    pub fn handle(&self) -> RuntimeHandle {
        self.default_handle
            .clone()
            .expect("service spawned without default context; use client()")
    }

    /// Stop the thread (idempotent; also called on drop).
    pub fn shutdown(&mut self) {
        let _ = self.client.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn service_loop(rx: Receiver<Request>) {
    let mut state = ServiceState {
        rt: None,
        datasets: HashMap::new(),
        next_id: 0,
        trainers: HashMap::new(),
    };
    for req in rx {
        match req {
            Request::Register { train, test, reply } => {
                let id = state.next_id;
                state.next_id += 1;
                state.datasets.insert(id, (train, test));
                let _ = reply.send(id);
            }
            Request::Unregister { id } => {
                state.datasets.remove(&id);
            }
            Request::Train { kind, lr, ds, params, samples, reply } => {
                let _ = reply.send(state.handle_train(kind, lr, ds, params, &samples));
            }
            Request::TrainMany { kind, lr, ds, work, reply } => {
                let _ = reply.send(state.handle_train_many(kind, lr, ds, work));
            }
            Request::Evaluate { kind, lr, ds, params, reply } => {
                let _ = reply.send(state.handle_evaluate(kind, lr, ds, &params));
            }
            Request::EvalMany { kind, lr, ds, work, path, reply } => {
                let _ = reply.send(state.handle_eval_many(kind, lr, ds, work, path));
            }
            Request::InitParams { kind, seed, reply } => {
                let res = state
                    .runtime()
                    .and_then(|rt| rt.init_params(kind, seed));
                let _ = reply.send(res);
            }
            Request::Shutdown => break,
        }
    }
}

impl ServiceClient {
    fn send(&self, req: Request) -> Result<()> {
        self.tx.send(req).map_err(|_| anyhow!("runtime service gone"))
    }

    /// Register a `(train, test)` dataset pair; returns its id. Callers
    /// should [`ServiceClient::unregister_dataset`] when the run finishes so
    /// the service does not accumulate dead datasets.
    pub fn register_dataset(&self, train: Dataset, test: Dataset) -> Result<DatasetId> {
        let (tx, rx) = channel();
        self.send(Request::Register { train, test, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))
    }

    /// Drop a registered dataset pair (fire-and-forget).
    pub fn unregister_dataset(&self, id: DatasetId) {
        let _ = self.send(Request::Unregister { id });
    }

    /// Bind this client to a `(model, lr, dataset)` context.
    pub fn bind(&self, kind: ModelKind, lr: f32, ds: DatasetId) -> RuntimeHandle {
        RuntimeHandle { client: self.clone(), kind, lr, ds }
    }

    /// One interval of local updates; returns updated params + loss.
    pub fn train(
        &self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: Params,
        samples: Vec<u32>,
    ) -> Result<(Params, Option<f32>)> {
        let (tx, rx) = channel();
        self.send(Request::Train { kind, lr, ds, params, samples, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// One batched interval: every device's local updates in stacked
    /// multi-device executions; returns the work list with updated params
    /// and per-device losses.
    pub fn train_many(
        &self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        work: Vec<DeviceWork>,
    ) -> Result<Vec<DeviceWork>> {
        let (tx, rx) = channel();
        self.send(Request::TrainMany { kind, lr, ds, work, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// Test-set accuracy of the given parameters on dataset `ds`.
    pub fn evaluate(
        &self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: Params,
    ) -> Result<f64> {
        let (tx, rx) = channel();
        self.send(Request::Evaluate { kind, lr, ds, params, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// One batched evaluation round-trip: the whole work list scores on
    /// the service thread; returns it with accuracies filled in.
    pub fn eval_many(
        &self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        work: Vec<EvalWork>,
        path: EvalPath,
    ) -> Result<Vec<EvalWork>> {
        let (tx, rx) = channel();
        self.send(Request::EvalMany { kind, lr, ds, work, path, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// Seeded parameter initialization on the service thread.
    pub fn init_params(&self, kind: ModelKind, seed: u64) -> Result<Params> {
        let (tx, rx) = channel();
        self.send(Request::InitParams { kind, seed, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }
}

impl RuntimeHandle {
    /// Run one interval of local updates; returns updated params + loss.
    pub fn train(&self, params: Params, samples: Vec<u32>) -> Result<(Params, Option<f32>)> {
        self.client.train(self.kind, self.lr, self.ds, params, samples)
    }

    /// Run one batched multi-device interval on the service thread.
    pub fn train_many(&self, work: Vec<DeviceWork>) -> Result<Vec<DeviceWork>> {
        self.client.train_many(self.kind, self.lr, self.ds, work)
    }

    /// Test-set accuracy of the given parameters.
    pub fn evaluate(&self, params: Params) -> Result<f64> {
        self.client.evaluate(self.kind, self.lr, self.ds, params)
    }

    /// Run one batched evaluation on the service thread.
    pub fn eval_many(&self, work: Vec<EvalWork>, path: EvalPath) -> Result<Vec<EvalWork>> {
        self.client.eval_many(self.kind, self.lr, self.ds, work, path)
    }

    /// Seeded parameter initialization on the service thread.
    pub fn init_params(&self, seed: u64) -> Result<Params> {
        self.client.init_params(self.kind, seed)
    }
}

/// A bound handle is a full engine backend: [`crate::fed::session::Session`]
/// can train through the service thread from any worker.
impl Compute for RuntimeHandle {
    fn init_params(&self, seed: u64) -> Result<Params> {
        RuntimeHandle::init_params(self, seed)
    }

    fn train_interval(&self, params: &mut Params, samples: &[u32]) -> Result<Option<f32>> {
        let owned = std::mem::take(params);
        let (updated, loss) = RuntimeHandle::train(self, owned, samples.to_vec())?;
        *params = updated;
        Ok(loss)
    }

    fn train_interval_many(&self, work: &mut [DeviceWork]) -> Result<()> {
        let sent: Vec<DeviceWork> = work.iter_mut().map(std::mem::take).collect();
        let updated = RuntimeHandle::train_many(self, sent)?;
        anyhow::ensure!(
            updated.len() == work.len(),
            "train_many reply: {} items, sent {}",
            updated.len(),
            work.len()
        );
        for (w, u) in work.iter_mut().zip(updated) {
            *w = u;
        }
        Ok(())
    }

    fn evaluate(&self, params: &[HostTensor]) -> Result<f64> {
        RuntimeHandle::evaluate(self, params.to_vec())
    }

    fn evaluate_subset(&self, params: &[HostTensor], samples: &[u32]) -> Result<f64> {
        // a single-unit scalar-path EvalMany: one round-trip, and the
        // service executes through Trainer::evaluate_subset — bit-identical
        // to the serial scalar path
        let work = vec![EvalWork {
            params: params.to_vec(),
            samples: samples.to_vec(),
            accuracy: None,
        }];
        let out = RuntimeHandle::eval_many(self, work, EvalPath::Scalar)?;
        out.first()
            .and_then(|w| w.accuracy)
            .ok_or_else(|| anyhow!("eval_many reply missing accuracy"))
    }

    fn evaluate_many(&self, work: &mut [EvalWork], path: EvalPath) -> Result<()> {
        let sent: Vec<EvalWork> = work.iter_mut().map(std::mem::take).collect();
        let updated = RuntimeHandle::eval_many(self, sent, path)?;
        anyhow::ensure!(
            updated.len() == work.len(),
            "eval_many reply: {} items, sent {}",
            updated.len(),
            work.len()
        );
        for (w, u) in work.iter_mut().zip(updated) {
            *w = u;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDigits;
    use crate::util::rng::Rng;

    #[test]
    fn service_trains_from_other_threads() {
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(1);
        let (train, test) = gen.train_test(600, 200, &mut rng);
        let mut svc = RuntimeService::spawn(ModelKind::Mlp, 0.05, train, test);
        let handle = svc.handle();

        let params = handle.init_params(3).unwrap();
        let before = handle.evaluate(params.clone()).unwrap();

        // two worker threads train disjoint shards concurrently
        let h1 = handle.clone();
        let p1 = params.clone();
        let t1 = std::thread::spawn(move || {
            let mut p = p1;
            for _ in 0..6 {
                let (np, _) = h1.train(p, (0..300).collect()).unwrap();
                p = np;
            }
            p
        });
        let h2 = handle.clone();
        let p2 = params.clone();
        let t2 = std::thread::spawn(move || {
            let mut p = p2;
            for _ in 0..6 {
                let (np, _) = h2.train(p, (300..600).collect()).unwrap();
                p = np;
            }
            p
        });
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();

        // fedavg of the two shard models
        let agg = crate::fed::aggregator::aggregate(&[(&r1, 1.0), (&r2, 1.0)]).unwrap();
        let after = handle.evaluate(agg).unwrap();
        assert!(after > before + 0.15, "{before} -> {after}");
        svc.shutdown();
    }

    /// The batched request must match per-device scalar requests through
    /// the same service (tolerance per DESIGN.md §Perf rule 7).
    #[test]
    fn service_train_many_matches_scalar_requests() {
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(5);
        let (train, test) = gen.train_test(600, 100, &mut rng);
        let mut svc = RuntimeService::spawn(ModelKind::Mlp, 0.05, train, test);
        let handle = svc.handle();
        let params = handle.init_params(9).unwrap();

        let shard = |k: u32| -> Vec<u32> { (k * 150..k * 150 + 120).collect() };
        let work: Vec<DeviceWork> = (0..3)
            .map(|k| DeviceWork {
                params: params.clone(),
                samples: shard(k),
                loss: None,
            })
            .collect();
        let out = handle.train_many(work).unwrap();
        assert_eq!(out.len(), 3);
        for (k, w) in out.iter().enumerate() {
            let (sp, sl) = handle.train(params.clone(), shard(k as u32)).unwrap();
            let sl = sl.unwrap();
            let bl = w.loss.unwrap();
            assert!((sl - bl).abs() <= 1e-5 * (1.0 + sl.abs()), "{k}: {sl} vs {bl}");
            for (a, b) in w.params.iter().zip(&sp) {
                let max_diff = a
                    .data
                    .iter()
                    .zip(&b.data)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0f32, f32::max);
                assert!(max_diff <= 1e-4, "device {k}: max diff {max_diff}");
            }
        }
        svc.shutdown();
    }

    /// One EvalMany round-trip must score a whole work list; the batched
    /// path agrees with per-item scalar requests within the DESIGN.md
    /// §Perf rule 7 accuracy tolerance, and the scalar path is exact.
    #[test]
    fn service_eval_many_matches_scalar_requests() {
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(8);
        let (train, test) = gen.train_test(600, 200, &mut rng);
        let mut svc = RuntimeService::spawn(ModelKind::Mlp, 0.05, train, test);
        let handle = svc.handle();
        let params = handle.init_params(4).unwrap();
        let (trained, _) = handle.train(params.clone(), (0..600).collect()).unwrap();

        let full: Vec<u32> = (0..200).collect();
        let make_work = || -> Vec<EvalWork> {
            vec![
                EvalWork { params: trained.clone(), samples: full.clone(), accuracy: None },
                EvalWork { params: params.clone(), samples: (0..50).collect(), accuracy: None },
                EvalWork { params: trained.clone(), samples: Vec::new(), accuracy: None },
            ]
        };
        let scalar_ref = handle.evaluate(trained.clone()).unwrap();

        let batched = handle.eval_many(make_work(), EvalPath::Batched).unwrap();
        assert_eq!(batched.len(), 3);
        assert!((batched[0].accuracy.unwrap() - scalar_ref).abs() <= 5e-3);
        assert_eq!(batched[2].accuracy, Some(0.0));

        let scalar = handle.eval_many(make_work(), EvalPath::Scalar).unwrap();
        assert_eq!(scalar[0].accuracy.unwrap(), scalar_ref);
        for (a, b) in batched.iter().zip(&scalar) {
            assert!(
                (a.accuracy.unwrap() - b.accuracy.unwrap()).abs() <= 5e-3,
                "{:?} vs {:?}",
                a.accuracy,
                b.accuracy
            );
        }
        svc.shutdown();
    }

    #[test]
    fn shared_service_isolates_datasets() {
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(2);
        let (train_a, test_a) = gen.train_test(400, 100, &mut rng);
        let (train_b, test_b) = gen.train_test(400, 100, &mut rng);

        let mut svc = RuntimeService::spawn_shared();
        let client = svc.client();
        let a = client.register_dataset(train_a, test_a).unwrap();
        let b = client.register_dataset(train_b, test_b).unwrap();
        assert_ne!(a, b);

        let params = client.init_params(ModelKind::Mlp, 7).unwrap();
        let (pa, la) = client
            .train(ModelKind::Mlp, 0.05, a, params.clone(), (0..400).collect())
            .unwrap();
        let (_pb, lb) = client
            .train(ModelKind::Mlp, 0.05, b, params.clone(), (0..400).collect())
            .unwrap();
        assert!(la.unwrap() > 0.0 && lb.unwrap() > 0.0);
        let acc = client.evaluate(ModelKind::Mlp, 0.05, a, pa).unwrap();
        assert!(acc > 0.0);

        // dropped datasets error cleanly rather than training on stale data
        client.unregister_dataset(b);
        let err = client.train(ModelKind::Mlp, 0.05, b, params, (0..10).collect());
        assert!(err.is_err());
        svc.shutdown();
    }
}
