//! The runtime-service thread: owns the (thread-confined) PJRT runtime and
//! serves train/eval requests from any number of actor or pool-worker
//! threads — either one request at a time (the classic shape) or through
//! the **coalescing scheduler** (DESIGN.md §3, §Perf rule 10).
//!
//! The service is model- and dataset-agnostic: callers register
//! `(train, test)` dataset pairs (one per in-flight run) and address every
//! request with an explicit [`ModelKind`]/learning-rate/[`DatasetId`]
//! triple. [`Trainer`]s are built lazily per `(model, lr)` and cached for
//! the lifetime of the thread, so the expensive XLA compilation happens
//! once per entry point no matter how many runs stream through.
//!
//! ## Request lifecycle
//!
//! ```text
//!   submit ──────────► pack ─────────► dispatch ─────────► complete
//!   drain the channel; group queued    slots from every    demux per-slot
//!   immediate reqs     TrainMany/      request in a group  results back to
//!   run inline,        EvalMany by     stack into largest- each request's
//!   batchables queue   (family,        tile [D × BATCH]    reply channel
//!   (≤ max_pending     model, lr)      executions
//!    per cycle)        FIFO
//! ```
//!
//! With [`ServiceConfig::coalesce`] **off** (the default), batchable
//! requests dispatch immediately and singly — bit-identical to the
//! pre-scheduler service. With it **on**, pending `TrainMany`/`EvalMany`
//! requests from *different sessions* pack into shared dispatches: every
//! slot stages from its own request's dataset
//! ([`crate::fed::trainer::TrainUnit`]/[`crate::fed::eval::EvalUnit`]) and
//! executes through the **largest compiled tile**
//! ([`crate::fed::trainer::TileFill::Largest`]), which makes a slot's
//! result a pure function of the slot input — invariant to which partner
//! sessions share the dispatch, to the service count, and to channel
//! arrival order (`tests/determinism.rs`). Scalar requests
//! (`Train`/`Evaluate`/`InitParams`, and `EvalMany` on the scalar path)
//! never coalesce and stay bit-identical to the classic service.
//!
//! Two client views exist:
//! * [`ServiceClient`] — the raw cloneable handle with the full addressed
//!   API (what [`crate::coordinator::pool::SimPool`] workers use);
//! * [`RuntimeHandle`] — a client bound to one `(model, lr, dataset)`
//!   context. It keeps the original positional `train/evaluate/init_params`
//!   API used by the [`crate::coordinator::cluster`] actors, and implements
//!   [`crate::fed::session::Compute`] so a whole engine session can run
//!   against the service from any thread.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::data::dataset::Dataset;
use crate::fed::eval::{EvalPath, EvalUnit, EvalWork};
use crate::fed::session::Compute;
use crate::fed::trainer::{DeviceWork, TileFill, TrainUnit, Trainer};
use crate::runtime::{HostTensor, ModelKind, Runtime};

/// Model parameters as they travel between threads. Always moved by
/// value — requests carry an *owned* tensor vector, never a shared
/// handle — which is what lets the copy-on-write epoch store (DESIGN.md
/// §Perf rule 14) stay session-local: callers materialize a private
/// copy (`Arc::make_mut` / unwrap-or-clone) before dispatching, so the
/// service thread can mutate freely without aliasing any replica.
pub type Params = Vec<HostTensor>;

/// Handle to a `(train, test)` dataset pair registered with the service.
pub type DatasetId = usize;

/// Scheduler knobs of one service thread (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Coalesce pending `TrainMany`/`EvalMany` requests across sessions
    /// into shared largest-tile dispatches. Off by default: the classic
    /// one-request-at-a-time service, bit-identical to previous releases.
    pub coalesce: bool,
    /// Most batchable requests drained from the channel per scheduling
    /// cycle — the starvation bound: whatever exceeds it stays in the
    /// channel and is dispatched in the next cycle, ahead of newer
    /// arrivals (the channel is FIFO). Ignored when `coalesce` is off.
    pub max_pending: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { coalesce: false, max_pending: 32 }
    }
}

impl ServiceConfig {
    /// The coalescing-scheduler configuration (`--services K` runs use
    /// this; see [`crate::coordinator::pool::SimPool::coalescing`]).
    pub fn coalescing() -> ServiceConfig {
        ServiceConfig { coalesce: true, ..Default::default() }
    }
}

enum Request {
    Register {
        train: Dataset,
        test: Dataset,
        reply: Sender<DatasetId>,
    },
    Unregister {
        id: DatasetId,
    },
    Train {
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: Params,
        samples: Vec<u32>,
        reply: Sender<Result<(Params, Option<f32>)>>,
    },
    TrainMany {
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        work: Vec<DeviceWork>,
        reply: Sender<Result<Vec<DeviceWork>>>,
    },
    Evaluate {
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: Params,
        reply: Sender<Result<f64>>,
    },
    EvalMany {
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        work: Vec<EvalWork>,
        path: EvalPath,
        reply: Sender<Result<Vec<EvalWork>>>,
    },
    InitParams {
        kind: ModelKind,
        seed: u64,
        reply: Sender<Result<Params>>,
    },
    Shutdown,
}

// ---------------------------------------------------------------------------
// Scheduler core (pure parts are unit-tested without a runtime)
// ---------------------------------------------------------------------------

/// Which batchable request family a queued item belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum BatchFamily {
    Train,
    Eval,
}

/// Group-by key of the coalescing scheduler: two requests share a
/// dispatch iff they agree on family, model and the learning rate
/// bit-for-bit (the lr is an executable input, but the trainer cache is
/// keyed on its exact bits — mixing nearby lrs would mix trainer state).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct BatchKey {
    family: BatchFamily,
    kind: ModelKind,
    lr_bits: u32,
}

/// Pack one cycle's drained requests into dispatch groups: groups are
/// ordered by first appearance of their key, members stay in arrival
/// (FIFO) order, and every index appears in exactly one group — the
/// fairness property the scheduler tests pin (nothing queued is ever
/// dropped or double-dispatched within a cycle).
fn plan_groups(keys: &[BatchKey]) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut index: HashMap<BatchKey, usize> = HashMap::new();
    for (i, k) in keys.iter().enumerate() {
        match index.get(k) {
            Some(&g) => groups[g].push(i),
            None => {
                index.insert(*k, groups.len());
                groups.push(vec![i]);
            }
        }
    }
    groups
}

/// A queued batchable request awaiting pack/dispatch.
struct PendingBatch {
    key: BatchKey,
    ds: DatasetId,
    payload: BatchPayload,
}

enum BatchPayload {
    Train {
        work: Vec<DeviceWork>,
        reply: Sender<Result<Vec<DeviceWork>>>,
    },
    Eval {
        work: Vec<EvalWork>,
        path: EvalPath,
        reply: Sender<Result<Vec<EvalWork>>>,
    },
}

impl PendingBatch {
    /// Complete: send the (updated-in-place) work back to the requester.
    fn complete(self) {
        match self.payload {
            BatchPayload::Train { work, reply } => {
                let _ = reply.send(Ok(work));
            }
            BatchPayload::Eval { work, reply, .. } => {
                let _ = reply.send(Ok(work));
            }
        }
    }

    /// Complete with an error (per-request: a failed partner never eats
    /// another request's reply).
    fn fail(self, msg: &str) {
        match self.payload {
            BatchPayload::Train { reply, .. } => {
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
            BatchPayload::Eval { reply, .. } => {
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// Cloneable, unbound handle to the runtime-service thread.
#[derive(Clone)]
pub struct ServiceClient {
    tx: Sender<Request>,
}

/// A [`ServiceClient`] bound to one `(model, lr, dataset)` context.
#[derive(Clone)]
pub struct RuntimeHandle {
    client: ServiceClient,
    kind: ModelKind,
    lr: f32,
    ds: DatasetId,
}

/// The service itself (join handle + control).
pub struct RuntimeService {
    client: ServiceClient,
    default_handle: Option<RuntimeHandle>,
    join: Option<JoinHandle<()>>,
}

/// Thread-local state of the service loop: the (lazily loaded) runtime,
/// the dataset registry, and the per-(model, lr) trainer cache.
struct ServiceState {
    /// `None` until the first compute request: idle services (e.g. pool
    /// workers an experiment never exercises) cost one parked thread, not
    /// a PJRT client.
    rt: Option<Result<Runtime>>,
    datasets: HashMap<DatasetId, (Dataset, Dataset)>,
    next_id: DatasetId,
    trainers: HashMap<(ModelKind, u32), Trainer>,
}

impl ServiceState {
    fn runtime(&mut self) -> Result<&Runtime> {
        self.rt
            .get_or_insert_with(Runtime::load_default)
            .as_ref()
            .map_err(|e| anyhow!("runtime load failed: {e:#}"))
    }

    fn dataset(&self, id: DatasetId) -> Result<&(Dataset, Dataset)> {
        self.datasets
            .get(&id)
            .ok_or_else(|| anyhow!("dataset {id} not registered (or already dropped)"))
    }

    /// Build and cache the trainer for a `(model, lr)` pair if it does not
    /// exist yet. The lr is part of the key bit-exactly.
    fn ensure_trainer(&mut self, kind: ModelKind, lr: f32) -> Result<()> {
        let key = (kind, lr.to_bits());
        if !self.trainers.contains_key(&key) {
            let trainer = Trainer::new(self.runtime()?, kind, lr)?;
            self.trainers.insert(key, trainer);
        }
        Ok(())
    }

    fn handle_train(
        &mut self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        mut params: Params,
        samples: &[u32],
    ) -> Result<(Params, Option<f32>)> {
        // look up the dataset first so a stale id errors before compiling
        self.dataset(ds)?;
        self.ensure_trainer(kind, lr)?;
        let trainer = &self.trainers[&(kind, lr.to_bits())];
        let train_ds = &self.datasets[&ds].0;
        let loss = trainer.train_interval(&mut params, train_ds, samples)?;
        Ok((params, loss))
    }

    /// Immediate batched interval (coalescing off): all devices' updates
    /// execute as stacked `[D × BATCH]` steps on the service thread (one
    /// queue round-trip and one PJRT dispatch per lock-step for the whole
    /// fleet) — bit-identical to the pre-scheduler service.
    fn handle_train_many(
        &mut self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        mut work: Vec<DeviceWork>,
    ) -> Result<Vec<DeviceWork>> {
        self.dataset(ds)?;
        self.ensure_trainer(kind, lr)?;
        let rt = match self.rt.as_ref() {
            Some(Ok(rt)) => rt,
            _ => return Err(anyhow!("runtime unavailable after trainer build")),
        };
        let trainer = &self.trainers[&(kind, lr.to_bits())];
        let train_ds = &self.datasets[&ds].0;
        trainer.train_interval_many(rt, train_ds, &mut work)?;
        Ok(work)
    }

    fn handle_evaluate(
        &mut self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: &Params,
    ) -> Result<f64> {
        self.dataset(ds)?;
        self.ensure_trainer(kind, lr)?;
        let trainer = &self.trainers[&(kind, lr.to_bits())];
        let test_ds = &self.datasets[&ds].1;
        trainer.evaluate(params, test_ds)
    }

    /// Immediate batched evaluation (coalescing off, and the scalar path
    /// always): the whole work list scores on the service thread — one
    /// queue round-trip per `evaluate_many` call (i.e. one per curve
    /// point for pooled sessions), with stacked `[D × BATCH]` execution
    /// unless `path` forces the scalar chunks.
    fn handle_eval_many(
        &mut self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        mut work: Vec<EvalWork>,
        path: EvalPath,
    ) -> Result<Vec<EvalWork>> {
        self.dataset(ds)?;
        self.ensure_trainer(kind, lr)?;
        let rt = match self.rt.as_ref() {
            Some(Ok(rt)) => rt,
            _ => return Err(anyhow!("runtime unavailable after trainer build")),
        };
        let trainer = &self.trainers[&(kind, lr.to_bits())];
        let test_ds = &self.datasets[&ds].1;
        trainer.evaluate_many(rt, test_ds, &mut work, path)?;
        Ok(work)
    }
}

/// The service loop driver: state + the coalescing queue.
struct Scheduler {
    state: ServiceState,
    cfg: ServiceConfig,
    queue: Vec<PendingBatch>,
}

impl Scheduler {
    /// Handle one request: immediate requests run inline (in arrival
    /// order), batchables queue for the cycle's pack/dispatch. Returns
    /// `true` on `Shutdown`.
    fn submit(&mut self, req: Request) -> bool {
        match req {
            Request::Register { train, test, reply } => {
                let id = self.state.next_id;
                self.state.next_id += 1;
                self.state.datasets.insert(id, (train, test));
                let _ = reply.send(id);
            }
            Request::Unregister { id } => {
                self.state.datasets.remove(&id);
            }
            Request::Train { kind, lr, ds, params, samples, reply } => {
                let _ = reply.send(self.state.handle_train(kind, lr, ds, params, &samples));
            }
            Request::TrainMany { kind, lr, ds, work, reply } => {
                if self.cfg.coalesce {
                    self.queue.push(PendingBatch {
                        key: BatchKey {
                            family: BatchFamily::Train,
                            kind,
                            lr_bits: lr.to_bits(),
                        },
                        ds,
                        payload: BatchPayload::Train { work, reply },
                    });
                } else {
                    let _ = reply.send(self.state.handle_train_many(kind, lr, ds, work));
                }
            }
            Request::Evaluate { kind, lr, ds, params, reply } => {
                let _ = reply.send(self.state.handle_evaluate(kind, lr, ds, &params));
            }
            Request::EvalMany { kind, lr, ds, work, path, reply } => {
                // the scalar eval path must stay bit-identical to the
                // classic service, so it never coalesces
                if self.cfg.coalesce && path != EvalPath::Scalar {
                    self.queue.push(PendingBatch {
                        key: BatchKey {
                            family: BatchFamily::Eval,
                            kind,
                            lr_bits: lr.to_bits(),
                        },
                        ds,
                        payload: BatchPayload::Eval { work, path, reply },
                    });
                } else {
                    let _ =
                        reply.send(self.state.handle_eval_many(kind, lr, ds, work, path));
                }
            }
            Request::InitParams { kind, seed, reply } => {
                let res = self.state.runtime().and_then(|rt| rt.init_params(kind, seed));
                let _ = reply.send(res);
            }
            Request::Shutdown => return true,
        }
        false
    }

    /// Pack the cycle's queue into per-key groups and dispatch each.
    fn flush(&mut self) {
        if self.queue.is_empty() {
            return;
        }
        let pending = std::mem::take(&mut self.queue);
        let keys: Vec<BatchKey> = pending.iter().map(|p| p.key).collect();
        let mut slots: Vec<Option<PendingBatch>> = pending.into_iter().map(Some).collect();
        for group in plan_groups(&keys) {
            let batch: Vec<PendingBatch> =
                group.iter().map(|&i| slots[i].take().expect("slot owned once")).collect();
            self.dispatch(batch);
        }
    }

    /// Dispatch one same-key group: stack every request's slots into
    /// largest-tile executions and demux the in-place results back to the
    /// reply channels. Per-request failures (stale dataset ids) error
    /// that request alone; executor failures error the whole group.
    fn dispatch(&mut self, batch: Vec<PendingBatch>) {
        let key = batch[0].key;
        // resolve datasets first: a stale id errors before any compile,
        // and never poisons co-scheduled requests
        let mut live: Vec<PendingBatch> = Vec::with_capacity(batch.len());
        for p in batch {
            if self.state.datasets.contains_key(&p.ds) {
                live.push(p);
            } else {
                let msg = format!("dataset {} not registered (or already dropped)", p.ds);
                p.fail(&msg);
            }
        }
        if live.is_empty() {
            return;
        }
        let lr = f32::from_bits(key.lr_bits);
        if let Err(e) = self.state.ensure_trainer(key.kind, lr) {
            let msg = format!("trainer build failed: {e:#}");
            for p in live {
                p.fail(&msg);
            }
            return;
        }
        let rt = match self.state.rt.as_ref() {
            Some(Ok(rt)) => rt,
            _ => {
                for p in live {
                    p.fail("runtime unavailable after trainer build");
                }
                return;
            }
        };
        let trainer = &self.state.trainers[&(key.kind, key.lr_bits)];
        let datasets = &self.state.datasets;

        match key.family {
            BatchFamily::Train => {
                let mut units: Vec<TrainUnit> = Vec::new();
                for p in live.iter_mut() {
                    let ds = &datasets[&p.ds].0;
                    let BatchPayload::Train { work, .. } = &mut p.payload else {
                        unreachable!("train group carries train payloads");
                    };
                    units.extend(work.iter_mut().map(|w| TrainUnit { ds, work: w }));
                }
                let res = trainer.train_interval_units(rt, &mut units, TileFill::Largest);
                drop(units);
                match res {
                    Ok(()) => live.into_iter().for_each(PendingBatch::complete),
                    Err(e) => {
                        let msg = format!("coalesced train dispatch failed: {e:#}");
                        for p in live {
                            p.fail(&msg);
                        }
                    }
                }
            }
            BatchFamily::Eval => self.dispatch_eval(live),
        }
    }

    /// Eval groups additionally resolve each request's *effective* path:
    /// `Auto` resolves on the request's own chunk count — never on
    /// partners, so routing is partner-invariant — and scalar-resolved
    /// requests score through the bit-exact scalar path while the rest
    /// stack.
    fn dispatch_eval(&self, live: Vec<PendingBatch>) {
        let key = live[0].key;
        let trainer = &self.state.trainers[&(key.kind, key.lr_bits)];
        let rt = match self.state.rt.as_ref() {
            Some(Ok(rt)) => rt,
            _ => unreachable!("dispatch checked the runtime"),
        };
        let datasets = &self.state.datasets;
        let b = trainer.batch;

        let mut stacked: Vec<PendingBatch> = Vec::new();
        let mut done: Vec<PendingBatch> = Vec::new();
        for mut p in live {
            let ds = &datasets[&p.ds].1;
            let BatchPayload::Eval { work, path, .. } = &mut p.payload else {
                unreachable!("eval group carries eval payloads");
            };
            let n_units: usize = work.iter().map(|w| w.samples.len().div_ceil(b)).sum();
            let use_stack = match *path {
                EvalPath::Batched => true,
                EvalPath::Auto => n_units > 1,
                // Scalar never reaches the queue (see submit)
                EvalPath::Scalar => false,
            };
            if use_stack {
                stacked.push(p);
                continue;
            }
            // scalar-resolved: score in place now, bit-identical to the
            // immediate path (it IS evaluate_subset per unit)
            let mut failed = None;
            for w in work.iter_mut() {
                match trainer.evaluate_subset(&w.params, ds, &w.samples) {
                    Ok(acc) => w.accuracy = Some(acc),
                    Err(e) => {
                        failed = Some(format!("scalar eval failed: {e:#}"));
                        break;
                    }
                }
            }
            match failed {
                None => done.push(p),
                Some(msg) => p.fail(&msg),
            }
        }

        if !stacked.is_empty() {
            let mut units: Vec<EvalUnit> = Vec::new();
            for p in stacked.iter_mut() {
                let ds = &datasets[&p.ds].1;
                let BatchPayload::Eval { work, .. } = &mut p.payload else {
                    unreachable!("eval group carries eval payloads");
                };
                units.extend(work.iter_mut().map(|w| EvalUnit { ds, work: w }));
            }
            let res = trainer.evaluate_units(rt, &mut units, TileFill::Largest);
            drop(units);
            match res {
                Ok(()) => done.extend(stacked),
                Err(e) => {
                    let msg = format!("coalesced eval dispatch failed: {e:#}");
                    for p in stacked {
                        p.fail(&msg);
                    }
                }
            }
        }
        done.into_iter().for_each(PendingBatch::complete);
    }
}

impl RuntimeService {
    /// Spawn a model/dataset-agnostic service thread with the default
    /// (non-coalescing) scheduler. Register datasets and bind handles
    /// through [`RuntimeService::client`].
    pub fn spawn_shared() -> RuntimeService {
        Self::spawn_with(ServiceConfig::default())
    }

    /// Spawn a model/dataset-agnostic service thread with explicit
    /// scheduler knobs (see [`ServiceConfig`]).
    pub fn spawn_with(cfg: ServiceConfig) -> RuntimeService {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let join = std::thread::Builder::new()
            .name("fogml-runtime".into())
            .spawn(move || service_loop(rx, cfg))
            .expect("spawn runtime service");
        RuntimeService {
            client: ServiceClient { tx },
            default_handle: None,
            join: Some(join),
        }
    }

    /// Spawn the service pre-bound to one model/lr/dataset context — the
    /// original single-tenant API the cluster actors use.
    pub fn spawn(kind: ModelKind, lr: f32, train_ds: Dataset, test_ds: Dataset) -> RuntimeService {
        let mut svc = Self::spawn_shared();
        let ds = svc
            .client
            .register_dataset(train_ds, test_ds)
            .expect("register default datasets");
        svc.default_handle = Some(svc.client.bind(kind, lr, ds));
        svc
    }

    /// The raw, unbound client (register datasets, address any model).
    pub fn client(&self) -> ServiceClient {
        self.client.clone()
    }

    /// The default bound handle (only for services created via
    /// [`RuntimeService::spawn`]).
    pub fn handle(&self) -> RuntimeHandle {
        self.default_handle
            .clone()
            .expect("service spawned without default context; use client()")
    }

    /// Stop the thread (idempotent; also called on drop).
    pub fn shutdown(&mut self) {
        let _ = self.client.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One scheduling cycle per outer iteration: block for the first request,
/// opportunistically drain whatever else already arrived (coalescing mode,
/// bounded by `max_pending`), then pack → dispatch → complete the queued
/// batchables. Because the drain never *waits*, a lone session pays zero
/// added latency; co-scheduled sessions enqueue while a dispatch runs and
/// coalesce naturally on the next cycle.
fn service_loop(rx: Receiver<Request>, cfg: ServiceConfig) {
    let mut sched = Scheduler {
        state: ServiceState {
            rt: None,
            datasets: HashMap::new(),
            next_id: 0,
            trainers: HashMap::new(),
        },
        cfg,
        queue: Vec::new(),
    };
    loop {
        let Ok(first) = rx.recv() else { break };
        let mut shutdown = sched.submit(first);
        if sched.cfg.coalesce {
            while !shutdown && sched.queue.len() < sched.cfg.max_pending.max(1) {
                match rx.try_recv() {
                    Ok(req) => shutdown = sched.submit(req),
                    Err(_) => break,
                }
            }
        }
        // queued work is always flushed — a shutdown drained mid-cycle
        // still answers every pending request before the thread exits
        sched.flush();
        if shutdown {
            break;
        }
    }
}

impl ServiceClient {
    fn send(&self, req: Request) -> Result<()> {
        self.tx.send(req).map_err(|_| anyhow!("runtime service gone"))
    }

    /// Register a `(train, test)` dataset pair; returns its id. Callers
    /// should [`ServiceClient::unregister_dataset`] when the run finishes so
    /// the service does not accumulate dead datasets.
    pub fn register_dataset(&self, train: Dataset, test: Dataset) -> Result<DatasetId> {
        let (tx, rx) = channel();
        self.send(Request::Register { train, test, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))
    }

    /// Drop a registered dataset pair (fire-and-forget).
    pub fn unregister_dataset(&self, id: DatasetId) {
        let _ = self.send(Request::Unregister { id });
    }

    /// Bind this client to a `(model, lr, dataset)` context.
    pub fn bind(&self, kind: ModelKind, lr: f32, ds: DatasetId) -> RuntimeHandle {
        RuntimeHandle { client: self.clone(), kind, lr, ds }
    }

    /// One interval of local updates; returns updated params + loss.
    pub fn train(
        &self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: Params,
        samples: Vec<u32>,
    ) -> Result<(Params, Option<f32>)> {
        let (tx, rx) = channel();
        self.send(Request::Train { kind, lr, ds, params, samples, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// One batched interval: every device's local updates in stacked
    /// multi-device executions; returns the work list with updated params
    /// and per-device losses. On a coalescing service the dispatch may be
    /// shared with other sessions' requests (results are invariant to
    /// that — DESIGN.md §Perf rule 10).
    pub fn train_many(
        &self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        work: Vec<DeviceWork>,
    ) -> Result<Vec<DeviceWork>> {
        let (tx, rx) = channel();
        self.send(Request::TrainMany { kind, lr, ds, work, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// Test-set accuracy of the given parameters on dataset `ds`.
    pub fn evaluate(
        &self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        params: Params,
    ) -> Result<f64> {
        let (tx, rx) = channel();
        self.send(Request::Evaluate { kind, lr, ds, params, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// One batched evaluation round-trip: the whole work list scores on
    /// the service thread; returns it with accuracies filled in. Like
    /// [`ServiceClient::train_many`], a coalescing service may share the
    /// stacked dispatch across sessions.
    pub fn eval_many(
        &self,
        kind: ModelKind,
        lr: f32,
        ds: DatasetId,
        work: Vec<EvalWork>,
        path: EvalPath,
    ) -> Result<Vec<EvalWork>> {
        let (tx, rx) = channel();
        self.send(Request::EvalMany { kind, lr, ds, work, path, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// Seeded parameter initialization on the service thread.
    pub fn init_params(&self, kind: ModelKind, seed: u64) -> Result<Params> {
        let (tx, rx) = channel();
        self.send(Request::InitParams { kind, seed, reply: tx })?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }
}

impl RuntimeHandle {
    /// Run one interval of local updates; returns updated params + loss.
    pub fn train(&self, params: Params, samples: Vec<u32>) -> Result<(Params, Option<f32>)> {
        self.client.train(self.kind, self.lr, self.ds, params, samples)
    }

    /// Run one batched multi-device interval on the service thread.
    pub fn train_many(&self, work: Vec<DeviceWork>) -> Result<Vec<DeviceWork>> {
        self.client.train_many(self.kind, self.lr, self.ds, work)
    }

    /// Test-set accuracy of the given parameters.
    pub fn evaluate(&self, params: Params) -> Result<f64> {
        self.client.evaluate(self.kind, self.lr, self.ds, params)
    }

    /// Run one batched evaluation on the service thread.
    pub fn eval_many(&self, work: Vec<EvalWork>, path: EvalPath) -> Result<Vec<EvalWork>> {
        self.client.eval_many(self.kind, self.lr, self.ds, work, path)
    }

    /// Seeded parameter initialization on the service thread.
    pub fn init_params(&self, seed: u64) -> Result<Params> {
        self.client.init_params(self.kind, seed)
    }
}

/// A bound handle is a full engine backend: [`crate::fed::session::Session`]
/// can train through the service thread from any worker.
impl Compute for RuntimeHandle {
    fn init_params(&self, seed: u64) -> Result<Params> {
        RuntimeHandle::init_params(self, seed)
    }

    fn train_interval(&self, params: &mut Params, samples: &[u32]) -> Result<Option<f32>> {
        let owned = std::mem::take(params);
        let (updated, loss) = RuntimeHandle::train(self, owned, samples.to_vec())?;
        *params = updated;
        Ok(loss)
    }

    fn train_interval_many(&self, work: &mut [DeviceWork]) -> Result<()> {
        let sent: Vec<DeviceWork> = work.iter_mut().map(std::mem::take).collect();
        let updated = RuntimeHandle::train_many(self, sent)?;
        anyhow::ensure!(
            updated.len() == work.len(),
            "train_many reply: {} items, sent {}",
            updated.len(),
            work.len()
        );
        for (w, u) in work.iter_mut().zip(updated) {
            *w = u;
        }
        Ok(())
    }

    fn evaluate(&self, params: &[HostTensor]) -> Result<f64> {
        RuntimeHandle::evaluate(self, params.to_vec())
    }

    fn evaluate_subset(&self, params: &[HostTensor], samples: &[u32]) -> Result<f64> {
        // a single-unit scalar-path EvalMany: one round-trip, and the
        // service executes through Trainer::evaluate_subset — bit-identical
        // to the serial scalar path
        let work = vec![EvalWork {
            params: params.to_vec(),
            samples: samples.to_vec(),
            accuracy: None,
        }];
        let out = RuntimeHandle::eval_many(self, work, EvalPath::Scalar)?;
        out.first()
            .and_then(|w| w.accuracy)
            .ok_or_else(|| anyhow!("eval_many reply missing accuracy"))
    }

    fn evaluate_many(&self, work: &mut [EvalWork], path: EvalPath) -> Result<()> {
        let sent: Vec<EvalWork> = work.iter_mut().map(std::mem::take).collect();
        let updated = RuntimeHandle::eval_many(self, sent, path)?;
        anyhow::ensure!(
            updated.len() == work.len(),
            "eval_many reply: {} items, sent {}",
            updated.len(),
            work.len()
        );
        for (w, u) in work.iter_mut().zip(updated) {
            *w = u;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDigits;
    use crate::util::rng::Rng;

    // -- pure scheduler logic (no runtime, runs under the CI hard gate) ----

    fn key(family: BatchFamily, lr: f32) -> BatchKey {
        BatchKey { family, kind: ModelKind::Mlp, lr_bits: lr.to_bits() }
    }

    #[test]
    fn plan_groups_packs_by_key_in_fifo_order() {
        let keys = vec![
            key(BatchFamily::Train, 0.05),
            key(BatchFamily::Eval, 0.05),
            key(BatchFamily::Train, 0.05),
            key(BatchFamily::Train, 0.02),
            key(BatchFamily::Train, 0.05),
            key(BatchFamily::Eval, 0.05),
        ];
        let groups = plan_groups(&keys);
        // ordered by first appearance; members in arrival order
        assert_eq!(groups, vec![vec![0, 2, 4], vec![1, 5], vec![3]]);
    }

    /// Every queued request lands in exactly one group — nothing starves
    /// within a cycle and nothing dispatches twice.
    #[test]
    fn plan_groups_covers_every_request_exactly_once() {
        let lrs = [0.05f32, 0.02, 0.05, 0.1, 0.02, 0.05, 0.1, 0.1];
        let keys: Vec<BatchKey> = lrs
            .iter()
            .enumerate()
            .map(|(i, &lr)| {
                key(if i % 3 == 0 { BatchFamily::Eval } else { BatchFamily::Train }, lr)
            })
            .collect();
        let groups = plan_groups(&keys);
        let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..keys.len()).collect::<Vec<_>>());
        for g in &groups {
            assert!(g.windows(2).all(|w| w[0] < w[1]), "FIFO violated: {g:?}");
            let k = keys[g[0]];
            assert!(g.iter().all(|&i| keys[i] == k), "mixed keys in {g:?}");
        }
    }

    /// The lr is part of the key bit-exactly: nearby-but-different rates
    /// must not share a trainer or a dispatch.
    #[test]
    fn plan_groups_distinguishes_lr_bits() {
        let keys =
            vec![key(BatchFamily::Train, 0.05), key(BatchFamily::Train, 0.05 + 1e-8)];
        assert_eq!(plan_groups(&keys).len(), 2);
        let same = vec![key(BatchFamily::Train, 0.05), key(BatchFamily::Train, 0.05)];
        assert_eq!(plan_groups(&same).len(), 1);
    }

    #[test]
    fn service_config_defaults_are_classic() {
        let cfg = ServiceConfig::default();
        assert!(!cfg.coalesce, "coalescing must be opt-in");
        assert!(cfg.max_pending >= 1);
        assert!(ServiceConfig::coalescing().coalesce);
    }

    /// Reply routing through the full submit → pack → dispatch → complete
    /// cycle, without ever touching a runtime: requests against
    /// unregistered datasets each get their own error reply (never a
    /// partner's), the service stays alive, and nothing hangs even when
    /// the request count exceeds `max_pending` (multi-cycle draining).
    #[test]
    fn coalesced_error_routing_needs_no_runtime() {
        let mut svc = RuntimeService::spawn_with(ServiceConfig {
            coalesce: true,
            max_pending: 2,
        });
        let client = svc.client();

        let mut joins = Vec::new();
        for ds in 100..106 {
            let c = client.clone();
            joins.push(std::thread::spawn(move || {
                let work = vec![DeviceWork {
                    params: Vec::new(),
                    samples: vec![0, 1],
                    loss: None,
                }];
                (ds, c.train_many(ModelKind::Mlp, 0.05, ds, work))
            }));
        }
        for j in joins {
            let (ds, res) = j.join().unwrap();
            let err = res.expect_err("unregistered dataset must error").to_string();
            assert!(err.contains(&format!("dataset {ds}")), "{ds}: {err}");
        }

        // batched eval requests route errors the same way
        let work = vec![EvalWork { params: Vec::new(), samples: vec![0], accuracy: None }];
        let err = client
            .eval_many(ModelKind::Mlp, 0.05, 999, work, EvalPath::Batched)
            .expect_err("unregistered dataset must error")
            .to_string();
        assert!(err.contains("dataset 999"), "{err}");

        // the service survived every failed dispatch
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(3);
        let (train, test) = gen.train_test(60, 20, &mut rng);
        let id = client.register_dataset(train, test).unwrap();
        client.unregister_dataset(id);
        svc.shutdown();
    }

    // -- runtime-backed (skip under the pure-CPU xla stub) ------------------

    #[test]
    fn service_trains_from_other_threads() {
        if crate::runtime::test_runtime().is_none() {
            return;
        }
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(1);
        let (train, test) = gen.train_test(600, 200, &mut rng);
        let mut svc = RuntimeService::spawn(ModelKind::Mlp, 0.05, train, test);
        let handle = svc.handle();

        let params = handle.init_params(3).unwrap();
        let before = handle.evaluate(params.clone()).unwrap();

        // two worker threads train disjoint shards concurrently
        let h1 = handle.clone();
        let p1 = params.clone();
        let t1 = std::thread::spawn(move || {
            let mut p = p1;
            for _ in 0..6 {
                let (np, _) = h1.train(p, (0..300).collect()).unwrap();
                p = np;
            }
            p
        });
        let h2 = handle.clone();
        let p2 = params.clone();
        let t2 = std::thread::spawn(move || {
            let mut p = p2;
            for _ in 0..6 {
                let (np, _) = h2.train(p, (300..600).collect()).unwrap();
                p = np;
            }
            p
        });
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();

        // fedavg of the two shard models
        let agg = crate::fed::aggregator::aggregate(&[(&r1, 1.0), (&r2, 1.0)]).unwrap().unwrap();
        let after = handle.evaluate(agg).unwrap();
        assert!(after > before + 0.15, "{before} -> {after}");
        svc.shutdown();
    }

    /// The batched request must match per-device scalar requests through
    /// the same service (tolerance per DESIGN.md §Perf rule 7).
    #[test]
    fn service_train_many_matches_scalar_requests() {
        if crate::runtime::test_runtime().is_none() {
            return;
        }
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(5);
        let (train, test) = gen.train_test(600, 100, &mut rng);
        let mut svc = RuntimeService::spawn(ModelKind::Mlp, 0.05, train, test);
        let handle = svc.handle();
        let params = handle.init_params(9).unwrap();

        let shard = |k: u32| -> Vec<u32> { (k * 150..k * 150 + 120).collect() };
        let work: Vec<DeviceWork> = (0..3)
            .map(|k| DeviceWork {
                params: params.clone(),
                samples: shard(k),
                loss: None,
            })
            .collect();
        let out = handle.train_many(work).unwrap();
        assert_eq!(out.len(), 3);
        for (k, w) in out.iter().enumerate() {
            let (sp, sl) = handle.train(params.clone(), shard(k as u32)).unwrap();
            let sl = sl.unwrap();
            let bl = w.loss.unwrap();
            assert!((sl - bl).abs() <= 1e-5 * (1.0 + sl.abs()), "{k}: {sl} vs {bl}");
            for (a, b) in w.params.iter().zip(&sp) {
                let max_diff = a
                    .data
                    .iter()
                    .zip(&b.data)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0f32, f32::max);
                assert!(max_diff <= 1e-4, "device {k}: max diff {max_diff}");
            }
        }
        svc.shutdown();
    }

    /// One EvalMany round-trip must score a whole work list; the batched
    /// path agrees with per-item scalar requests within the DESIGN.md
    /// §Perf rule 7 accuracy tolerance, and the scalar path is exact.
    #[test]
    fn service_eval_many_matches_scalar_requests() {
        if crate::runtime::test_runtime().is_none() {
            return;
        }
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(8);
        let (train, test) = gen.train_test(600, 200, &mut rng);
        let mut svc = RuntimeService::spawn(ModelKind::Mlp, 0.05, train, test);
        let handle = svc.handle();
        let params = handle.init_params(4).unwrap();
        let (trained, _) = handle.train(params.clone(), (0..600).collect()).unwrap();

        let full: Vec<u32> = (0..200).collect();
        let make_work = || -> Vec<EvalWork> {
            vec![
                EvalWork { params: trained.clone(), samples: full.clone(), accuracy: None },
                EvalWork { params: params.clone(), samples: (0..50).collect(), accuracy: None },
                EvalWork { params: trained.clone(), samples: Vec::new(), accuracy: None },
            ]
        };
        let scalar_ref = handle.evaluate(trained.clone()).unwrap();

        let batched = handle.eval_many(make_work(), EvalPath::Batched).unwrap();
        assert_eq!(batched.len(), 3);
        assert!((batched[0].accuracy.unwrap() - scalar_ref).abs() <= 5e-3);
        assert_eq!(batched[2].accuracy, Some(0.0));

        let scalar = handle.eval_many(make_work(), EvalPath::Scalar).unwrap();
        assert_eq!(scalar[0].accuracy.unwrap(), scalar_ref);
        for (a, b) in batched.iter().zip(&scalar) {
            assert!(
                (a.accuracy.unwrap() - b.accuracy.unwrap()).abs() <= 5e-3,
                "{:?} vs {:?}",
                a.accuracy,
                b.accuracy
            );
        }
        svc.shutdown();
    }

    /// A coalescing service must return each session the same bits it
    /// would get from its requests dispatched alone — concurrent partner
    /// requests (on another dataset) share dispatches without perturbing
    /// anyone's results (§Perf rule 10 at the service level).
    #[test]
    fn coalesced_requests_are_partner_invariant() {
        if crate::runtime::test_runtime().is_none() {
            return;
        }
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(12);
        let (train_a, test_a) = gen.train_test(400, 100, &mut rng);
        let (train_b, test_b) = gen.train_test(400, 100, &mut rng);

        let run_session_a = |client: &ServiceClient| -> (Vec<DeviceWork>, Vec<EvalWork>) {
            let ds = client.register_dataset(train_a.clone(), test_a.clone()).unwrap();
            let params = client.init_params(ModelKind::Mlp, 21).unwrap();
            let work: Vec<DeviceWork> = (0..3)
                .map(|k| DeviceWork {
                    params: params.clone(),
                    samples: (k * 100..k * 100 + 80).collect(),
                    loss: None,
                })
                .collect();
            let trained = client.train_many(ModelKind::Mlp, 0.05, ds, work).unwrap();
            let eval = vec![EvalWork {
                params: trained[0].params.clone(),
                samples: (0..100).collect(),
                accuracy: None,
            }];
            let scored = client
                .eval_many(ModelKind::Mlp, 0.05, ds, eval, EvalPath::Batched)
                .unwrap();
            client.unregister_dataset(ds);
            (trained, scored)
        };

        // alone on its own coalescing service
        let mut svc_alone = RuntimeService::spawn_with(ServiceConfig::coalescing());
        let (alone_train, alone_eval) = run_session_a(&svc_alone.client());
        svc_alone.shutdown();

        // with a concurrent partner hammering the same service
        let mut svc_shared = RuntimeService::spawn_with(ServiceConfig::coalescing());
        let client = svc_shared.client();
        let partner_client = client.clone();
        let (ptrain, ptest) = (train_b.clone(), test_b.clone());
        let partner = std::thread::spawn(move || {
            let ds = partner_client.register_dataset(ptrain, ptest).unwrap();
            let params = partner_client.init_params(ModelKind::Mlp, 99).unwrap();
            for rep in 0..4 {
                let work = vec![DeviceWork {
                    params: params.clone(),
                    samples: (rep * 50..rep * 50 + 50).collect(),
                    loss: None,
                }];
                partner_client.train_many(ModelKind::Mlp, 0.05, ds, work).unwrap();
            }
            partner_client.unregister_dataset(ds);
        });
        let (shared_train, shared_eval) = run_session_a(&client);
        partner.join().unwrap();
        svc_shared.shutdown();

        for (k, (a, b)) in alone_train.iter().zip(&shared_train).enumerate() {
            assert_eq!(a.loss, b.loss, "device {k} loss");
            for (p, (x, y)) in a.params.iter().zip(&b.params).enumerate() {
                assert_eq!(x.data, y.data, "device {k} param {p}");
            }
        }
        assert_eq!(alone_eval[0].accuracy, shared_eval[0].accuracy);
    }

    #[test]
    fn shared_service_isolates_datasets() {
        if crate::runtime::test_runtime().is_none() {
            return;
        }
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(2);
        let (train_a, test_a) = gen.train_test(400, 100, &mut rng);
        let (train_b, test_b) = gen.train_test(400, 100, &mut rng);

        let mut svc = RuntimeService::spawn_shared();
        let client = svc.client();
        let a = client.register_dataset(train_a, test_a).unwrap();
        let b = client.register_dataset(train_b, test_b).unwrap();
        assert_ne!(a, b);

        let params = client.init_params(ModelKind::Mlp, 7).unwrap();
        let (pa, la) = client
            .train(ModelKind::Mlp, 0.05, a, params.clone(), (0..400).collect())
            .unwrap();
        let (_pb, lb) = client
            .train(ModelKind::Mlp, 0.05, b, params.clone(), (0..400).collect())
            .unwrap();
        assert!(la.unwrap() > 0.0 && lb.unwrap() > 0.0);
        let acc = client.evaluate(ModelKind::Mlp, 0.05, a, pa).unwrap();
        assert!(acc > 0.0);

        // dropped datasets error cleanly rather than training on stale data
        client.unregister_dataset(b);
        let err = client.train(ModelKind::Mlp, 0.05, b, params, (0..10).collect());
        assert!(err.is_err());
        svc.shutdown();
    }
}
