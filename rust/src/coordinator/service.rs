//! The runtime-service thread: owns the (thread-confined) PJRT runtime and
//! serves train/eval requests from any number of actor threads.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::data::dataset::Dataset;
use crate::fed::trainer::Trainer;
use crate::runtime::{HostTensor, ModelKind, Runtime};

/// Model parameters as they travel between threads.
pub type Params = Vec<HostTensor>;

enum Request {
    Train {
        params: Params,
        samples: Vec<u32>,
        reply: Sender<Result<(Params, Option<f32>)>>,
    },
    Evaluate {
        params: Params,
        reply: Sender<Result<f64>>,
    },
    InitParams {
        seed: u64,
        reply: Sender<Result<Params>>,
    },
    Shutdown,
}

/// Cloneable handle to the runtime-service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
}

/// The service itself (join handle + control).
pub struct RuntimeService {
    handle: RuntimeHandle,
    join: Option<JoinHandle<()>>,
}

impl RuntimeService {
    /// Spawn the service thread. It compiles the model's entries on first
    /// use and serves requests until [`RuntimeService::shutdown`].
    pub fn spawn(kind: ModelKind, lr: f32, train_ds: Dataset, test_ds: Dataset) -> RuntimeService {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let join = std::thread::Builder::new()
            .name("fogml-runtime".into())
            .spawn(move || {
                let rt = match Runtime::load_default() {
                    Ok(rt) => rt,
                    Err(e) => {
                        // fail every request with the load error
                        for req in rx {
                            match req {
                                Request::Train { reply, .. } => {
                                    let _ = reply.send(Err(anyhow!("runtime load failed: {e:#}")));
                                }
                                Request::Evaluate { reply, .. } => {
                                    let _ = reply.send(Err(anyhow!("runtime load failed: {e:#}")));
                                }
                                Request::InitParams { reply, .. } => {
                                    let _ = reply.send(Err(anyhow!("runtime load failed: {e:#}")));
                                }
                                Request::Shutdown => break,
                            }
                        }
                        return;
                    }
                };
                let trainer = Trainer::new(&rt, kind, lr).expect("trainer init");
                for req in rx {
                    match req {
                        Request::Train { mut params, samples, reply } => {
                            let res = trainer
                                .train_interval(&mut params, &train_ds, &samples)
                                .map(|loss| (params, loss));
                            let _ = reply.send(res);
                        }
                        Request::Evaluate { params, reply } => {
                            let _ = reply.send(trainer.evaluate(&params, &test_ds));
                        }
                        Request::InitParams { seed, reply } => {
                            let _ = reply.send(rt.init_params(kind, seed));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawn runtime service");
        RuntimeService { handle: RuntimeHandle { tx }, join: Some(join) }
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }

    /// Stop the thread (idempotent; also called on drop).
    pub fn shutdown(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl RuntimeHandle {
    /// Run one interval of local updates; returns updated params + loss.
    pub fn train(&self, params: Params, samples: Vec<u32>) -> Result<(Params, Option<f32>)> {
        let (tx, rx) = channel();
        self.tx
            .send(Request::Train { params, samples, reply: tx })
            .map_err(|_| anyhow!("runtime service gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// Test-set accuracy of the given parameters.
    pub fn evaluate(&self, params: Params) -> Result<f64> {
        let (tx, rx) = channel();
        self.tx
            .send(Request::Evaluate { params, reply: tx })
            .map_err(|_| anyhow!("runtime service gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// Seeded parameter initialization on the service thread.
    pub fn init_params(&self, seed: u64) -> Result<Params> {
        let (tx, rx) = channel();
        self.tx
            .send(Request::InitParams { seed, reply: tx })
            .map_err(|_| anyhow!("runtime service gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDigits;
    use crate::util::rng::Rng;

    #[test]
    fn service_trains_from_other_threads() {
        let gen = SynthDigits::new(0xF0D5);
        let mut rng = Rng::new(1);
        let (train, test) = gen.train_test(600, 200, &mut rng);
        let mut svc = RuntimeService::spawn(ModelKind::Mlp, 0.05, train, test);
        let handle = svc.handle();

        let params = handle.init_params(3).unwrap();
        let before = handle.evaluate(params.clone()).unwrap();

        // two worker threads train disjoint shards concurrently
        let h1 = handle.clone();
        let p1 = params.clone();
        let t1 = std::thread::spawn(move || {
            let mut p = p1;
            for _ in 0..6 {
                let (np, _) = h1.train(p, (0..300).collect()).unwrap();
                p = np;
            }
            p
        });
        let h2 = handle.clone();
        let p2 = params.clone();
        let t2 = std::thread::spawn(move || {
            let mut p = p2;
            for _ in 0..6 {
                let (np, _) = h2.train(p, (300..600).collect()).unwrap();
                p = np;
            }
            p
        });
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();

        // fedavg of the two shard models
        let agg = crate::fed::aggregator::aggregate(&[(&r1, 1.0), (&r2, 1.0)]).unwrap();
        let after = handle.evaluate(agg).unwrap();
        assert!(after > before + 0.15, "{before} -> {after}");
        svc.shutdown();
    }
}
