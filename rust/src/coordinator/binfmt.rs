//! The binary shard wire format (`shard_I_of_N.fsb`): versioned,
//! length-prefixed, little-endian, zero text serde.
//!
//! The JSON shard path ([`crate::coordinator::shard`]) round-trips every
//! float exactly but pays `Display`/parse on the full [`EngineOutput`]
//! per run — at sweep scales of 10⁵–10⁶ runs the merge step is parse-
//! bound. This module writes the same [`ShardFile`] payload as raw
//! little-endian bytes through a streaming [`ByteWriter`] and reads it
//! back with a forward-only zero-copy [`ByteReader`]: every `f64` is its
//! raw bit pattern (`to_bits`/`from_bits`), so NaN payload bits, ±inf,
//! `-0.0` and subnormals round-trip *bitwise* — strictly stronger than
//! the JSON path, whose tagged-string escapes canonicalize NaN payloads.
//!
//! # Wire layout (all integers little-endian)
//!
//! ```text
//! header:
//!   magic             8 bytes   "FOGMLSB\0"
//!   version           u32       BINARY_FORMAT_VERSION (currently 1)
//!   experiment        str_lp    u32 byte length + UTF-8 bytes
//!   shard_index       u32       1-based I
//!   shard_count       u32       N
//!   total_runs        u64       whole-grid run count
//!   grid_fingerprint  u64       per-run FNV-1a fps folded in order
//!   opts              str_lp    canonical JSON text of the opts blob
//!   run_count         u64       records that follow
//! per run record:
//!   payload_len       u64       byte length of the record body
//!   body:
//!     index             u64     global grid index
//!     fingerprint       u64     config fingerprint
//!     accuracy          f64     raw bits
//!     curve_len         u32     then curve_len × (t u64, acc f64)
//!     loss_rows         u32     then per row:
//!       cols            u32     then per cell: tag u8 (0 = None,
//!                               1 = Some) + f32 raw bits iff Some
//!     ledger            3 × f64 process, transfer, discard
//!     movement_len      u32     then movement_len × 4 × u64
//!                               (collected, processed, offloaded,
//!                                discarded)
//!     similarity        2 × f64 before, after
//!     mean_active       f64
//!     total_collected   u64
//! ```
//!
//! The length prefix makes each record body self-delimiting: the reader
//! parses it through a bounded [`ByteReader::sub_reader`] and rejects
//! records that do not consume exactly their declared length, so a
//! corrupt record cannot desynchronize its successors silently.
//!
//! The opts blob rides along as its canonical JSON *text* — it is an
//! opaque handful of bytes owned by `experiments::ExpOptions`, read a
//! single time per merge, and keeping it textual means the two formats
//! share one options codec (and one equality check in
//! [`crate::coordinator::shard::load_shard_set`]).
//!
//! # Contract
//!
//! `read_shard(write_shard(f)) == f` with every float bit-identical, and
//! merging `.fsb` shards produces artifacts byte-identical to merging
//! `.json` shards and to an unsharded run (DESIGN.md §Perf rule 9;
//! `tests/shard_merge.rs`). JSON stays the debug/interop default —
//! binary is the opt-in fast path (`fogml exp --shard-format binary`).

use std::io::Write;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::shard::{RunRecord, ShardFile, ShardSpec};
use crate::fed::accounting::{IntervalStats, Ledger, MovementTotals};
use crate::fed::EngineOutput;
use crate::util::binio::{ByteReader, ByteWriter};
use crate::util::json::Json;

/// First 8 bytes of every binary shard file.
pub const BINARY_MAGIC: &[u8; 8] = b"FOGMLSB\0";

/// Version stamp after the magic; readers reject anything else.
pub const BINARY_FORMAT_VERSION: u32 = 1;

/// Content sniff: does `bytes` start like a binary shard file? Used by
/// the auto-detecting loaders (`ShardFile::load`, `fogml merge`) — the
/// magic is not valid UTF-8-leading JSON, so the two formats can never
/// be confused.
pub fn is_binary(bytes: &[u8]) -> bool {
    bytes.starts_with(BINARY_MAGIC)
}

fn to_u32(x: usize, what: &str) -> Result<u32> {
    u32::try_from(x).map_err(|_| anyhow!("{what} {x} exceeds the u32 wire field"))
}

fn to_usize(x: u64, what: &str) -> Result<usize> {
    usize::try_from(x).map_err(|_| anyhow!("{what} {x} does not fit in usize"))
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

fn put_output(w: &mut ByteWriter<&mut Vec<u8>>, o: &EngineOutput) -> Result<()> {
    w.put_f64(o.accuracy)?;
    w.put_u32(to_u32(o.accuracy_curve.len(), "curve length")?)?;
    for &(t, acc) in &o.accuracy_curve {
        w.put_u64(t as u64)?;
        w.put_f64(acc)?;
    }
    w.put_u32(to_u32(o.per_device_loss.len(), "loss row count")?)?;
    for row in &o.per_device_loss {
        w.put_u32(to_u32(row.len(), "loss column count")?)?;
        for cell in row {
            match cell {
                None => w.put_u8(0)?,
                Some(x) => {
                    w.put_u8(1)?;
                    w.put_u32(x.to_bits())?;
                }
            }
        }
    }
    w.put_f64(o.ledger.process)?;
    w.put_f64(o.ledger.transfer)?;
    w.put_f64(o.ledger.discard)?;
    w.put_u32(to_u32(o.movement.per_interval.len(), "movement length")?)?;
    for s in &o.movement.per_interval {
        w.put_u64(s.collected as u64)?;
        w.put_u64(s.processed as u64)?;
        w.put_u64(s.offloaded as u64)?;
        w.put_u64(s.discarded as u64)?;
    }
    w.put_f64(o.similarity.0)?;
    w.put_f64(o.similarity.1)?;
    w.put_f64(o.mean_active)?;
    w.put_u64(o.total_collected as u64)?;
    Ok(())
}

/// Stream `file` into `sink` in the binary wire format. Allocation stays
/// O(max record size): the header goes straight to the sink and each run
/// record is staged once in a reusable scratch buffer (its length prefix
/// must precede bytes whose length is not known until serialized), then
/// written through. Returns the total bytes written.
pub fn write_shard<W: Write>(sink: W, file: &ShardFile) -> Result<u64> {
    let mut w = ByteWriter::new(sink);
    w.put_bytes(BINARY_MAGIC)?;
    w.put_u32(BINARY_FORMAT_VERSION)?;
    w.put_str_lp(&file.experiment)?;
    w.put_u32(to_u32(file.spec.index, "shard index")?)?;
    w.put_u32(to_u32(file.spec.count, "shard count")?)?;
    w.put_u64(file.total_runs as u64)?;
    w.put_u64(file.grid_fingerprint)?;
    w.put_str_lp(&file.opts.to_string())?;
    w.put_u64(file.runs.len() as u64)?;

    let mut scratch: Vec<u8> = Vec::new();
    for rec in &file.runs {
        scratch.clear();
        let mut body = ByteWriter::new(&mut scratch);
        body.put_u64(rec.index as u64)?;
        body.put_u64(rec.fingerprint)?;
        put_output(&mut body, &rec.output)?;
        w.put_u64(scratch.len() as u64)?;
        w.put_bytes(&scratch)?;
    }
    let written = w.written();
    w.into_inner()?;
    Ok(written)
}

// ---------------------------------------------------------------------------
// Reading
// ---------------------------------------------------------------------------

fn get_output(r: &mut ByteReader<'_>) -> Result<EngineOutput> {
    let accuracy = r.get_f64("accuracy")?;
    let curve_len = r.get_u32("curve length")? as usize;
    let mut accuracy_curve = Vec::with_capacity(curve_len.min(1 << 16));
    for _ in 0..curve_len {
        let t = to_usize(r.get_u64("curve t")?, "curve t")?;
        let acc = r.get_f64("curve accuracy")?;
        accuracy_curve.push((t, acc));
    }
    let rows = r.get_u32("loss row count")? as usize;
    let mut per_device_loss = Vec::with_capacity(rows.min(1 << 16));
    for _ in 0..rows {
        let cols = r.get_u32("loss column count")? as usize;
        let mut row = Vec::with_capacity(cols.min(1 << 16));
        for _ in 0..cols {
            row.push(match r.get_u8("loss cell tag")? {
                0 => None,
                1 => Some(f32::from_bits(r.get_u32("loss cell")?)),
                t => bail!("loss cell tag {t} at byte {} (want 0 or 1)", r.pos()),
            });
        }
        per_device_loss.push(row);
    }
    let ledger = Ledger {
        process: r.get_f64("ledger process")?,
        transfer: r.get_f64("ledger transfer")?,
        discard: r.get_f64("ledger discard")?,
    };
    let intervals = r.get_u32("movement length")? as usize;
    let mut movement = MovementTotals::default();
    for _ in 0..intervals {
        movement.push(IntervalStats {
            collected: to_usize(r.get_u64("collected")?, "collected")?,
            processed: to_usize(r.get_u64("processed")?, "processed")?,
            offloaded: to_usize(r.get_u64("offloaded")?, "offloaded")?,
            discarded: to_usize(r.get_u64("discarded")?, "discarded")?,
        });
    }
    let similarity = (r.get_f64("similarity before")?, r.get_f64("similarity after")?);
    let mean_active = r.get_f64("mean_active")?;
    let total_collected = to_usize(r.get_u64("total_collected")?, "total_collected")?;
    Ok(EngineOutput {
        accuracy,
        accuracy_curve,
        per_device_loss,
        ledger,
        movement,
        similarity,
        mean_active,
        total_collected,
    })
}

/// Parse one binary shard file from `bytes` (typically a whole-file
/// `fs::read`). Forward-only and zero-copy until the final owned
/// [`ShardFile`] is assembled; validation matches the JSON path
/// ([`ShardFile::validate`]) so both formats reject the same malformed
/// inputs.
pub fn read_shard(bytes: &[u8]) -> Result<ShardFile> {
    let mut r = ByteReader::new(bytes);
    r.expect(BINARY_MAGIC, "magic")
        .map_err(|e| anyhow!("not a fogml binary shard file: {e}"))?;
    let version = r.get_u32("version")?;
    if version != BINARY_FORMAT_VERSION {
        bail!(
            "unsupported binary shard version {version} (this build reads {BINARY_FORMAT_VERSION})"
        );
    }
    let experiment = r.get_str_lp("experiment")?.to_string();
    let spec = ShardSpec {
        index: r.get_u32("shard index")? as usize,
        count: r.get_u32("shard count")? as usize,
    };
    let total_runs = to_usize(r.get_u64("total_runs")?, "total_runs")?;
    let grid_fingerprint = r.get_u64("grid_fingerprint")?;
    let opts_text = r.get_str_lp("opts")?;
    let opts = Json::parse(opts_text).context("opts blob")?;
    let run_count = to_usize(r.get_u64("run_count")?, "run_count")?;

    let mut runs = Vec::with_capacity(run_count.min(1 << 20));
    for k in 0..run_count {
        let len = to_usize(r.get_u64("record length")?, "record length")?;
        let mut body = r
            .sub_reader(len, "run record")
            .map_err(|e| anyhow!("record {k}: {e}"))?;
        let index = to_usize(body.get_u64("run index")?, "run index")?;
        let fingerprint = body.get_u64("config fingerprint")?;
        let output = get_output(&mut body).with_context(|| format!("record {k}"))?;
        if !body.is_empty() {
            bail!(
                "record {k} declared {len} bytes but its body parsed {} short — corrupt length prefix",
                body.remaining()
            );
        }
        runs.push(RunRecord { index, fingerprint, output });
    }
    if !r.is_empty() {
        bail!(
            "{} trailing bytes after the last declared record — corrupt run_count or concatenated files",
            r.remaining()
        );
    }
    let file = ShardFile { experiment, spec, total_runs, grid_fingerprint, opts, runs };
    file.validate()?;
    Ok(file)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    /// An output exercising every wire branch: NaN payload bits, ±inf,
    /// -0.0, subnormals, the 0.1+0.2 classic, None/Some loss cells, f32
    /// NaN payloads, and an empty loss row.
    fn torture_output() -> EngineOutput {
        let mut movement = MovementTotals::default();
        movement.push(IntervalStats { collected: 7, processed: 5, offloaded: 2, discarded: 0 });
        movement.push(IntervalStats { collected: 0, processed: 0, offloaded: 0, discarded: 3 });
        EngineOutput {
            accuracy: 0.1 + 0.2,
            accuracy_curve: vec![
                (0, f64::from_bits(0x7FF8_DEAD_BEEF_CAFE)), // NaN payload
                (10, f64::NEG_INFINITY),
                (20, -0.0),
                (30, 5e-324), // smallest subnormal
            ],
            per_device_loss: vec![
                vec![None, Some(f32::from_bits(0x7FC0_1234)), Some(-0.0f32)],
                vec![],
                vec![Some(f32::INFINITY), None],
            ],
            ledger: Ledger { process: 1e-17, transfer: f64::INFINITY, discard: -3.5 },
            movement,
            similarity: (f64::NAN, 0.25),
            mean_active: f64::MIN_POSITIVE,
            total_collected: 12345,
        }
    }

    fn torture_file() -> ShardFile {
        ShardFile {
            experiment: "fig9".to_string(),
            spec: ShardSpec { index: 2, count: 3 },
            total_runs: 7,
            grid_fingerprint: 0xDEAD_BEEF_F00D_CAFE,
            opts: Json::obj(vec![("seeds", Json::from(5usize))]),
            runs: vec![
                RunRecord { index: 1, fingerprint: 0x1111, output: torture_output() },
                RunRecord { index: 4, fingerprint: 0x4444, output: EngineOutput::default() },
            ],
        }
    }

    fn assert_output_bits_eq(a: &EngineOutput, b: &EngineOutput) {
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.accuracy_curve.len(), b.accuracy_curve.len());
        for (x, y) in a.accuracy_curve.iter().zip(&b.accuracy_curve) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits());
        }
        assert_eq!(a.per_device_loss.len(), b.per_device_loss.len());
        for (ra, rb) in a.per_device_loss.iter().zip(&b.per_device_loss) {
            assert_eq!(ra.len(), rb.len());
            for (ca, cb) in ra.iter().zip(rb) {
                assert_eq!(ca.map(f32::to_bits), cb.map(f32::to_bits));
            }
        }
        assert_eq!(a.ledger.process.to_bits(), b.ledger.process.to_bits());
        assert_eq!(a.ledger.transfer.to_bits(), b.ledger.transfer.to_bits());
        assert_eq!(a.ledger.discard.to_bits(), b.ledger.discard.to_bits());
        assert_eq!(a.movement.per_interval, b.movement.per_interval);
        assert_eq!(a.similarity.0.to_bits(), b.similarity.0.to_bits());
        assert_eq!(a.similarity.1.to_bits(), b.similarity.1.to_bits());
        assert_eq!(a.mean_active.to_bits(), b.mean_active.to_bits());
        assert_eq!(a.total_collected, b.total_collected);
    }

    fn encode(file: &ShardFile) -> Vec<u8> {
        let mut buf = Vec::new();
        write_shard(&mut buf, file).unwrap();
        buf
    }

    #[test]
    fn torture_round_trip_is_bitwise() {
        let file = torture_file();
        let buf = encode(&file);
        let back = read_shard(&buf).unwrap();
        assert_eq!(back.experiment, file.experiment);
        assert_eq!(back.spec, file.spec);
        assert_eq!(back.total_runs, file.total_runs);
        assert_eq!(back.grid_fingerprint, file.grid_fingerprint);
        assert_eq!(back.opts, file.opts);
        assert_eq!(back.runs.len(), file.runs.len());
        for (a, b) in file.runs.iter().zip(&back.runs) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.fingerprint, b.fingerprint);
            assert_output_bits_eq(&a.output, &b.output);
        }
    }

    #[test]
    fn nan_payload_bits_survive_where_json_cannot() {
        // the JSON path canonicalizes every NaN to the "NaN" tag; the
        // binary path must preserve arbitrary payload bits
        let payload = 0x7FF8_0BAD_F00D_BEEF_u64;
        let mut file = torture_file();
        file.runs[0].output.accuracy = f64::from_bits(payload);
        let back = read_shard(&encode(&file)).unwrap();
        assert_eq!(back.runs[0].output.accuracy.to_bits(), payload);
    }

    #[test]
    fn write_shard_reports_exact_byte_count() {
        let file = torture_file();
        let mut buf = Vec::new();
        let n = write_shard(&mut buf, &file).unwrap();
        assert_eq!(n, buf.len() as u64);
    }

    #[test]
    fn truncation_at_every_boundary_is_rejected() {
        let buf = encode(&torture_file());
        // chopping the buffer anywhere must error, never panic or
        // silently succeed — step 7 keeps the test fast, the prefix
        // sweep below byte 64 covers every header field boundary
        let cuts: Vec<usize> =
            (0..64.min(buf.len())).chain((64..buf.len()).step_by(7)).collect();
        for cut in cuts {
            assert!(
                read_shard(&buf[..cut]).is_err(),
                "truncation to {cut} of {} bytes must be rejected",
                buf.len()
            );
        }
    }

    #[test]
    fn bad_magic_version_and_garbage_are_rejected() {
        let good = encode(&torture_file());

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        let e = read_shard(&bad_magic).unwrap_err();
        assert!(e.to_string().contains("not a fogml binary shard"), "{e}");

        let mut bad_version = good.clone();
        bad_version[8] = 99; // version u32 LE starts right after the magic
        let e = read_shard(&bad_version).unwrap_err();
        assert!(e.to_string().contains("version 99"), "{e}");

        assert!(read_shard(b"").is_err());
        assert!(read_shard(b"{\"kind\":\"fogml-shard\"}").is_err());
    }

    #[test]
    fn record_length_mismatch_is_rejected() {
        let file = torture_file();
        let buf = encode(&file);
        // locate the first record's length prefix: header is everything
        // up to run_count, which sits 8 bytes before the first record
        let header_len = 8 + 4 // magic + version
            + 4 + file.experiment.len()
            + 4 + 4 + 8 + 8
            + 4 + file.opts.to_string().len()
            + 8;
        let mut bloated = buf.clone();
        bloated[header_len] = bloated[header_len].wrapping_add(1);
        let e = read_shard(&bloated).unwrap_err();
        // a longer-than-actual length either truncates a later field or
        // leaves the body short — both must surface as errors
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut buf = encode(&torture_file());
        buf.push(0);
        let e = read_shard(&buf).unwrap_err();
        assert!(e.to_string().contains("trailing"), "{e}");
    }

    #[test]
    fn is_binary_sniffs_only_the_magic() {
        assert!(is_binary(&encode(&torture_file())));
        assert!(!is_binary(b"{\"kind\":\"fogml-shard\"}"));
        assert!(!is_binary(b""));
        assert!(!is_binary(b"FOGMLSB")); // 7 bytes: too short
    }

    #[test]
    fn property_random_outputs_round_trip_bitwise() {
        prop::for_all("binfmt random outputs", 64, |g| {
            let rng = g.rng();
            let n_curve = rng.below(6);
            let n_rows = rng.below(4);
            let n_intervals = rng.below(4);
            let mut movement = MovementTotals::default();
            for _ in 0..n_intervals {
                movement.push(IntervalStats {
                    collected: rng.below(100),
                    processed: rng.below(100),
                    offloaded: rng.below(100),
                    discarded: rng.below(100),
                });
            }
            let output = EngineOutput {
                // raw u64 bit patterns: hits NaNs, infs, subnormals
                accuracy: f64::from_bits(rng.next_u64()),
                accuracy_curve: (0..n_curve)
                    .map(|t| (t, f64::from_bits(rng.next_u64())))
                    .collect(),
                per_device_loss: (0..n_rows)
                    .map(|_| {
                        (0..rng.below(5))
                            .map(|_| {
                                rng.bool(0.3)
                                    .then(|| f32::from_bits(rng.next_u64() as u32))
                            })
                            .collect()
                    })
                    .collect(),
                ledger: Ledger {
                    process: f64::from_bits(rng.next_u64()),
                    transfer: f64::from_bits(rng.next_u64()),
                    discard: f64::from_bits(rng.next_u64()),
                },
                movement,
                similarity: (
                    f64::from_bits(rng.next_u64()),
                    f64::from_bits(rng.next_u64()),
                ),
                mean_active: f64::from_bits(rng.next_u64()),
                total_collected: rng.below(1 << 20),
            };
            let count = 1 + rng.below(8);
            let index = rng.below(count); // shard (index+1)/count owns `index`
            let file = ShardFile {
                experiment: "prop".to_string(),
                spec: ShardSpec { index: index + 1, count },
                total_runs: count * 3,
                grid_fingerprint: rng.next_u64(),
                opts: Json::Null,
                runs: vec![RunRecord {
                    index,
                    fingerprint: rng.next_u64(),
                    output: output.clone(),
                }],
            };
            let back = read_shard(&encode(&file)).unwrap();
            assert_eq!(back.grid_fingerprint, file.grid_fingerprint);
            assert_output_bits_eq(&output, &back.runs[0].output);
        });
    }
}
