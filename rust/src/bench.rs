//! Micro/benchmark harness (offline stand-in for `criterion`).
//!
//! `rust/benches/*.rs` are `harness = false` binaries that use
//! [`Runner`]: warmup iterations, timed iterations, mean/p50/p95 report in
//! criterion-like console format plus machine-readable JSON under
//! `results/bench/`.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// One benchmark's timing summary (nanoseconds).
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Sample {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name.as_str())),
            ("iters", Json::from(self.iters)),
            ("mean_ns", Json::from(self.mean_ns)),
            ("p50_ns", Json::from(self.p50_ns)),
            ("p95_ns", Json::from(self.p95_ns)),
            ("min_ns", Json::from(self.min_ns)),
        ])
    }
}

/// Bench runner with fixed warmup/measure iteration counts.
pub struct Runner {
    pub group: String,
    pub warmup_iters: usize,
    pub measure_iters: usize,
    samples: Vec<Sample>,
}

impl Runner {
    pub fn new(group: &str) -> Runner {
        Runner {
            group: group.to_string(),
            warmup_iters: 3,
            measure_iters: 10,
            samples: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: usize, measure: usize) -> Runner {
        self.warmup_iters = warmup;
        self.measure_iters = measure;
        self
    }

    /// Time `f` (one call = one iteration).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Sample {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let start = Instant::now();
            f();
            times.push(start.elapsed().as_nanos() as f64);
        }
        let sample = Sample {
            name: name.to_string(),
            iters: self.measure_iters,
            mean_ns: stats::mean(&times),
            p50_ns: stats::quantile(&times, 0.5),
            p95_ns: stats::quantile(&times, 0.95),
            min_ns: stats::min(&times),
        };
        println!(
            "{}/{:<40} mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.group,
            sample.name,
            fmt_ns(sample.mean_ns),
            fmt_ns(sample.p50_ns),
            fmt_ns(sample.p95_ns),
        );
        self.samples.push(sample);
        self.samples.last().unwrap()
    }

    /// Write all samples as JSON under `results/bench/<group>.json`.
    pub fn write_results(&self) -> std::io::Result<()> {
        std::fs::create_dir_all("results/bench")?;
        let json = Json::Arr(self.samples.iter().map(Sample::to_json).collect());
        std::fs::write(format!("results/bench/{}.json", self.group), json.to_string())
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }
}

/// Human-friendly nanosecond formatting (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_measures_and_reports() {
        let mut r = Runner::new("test").with_iters(1, 5);
        let mut counter = 0u64;
        let s = r.bench("noop_loop", || {
            for i in 0..1000u64 {
                counter = counter.wrapping_add(i);
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p95_ns);
        assert!(counter > 0);
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.000 s");
    }
}
