//! Property-testing mini-framework (offline stand-in for `proptest`).
//!
//! A property is a closure over a [`Gen`] (seeded case generator). The
//! runner executes it for `cases` random seeds; on failure it reports the
//! failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the libxla_extension rpath)
//! use fogml::prop::{for_all, Gen};
//! for_all("sum_commutes", 200, |g: &mut Gen| {
//!     let a = g.f64_in(0.0, 10.0);
//!     let b = g.f64_in(0.0, 10.0);
//!     assert!((a + b - (b + a)).abs() < 1e-12);
//! });
//! ```

use crate::util::rng::Rng;

/// Seeded case generator handed to each property execution.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), case_seed: seed }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Vector of f64 drawn uniformly from [lo, hi).
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }
}

/// Run `property` for `cases` seeds derived deterministically from the
/// property name. Panics (via the property's own assertions) with the
/// failing seed in the panic context.
pub fn for_all<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut property: F) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut g)
        }));
        if let Err(payload) = result {
            eprintln!(
                "property '{name}' failed on case {case} (seed={seed:#x}); \
                 replay with Gen::new({seed:#x})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x1000_0000_01b3);
    }
    hash
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        for_all("trivial", 50, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn failing_property_propagates_panic() {
        let r = std::panic::catch_unwind(|| {
            for_all("always_fails", 3, |_| panic!("boom"));
        });
        assert!(r.is_err());
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<f64> = Vec::new();
        for_all("det", 10, |g| first.push(g.f64_in(0.0, 1.0)));
        let mut second: Vec<f64> = Vec::new();
        for_all("det", 10, |g| second.push(g.f64_in(0.0, 1.0)));
        assert_eq!(first, second);
    }
}
