//! `fogml` — CLI for the network-aware distributed learning system.
//!
//! ```text
//! fogml train [--model mlp|cnn] [--method aware|federated|centralized]
//!             [--n 10] [--t-max 100] [--tau 10] [--seed 1] [--iid true]
//!             [--topology full|random|smallworld|hierarchical|scalefree]
//!             [--rho 0.5] [--costs testbed-lte|testbed-wifi|synthetic]
//!             [--discard linear-r|linear-g|sqrt] [--capacity] [--estimated]
//!             [--p-exit 0.02] [--p-entry 0.02] [--curve]
//!             [--train-path auto|batched|scalar]
//!             [--eval-schedule full|subset|subset:K]
//!             [--eval-path auto|batched|scalar]
//!             [--movement-backend auto|dense|sparse] [--warm-start]
//!             [--solver-threads auto|K] [--services K]
//!             [--participation full|uniform:K|importance:K] [--no-trace]
//! fogml exp <table2|table3|table4|table5|fig4|fig5|fig6|fig7|fig8|fig9|fig10|theory|all>
//!             [--seeds 3] [--model mlp|cnn] [--out results] [--jobs 1]
//!             [--curve] [--eval-schedule full|subset|subset:K]
//!             [--solver-threads auto|K]
//!             [--participation full|uniform:K|importance:K]
//!             [--services K] [--shard I/N] [--shard-format json|binary]
//! fogml merge <shard-dir> [--out DIR]
//! fogml shard convert <file|dir> --to json|binary [--out DIR]
//! fogml cluster [--devices 4] [--rounds 5]
//! ```
//!
//! `--jobs N` fans the sweep drivers' (config, seed) grids out over N
//! pooled engine workers (see `coordinator::pool`); `--jobs 1` reproduces
//! the serial numbers bit-for-bit.
//!
//! `--services K` shares K **coalescing** runtime services across the
//! pool instead of one classic service per worker: concurrent sessions'
//! batched train/eval requests pack into shared largest-tile XLA
//! dispatches (DESIGN.md §Perf rule 10). Outputs are invariant to K, to
//! `--jobs` and to whichever runs share the dispatches, and agree with
//! the default service mode within the §Perf rule 7/8 tolerances. On
//! `train`, `--services K` routes the single run through a coalescing
//! service so its numbers match pooled `--services` runs bit-for-bit.
//! The flag is recorded in shard files: `fogml merge` refuses to mix
//! shards run under different service modes.
//!
//! `--shard I/N` runs only the I-th round-robin slice of a pool-backed
//! experiment's (config, seed) grid and writes `shard_I_of_N.json` under
//! `--out` instead of tables/CSVs — run all N slices (any machines, any
//! order, any `--jobs`), gather the files into one directory, then
//! `fogml merge <dir>` validates the set (fingerprints, completeness)
//! and regenerates every artifact byte-identical to an unsharded run
//! (see `coordinator::shard` and EXPERIMENTS.md). `--shard-format
//! binary` writes `shard_I_of_N.fsb` instead: the length-prefixed
//! little-endian format (`coordinator::binfmt`) that skips text serde
//! entirely — same bytes out of the merge, fraction of the I/O cost at
//! sweep scale. `fogml merge` auto-detects each file's format by
//! content; `fogml shard convert` rewrites a file (or every shard file
//! in a directory) into `--to json|binary` under `--out` (default: next
//! to the source) and verifies each conversion round-trips exactly.
//!
//! `--train-path` selects how an interval's local updates execute:
//! `auto` (default) stacks all concurrently-training devices into one
//! `[D × BATCH]` XLA call per chunk step whenever more than one device
//! trains; `scalar` forces the per-device dispatch; `batched` forces the
//! stacked entry even for a single trainee (see DESIGN.md §Perf rule 7).
//!
//! `--eval-schedule` picks what each `--curve` point evaluates: `full`
//! (the whole test set — the historical behavior) or `subset[:K]` (rotate
//! K seeded test shards, ≈K× cheaper curves at matched noise);
//! `--eval-path` picks how: stacked `[D × BATCH]` chunk groups (`auto`/
//! `batched`) or one XLA call per chunk (`scalar`, the default — keeps
//! curves bit-identical to previous releases) — DESIGN.md §Perf rule 8.
//! On `exp`, `--curve` also emits `<name>_curve.csv` per driver.
//!
//! `--movement-backend` picks the movement-plan representation: `dense`
//! (the n×n matrix), `sparse` (one value per topology edge — O(V + E)
//! memory and solve time), or `auto` (default: dense below 512 devices,
//! sparse at or above). The two are bit-identical (DESIGN.md §Perf rule
//! 11). `--warm-start` starts each interval's PGD solve from the previous
//! interval's plan reprojected onto the new active set (opt-in: it changes
//! the solver trajectory, so defaults stay bit-identical).
//!
//! `--solver-threads` sets how many worker threads the movement solvers
//! fan their fixed-chunk row passes across: `K` forces a count, `auto`
//! (default) keeps one worker at paper scale and divides the machine's
//! cores by the pool's worker share above ~2k devices. The chunk
//! geometry depends only on the device count, so every setting produces
//! bit-identical plans — the flag changes wall time, never results
//! (DESIGN.md §Perf rule 12).
//!
//! `--participation` samples K of the active devices per aggregation
//! period (`fed::participation`): `uniform:K` draws uniformly,
//! `importance:K` draws proportionally to data volume over believed
//! processing cost with Horvitz–Thompson reweighting in the aggregator.
//! Unsampled devices become offload-only sources in the movement problem
//! (capacity zero), so their collections flow toward sampled neighbors.
//! `full` (the default) materializes no sampling state and is
//! bit-identical to previous releases; the schedule is an identity field
//! in shard files — `fogml merge` refuses mixed-schedule sets (DESIGN.md
//! §Perf rule 13).
//!
//! `--no-trace` drops the O(t_max·n) observation state — per-device loss
//! rows and the collected/processed sample logs behind the similarity
//! metric. Accuracy, curves, ledgers and movement are bit-unchanged;
//! only the trace-derived outputs empty out (similarity prints are
//! skipped). Useful for large-n throughput runs (DESIGN.md §Perf
//! rule 14).

use anyhow::{bail, Result};

use fogml::cli::Args;
use fogml::config::{
    CapacityPolicy, Churn, EngineConfig, InfoMode, Method, MovementBackend, SolverThreads,
    TopologyKind, TrainPath,
};
use fogml::coordinator::shard::{discover_shard_files, ShardFile};
use fogml::coordinator::{Cluster, ClusterConfig, ShardFormat, ShardSpec, SimPool};
use fogml::costs::{CostSource, Medium};
use fogml::experiments::{self, ExpOptions};
use fogml::fed;
use fogml::fed::eval::{EvalPath, EvalSchedule};
use fogml::fed::participation::ParticipationSchedule;
use fogml::movement::DiscardModel;
use fogml::runtime::{ModelKind, Runtime};

fn main() {
    if let Err(e) = run() {
        eprintln!("fogml: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("exp") => cmd_exp(&args),
        Some("merge") => cmd_merge(&args),
        Some("shard") => cmd_shard(&args),
        Some("cluster") => cmd_cluster(&args),
        Some(other) => bail!("unknown subcommand '{other}' (want train|exp|merge|shard|cluster)"),
        None => {
            println!("fogml — Network-Aware Optimization of Distributed Learning for Fog Computing");
            println!("usage: fogml <train|exp|merge|shard|cluster> [options]   (see README.md and EXPERIMENTS.md)");
            Ok(())
        }
    }
}

/// Build an [`EngineConfig`] from CLI options (shared by `train`).
fn config_from_args(args: &Args) -> Result<EngineConfig> {
    let mut cfg = EngineConfig::default();
    cfg.method = match args.get("method").unwrap_or("aware") {
        "aware" | "network-aware" => Method::NetworkAware,
        "federated" => Method::Federated,
        "centralized" => Method::Centralized,
        other => bail!("unknown --method {other}"),
    };
    if let Some(m) = args.get("model") {
        cfg.model = ModelKind::parse(m)?;
        cfg.lr = fogml::config::default_lr(cfg.model);
    }
    cfg.n = args.get_or("n", cfg.n)?;
    cfg.t_max = args.get_or("t-max", cfg.t_max)?;
    cfg.tau = args.get_or("tau", cfg.tau)?;
    cfg.lr = args.get_or("lr", cfg.lr)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    cfg.n_train = args.get_or("train-size", cfg.n_train)?;
    cfg.n_test = args.get_or("test-size", cfg.n_test)?;
    cfg.iid = args.get_or("iid", true)?;
    cfg.eval_curve = args.flag("curve");
    cfg.topology = match args.get("topology").unwrap_or("full") {
        "full" => TopologyKind::Full,
        "random" => TopologyKind::Random(args.get_or("rho", 0.5)?),
        "smallworld" => TopologyKind::SmallWorld,
        "hierarchical" => TopologyKind::Hierarchical,
        "scalefree" => TopologyKind::ScaleFree,
        other => bail!("unknown --topology {other}"),
    };
    cfg.cost_source = match args.get("costs").unwrap_or("testbed-lte") {
        "testbed-lte" | "lte" => CostSource::Testbed(Medium::Lte),
        "testbed-wifi" | "wifi" => CostSource::Testbed(Medium::Wifi),
        "synthetic" => CostSource::Synthetic,
        other => bail!("unknown --costs {other}"),
    };
    cfg.discard_model = match args.get("discard").unwrap_or("linear-r") {
        "linear-r" => DiscardModel::LinearR,
        "linear-g" => DiscardModel::LinearG,
        "sqrt" => DiscardModel::Sqrt,
        other => bail!("unknown --discard {other}"),
    };
    if args.flag("capacity") {
        cfg.capacity = CapacityPolicy::MeanArrivals;
    }
    if args.flag("estimated") {
        cfg.info = InfoMode::Estimated(EngineConfig::DEFAULT_EST_WINDOWS);
    }
    if let Some(p) = args.get("train-path") {
        cfg.train_path = TrainPath::parse(p)?;
    }
    if let Some(s) = args.get("eval-schedule") {
        cfg.eval_schedule = EvalSchedule::parse(s)?;
    }
    if let Some(p) = args.get("eval-path") {
        cfg.eval_path = EvalPath::parse(p)?;
    }
    if let Some(b) = args.get("movement-backend") {
        cfg.movement_backend = MovementBackend::parse(b)?;
    }
    if args.flag("warm-start") {
        cfg.warm_start = true;
    }
    if let Some(v) = args.get("solver-threads") {
        cfg.solver_threads = SolverThreads::parse(v)?;
    }
    if let Some(p) = args.get("participation") {
        cfg.participation = ParticipationSchedule::parse(p)?;
    }
    if args.flag("no-trace") {
        // drop the O(t_max·n) per-device trace state (loss rows, sample
        // logs, similarity) — observation only, outputs are unchanged
        cfg.trace = false;
    }
    let p_exit: f64 = args.get_or("p-exit", 0.0)?;
    let p_entry: f64 = args.get_or("p-entry", 0.0)?;
    if p_exit > 0.0 || p_entry > 0.0 {
        cfg.churn = Some(Churn { p_exit, p_entry });
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let started = std::time::Instant::now();
    let out = match args.get_parsed::<usize>("services")? {
        // route the run through a shared coalescing service: numbers
        // match pooled `--services` runs bit-for-bit (the tile policy is
        // the largest-fill one, not the serial smallest-fill)
        Some(k) => {
            let pool = SimPool::coalescing(1, k);
            pool.run_many(std::slice::from_ref(&cfg))?.remove(0)
        }
        None => {
            let rt = Runtime::load_default()?;
            fed::run(&cfg, &rt)?
        }
    };
    let elapsed = started.elapsed();

    println!("== fogml train ==");
    println!(
        "method          {:?} / {} / {}",
        cfg.method,
        cfg.model,
        if cfg.iid { "iid" } else { "non-iid" }
    );
    println!("accuracy        {:.2}%", 100.0 * out.accuracy);
    if !out.accuracy_curve.is_empty() {
        let pts: Vec<String> = out
            .accuracy_curve
            .iter()
            .map(|(t, a)| format!("t={t}:{:.1}%", 100.0 * a))
            .collect();
        println!("curve           {}", pts.join(" "));
    }
    println!(
        "costs           process {:.1}  transfer {:.1}  discard {:.1}  total {:.1}  unit {:.3}",
        out.ledger.process,
        out.ledger.transfer,
        out.ledger.discard,
        out.ledger.total(),
        out.ledger.unit_cost(out.total_collected as f64)
    );
    let m = &out.movement;
    println!(
        "movement        collected {}  processed {}  offloaded {}  discarded {}",
        m.collected(),
        m.processed(),
        m.offloaded(),
        m.discarded()
    );
    let (rate_mean, rate_min, rate_max) = m.movement_rate_stats();
    println!("movement rate   mean {rate_mean:.2}  range [{rate_min:.2}, {rate_max:.2}]");
    if cfg.trace {
        println!(
            "similarity      before {:.2}%  after {:.2}%",
            100.0 * out.similarity.0,
            100.0 * out.similarity.1
        );
    }
    println!("active nodes    {:.1} mean", out.mean_active);
    println!("wall time       {:.2?}", elapsed);
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let opts = ExpOptions {
        seeds: args.get_or("seeds", 3usize)?,
        model: match args.get("model") {
            Some(m) => Some(ModelKind::parse(m)?),
            None => None,
        },
        out_dir: args.get("out").unwrap_or("results").to_string(),
        jobs: args.get_or("jobs", 1usize)?,
        curve: args.flag("curve"),
        eval_schedule: match args.get("eval-schedule") {
            Some(s) => EvalSchedule::parse(s)?,
            None => EvalSchedule::Full,
        },
        services: args.get_parsed("services")?,
        solver_threads: match args.get("solver-threads") {
            Some(v) => Some(SolverThreads::parse(v)?),
            None => None,
        },
        participation: match args.get("participation") {
            Some(p) => Some(ParticipationSchedule::parse(p)?),
            None => None,
        },
        shard: match args.get("shard") {
            Some(s) => Some(ShardSpec::parse(s)?),
            None => None,
        },
        shard_format: match args.get("shard-format") {
            Some(f) => ShardFormat::parse(f)?,
            None => ShardFormat::default(),
        },
        base: None,
    };
    experiments::dispatch(which, &opts)
}

fn cmd_merge(args: &Args) -> Result<()> {
    let Some(dir) = args.positional.get(1) else {
        bail!("usage: fogml merge <shard-dir> [--out DIR]");
    };
    experiments::merge(dir, args.get("out"))
}

fn cmd_shard(args: &Args) -> Result<()> {
    const USAGE: &str = "usage: fogml shard convert <file|dir> --to json|binary [--out DIR]";
    match args.positional.get(1).map(String::as_str) {
        Some("convert") => cmd_shard_convert(args),
        _ => bail!("{USAGE}"),
    }
}

/// Rewrite shard files between the JSON and binary on-disk formats.
/// Verifies every conversion by reloading the written file and comparing
/// its canonical JSON rendering against the source — exactly the
/// equality the byte-identical-merge contract rests on.
fn cmd_shard_convert(args: &Args) -> Result<()> {
    let Some(target) = args.positional.get(2) else {
        bail!("fogml shard convert: missing <file|dir> argument");
    };
    let Some(to) = args.get("to") else {
        bail!("fogml shard convert: missing --to json|binary");
    };
    let to = ShardFormat::parse(to)?;
    let target = std::path::Path::new(target);

    // one file, or every recognized shard file in a directory
    let sources: Vec<std::path::PathBuf> = if target.is_dir() {
        let files = discover_shard_files(target)?;
        if files.is_empty() {
            bail!(
                "no shard files (shard_I_of_N.json or shard_I_of_N.fsb) found in {}",
                target.display()
            );
        }
        files.into_iter().map(|(_, _, p)| p).collect()
    } else {
        vec![target.to_path_buf()]
    };

    for src in &sources {
        let file = ShardFile::load(src)?;
        let out_dir = match args.get("out") {
            Some(d) => std::path::PathBuf::from(d),
            None => src.parent().unwrap_or(std::path::Path::new(".")).to_path_buf(),
        };
        let dst = file.save_as(&out_dir, to)?;
        // round-trip verification: reload what we just wrote and demand
        // canonical equality with the source
        let back = ShardFile::load(&dst)?;
        if back.to_json().to_string() != file.to_json().to_string() {
            bail!(
                "round-trip verification failed: {} re-reads differently from {} — refusing to trust the conversion",
                dst.display(),
                src.display()
            );
        }
        let (src_len, dst_len) = (
            std::fs::metadata(src).map(|m| m.len()).unwrap_or(0),
            std::fs::metadata(&dst).map(|m| m.len()).unwrap_or(0),
        );
        println!(
            "{} -> {}  ({} -> {} bytes, {} runs, round-trip verified)",
            src.display(),
            dst.display(),
            src_len,
            dst_len,
            file.runs.len()
        );
    }
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let cfg = ClusterConfig {
        n_devices: args.get_or("devices", 4usize)?,
        rounds: args.get_or("rounds", 5usize)?,
        tau: args.get_or("tau", 5usize)?,
        seed: args.get_or("seed", 1u64)?,
        ..Default::default()
    };
    let report = Cluster::run(&cfg)?;
    println!(
        "== fogml cluster ({} devices, {} rounds) ==",
        cfg.n_devices, cfg.rounds
    );
    for (round, acc) in report.round_accuracy.iter().enumerate() {
        println!("round {round}: accuracy {:.2}%", 100.0 * acc);
    }
    println!("device samples: {:?}", report.device_samples);
    Ok(())
}
