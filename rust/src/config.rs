//! Experiment configuration: every knob of the paper's evaluation (§V-A)
//! in one struct, with the paper's defaults.

use crate::costs::traces::ErrorWeightProfile;
use crate::costs::{CostSource, Medium};
use crate::fed::eval::{EvalPath, EvalSchedule};
use crate::fed::participation::ParticipationSchedule;
use crate::movement::DiscardModel;
use crate::runtime::ModelKind;

/// Fog topology families (Table I, §V-D).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TopologyKind {
    /// `E = {(i,j) : i ≠ j}` — the §V-B default.
    Full,
    /// Erdős–Rényi with connection probability ρ (§V-C2).
    Random(f64),
    /// Watts–Strogatz small world, k = n/5 ring neighbors (§V-D social).
    SmallWorld,
    /// n/3 cheapest devices as heads, 2 random leaves each (§V-D).
    Hierarchical,
    /// Barabási–Albert scale-free (Theorem 5's model).
    ScaleFree,
}

/// Whether the optimizer sees true or estimated costs (§IV-A, Table III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InfoMode {
    Perfect,
    /// Time-averaged over `windows` estimation intervals.
    Estimated(usize),
}

/// Capacity regime (§V-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CapacityPolicy {
    Unconstrained,
    /// `C_i(t) = C_ij(t) = |D_V| / (nT)`.
    MeanArrivals,
}

/// Learning methodology under comparison (Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// The paper's contribution: movement optimization + federated updates.
    NetworkAware,
    /// Plain federated learning: `G_i(t) = D_i(t)`, no movement.
    Federated,
    /// All data processed at one server (accuracy upper baseline).
    Centralized,
}

/// Node churn parameters (§V-E).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Churn {
    pub p_exit: f64,
    pub p_entry: f64,
}

/// Which execution path an interval's local updates take (DESIGN.md §Perf
/// rule 7): stacked `[D × BATCH]` multi-device steps amortize PJRT dispatch
/// across devices; the scalar path issues one call per device per chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TrainPath {
    /// Batched whenever more than one device trains in the interval,
    /// scalar otherwise (the default).
    #[default]
    Auto,
    /// Always route through the stacked multi-device entry (pads to the
    /// smallest compiled device tile even for a single trainee).
    Batched,
    /// Always dispatch per device — the pre-batching behavior; also the
    /// reference side of `tests/batched_equivalence.rs`.
    Scalar,
}

impl TrainPath {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(TrainPath::Auto),
            "batched" => Ok(TrainPath::Batched),
            "scalar" => Ok(TrainPath::Scalar),
            other => anyhow::bail!(
                "unknown train path '{other}' (want auto|batched|scalar)"
            ),
        }
    }
}

/// Which movement-plan representation the engine solves on (DESIGN.md
/// §Perf rule 11). Both produce bit-identical plans; the sparse path does
/// O(V + E) work and storage per interval instead of O(n²).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MovementBackend {
    /// Dense below [`MovementBackend::AUTO_THRESHOLD`] devices, sparse at
    /// or above it (the default).
    #[default]
    Auto,
    /// Always the n×n [`crate::movement::MovementPlan`].
    Dense,
    /// Always the edge-indexed [`crate::movement::SparsePlan`].
    Sparse,
}

impl MovementBackend {
    /// `Auto` switches to sparse at this device count: below it the dense
    /// n² plan fits comfortably in cache and the paper-scale experiments
    /// (n ≤ 50) keep their historical code path.
    pub const AUTO_THRESHOLD: usize = 512;

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(MovementBackend::Auto),
            "dense" => Ok(MovementBackend::Dense),
            "sparse" => Ok(MovementBackend::Sparse),
            other => anyhow::bail!(
                "unknown movement backend '{other}' (want auto|dense|sparse)"
            ),
        }
    }

    /// Concrete backend for an `n`-device run.
    pub fn resolve(self, n: usize) -> MovementBackend {
        match self {
            MovementBackend::Auto => {
                if n < Self::AUTO_THRESHOLD {
                    MovementBackend::Dense
                } else {
                    MovementBackend::Sparse
                }
            }
            other => other,
        }
    }
}

/// Worker-thread budget for the intra-solver parallel layer
/// (`util::par`; DESIGN.md §Perf rule 12). Chunk geometry is a
/// function of n only and reductions combine per-chunk partials in
/// ascending chunk order, so every setting produces **bit-identical**
/// plans — this knob trades wall-clock only, never outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolverThreads {
    /// One worker below [`SolverThreads::AUTO_MIN_N`] devices (paper-scale
    /// problems fit one core's cache and threads would only add spawn
    /// overhead); above it, the machine's parallelism divided by the
    /// pool's concurrent-worker count, so `--jobs`/`--services` level
    /// parallelism and solver-level parallelism compose without
    /// oversubscription (the default).
    #[default]
    Auto,
    /// Exactly `K` workers regardless of problem size or pool sharing.
    Fixed(usize),
}

impl SolverThreads {
    /// `Auto` stays serial below this device count: paper-scale solves
    /// (n ≤ 50) are far too small to amortize thread spawns, and the
    /// sparse O(E) engine only becomes solver-bound well above the dense
    /// cutover ([`MovementBackend::AUTO_THRESHOLD`]).
    pub const AUTO_MIN_N: usize = 2048;

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.to_ascii_lowercase();
        if s == "auto" {
            return Ok(SolverThreads::Auto);
        }
        match s.parse::<usize>() {
            Ok(k) if k >= 1 => Ok(SolverThreads::Fixed(k)),
            _ => anyhow::bail!(
                "unknown solver threads '{s}' (want auto or a worker count >= 1)"
            ),
        }
    }

    /// Concrete worker count for an `n`-device solve when `pool_share`
    /// same-process pool workers run sessions concurrently (1 outside a
    /// pool). Never 0; `Fixed` is honored verbatim.
    pub fn resolve(self, n: usize, pool_share: usize) -> usize {
        match self {
            SolverThreads::Fixed(k) => k.max(1),
            SolverThreads::Auto => {
                if n < Self::AUTO_MIN_N {
                    1
                } else {
                    let machine = std::thread::available_parallelism()
                        .map(std::num::NonZeroUsize::get)
                        .unwrap_or(1);
                    (machine / pool_share.max(1)).max(1)
                }
            }
        }
    }
}

/// Full engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub method: Method,
    pub model: ModelKind,
    /// Number of fog devices n.
    pub n: usize,
    /// Time horizon T (intervals).
    pub t_max: usize,
    /// Aggregation period τ.
    pub tau: usize,
    /// SGD learning rate η.
    pub lr: f32,
    /// iid vs 5-of-10-label non-iid device data (§V-A).
    pub iid: bool,
    pub n_train: usize,
    pub n_test: usize,
    pub topology: TopologyKind,
    pub cost_source: CostSource,
    pub capacity: CapacityPolicy,
    pub info: InfoMode,
    pub discard_model: DiscardModel,
    pub churn: Option<Churn>,
    pub error_profile: ErrorWeightProfile,
    /// Evaluate test accuracy at every aggregation (slower; for curves).
    pub eval_curve: bool,
    /// Which test samples each curve point scores (full pass vs rotating
    /// seeded shards — see `fed::eval::EvalSchedule`).
    pub eval_schedule: EvalSchedule,
    /// Scalar vs stacked chunk dispatch of curve evaluations
    /// (`fed::eval::EvalPath`; DESIGN.md §Perf rule 8).
    pub eval_path: EvalPath,
    /// Scalar vs stacked multi-device dispatch of local updates.
    pub train_path: TrainPath,
    /// Dense n×n vs edge-indexed movement plans (bit-identical outputs;
    /// DESIGN.md §Perf rule 11).
    pub movement_backend: MovementBackend,
    /// Warm-start the PGD movement solver from the previous interval's
    /// plan (reprojected onto the new active set). Off by default: warm
    /// starts change PGD's trajectory, so defaults stay bit-identical to
    /// the cold-start solver.
    pub warm_start: bool,
    /// Intra-solver worker budget (bit-invariant — DESIGN.md §Perf
    /// rule 12). `Auto` is serial at paper scale and scales out with the
    /// problem; recorded in shard opts so `fogml merge` stays consistent.
    pub solver_threads: SolverThreads,
    /// Per-period device sampling (`fed::participation`; DESIGN.md §Perf
    /// rule 13). `Full` by default — sampling changes which devices train,
    /// so the schedule is an identity field in the shard opts blob and
    /// mixed-schedule merges are refused.
    pub participation: ParticipationSchedule,
    /// Record the O(t_max·n) per-device trace state (dense per-device
    /// loss rows, collected/processed sample logs, and the label-
    /// similarity summary derived from them). On by default — the CLI
    /// front ends and fig4/similarity pipelines report these — and
    /// purely observational: flipping it never changes accuracy, curves,
    /// ledgers, or movement stats (DESIGN.md §Perf rule 14). Scaling
    /// benches turn it off so resident state is O(n), not O(t_max·n).
    pub trace: bool,
    pub seed: u64,
}

impl Default for EngineConfig {
    /// Paper defaults (§V-A): n = 10 devices, τ = 10, T = 100; η is 0.05
    /// rather than the paper's 0.01 — calibrated so the centralized
    /// baseline reaches the same high-80s/low-90s accuracy band on
    /// SynthDigits as the paper's MNIST MLP (DESIGN.md §2),
    /// fully-connected topology, testbed costs, iid data, perfect
    /// information, no capacities, linear discard cost. The paper reports
    /// CNN by default; we default to MLP for sweep speed and use CNN where
    /// the table calls for it (DESIGN.md §4).
    fn default() -> Self {
        EngineConfig {
            method: Method::NetworkAware,
            model: ModelKind::Mlp,
            n: 10,
            t_max: 100,
            tau: 10,
            lr: 0.05,
            iid: true,
            n_train: 8000,
            n_test: 2000,
            topology: TopologyKind::Full,
            cost_source: CostSource::Testbed(Medium::Lte),
            capacity: CapacityPolicy::Unconstrained,
            info: InfoMode::Perfect,
            discard_model: DiscardModel::LinearR,
            churn: None,
            error_profile: ErrorWeightProfile::default(),
            eval_curve: false,
            eval_schedule: EvalSchedule::Full,
            // Scalar (not Auto like train_path): default curves stay
            // bit-identical to the pre-subsystem eval_curve; stacked
            // eval is opt-in via --eval-path (DESIGN.md §Perf rule 8)
            eval_path: EvalPath::Scalar,
            train_path: TrainPath::Auto,
            movement_backend: MovementBackend::Auto,
            warm_start: false,
            solver_threads: SolverThreads::Auto,
            participation: ParticipationSchedule::Full,
            trace: true,
            seed: 1,
        }
    }
}

/// Calibrated default learning rate per model (DESIGN.md §2: the CNN needs
/// a smaller step to stay stable under small-batch federated updates on
/// SynthDigits).
pub fn default_lr(model: ModelKind) -> f32 {
    match model {
        ModelKind::Mlp => 0.05,
        ModelKind::Cnn => 0.02,
    }
}

impl EngineConfig {
    /// Number of estimation windows used by Table III settings C/E
    /// (10 windows over T = 100, i.e. re-estimate every 10 intervals).
    pub const DEFAULT_EST_WINDOWS: usize = 10;

    /// Set the model together with its calibrated learning rate.
    pub fn with_model(mut self, model: ModelKind) -> Self {
        self.model = model;
        self.lr = default_lr(model);
        self
    }

    /// Mean arrivals per device-interval, `|D_V| / (nT)` — also the uniform
    /// capacity value under [`CapacityPolicy::MeanArrivals`].
    pub fn mean_arrivals(&self) -> f64 {
        self.n_train as f64 / (self.n * self.t_max) as f64
    }

    // -- builder-style helpers (used heavily by experiment drivers) --------

    pub fn with(mut self, f: impl FnOnce(&mut Self)) -> Self {
        f(&mut self);
        self
    }

    pub fn seeded(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = EngineConfig::default();
        assert_eq!(c.n, 10);
        assert_eq!(c.tau, 10);
        assert_eq!(c.t_max, 100);
        assert_eq!(c.lr, 0.05);
        assert_eq!(c.mean_arrivals(), 8.0);
    }

    #[test]
    fn train_path_parses() {
        assert_eq!(TrainPath::parse("auto").unwrap(), TrainPath::Auto);
        assert_eq!(TrainPath::parse("Batched").unwrap(), TrainPath::Batched);
        assert_eq!(TrainPath::parse("scalar").unwrap(), TrainPath::Scalar);
        assert!(TrainPath::parse("vectorized").is_err());
        assert_eq!(EngineConfig::default().train_path, TrainPath::Auto);
    }

    #[test]
    fn eval_defaults_preserve_legacy_curves() {
        // Full schedule + Scalar path is exactly the historical
        // per-aggregation full pass: default curves are bit-identical to
        // pre-subsystem runs (tests/eval_equivalence.rs proves the
        // bit-identity; this pins the default selection)
        let c = EngineConfig::default();
        assert_eq!(c.eval_schedule, EvalSchedule::Full);
        assert_eq!(c.eval_path, EvalPath::Scalar);
        assert!(!c.eval_curve);
    }

    #[test]
    fn movement_backend_parses_and_resolves() {
        assert_eq!(MovementBackend::parse("auto").unwrap(), MovementBackend::Auto);
        assert_eq!(MovementBackend::parse("Dense").unwrap(), MovementBackend::Dense);
        assert_eq!(MovementBackend::parse("sparse").unwrap(), MovementBackend::Sparse);
        assert!(MovementBackend::parse("csr").is_err());
        assert_eq!(MovementBackend::Auto.resolve(10), MovementBackend::Dense);
        assert_eq!(MovementBackend::Auto.resolve(100_000), MovementBackend::Sparse);
        assert_eq!(MovementBackend::Dense.resolve(100_000), MovementBackend::Dense);
        assert_eq!(MovementBackend::Sparse.resolve(10), MovementBackend::Sparse);
    }

    #[test]
    fn movement_defaults_stay_bit_identical() {
        // Auto resolves Dense at every paper scale (n <= 50) and warm
        // starts are off: default runs keep the historical solver exactly
        let c = EngineConfig::default();
        assert_eq!(c.movement_backend, MovementBackend::Auto);
        assert_eq!(c.movement_backend.resolve(c.n), MovementBackend::Dense);
        assert!(!c.warm_start);
    }

    #[test]
    fn solver_threads_parses_and_resolves() {
        assert_eq!(SolverThreads::parse("auto").unwrap(), SolverThreads::Auto);
        assert_eq!(SolverThreads::parse("Auto").unwrap(), SolverThreads::Auto);
        assert_eq!(SolverThreads::parse("4").unwrap(), SolverThreads::Fixed(4));
        assert!(SolverThreads::parse("0").is_err());
        assert!(SolverThreads::parse("many").is_err());
        // Fixed is honored verbatim (clamped away from 0) at any scale
        assert_eq!(SolverThreads::Fixed(3).resolve(10, 8), 3);
        assert_eq!(SolverThreads::Fixed(0).resolve(10, 1), 1);
        // Auto stays serial below the threshold, shares the machine above
        assert_eq!(SolverThreads::Auto.resolve(50, 1), 1);
        assert!(SolverThreads::Auto.resolve(100_000, 1) >= 1);
        assert_eq!(SolverThreads::Auto.resolve(100_000, usize::MAX), 1);
    }

    #[test]
    fn solver_threads_default_is_serial_at_paper_scale() {
        // Auto resolves to one worker for every paper-scale n, and one
        // worker runs the identical fixed-chunk reduction — default runs
        // keep the historical solver arithmetic exactly (DESIGN.md §Perf
        // rule 12; tests/solver_agreement.rs proves the thread-count
        // invariance itself)
        let c = EngineConfig::default();
        assert_eq!(c.solver_threads, SolverThreads::Auto);
        assert_eq!(c.solver_threads.resolve(c.n, 1), 1);
        assert_eq!(c.solver_threads.resolve(50, 4), 1);
    }

    #[test]
    fn participation_default_is_full() {
        // Full materializes no sampling state at all inside the session
        // (fed::participation::ParticipationState::new returns None), so
        // default runs keep the pre-subsystem engine bit-for-bit
        // (tests/participation.rs proves the bit-identity; this pins the
        // default selection — DESIGN.md §Perf rule 13)
        let c = EngineConfig::default();
        assert_eq!(c.participation, ParticipationSchedule::Full);
    }

    #[test]
    fn trace_default_is_on() {
        // the CLI front ends print the similarity summary and fig4 reads
        // the dense loss rows, so default runs must keep recording the
        // trace state; large-n scaling benches opt out explicitly
        // (DESIGN.md §Perf rule 14; tests/aggregation.rs proves the flag
        // is observation-only)
        assert!(EngineConfig::default().trace);
    }

    #[test]
    fn builder_helpers() {
        let c = EngineConfig::default().with(|c| c.n = 20).seeded(7);
        assert_eq!(c.n, 20);
        assert_eq!(c.seed, 7);
    }
}
