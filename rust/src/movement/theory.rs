//! Closed forms of Theorems 4, 5 and 6, plus Monte-Carlo validators.
//!
//! These are used by `fogml exp theory` to reproduce the paper's analytical
//! claims against simulation, and by unit tests to pin the solvers to the
//! math.

use crate::topology::Graph;
use crate::util::rng::Rng;
use crate::util::stats::binomial;

// ---------------------------------------------------------------------------
// Theorem 4 — hierarchical scenario with convex error cost
// ---------------------------------------------------------------------------

/// Closed-form optimum for the Theorem-4 scenario.
#[derive(Debug, Clone)]
pub struct Theorem4Solution {
    /// Fraction discarded per device, `r*_i = 1 - (γ/2c_i)^{2/3}/D_i - s_i`.
    pub r: Vec<f64>,
    /// Fraction offloaded per device,
    /// `s*_i = (γ / 2(c_{n+1} + c_t))^{2/3} / Σ_j D_j`.
    pub s: Vec<f64>,
}

/// Theorem 4: n devices with static costs `c_i` and data rates `D_i`
/// offload to an edge server with processing cost `c_server` over links of
/// identical cost `c_t`; the discard cost is `γ/√G_i` (Lemma 1). Assumes
/// `D_i` large enough that the fractions fall in [0, 1] (we clamp).
pub fn theorem4_closed_form(
    gamma: f64,
    c_dev: &[f64],
    c_server: f64,
    c_t: f64,
    d: &[f64],
) -> Theorem4Solution {
    let total_d: f64 = d.iter().sum();
    let s_star = ((gamma / (2.0 * (c_server + c_t))).powf(2.0 / 3.0) / total_d).clamp(0.0, 1.0);
    let mut r = Vec::with_capacity(c_dev.len());
    let s = vec![s_star; c_dev.len()];
    for (i, &ci) in c_dev.iter().enumerate() {
        let keep = (gamma / (2.0 * ci)).powf(2.0 / 3.0) / d[i];
        r.push((1.0 - keep - s_star).clamp(0.0, 1.0));
    }
    Theorem4Solution { r, s }
}

// ---------------------------------------------------------------------------
// Theorem 5 — value of offloading on social topologies
// ---------------------------------------------------------------------------

/// Eq. (15): expected per-device cost savings from offloading when a device
/// with `k` neighbors has costs `c ~ U(0, C)`, `c_ij = 0`, no discarding.
/// `degree_fracs[k]` = fraction of devices with k neighbors (index 0 unused
/// mass contributes no savings).
pub fn theorem5_savings(c_range: f64, degree_fracs: &[f64]) -> f64 {
    degree_fracs
        .iter()
        .enumerate()
        .map(|(k, &frac)| frac * savings_for_degree(c_range, k as u64))
        .sum()
}

/// The inner bracket of eq. (15) for a single degree k.
pub fn savings_for_degree(c_range: f64, k: u64) -> f64 {
    if k == 0 {
        return 0.0;
    }
    let kf = k as f64;
    let mut sum_l = 0.0;
    for l in 0..k {
        let lf = l as f64;
        sum_l += binomial(k, l) * c_range * (if l % 2 == 0 { 1.0 } else { -1.0 }) * (kf + 3.0)
            / ((lf + 2.0) * (lf + 3.0));
    }
    let sign_k = if k % 2 == 0 { 1.0 } else { -1.0 };
    c_range / 2.0 - c_range * sign_k / (kf + 2.0) - sum_l
}

/// The simplified exact form of the same expectation,
/// `E[max(0, c - min_k c_j)] = C (k/(k+1) - 1/2 + 1/((k+1)(k+2)))`,
/// derived by direct integration — used to cross-check eq. (15).
pub fn savings_for_degree_simplified(c_range: f64, k: u64) -> f64 {
    if k == 0 {
        return 0.0;
    }
    let kf = k as f64;
    c_range * (kf / (kf + 1.0) - 0.5 + 1.0 / ((kf + 1.0) * (kf + 2.0)))
}

/// Monte-Carlo estimate of the Theorem-5 expectation: draw device and
/// neighbor costs `U(0, C)` and average `max(0, c_i - min_j c_j)`.
pub fn simulate_savings(c_range: f64, k: u64, trials: usize, rng: &mut Rng) -> f64 {
    if k == 0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for _ in 0..trials {
        let ci = rng.uniform(0.0, c_range);
        let min_n = (0..k)
            .map(|_| rng.uniform(0.0, c_range))
            .fold(f64::INFINITY, f64::min);
        acc += (ci - min_n).max(0.0);
    }
    acc / trials as f64
}

/// Degree-fraction vector `N(k)` of a scale-free network,
/// `N(k) = Γ k^{1-γ}` normalized over `1..=k_max` (Theorem 5's model).
pub fn scale_free_degree_fracs(gamma_exp: f64, k_max: usize) -> Vec<f64> {
    let mut fracs = vec![0.0; k_max + 1];
    let mut z = 0.0;
    for k in 1..=k_max {
        let w = (k as f64).powf(1.0 - gamma_exp);
        fracs[k] = w;
        z += w;
    }
    for f in fracs.iter_mut() {
        *f /= z;
    }
    fracs
}

// ---------------------------------------------------------------------------
// Theorem 6 — expected capacity-constraint violations
// ---------------------------------------------------------------------------

/// Theorem-6 estimate of the expected number of devices whose capacity is
/// violated when devices follow the Theorem-3 policy with `c ~ U(0, C)`,
/// `c_ij = 0`, no discarding, constant data rate `D`, and capacities drawn
/// i.i.d. from `cap_samples` (an empirical distribution).
///
/// The expected processed load of a device with `k` neighbors is
/// `D · (1 - P_o(k) + k Σ_n P_o(n) p_k(n) / n)`; with uniform costs the
/// offload probability is `P_o(k) = k/(k+1)` and neighbor-degree
/// distribution `p_k(n)` is measured from the graph. The load of a device
/// is compared against capacity draws to get a violation probability.
pub fn theorem6_expected_violations(graph: &Graph, d_rate: f64, cap_samples: &[f64]) -> f64 {
    let n = graph.n();
    if n == 0 || cap_samples.is_empty() {
        return 0.0;
    }
    // degree histogram N(k) (counts) and neighbor-degree distribution
    let hist = graph.degree_histogram();
    let p_o = |k: usize| k as f64 / (k as f64 + 1.0);

    let mut expected = 0.0;
    for i in 0..n {
        let k = graph.out_degree(i);
        // empirical p_k(n): degree distribution of i's own neighbors
        let mut inbound_term = 0.0;
        for &j in graph.out_neighbors(i) {
            let nj = graph.out_degree(j);
            if nj > 0 {
                // neighbor j offloads with prob P_o(nj) to a uniformly
                // chosen min-cost neighbor -> lands on i w.p. 1/nj
                inbound_term += p_o(nj) / nj as f64;
            }
        }
        let load = d_rate * ((1.0 - p_o(k)) + inbound_term);
        // violation probability under the capacity distribution
        let p_viol = cap_samples.iter().filter(|&&c| load > c).count() as f64
            / cap_samples.len() as f64;
        expected += p_viol;
    }
    let _ = hist;
    expected
}

/// Monte-Carlo companion: draw costs and capacities, run the Theorem-3
/// policy (offload to min-cost neighbor if cheaper than local) and count
/// devices whose realized load exceeds their capacity.
pub fn simulate_violations(
    graph: &Graph,
    d_rate: f64,
    c_range: f64,
    cap_samples: &[f64],
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let n = graph.n();
    let mut total = 0.0;
    for _ in 0..trials {
        let costs: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, c_range)).collect();
        let mut load = vec![0.0f64; n];
        for i in 0..n {
            // min-cost neighbor (c_ij = 0)
            let best = graph
                .out_neighbors(i)
                .iter()
                .copied()
                .min_by(|&a, &b| costs[a].partial_cmp(&costs[b]).unwrap());
            match best {
                Some(k) if costs[k] < costs[i] => load[k] += d_rate,
                _ => load[i] += d_rate,
            }
        }
        let violations = (0..n)
            .filter(|&i| load[i] > cap_samples[rng.below(cap_samples.len())])
            .count();
        total += violations as f64;
    }
    total / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::generators::scale_free;

    #[test]
    fn theorem5_eq15_matches_direct_integration() {
        // the paper's eq. (15) and the simplified closed form must agree
        for k in 1..=12u64 {
            let paper = savings_for_degree(1.0, k);
            let simple = savings_for_degree_simplified(1.0, k);
            assert!(
                (paper - simple).abs() < 1e-9,
                "k={k}: eq15={paper} simplified={simple}"
            );
        }
    }

    #[test]
    fn theorem5_matches_monte_carlo() {
        let mut rng = Rng::new(11);
        for k in [1u64, 2, 4, 8] {
            let analytic = savings_for_degree_simplified(2.0, k);
            let sim = simulate_savings(2.0, k, 200_000, &mut rng);
            assert!(
                (analytic - sim).abs() < 0.01 * 2.0,
                "k={k}: analytic={analytic} sim={sim}"
            );
        }
    }

    #[test]
    fn theorem5_savings_linear_in_c() {
        let fracs = scale_free_degree_fracs(2.5, 20);
        let s1 = theorem5_savings(1.0, &fracs);
        let s2 = theorem5_savings(2.0, &fracs);
        let s4 = theorem5_savings(4.0, &fracs);
        assert!((s2 / s1 - 2.0).abs() < 1e-9);
        assert!((s4 / s1 - 4.0).abs() < 1e-9);
        // savings below the average computing cost C/2 (paper's remark)
        assert!(s1 < 0.5);
        assert!(s1 > 0.0);
    }

    #[test]
    fn theorem5_savings_increase_with_connectivity() {
        let mut prev = 0.0;
        for k in 1..10u64 {
            let s = savings_for_degree_simplified(1.0, k);
            assert!(s > prev, "not monotone at k={k}");
            prev = s;
        }
        // asymptote: with many neighbors the savings approach C/2
        assert!(savings_for_degree_simplified(1.0, 200) > 0.49);
    }

    #[test]
    fn scale_free_fracs_normalized_and_decreasing() {
        let fracs = scale_free_degree_fracs(2.5, 30);
        let sum: f64 = fracs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        for k in 2..30 {
            assert!(fracs[k] < fracs[k - 1]);
        }
    }

    #[test]
    fn theorem4_monotonicity() {
        // higher compute cost -> more discarded
        let d = vec![1000.0; 3];
        let sol = theorem4_closed_form(50.0, &[0.3, 0.6, 0.9], 0.1, 0.05, &d);
        assert!(sol.r[0] < sol.r[1] && sol.r[1] < sol.r[2]);
        // all fractions valid
        for i in 0..3 {
            assert!((0.0..=1.0).contains(&sol.r[i]));
            assert!((0.0..=1.0).contains(&sol.s[i]));
            assert!(sol.r[i] + sol.s[i] <= 1.0 + 1e-12);
        }
        // pricier server -> less offloading
        let sol_cheap = theorem4_closed_form(50.0, &[0.5; 3], 0.05, 0.05, &d);
        let sol_dear = theorem4_closed_form(50.0, &[0.5; 3], 0.4, 0.05, &d);
        assert!(sol_dear.s[0] < sol_cheap.s[0]);
    }

    #[test]
    fn theorem6_close_to_simulation_on_scale_free() {
        let mut rng = Rng::new(21);
        let graph = scale_free(60, 2, &mut rng);
        let d = 5.0;
        // capacities around the expected load scale
        let cap_samples: Vec<f64> = (0..500).map(|_| rng.uniform(2.0, 14.0)).collect();
        let analytic = theorem6_expected_violations(&graph, d, &cap_samples);
        let sim = simulate_violations(&graph, d, 1.0, &cap_samples, 3000, &mut rng);
        // the theorem uses expected loads (Jensen gap vs realized loads);
        // the two should agree on scale
        assert!(
            (analytic - sim).abs() < 0.35 * sim.max(1.0),
            "analytic={analytic} sim={sim}"
        );
        assert!(analytic > 0.0 && sim > 0.0);
    }

    #[test]
    fn theorem6_zero_when_capacity_huge() {
        let mut rng = Rng::new(22);
        let graph = scale_free(30, 2, &mut rng);
        let caps = vec![1e9];
        assert_eq!(theorem6_expected_violations(&graph, 5.0, &caps), 0.0);
    }
}
