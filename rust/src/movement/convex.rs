//! Projected-gradient solver for the convex `f_i(t)/√G_i(t)` discard model.
//!
//! §IV-A2 derives this error cost from Lemma 1 + Theorem 1 (the local-loss
//! bound decays as `1/√G_i`). The resulting per-interval problem is convex
//! in `(s, r)`: the linear processing/offloading terms plus a convex
//! composition `f · φ(G̃_i)` with `φ(G) = (G + 1)^{-1/2}` — the `+1`
//! smoothing keeps the gradient bounded at zero data, exactly as solving at
//! datapoint granularity would (you cannot process half a point).
//!
//! The feasible set is a product of per-device simplices
//! `{r_i, s_ii, s_ij (j ∈ N_i) ≥ 0, sum = 1}` — capacities are handled by
//! the separate [`super::repair`] pass, mirroring the paper's two-stage
//! procedure justified by Theorem 6. Projected gradient descent with a
//! diminishing step and best-iterate tracking converges fast at these sizes
//! (n ≤ 50 ⇒ ≤ 2.5k variables).

use crate::movement::plan::MovementPlan;
use crate::movement::problem::MovementProblem;
use crate::movement::SolverWorkspace;

/// Smoothing constant in `φ(G) = (G + SQRT_EPS)^{-1/2}`.
pub const SQRT_EPS: f64 = 1.0;

/// Consecutive no-improvement iterations before a `tol > 0` run stops.
const STALL_LIMIT: usize = 25;

/// PGD hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct PgdOptions {
    pub iterations: usize,
    pub step0: f64,
    /// Early-exit tolerance: with `tol > 0`, the loop stops after
    /// [`STALL_LIMIT`] consecutive iterations that fail to improve the
    /// best objective by more than `tol`. `0.0` (the default) disables
    /// early exit entirely, keeping iteration counts — and therefore
    /// outputs — bit-identical to the original fixed-budget solver.
    pub tol: f64,
}

impl Default for PgdOptions {
    fn default() -> Self {
        PgdOptions { iterations: 400, step0: 0.0, tol: 0.0 } // step0 = 0 -> auto
    }
}

/// Solve the Sqrt-model problem by projected gradient descent, warm-started
/// from the Theorem-3 greedy solution under the linear model.
pub fn solve(p: &MovementProblem, opts: PgdOptions) -> MovementPlan {
    let mut ws = SolverWorkspace::new();
    solve_with(p, opts, &mut ws);
    ws.plan
}

/// Workspace-reusing variant of [`solve`]: the best iterate lands in
/// `ws.plan`. Every buffer is zeroed or fully overwritten first, so the
/// result is bit-identical to a fresh [`solve`].
pub fn solve_with(p: &MovementProblem, opts: PgdOptions, ws: &mut SolverWorkspace) {
    let n = p.n();
    // Warm start (opt-in, DESIGN.md §Perf rule 11): reproject the previous
    // interval's plan onto the new active set instead of re-deriving the
    // greedy vertex. Churn flips few devices, so the previous optimum is a
    // near-feasible near-optimum of the new problem.
    let warm = ws.warm_start && ws.prev_valid && ws.prev.n == n;
    if warm {
        ws.plan.clone_from(&ws.prev);
        for i in 0..n {
            if !p.active[i] || p.d[i] == 0.0 {
                // devices outside the problem revert to the vacuous
                // keep-all row the solvers emit for them
                for j in 0..n {
                    ws.plan.s[i * n + j] = 0.0;
                }
                ws.plan.s[i * n + i] = 1.0;
                ws.plan.r[i] = 0.0;
            }
        }
        // drops stale mass aimed at now-inactive devices and renormalizes
        project_rows(p, ws);
    } else {
        crate::movement::greedy::solve_into(p, &mut ws.plan);
    }

    // auto step size: inversely proportional to the largest row scale
    let max_d = p.d.iter().cloned().fold(1.0, f64::max);
    let step0 = if opts.step0 > 0.0 { opts.step0 } else { 0.5 / max_d };

    ws.best.clone_from(&ws.plan);
    let mut best_obj = ws.plan.objective(p);
    let mut stall = 0usize;

    ws.grad_s.clear();
    ws.grad_s.resize(n * n, 0.0);
    for it in 0..opts.iterations {
        gradient(p, &ws.plan, &mut ws.grad_s, &mut ws.g_tilde);
        let step = step0 / (1.0 + (it as f64 / 40.0)).sqrt();
        // gradient step on s (r has zero gradient; the simplex projection
        // absorbs mass into r when the s-coordinates shrink)
        for i in 0..n {
            if !p.active[i] || p.d[i] == 0.0 {
                continue;
            }
            for j in 0..n {
                if j == i || p.graph.has_edge(i, j) {
                    ws.plan.s[i * n + j] -= step * ws.grad_s[i * n + j];
                }
            }
        }
        project_rows(p, ws);
        let obj = ws.plan.objective(p);
        if obj < best_obj {
            if opts.tol > 0.0 && best_obj - obj > opts.tol {
                stall = 0;
            }
            best_obj = obj;
            ws.best.clone_from(&ws.plan);
        }
        if opts.tol > 0.0 {
            stall += 1;
            if stall > STALL_LIMIT {
                break;
            }
        }
    }
    ws.plan.clone_from(&ws.best);
}

/// ∂F/∂s_ij for the smoothed objective (see module docs).
/// ∂F/∂s_ii = d_i (c_i(t) + f_i(t) φ'(G̃_i))
/// ∂F/∂s_ij = d_i (c_ij(t) + c_j(t+1) + f_j(t) φ'(G̃_j)), j ≠ i
fn gradient(
    p: &MovementProblem,
    plan: &MovementPlan,
    grad_s: &mut [f64],
    g_tilde: &mut Vec<f64>,
) {
    let n = p.n();
    // G̃_i = s_ii d_i + inbound_prev_i + Σ_{j≠i} s_ji d_j
    g_tilde.clear();
    g_tilde.resize(n, 0.0);
    for i in 0..n {
        g_tilde[i] = plan.s(i, i) * p.d[i] + p.inbound_prev[i];
    }
    for i in 0..n {
        if p.d[i] == 0.0 {
            continue;
        }
        for j in 0..n {
            if j != i {
                g_tilde[j] += plan.s(i, j) * p.d[i];
            }
        }
    }
    let phi_prime = |g: f64| -0.5 * (g + SQRT_EPS).powf(-1.5);

    for i in 0..n {
        if !p.active[i] || p.d[i] == 0.0 {
            continue;
        }
        grad_s[i * n + i] =
            p.d[i] * (p.costs.c_node(p.t, i) + p.costs.f(p.t, i) * phi_prime(g_tilde[i]));
        for j in 0..n {
            if j == i || !p.graph.has_edge(i, j) || !p.active[j] {
                continue;
            }
            grad_s[i * n + j] = p.d[i]
                * (p.costs.c_link(p.t, i, j)
                    + p.costs.c_node(p.t + 1, j)
                    + p.costs.f(p.t, j) * phi_prime(g_tilde[j]));
        }
    }
}

/// Project every device row onto its simplex (r_i, s_ii, s_ij for active
/// out-neighbors; other coordinates forced to 0). Uses the workspace's
/// gather/projection buffers (`ws.plan` is the row source and target).
fn project_rows(p: &MovementProblem, ws: &mut SolverWorkspace) {
    let n = p.n();
    for i in 0..n {
        if !p.active[i] || p.d[i] == 0.0 {
            continue;
        }
        // gather the free coordinates of row i
        ws.coords.clear();
        ws.coords.push((None, ws.plan.r[i])); // r_i
        ws.coords.push((Some(i), ws.plan.s(i, i)));
        for j in p.graph.out_neighbors(i) {
            if p.active[*j] {
                ws.coords.push((Some(*j), ws.plan.s(i, *j)));
            }
        }
        ws.values.clear();
        ws.values.extend(ws.coords.iter().map(|&(_, v)| v));
        project_simplex_into(&ws.values, &mut ws.scratch, &mut ws.projected);
        // zero the whole row, then write back the projected coordinates
        ws.plan.r[i] = 0.0;
        for j in 0..n {
            ws.plan.s[i * n + j] = 0.0;
        }
        for (&(target, _), &v) in ws.coords.iter().zip(ws.projected.iter()) {
            match target {
                None => ws.plan.r[i] = v,
                Some(j) => ws.plan.s[i * n + j] = v,
            }
        }
    }
}

/// Sparse mirror of [`solve_with`]: PGD over the edge-indexed plan in
/// `ws.sparse` — gradients, updates, and projections touch only stored
/// edge slots, so one iteration is O(V + E) instead of O(n²).
///
/// Bitwise agreement with the dense solver (when `to_dense`d) holds
/// because every float op the dense path performs on *off-edge* or
/// inactive coordinates is an exact no-op: their gradient entries are
/// never written (zeroed once), so the update subtracts `step·0.0`, and
/// the G̃ accumulation adds `0.0·d_i` to nonnegative partial sums.
pub fn solve_sparse_with(p: &MovementProblem, opts: PgdOptions, ws: &mut SolverWorkspace) {
    let n = p.n();
    ws.sparse.rebuild(p.graph);
    let warm = ws.warm_start
        && ws.prev_sparse_valid
        && ws.prev_sparse.n == n
        && ws.prev_sparse.offsets == ws.sparse.offsets
        && ws.prev_sparse.targets == ws.sparse.targets;
    if warm {
        ws.sparse.s_edge.copy_from_slice(&ws.prev_sparse.s_edge);
        ws.sparse.local.copy_from_slice(&ws.prev_sparse.local);
        ws.sparse.discard.copy_from_slice(&ws.prev_sparse.discard);
        for i in 0..n {
            if !p.active[i] || p.d[i] == 0.0 {
                for e in ws.sparse.offsets[i]..ws.sparse.offsets[i + 1] {
                    ws.sparse.s_edge[e] = 0.0;
                }
                ws.sparse.local[i] = 1.0;
                ws.sparse.discard[i] = 0.0;
            }
        }
        project_rows_sparse(p, ws);
    } else {
        crate::movement::greedy::solve_sparse_into(p, &mut ws.sparse);
    }

    let max_d = p.d.iter().cloned().fold(1.0, f64::max);
    let step0 = if opts.step0 > 0.0 { opts.step0 } else { 0.5 / max_d };

    ws.sparse_best.clone_from(&ws.sparse);
    let mut best_obj = ws.sparse.objective(p);
    let mut stall = 0usize;

    let m = ws.sparse.num_edges();
    ws.grad_edge.clear();
    ws.grad_edge.resize(m, 0.0);
    ws.grad_local.clear();
    ws.grad_local.resize(n, 0.0);
    for it in 0..opts.iterations {
        gradient_sparse(p, &ws.sparse, &mut ws.grad_edge, &mut ws.grad_local, &mut ws.g_tilde);
        let step = step0 / (1.0 + (it as f64 / 40.0)).sqrt();
        for i in 0..n {
            if !p.active[i] || p.d[i] == 0.0 {
                continue;
            }
            ws.sparse.local[i] -= step * ws.grad_local[i];
            for e in ws.sparse.offsets[i]..ws.sparse.offsets[i + 1] {
                ws.sparse.s_edge[e] -= step * ws.grad_edge[e];
            }
        }
        project_rows_sparse(p, ws);
        let obj = ws.sparse.objective(p);
        if obj < best_obj {
            if opts.tol > 0.0 && best_obj - obj > opts.tol {
                stall = 0;
            }
            best_obj = obj;
            ws.sparse_best.clone_from(&ws.sparse);
        }
        if opts.tol > 0.0 {
            stall += 1;
            if stall > STALL_LIMIT {
                break;
            }
        }
    }
    ws.sparse.clone_from(&ws.sparse_best);
}

/// Sparse mirror of [`gradient`]: per-edge-slot gradients. Entries whose
/// target is inactive are never written (they stay at the initial 0.0),
/// matching the dense solver's untouched coordinates.
fn gradient_sparse(
    p: &MovementProblem,
    sp: &crate::movement::sparse::SparsePlan,
    grad_edge: &mut [f64],
    grad_local: &mut [f64],
    g_tilde: &mut Vec<f64>,
) {
    let n = p.n();
    g_tilde.clear();
    g_tilde.resize(n, 0.0);
    for i in 0..n {
        g_tilde[i] = sp.local[i] * p.d[i] + p.inbound_prev[i];
    }
    for i in 0..n {
        if p.d[i] == 0.0 {
            continue;
        }
        for e in sp.offsets[i]..sp.offsets[i + 1] {
            g_tilde[sp.targets[e]] += sp.s_edge[e] * p.d[i];
        }
    }
    let phi_prime = |g: f64| -0.5 * (g + SQRT_EPS).powf(-1.5);

    for i in 0..n {
        if !p.active[i] || p.d[i] == 0.0 {
            continue;
        }
        grad_local[i] =
            p.d[i] * (p.costs.c_node(p.t, i) + p.costs.f(p.t, i) * phi_prime(g_tilde[i]));
        for e in sp.offsets[i]..sp.offsets[i + 1] {
            let j = sp.targets[e];
            if !p.active[j] {
                continue;
            }
            grad_edge[e] = p.d[i]
                * (p.costs.c_link(p.t, i, j)
                    + p.costs.c_node(p.t + 1, j)
                    + p.costs.f(p.t, j) * phi_prime(g_tilde[j]));
        }
    }
}

/// Sparse mirror of [`project_rows`]: gathers each device row in the same
/// order the dense path does — `r_i`, `s_ii`, then active out-neighbors
/// ascending — so the Duchi projection sees an identical value sequence.
fn project_rows_sparse(p: &MovementProblem, ws: &mut SolverWorkspace) {
    let n = p.n();
    for i in 0..n {
        if !p.active[i] || p.d[i] == 0.0 {
            continue;
        }
        ws.values.clear();
        ws.values.push(ws.sparse.discard[i]); // r_i
        ws.values.push(ws.sparse.local[i]); // s_ii
        for e in ws.sparse.offsets[i]..ws.sparse.offsets[i + 1] {
            if p.active[ws.sparse.targets[e]] {
                ws.values.push(ws.sparse.s_edge[e]);
            }
        }
        project_simplex_into(&ws.values, &mut ws.scratch, &mut ws.projected);
        // zero the whole row, then scatter back in gather order
        ws.sparse.discard[i] = 0.0;
        ws.sparse.local[i] = 0.0;
        for e in ws.sparse.offsets[i]..ws.sparse.offsets[i + 1] {
            ws.sparse.s_edge[e] = 0.0;
        }
        let mut cursor = ws.projected.iter();
        ws.sparse.discard[i] = *cursor.next().expect("r coordinate");
        ws.sparse.local[i] = *cursor.next().expect("s_ii coordinate");
        for e in ws.sparse.offsets[i]..ws.sparse.offsets[i + 1] {
            if p.active[ws.sparse.targets[e]] {
                ws.sparse.s_edge[e] = *cursor.next().expect("edge coordinate");
            }
        }
    }
}

/// Euclidean projection of `v` onto the probability simplex
/// (Held–Wolfe–Crowder / Duchi et al. algorithm).
pub fn project_simplex(v: &[f64]) -> Vec<f64> {
    let mut scratch = Vec::new();
    let mut out = Vec::new();
    project_simplex_into(v, &mut scratch, &mut out);
    out
}

/// Allocation-free variant of [`project_simplex`]: `scratch` holds the
/// descending sort, `out` receives the projection.
pub fn project_simplex_into(v: &[f64], scratch: &mut Vec<f64>, out: &mut Vec<f64>) {
    scratch.clear();
    scratch.extend_from_slice(v);
    scratch.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut theta = 0.0;
    for (k, &uk) in scratch.iter().enumerate() {
        css += uk;
        let candidate = (css - 1.0) / (k + 1) as f64;
        if uk - candidate > 0.0 {
            theta = candidate;
        }
    }
    out.clear();
    out.extend(v.iter().map(|&x| (x - theta).max(0.0)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::movement::problem::DiscardModel;
    use crate::movement::theory;
    use crate::prop::for_all;
    use crate::topology::generators::{erdos_renyi, star};

    #[test]
    fn simplex_projection_basics() {
        let p = project_simplex(&[0.5, 0.5]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(p, vec![0.5, 0.5]);

        let p = project_simplex(&[2.0, 0.0]);
        assert_eq!(p, vec![1.0, 0.0]);

        let p = project_simplex(&[-1.0, -2.0]);
        assert!((p[0] - 1.0).abs() < 1e-12);
        assert_eq!(p[1], 0.0);
    }

    #[test]
    fn prop_simplex_projection_valid() {
        for_all("simplex_proj", 200, |g| {
            let len = g.usize_in(1, 12);
            let v = g.vec_f64(len, -3.0, 3.0);
            let p = project_simplex(&v);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
            // projection is the closest point: spot-check vs a few random
            // feasible points
            let d_proj: f64 = v.iter().zip(&p).map(|(a, b)| (a - b) * (a - b)).sum();
            for _ in 0..5 {
                let mut q = g.vec_f64(len, 0.0, 1.0);
                let s: f64 = q.iter().sum();
                if s > 0.0 {
                    for x in q.iter_mut() {
                        *x /= s;
                    }
                    let d_q: f64 = v.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
                    assert!(d_proj <= d_q + 1e-9);
                }
            }
        });
    }

    /// PGD must recover the Theorem-4 closed form on the hierarchical
    /// (star) scenario: n devices offloading to a cheap edge server.
    #[test]
    fn pgd_matches_theorem4_closed_form() {
        let n_dev = 4;
        let n = n_dev + 1; // device `n_dev` is the edge server
        let server = n_dev;
        let graph = star(n, server);
        let d_i = 600.0;
        let gamma = 60.0;
        let c_dev = 0.6;
        let c_server = 0.12;
        let c_t = 0.05;

        let mut costs = CostSchedule::zeros(n, 3);
        for t in 0..3 {
            for i in 0..n_dev {
                costs.compute[t][i] = c_dev;
                costs.error_weight[t][i] = gamma;
                costs.link[t][i * n + server] = c_t;
            }
            costs.compute[t][server] = c_server;
            costs.error_weight[t][server] = gamma;
        }
        let mut d = vec![d_i; n_dev];
        d.push(0.0); // server collects nothing
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::Sqrt,
        };
        let plan = solve(&p, PgdOptions { iterations: 3000, step0: 0.0, tol: 0.0 });
        plan.assert_feasible(&p, 1e-6);

        let closed = theory::theorem4_closed_form(
            gamma,
            &vec![c_dev; n_dev],
            c_server,
            c_t,
            &vec![d_i; n_dev],
        );

        // the closed form is the optimum of the unsmoothed objective;
        // compare decisions within tolerance
        for i in 0..n_dev {
            assert!(
                (plan.r[i] - closed.r[i]).abs() < 0.05,
                "device {i}: pgd r={} closed r={}",
                plan.r[i],
                closed.r[i]
            );
            assert!(
                (plan.s(i, server) - closed.s[i]).abs() < 0.05,
                "device {i}: pgd s={} closed s={}",
                plan.s(i, server),
                closed.s[i]
            );
        }

        // and the PGD objective must not be worse than the closed form's
        let mut closed_plan = MovementPlan::keep_all(n);
        for i in 0..n_dev {
            closed_plan.set_s(i, i, 1.0 - closed.r[i] - closed.s[i]);
            closed_plan.set_s(i, server, closed.s[i]);
            closed_plan.r[i] = closed.r[i];
        }
        assert!(plan.objective(&p) <= closed_plan.objective(&p) + 1e-2);
    }

    /// Property: PGD output is always feasible and never worse than the
    /// greedy warm start under the Sqrt objective.
    #[test]
    fn prop_pgd_feasible_and_improves() {
        for_all("pgd_improves", 20, |g| {
            let n = g.usize_in(2, 6);
            let graph = erdos_renyi(n, g.f64_in(0.3, 1.0), g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.1, 3.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 0.5);
                        }
                    }
                }
            }
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 20.0)).collect();
            let inbound: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 5.0)).collect();
            let active = vec![true; n];
            let p = MovementProblem {
                t: 0,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: DiscardModel::Sqrt,
            };
            let warm = crate::movement::greedy::solve(&p);
            let plan = solve(&p, PgdOptions { iterations: 150, step0: 0.0, tol: 0.0 });
            plan.assert_feasible(&p, 1e-6);
            assert!(plan.objective(&p) <= warm.objective(&p) + 1e-9);
        });
    }
}
