//! Projected-gradient solver for the convex `f_i(t)/√G_i(t)` discard model.
//!
//! §IV-A2 derives this error cost from Lemma 1 + Theorem 1 (the local-loss
//! bound decays as `1/√G_i`). The resulting per-interval problem is convex
//! in `(s, r)`: the linear processing/offloading terms plus a convex
//! composition `f · φ(G̃_i)` with `φ(G) = (G + 1)^{-1/2}` — the `+1`
//! smoothing keeps the gradient bounded at zero data, exactly as solving at
//! datapoint granularity would (you cannot process half a point).
//!
//! The feasible set is a product of per-device simplices
//! `{r_i, s_ii, s_ij (j ∈ N_i) ≥ 0, sum = 1}` — capacities are handled by
//! the separate [`super::repair`] pass, mirroring the paper's two-stage
//! procedure justified by Theorem 6. Projected gradient descent with a
//! diminishing step and best-iterate tracking converges fast at these sizes
//! (n ≤ 50 ⇒ ≤ 2.5k variables).
//!
//! **Execution layout** (DESIGN.md §Perf rule 12): each PGD iteration is
//! two row-parallel sweeps over fixed-size row chunks ([`super::par`]):
//!
//! 1. a **row pass** — gradient row from the previous sweep's G̃, step,
//!    per-row simplex projection, and the row's *linear* objective terms
//!    folded into its chunk's partial sum (all row-local given G̃);
//! 2. a **gather pass** — per *target*, G̃ and this-interval inbound
//!    accumulated source-ascending (dense: a column scan; sparse: the CSR
//!    transpose row), then the `f/√G` objective terms appended to the same
//!    chunk partial.
//!
//! Partials combine serially in ascending chunk order, so the objective —
//! and with it best-iterate tracking and the final plan — is bit-invariant
//! to the worker count. The fused gather also replaces the historical
//! per-iteration standalone `objective()` recompute (which re-accumulated
//! G̃ from scratch): one transpose sweep now feeds both the gradient and
//! the objective, and agrees with [`MovementPlan::objective`] bitwise.

use crate::util::par::{self, ProjBuffers};
use crate::movement::plan::MovementPlan;
use crate::movement::problem::MovementProblem;
use crate::movement::sparse::SparsePlan;
use crate::movement::SolverWorkspace;
use std::ops::Range;

/// Smoothing constant in `φ(G) = (G + SQRT_EPS)^{-1/2}`.
pub const SQRT_EPS: f64 = 1.0;

/// Consecutive no-improvement iterations before a `tol > 0` run stops.
const STALL_LIMIT: usize = 25;

/// `φ'(G)` — shared by the dense and sparse gradient rows.
#[inline]
fn phi_prime(g: f64) -> f64 {
    -0.5 * (g + SQRT_EPS).powf(-1.5)
}

/// PGD hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct PgdOptions {
    pub iterations: usize,
    pub step0: f64,
    /// Early-exit tolerance: with `tol > 0`, the loop stops after
    /// [`STALL_LIMIT`] consecutive iterations that fail to improve the
    /// best objective by more than `tol`. `0.0` (the default) disables
    /// early exit entirely, keeping iteration counts — and therefore
    /// outputs — bit-identical to the original fixed-budget solver.
    pub tol: f64,
}

impl Default for PgdOptions {
    fn default() -> Self {
        PgdOptions { iterations: 400, step0: 0.0, tol: 0.0 } // step0 = 0 -> auto
    }
}

/// Solve the Sqrt-model problem by projected gradient descent, warm-started
/// from the Theorem-3 greedy solution under the linear model.
pub fn solve(p: &MovementProblem, opts: PgdOptions) -> MovementPlan {
    let mut ws = SolverWorkspace::new();
    solve_with(p, opts, &mut ws);
    ws.plan
}

/// Workspace-reusing variant of [`solve`]: the best iterate lands in
/// `ws.plan`. Every buffer is zeroed or fully overwritten first, so the
/// result is bit-identical to a fresh [`solve`].
pub fn solve_with(p: &MovementProblem, opts: PgdOptions, ws: &mut SolverWorkspace) {
    let n = p.n();
    let threads = ws.solver_threads.max(1);
    let chunk_rows = ws.chunk_rows.max(1);
    ws.ensure_chunks(n);
    // Warm start (opt-in, DESIGN.md §Perf rule 11): reproject the previous
    // interval's plan onto the new active set instead of re-deriving the
    // greedy vertex. Churn flips few devices, so the previous optimum is a
    // near-feasible near-optimum of the new problem.
    let warm = ws.warm_start && ws.prev_valid && ws.prev.n == n;
    if warm {
        ws.plan.clone_from(&ws.prev);
        for i in 0..n {
            if !p.active[i] || p.d[i] == 0.0 {
                // devices outside the problem revert to the vacuous
                // keep-all row the solvers emit for them
                for j in 0..n {
                    ws.plan.s[i * n + j] = 0.0;
                }
                ws.plan.s[i * n + i] = 1.0;
                ws.plan.r[i] = 0.0;
            }
        }
        // drops stale mass aimed at now-inactive devices and renormalizes
        project_rows(p, &mut ws.plan, &mut ws.proj, threads, chunk_rows);
    } else {
        crate::movement::greedy::solve_into_chunked(p, &mut ws.plan, threads, chunk_rows);
    }

    // auto step size: inversely proportional to the largest row scale
    let max_d = p.d.iter().cloned().fold(1.0, f64::max);
    let step0 = if opts.step0 > 0.0 { opts.step0 } else { 0.5 / max_d };

    ws.best.clone_from(&ws.plan);
    ws.grad_s.clear();
    ws.grad_s.resize(n * n, 0.0);
    ws.g_tilde.clear();
    ws.g_tilde.resize(n, 0.0);
    ws.inbound_now.clear();
    ws.inbound_now.resize(n, 0.0);

    // fused evaluation of the start plan: its linear objective terms, then
    // one gather sweep producing its objective AND iteration 0's G̃
    linear_pass(p, &ws.plan, &mut ws.partials, threads, chunk_rows);
    let mut best_obj = gather_pass(
        p,
        &ws.plan,
        &mut ws.g_tilde,
        &mut ws.inbound_now,
        &mut ws.partials,
        threads,
        chunk_rows,
    );
    let mut stall = 0usize;

    for it in 0..opts.iterations {
        let step = step0 / (1.0 + (it as f64 / 40.0)).sqrt();
        step_pass(
            p,
            &mut ws.plan,
            &mut ws.grad_s,
            &mut ws.proj,
            &mut ws.partials,
            &ws.g_tilde,
            step,
            threads,
            chunk_rows,
        );
        let obj = gather_pass(
            p,
            &ws.plan,
            &mut ws.g_tilde,
            &mut ws.inbound_now,
            &mut ws.partials,
            threads,
            chunk_rows,
        );
        if obj < best_obj {
            if opts.tol > 0.0 && best_obj - obj > opts.tol {
                stall = 0;
            }
            best_obj = obj;
            ws.best.clone_from(&ws.plan);
        }
        if opts.tol > 0.0 {
            stall += 1;
            if stall > STALL_LIMIT {
                break;
            }
        }
    }
    ws.plan.clone_from(&ws.best);
}

/// Linear objective terms of `plan` (processing + offloading), one partial
/// per chunk, rows ascending within each chunk. Read-only: evaluates the
/// start plan before any gradient step exists.
fn linear_pass(
    p: &MovementProblem,
    plan: &MovementPlan,
    partials: &mut [f64],
    threads: usize,
    chunk_rows: usize,
) {
    let n = p.n();
    par::run_chunks(threads, partials, |c, out| {
        let mut acc = 0.0;
        for i in par::chunk_range(c, n, chunk_rows) {
            let g_local = plan.s(i, i) * p.d[i] + p.inbound_prev[i];
            acc += g_local * p.costs.c_node(p.t, i);
            if p.d[i] > 0.0 {
                for j in 0..n {
                    if j != i && plan.s(i, j) > 0.0 {
                        let amount = p.d[i] * plan.s(i, j);
                        acc += amount
                            * (p.costs.c_link(p.t, i, j) + p.costs.c_node(p.t + 1, j));
                    }
                }
            }
        }
        *out = acc;
    });
}

/// One dense PGD iteration's row-parallel half: per active row, the
/// gradient from `g_tilde` (∂F/∂s_ii = d_i (c_i + f_i φ'(G̃_i));
/// ∂F/∂s_ij = d_i (c_ij + c_j(t+1) + f_j φ'(G̃_j))), the step (r has zero
/// gradient — the projection absorbs mass into it), the per-row simplex
/// projection, and finally the chunk's linear objective terms.
#[allow(clippy::too_many_arguments)]
fn step_pass(
    p: &MovementProblem,
    plan: &mut MovementPlan,
    grad_s: &mut [f64],
    proj: &mut [ProjBuffers],
    partials: &mut [f64],
    g_tilde: &[f64],
    step: f64,
    threads: usize,
    chunk_rows: usize,
) {
    struct RowChunk<'a> {
        rows: Range<usize>,
        s: &'a mut [f64],
        r: &'a mut [f64],
        grad: &'a mut [f64],
        proj: &'a mut ProjBuffers,
        linear: f64,
    }
    let n = p.n();
    let nc = partials.len();
    let mut items: Vec<RowChunk> = Vec::with_capacity(nc);
    for ((((c, s), r), grad), proj) in par::split_rows(&mut plan.s, n, chunk_rows)
        .enumerate()
        .zip(par::split_rows(&mut plan.r, 1, chunk_rows))
        .zip(par::split_rows(grad_s, n, chunk_rows))
        .zip(proj.iter_mut())
    {
        items.push(RowChunk {
            rows: par::chunk_range(c, n, chunk_rows),
            s,
            r,
            grad,
            proj,
            linear: 0.0,
        });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.rows.start;
        for i in it.rows.clone() {
            if !p.active[i] || p.d[i] == 0.0 {
                continue;
            }
            let li = i - base;
            it.grad[li * n + i] = p.d[i]
                * (p.costs.c_node(p.t, i) + p.costs.f(p.t, i) * phi_prime(g_tilde[i]));
            for j in 0..n {
                if j == i || !p.graph.has_edge(i, j) || !p.active[j] {
                    continue;
                }
                it.grad[li * n + j] = p.d[i]
                    * (p.costs.c_link(p.t, i, j)
                        + p.costs.c_node(p.t + 1, j)
                        + p.costs.f(p.t, j) * phi_prime(g_tilde[j]));
            }
            for j in 0..n {
                if j == i || p.graph.has_edge(i, j) {
                    it.s[li * n + j] -= step * it.grad[li * n + j];
                }
            }
            project_row(p, i, &mut it.s[li * n..(li + 1) * n], &mut it.r[li], it.proj);
        }
        // linear objective terms, rows ascending (the same sweep the
        // standalone objective() runs over this chunk)
        let mut acc = 0.0;
        for i in it.rows.clone() {
            let li = i - base;
            let g_local = it.s[li * n + i] * p.d[i] + p.inbound_prev[i];
            acc += g_local * p.costs.c_node(p.t, i);
            if p.d[i] > 0.0 {
                for j in 0..n {
                    if j != i && it.s[li * n + j] > 0.0 {
                        let amount = p.d[i] * it.s[li * n + j];
                        acc += amount
                            * (p.costs.c_link(p.t, i, j) + p.costs.c_node(p.t + 1, j));
                    }
                }
            }
        }
        it.linear = acc;
    });
    for (partial, it) in partials.iter_mut().zip(items.iter()) {
        *partial = it.linear;
    }
}

/// The target-parallel half: per target `j`, accumulate G̃_j (seeded with
/// `s_jj d_j + inbound_prev_j`) and this-interval inbound (seeded 0.0)
/// source-ascending in one column scan, then append the chunk's `f/√G`
/// objective terms to its partial (already holding the linear terms) and
/// combine partials ascending. Returns the objective of `plan`; leaves
/// `g_tilde` ready for the next gradient row pass.
fn gather_pass(
    p: &MovementProblem,
    plan: &MovementPlan,
    g_tilde: &mut [f64],
    inbound_now: &mut [f64],
    partials: &mut [f64],
    threads: usize,
    chunk_rows: usize,
) -> f64 {
    struct GatherChunk<'a> {
        targets: Range<usize>,
        g: &'a mut [f64],
        inb: &'a mut [f64],
        partial: f64,
    }
    let n = p.n();
    let mut items: Vec<GatherChunk> = Vec::with_capacity(partials.len());
    for (((c, g), inb), &partial) in par::split_rows(g_tilde, 1, chunk_rows)
        .enumerate()
        .zip(par::split_rows(inbound_now, 1, chunk_rows))
        .zip(partials.iter())
    {
        items.push(GatherChunk {
            targets: par::chunk_range(c, n, chunk_rows),
            g,
            inb,
            partial,
        });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.targets.start;
        for j in it.targets.clone() {
            let mut g = plan.s(j, j) * p.d[j] + p.inbound_prev[j];
            let mut inb = 0.0;
            for i in 0..n {
                if i == j || p.d[i] == 0.0 {
                    continue;
                }
                let c = plan.s(i, j) * p.d[i];
                g += c;
                inb += c;
            }
            it.g[j - base] = g;
            it.inb[j - base] = inb;
        }
        let mut acc = it.partial;
        for j in it.targets.clone() {
            if !p.active[j] {
                continue;
            }
            let g = plan.s(j, j) * p.d[j] + p.inbound_prev[j] + it.inb[j - base];
            acc += p.costs.f(p.t, j) / (g + SQRT_EPS).sqrt();
        }
        it.partial = acc;
    });
    for (partial, it) in partials.iter_mut().zip(items.iter()) {
        *partial = it.partial;
    }
    par::combine(partials)
}

/// Project one device row onto its simplex (r_i, s_ii, s_ij for active
/// out-neighbors; every other coordinate forced to 0). `s_row` is row i of
/// the dense plan.
fn project_row(
    p: &MovementProblem,
    i: usize,
    s_row: &mut [f64],
    r: &mut f64,
    buf: &mut ProjBuffers,
) {
    buf.coords.clear();
    buf.coords.push((None, *r)); // r_i
    buf.coords.push((Some(i), s_row[i]));
    for j in p.graph.out_neighbors(i) {
        if p.active[*j] {
            buf.coords.push((Some(*j), s_row[*j]));
        }
    }
    buf.values.clear();
    buf.values.extend(buf.coords.iter().map(|&(_, v)| v));
    project_simplex_into(&buf.values, &mut buf.scratch, &mut buf.projected);
    // zero the whole row, then write back the projected coordinates
    *r = 0.0;
    for v in s_row.iter_mut() {
        *v = 0.0;
    }
    for (&(target, _), &v) in buf.coords.iter().zip(buf.projected.iter()) {
        match target {
            None => *r = v,
            Some(j) => s_row[j] = v,
        }
    }
}

/// Project every active device row onto its simplex — the warm-start
/// reprojection. Purely row-local, so chunks fan out without reductions.
fn project_rows(
    p: &MovementProblem,
    plan: &mut MovementPlan,
    proj: &mut [ProjBuffers],
    threads: usize,
    chunk_rows: usize,
) {
    struct ProjChunk<'a> {
        rows: Range<usize>,
        s: &'a mut [f64],
        r: &'a mut [f64],
        proj: &'a mut ProjBuffers,
    }
    let n = p.n();
    let mut items: Vec<ProjChunk> = Vec::new();
    for (((c, s), r), proj) in par::split_rows(&mut plan.s, n, chunk_rows)
        .enumerate()
        .zip(par::split_rows(&mut plan.r, 1, chunk_rows))
        .zip(proj.iter_mut())
    {
        items.push(ProjChunk { rows: par::chunk_range(c, n, chunk_rows), s, r, proj });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.rows.start;
        for i in it.rows.clone() {
            if !p.active[i] || p.d[i] == 0.0 {
                continue;
            }
            let li = i - base;
            project_row(p, i, &mut it.s[li * n..(li + 1) * n], &mut it.r[li], it.proj);
        }
    });
}

/// Sparse mirror of [`solve_with`]: PGD over the edge-indexed plan in
/// `ws.sparse` — gradients, updates, and projections touch only stored
/// edge slots, so one iteration is O(V + E) instead of O(n²).
///
/// Bitwise agreement with the dense solver (when `to_dense`d) holds
/// because every float op the dense path performs on *off-edge* or
/// inactive coordinates is an exact no-op: their gradient entries are
/// never written (zeroed once), so the update subtracts `step·0.0`, and
/// the G̃/inbound gathers add `0.0·d_i` to nonnegative partial sums. The
/// chunk geometry and partial-combine order are identical to the dense
/// passes, so dense ≡ sparse holds at every thread count.
pub fn solve_sparse_with(p: &MovementProblem, opts: PgdOptions, ws: &mut SolverWorkspace) {
    let n = p.n();
    let threads = ws.solver_threads.max(1);
    let chunk_rows = ws.chunk_rows.max(1);
    ws.ensure_chunks(n);
    ws.sparse.rebuild(p.graph);
    let warm = ws.warm_start
        && ws.prev_sparse_valid
        && ws.prev_sparse.n == n
        && ws.prev_sparse.offsets == ws.sparse.offsets
        && ws.prev_sparse.targets == ws.sparse.targets;
    if warm {
        ws.sparse.s_edge.copy_from_slice(&ws.prev_sparse.s_edge);
        ws.sparse.local.copy_from_slice(&ws.prev_sparse.local);
        ws.sparse.discard.copy_from_slice(&ws.prev_sparse.discard);
        for i in 0..n {
            if !p.active[i] || p.d[i] == 0.0 {
                for e in ws.sparse.offsets[i]..ws.sparse.offsets[i + 1] {
                    ws.sparse.s_edge[e] = 0.0;
                }
                ws.sparse.local[i] = 1.0;
                ws.sparse.discard[i] = 0.0;
            }
        }
        project_rows_sparse(p, &mut ws.sparse, &mut ws.proj, threads, chunk_rows);
    } else {
        crate::movement::greedy::solve_sparse_into_chunked(p, &mut ws.sparse, threads, chunk_rows);
    }

    let max_d = p.d.iter().cloned().fold(1.0, f64::max);
    let step0 = if opts.step0 > 0.0 { opts.step0 } else { 0.5 / max_d };

    ws.sparse_best.clone_from(&ws.sparse);
    let m = ws.sparse.num_edges();
    ws.grad_edge.clear();
    ws.grad_edge.resize(m, 0.0);
    ws.grad_local.clear();
    ws.grad_local.resize(n, 0.0);
    ws.g_tilde.clear();
    ws.g_tilde.resize(n, 0.0);
    ws.inbound_now.clear();
    ws.inbound_now.resize(n, 0.0);

    linear_pass_sparse(p, &ws.sparse, &mut ws.partials, threads, chunk_rows);
    let mut best_obj = gather_pass_sparse(
        p,
        &ws.sparse,
        &mut ws.g_tilde,
        &mut ws.inbound_now,
        &mut ws.partials,
        threads,
        chunk_rows,
    );
    let mut stall = 0usize;

    for it in 0..opts.iterations {
        let step = step0 / (1.0 + (it as f64 / 40.0)).sqrt();
        step_pass_sparse(
            p,
            &mut ws.sparse,
            &mut ws.grad_edge,
            &mut ws.grad_local,
            &mut ws.proj,
            &mut ws.partials,
            &ws.g_tilde,
            step,
            threads,
            chunk_rows,
        );
        let obj = gather_pass_sparse(
            p,
            &ws.sparse,
            &mut ws.g_tilde,
            &mut ws.inbound_now,
            &mut ws.partials,
            threads,
            chunk_rows,
        );
        if obj < best_obj {
            if opts.tol > 0.0 && best_obj - obj > opts.tol {
                stall = 0;
            }
            best_obj = obj;
            ws.sparse_best.clone_from(&ws.sparse);
        }
        if opts.tol > 0.0 {
            stall += 1;
            if stall > STALL_LIMIT {
                break;
            }
        }
    }
    ws.sparse.clone_from(&ws.sparse_best);
}

/// Sparse mirror of [`linear_pass`]: off-edge dense terms fail the
/// `s > 0` guard, so skipping them preserves bits.
fn linear_pass_sparse(
    p: &MovementProblem,
    sp: &SparsePlan,
    partials: &mut [f64],
    threads: usize,
    chunk_rows: usize,
) {
    let n = p.n();
    par::run_chunks(threads, partials, |c, out| {
        let mut acc = 0.0;
        for i in par::chunk_range(c, n, chunk_rows) {
            let g_local = sp.local[i] * p.d[i] + p.inbound_prev[i];
            acc += g_local * p.costs.c_node(p.t, i);
            if p.d[i] > 0.0 {
                for e in sp.offsets[i]..sp.offsets[i + 1] {
                    if sp.s_edge[e] > 0.0 {
                        let j = sp.targets[e];
                        let amount = p.d[i] * sp.s_edge[e];
                        acc += amount
                            * (p.costs.c_link(p.t, i, j) + p.costs.c_node(p.t + 1, j));
                    }
                }
            }
        }
        *out = acc;
    });
}

/// Sparse mirror of [`step_pass`] over CSR row slices. Gradient entries
/// whose target is inactive are never written (they stay at the initial
/// 0.0), matching the dense solver's untouched coordinates.
#[allow(clippy::too_many_arguments)]
fn step_pass_sparse(
    p: &MovementProblem,
    sp: &mut SparsePlan,
    grad_edge: &mut [f64],
    grad_local: &mut [f64],
    proj: &mut [ProjBuffers],
    partials: &mut [f64],
    g_tilde: &[f64],
    step: f64,
    threads: usize,
    chunk_rows: usize,
) {
    struct SparseRowChunk<'a> {
        rows: Range<usize>,
        s_edge: &'a mut [f64],
        local: &'a mut [f64],
        discard: &'a mut [f64],
        grad_edge: &'a mut [f64],
        grad_local: &'a mut [f64],
        proj: &'a mut ProjBuffers,
        linear: f64,
    }
    let n = sp.n;
    let offsets = &sp.offsets;
    let targets = &sp.targets;
    let mut items: Vec<SparseRowChunk> = Vec::with_capacity(partials.len());
    let edge_chunks = par::split_csr(&mut sp.s_edge, offsets, n, chunk_rows);
    let grad_edge_chunks = par::split_csr(grad_edge, offsets, n, chunk_rows);
    for (((((c, s_edge), local), discard), (ge, gl)), proj) in edge_chunks
        .into_iter()
        .enumerate()
        .zip(par::split_rows(&mut sp.local, 1, chunk_rows))
        .zip(par::split_rows(&mut sp.discard, 1, chunk_rows))
        .zip(grad_edge_chunks.into_iter().zip(par::split_rows(grad_local, 1, chunk_rows)))
        .zip(proj.iter_mut())
    {
        items.push(SparseRowChunk {
            rows: par::chunk_range(c, n, chunk_rows),
            s_edge,
            local,
            discard,
            grad_edge: ge,
            grad_local: gl,
            proj,
            linear: 0.0,
        });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.rows.start;
        let ebase = offsets[base];
        for i in it.rows.clone() {
            if !p.active[i] || p.d[i] == 0.0 {
                continue;
            }
            let li = i - base;
            it.grad_local[li] = p.d[i]
                * (p.costs.c_node(p.t, i) + p.costs.f(p.t, i) * phi_prime(g_tilde[i]));
            for e in offsets[i]..offsets[i + 1] {
                let j = targets[e];
                if !p.active[j] {
                    continue;
                }
                it.grad_edge[e - ebase] = p.d[i]
                    * (p.costs.c_link(p.t, i, j)
                        + p.costs.c_node(p.t + 1, j)
                        + p.costs.f(p.t, j) * phi_prime(g_tilde[j]));
            }
            it.local[li] -= step * it.grad_local[li];
            for e in offsets[i]..offsets[i + 1] {
                it.s_edge[e - ebase] -= step * it.grad_edge[e - ebase];
            }
            project_row_sparse(
                p,
                i,
                offsets,
                targets,
                ebase,
                it.s_edge,
                &mut it.local[li],
                &mut it.discard[li],
                it.proj,
            );
        }
        let mut acc = 0.0;
        for i in it.rows.clone() {
            let li = i - base;
            let g_local = it.local[li] * p.d[i] + p.inbound_prev[i];
            acc += g_local * p.costs.c_node(p.t, i);
            if p.d[i] > 0.0 {
                for e in offsets[i]..offsets[i + 1] {
                    if it.s_edge[e - ebase] > 0.0 {
                        let j = targets[e];
                        let amount = p.d[i] * it.s_edge[e - ebase];
                        acc += amount
                            * (p.costs.c_link(p.t, i, j) + p.costs.c_node(p.t + 1, j));
                    }
                }
            }
        }
        it.linear = acc;
    });
    for (partial, it) in partials.iter_mut().zip(items.iter()) {
        *partial = it.linear;
    }
}

/// Sparse mirror of [`gather_pass`]: per target, the CSR transpose row
/// supplies in-edges source-ascending — the same per-target accumulation
/// chain as the dense column scan (off-edge dense contributions are
/// `+0.0` exact no-ops).
fn gather_pass_sparse(
    p: &MovementProblem,
    sp: &SparsePlan,
    g_tilde: &mut [f64],
    inbound_now: &mut [f64],
    partials: &mut [f64],
    threads: usize,
    chunk_rows: usize,
) -> f64 {
    struct GatherChunk<'a> {
        targets: Range<usize>,
        g: &'a mut [f64],
        inb: &'a mut [f64],
        partial: f64,
    }
    let n = sp.n;
    let mut items: Vec<GatherChunk> = Vec::with_capacity(partials.len());
    for (((c, g), inb), &partial) in par::split_rows(g_tilde, 1, chunk_rows)
        .enumerate()
        .zip(par::split_rows(inbound_now, 1, chunk_rows))
        .zip(partials.iter())
    {
        items.push(GatherChunk {
            targets: par::chunk_range(c, n, chunk_rows),
            g,
            inb,
            partial,
        });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.targets.start;
        for j in it.targets.clone() {
            let mut g = sp.local[j] * p.d[j] + p.inbound_prev[j];
            let mut inb = 0.0;
            for te in sp.t_offsets[j]..sp.t_offsets[j + 1] {
                let i = sp.t_sources[te];
                if p.d[i] == 0.0 {
                    continue;
                }
                let c = sp.s_edge[sp.t_slot[te]] * p.d[i];
                g += c;
                inb += c;
            }
            it.g[j - base] = g;
            it.inb[j - base] = inb;
        }
        let mut acc = it.partial;
        for j in it.targets.clone() {
            if !p.active[j] {
                continue;
            }
            let g = sp.local[j] * p.d[j] + p.inbound_prev[j] + it.inb[j - base];
            acc += p.costs.f(p.t, j) / (g + SQRT_EPS).sqrt();
        }
        it.partial = acc;
    });
    for (partial, it) in partials.iter_mut().zip(items.iter()) {
        *partial = it.partial;
    }
    par::combine(partials)
}

/// Project one sparse device row in the same gather order the dense path
/// uses — `r_i`, `s_ii`, then active out-neighbors ascending — so the
/// Duchi projection sees an identical value sequence. `s_edge` is the
/// chunk's CSR value slice, offset by `ebase`.
#[allow(clippy::too_many_arguments)]
fn project_row_sparse(
    p: &MovementProblem,
    i: usize,
    offsets: &[usize],
    targets: &[usize],
    ebase: usize,
    s_edge: &mut [f64],
    local: &mut f64,
    discard: &mut f64,
    buf: &mut ProjBuffers,
) {
    buf.values.clear();
    buf.values.push(*discard); // r_i
    buf.values.push(*local); // s_ii
    for e in offsets[i]..offsets[i + 1] {
        if p.active[targets[e]] {
            buf.values.push(s_edge[e - ebase]);
        }
    }
    project_simplex_into(&buf.values, &mut buf.scratch, &mut buf.projected);
    // zero the whole row, then scatter back in gather order
    *discard = 0.0;
    *local = 0.0;
    for e in offsets[i]..offsets[i + 1] {
        s_edge[e - ebase] = 0.0;
    }
    let mut cursor = buf.projected.iter();
    *discard = *cursor.next().expect("r coordinate");
    *local = *cursor.next().expect("s_ii coordinate");
    for e in offsets[i]..offsets[i + 1] {
        if p.active[targets[e]] {
            s_edge[e - ebase] = *cursor.next().expect("edge coordinate");
        }
    }
}

/// Sparse mirror of [`project_rows`] — the warm-start reprojection.
fn project_rows_sparse(
    p: &MovementProblem,
    sp: &mut SparsePlan,
    proj: &mut [ProjBuffers],
    threads: usize,
    chunk_rows: usize,
) {
    struct ProjChunk<'a> {
        rows: Range<usize>,
        s_edge: &'a mut [f64],
        local: &'a mut [f64],
        discard: &'a mut [f64],
        proj: &'a mut ProjBuffers,
    }
    let n = sp.n;
    let offsets = &sp.offsets;
    let targets = &sp.targets;
    let mut items: Vec<ProjChunk> = Vec::new();
    for ((((c, s_edge), local), discard), proj) in
        par::split_csr(&mut sp.s_edge, offsets, n, chunk_rows)
            .into_iter()
            .enumerate()
            .zip(par::split_rows(&mut sp.local, 1, chunk_rows))
            .zip(par::split_rows(&mut sp.discard, 1, chunk_rows))
            .zip(proj.iter_mut())
    {
        items.push(ProjChunk {
            rows: par::chunk_range(c, n, chunk_rows),
            s_edge,
            local,
            discard,
            proj,
        });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.rows.start;
        let ebase = offsets[base];
        for i in it.rows.clone() {
            if !p.active[i] || p.d[i] == 0.0 {
                continue;
            }
            let li = i - base;
            project_row_sparse(
                p,
                i,
                offsets,
                targets,
                ebase,
                it.s_edge,
                &mut it.local[li],
                &mut it.discard[li],
                it.proj,
            );
        }
    });
}

/// Euclidean projection of `v` onto the probability simplex
/// (Held–Wolfe–Crowder / Duchi et al. algorithm).
///
/// Thin allocating wrapper for tests and docs — every hot path routes
/// through [`project_simplex_into`] with workspace buffers instead.
pub fn project_simplex(v: &[f64]) -> Vec<f64> {
    let mut scratch = Vec::new();
    let mut out = Vec::new();
    project_simplex_into(v, &mut scratch, &mut out);
    out
}

/// Allocation-free variant of [`project_simplex`]: `scratch` holds the
/// descending sort, `out` receives the projection.
pub fn project_simplex_into(v: &[f64], scratch: &mut Vec<f64>, out: &mut Vec<f64>) {
    scratch.clear();
    scratch.extend_from_slice(v);
    scratch.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut theta = 0.0;
    for (k, &uk) in scratch.iter().enumerate() {
        css += uk;
        let candidate = (css - 1.0) / (k + 1) as f64;
        if uk - candidate > 0.0 {
            theta = candidate;
        }
    }
    out.clear();
    out.extend(v.iter().map(|&x| (x - theta).max(0.0)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::movement::problem::DiscardModel;
    use crate::movement::theory;
    use crate::prop::for_all;
    use crate::topology::generators::{erdos_renyi, star};

    #[test]
    fn simplex_projection_basics() {
        let p = project_simplex(&[0.5, 0.5]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(p, vec![0.5, 0.5]);

        let p = project_simplex(&[2.0, 0.0]);
        assert_eq!(p, vec![1.0, 0.0]);

        let p = project_simplex(&[-1.0, -2.0]);
        assert!((p[0] - 1.0).abs() < 1e-12);
        assert_eq!(p[1], 0.0);
    }

    #[test]
    fn prop_simplex_projection_valid() {
        for_all("simplex_proj", 200, |g| {
            let len = g.usize_in(1, 12);
            let v = g.vec_f64(len, -3.0, 3.0);
            let p = project_simplex(&v);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
            // projection is the closest point: spot-check vs a few random
            // feasible points
            let d_proj: f64 = v.iter().zip(&p).map(|(a, b)| (a - b) * (a - b)).sum();
            for _ in 0..5 {
                let mut q = g.vec_f64(len, 0.0, 1.0);
                let s: f64 = q.iter().sum();
                if s > 0.0 {
                    for x in q.iter_mut() {
                        *x /= s;
                    }
                    let d_q: f64 = v.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
                    assert!(d_proj <= d_q + 1e-9);
                }
            }
        });
    }

    /// The fused linear+gather evaluation must agree **bitwise** with the
    /// standalone `objective()` — at the default single-chunk geometry and
    /// under forced multi-chunk reductions, dense and sparse alike.
    #[test]
    fn prop_fused_objective_matches_standalone_bitwise() {
        for_all("fused_objective", 40, |g| {
            let n = g.usize_in(2, 7);
            let graph = erdos_renyi(n, g.f64_in(0.2, 1.0), g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.1, 2.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 0.5);
                        }
                    }
                }
            }
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 15.0)).collect();
            let inbound: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 4.0)).collect();
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.85)).collect();
            let p = MovementProblem {
                t: 0,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: DiscardModel::Sqrt,
            };
            let plan = crate::movement::greedy::solve(&p);
            let mut sp = SparsePlan::keep_all(&graph);
            sp.from_dense(&plan);
            for chunk_rows in [par::CHUNK_ROWS, 2] {
                let nc = par::num_chunks(n, chunk_rows);
                let mut g_tilde = vec![0.0; n];
                let mut inb = vec![0.0; n];
                let mut partials = vec![0.0; nc];
                linear_pass(&p, &plan, &mut partials, 1, chunk_rows);
                let fused =
                    gather_pass(&p, &plan, &mut g_tilde, &mut inb, &mut partials, 1, chunk_rows);
                assert_eq!(
                    fused.to_bits(),
                    plan.objective_chunked(&p, chunk_rows).to_bits(),
                    "dense fused vs standalone, chunk_rows={chunk_rows}"
                );
                let mut partials_sp = vec![0.0; nc];
                linear_pass_sparse(&p, &sp, &mut partials_sp, 1, chunk_rows);
                let fused_sp = gather_pass_sparse(
                    &p,
                    &sp,
                    &mut g_tilde,
                    &mut inb,
                    &mut partials_sp,
                    1,
                    chunk_rows,
                );
                assert_eq!(
                    fused_sp.to_bits(),
                    sp.objective_chunked(&p, chunk_rows).to_bits(),
                    "sparse fused vs standalone, chunk_rows={chunk_rows}"
                );
                assert_eq!(fused.to_bits(), fused_sp.to_bits(), "dense vs sparse fused");
            }
            // the default geometry reproduces the historical objective()
            assert_eq!(plan.objective(&p), plan.objective_chunked(&p, par::CHUNK_ROWS));
        });
    }

    /// PGD must recover the Theorem-4 closed form on the hierarchical
    /// (star) scenario: n devices offloading to a cheap edge server.
    #[test]
    fn pgd_matches_theorem4_closed_form() {
        let n_dev = 4;
        let n = n_dev + 1; // device `n_dev` is the edge server
        let server = n_dev;
        let graph = star(n, server);
        let d_i = 600.0;
        let gamma = 60.0;
        let c_dev = 0.6;
        let c_server = 0.12;
        let c_t = 0.05;

        let mut costs = CostSchedule::zeros(n, 3);
        for t in 0..3 {
            for i in 0..n_dev {
                costs.compute[t][i] = c_dev;
                costs.error_weight[t][i] = gamma;
                costs.link[t][i * n + server] = c_t;
            }
            costs.compute[t][server] = c_server;
            costs.error_weight[t][server] = gamma;
        }
        let mut d = vec![d_i; n_dev];
        d.push(0.0); // server collects nothing
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::Sqrt,
        };
        let plan = solve(&p, PgdOptions { iterations: 3000, step0: 0.0, tol: 0.0 });
        plan.assert_feasible(&p, 1e-6);

        let closed = theory::theorem4_closed_form(
            gamma,
            &vec![c_dev; n_dev],
            c_server,
            c_t,
            &vec![d_i; n_dev],
        );

        // the closed form is the optimum of the unsmoothed objective;
        // compare decisions within tolerance
        for i in 0..n_dev {
            assert!(
                (plan.r[i] - closed.r[i]).abs() < 0.05,
                "device {i}: pgd r={} closed r={}",
                plan.r[i],
                closed.r[i]
            );
            assert!(
                (plan.s(i, server) - closed.s[i]).abs() < 0.05,
                "device {i}: pgd s={} closed s={}",
                plan.s(i, server),
                closed.s[i]
            );
        }

        // and the PGD objective must not be worse than the closed form's
        let mut closed_plan = MovementPlan::keep_all(n);
        for i in 0..n_dev {
            closed_plan.set_s(i, i, 1.0 - closed.r[i] - closed.s[i]);
            closed_plan.set_s(i, server, closed.s[i]);
            closed_plan.r[i] = closed.r[i];
        }
        assert!(plan.objective(&p) <= closed_plan.objective(&p) + 1e-2);
    }

    /// Property: PGD output is always feasible and never worse than the
    /// greedy warm start under the Sqrt objective.
    #[test]
    fn prop_pgd_feasible_and_improves() {
        for_all("pgd_improves", 20, |g| {
            let n = g.usize_in(2, 6);
            let graph = erdos_renyi(n, g.f64_in(0.3, 1.0), g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.1, 3.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 0.5);
                        }
                    }
                }
            }
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 20.0)).collect();
            let inbound: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 5.0)).collect();
            let active = vec![true; n];
            let p = MovementProblem {
                t: 0,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: DiscardModel::Sqrt,
            };
            let warm = crate::movement::greedy::solve(&p);
            let plan = solve(&p, PgdOptions { iterations: 150, step0: 0.0, tol: 0.0 });
            plan.assert_feasible(&p, 1e-6);
            assert!(plan.objective(&p) <= warm.objective(&p) + 1e-9);
        });
    }
}
