//! Projected-gradient solver for the convex `f_i(t)/√G_i(t)` discard model.
//!
//! §IV-A2 derives this error cost from Lemma 1 + Theorem 1 (the local-loss
//! bound decays as `1/√G_i`). The resulting per-interval problem is convex
//! in `(s, r)`: the linear processing/offloading terms plus a convex
//! composition `f · φ(G̃_i)` with `φ(G) = (G + 1)^{-1/2}` — the `+1`
//! smoothing keeps the gradient bounded at zero data, exactly as solving at
//! datapoint granularity would (you cannot process half a point).
//!
//! The feasible set is a product of per-device simplices
//! `{r_i, s_ii, s_ij (j ∈ N_i) ≥ 0, sum = 1}` — capacities are handled by
//! the separate [`super::repair`] pass, mirroring the paper's two-stage
//! procedure justified by Theorem 6. Projected gradient descent with a
//! diminishing step and best-iterate tracking converges fast at these sizes
//! (n ≤ 50 ⇒ ≤ 2.5k variables).

use crate::movement::plan::MovementPlan;
use crate::movement::problem::MovementProblem;
use crate::movement::SolverWorkspace;

/// Smoothing constant in `φ(G) = (G + SQRT_EPS)^{-1/2}`.
pub const SQRT_EPS: f64 = 1.0;

/// PGD hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct PgdOptions {
    pub iterations: usize,
    pub step0: f64,
}

impl Default for PgdOptions {
    fn default() -> Self {
        PgdOptions { iterations: 400, step0: 0.0 } // step0 = 0 -> auto
    }
}

/// Solve the Sqrt-model problem by projected gradient descent, warm-started
/// from the Theorem-3 greedy solution under the linear model.
pub fn solve(p: &MovementProblem, opts: PgdOptions) -> MovementPlan {
    let mut ws = SolverWorkspace::new();
    solve_with(p, opts, &mut ws);
    ws.plan
}

/// Workspace-reusing variant of [`solve`]: the best iterate lands in
/// `ws.plan`. Every buffer is zeroed or fully overwritten first, so the
/// result is bit-identical to a fresh [`solve`].
pub fn solve_with(p: &MovementProblem, opts: PgdOptions, ws: &mut SolverWorkspace) {
    let n = p.n();
    crate::movement::greedy::solve_into(p, &mut ws.plan);

    // auto step size: inversely proportional to the largest row scale
    let max_d = p.d.iter().cloned().fold(1.0, f64::max);
    let step0 = if opts.step0 > 0.0 { opts.step0 } else { 0.5 / max_d };

    ws.best.clone_from(&ws.plan);
    let mut best_obj = ws.plan.objective(p);

    ws.grad_s.clear();
    ws.grad_s.resize(n * n, 0.0);
    for it in 0..opts.iterations {
        gradient(p, &ws.plan, &mut ws.grad_s, &mut ws.g_tilde);
        let step = step0 / (1.0 + (it as f64 / 40.0)).sqrt();
        // gradient step on s (r has zero gradient; the simplex projection
        // absorbs mass into r when the s-coordinates shrink)
        for i in 0..n {
            if !p.active[i] || p.d[i] == 0.0 {
                continue;
            }
            for j in 0..n {
                if j == i || p.graph.has_edge(i, j) {
                    ws.plan.s[i * n + j] -= step * ws.grad_s[i * n + j];
                }
            }
        }
        project_rows(p, ws);
        let obj = ws.plan.objective(p);
        if obj < best_obj {
            best_obj = obj;
            ws.best.clone_from(&ws.plan);
        }
    }
    ws.plan.clone_from(&ws.best);
}

/// ∂F/∂s_ij for the smoothed objective (see module docs).
/// ∂F/∂s_ii = d_i (c_i(t) + f_i(t) φ'(G̃_i))
/// ∂F/∂s_ij = d_i (c_ij(t) + c_j(t+1) + f_j(t) φ'(G̃_j)), j ≠ i
fn gradient(
    p: &MovementProblem,
    plan: &MovementPlan,
    grad_s: &mut [f64],
    g_tilde: &mut Vec<f64>,
) {
    let n = p.n();
    // G̃_i = s_ii d_i + inbound_prev_i + Σ_{j≠i} s_ji d_j
    g_tilde.clear();
    g_tilde.resize(n, 0.0);
    for i in 0..n {
        g_tilde[i] = plan.s(i, i) * p.d[i] + p.inbound_prev[i];
    }
    for i in 0..n {
        if p.d[i] == 0.0 {
            continue;
        }
        for j in 0..n {
            if j != i {
                g_tilde[j] += plan.s(i, j) * p.d[i];
            }
        }
    }
    let phi_prime = |g: f64| -0.5 * (g + SQRT_EPS).powf(-1.5);

    for i in 0..n {
        if !p.active[i] || p.d[i] == 0.0 {
            continue;
        }
        grad_s[i * n + i] =
            p.d[i] * (p.costs.c_node(p.t, i) + p.costs.f(p.t, i) * phi_prime(g_tilde[i]));
        for j in 0..n {
            if j == i || !p.graph.has_edge(i, j) || !p.active[j] {
                continue;
            }
            grad_s[i * n + j] = p.d[i]
                * (p.costs.c_link(p.t, i, j)
                    + p.costs.c_node(p.t + 1, j)
                    + p.costs.f(p.t, j) * phi_prime(g_tilde[j]));
        }
    }
}

/// Project every device row onto its simplex (r_i, s_ii, s_ij for active
/// out-neighbors; other coordinates forced to 0). Uses the workspace's
/// gather/projection buffers (`ws.plan` is the row source and target).
fn project_rows(p: &MovementProblem, ws: &mut SolverWorkspace) {
    let n = p.n();
    for i in 0..n {
        if !p.active[i] || p.d[i] == 0.0 {
            continue;
        }
        // gather the free coordinates of row i
        ws.coords.clear();
        ws.coords.push((None, ws.plan.r[i])); // r_i
        ws.coords.push((Some(i), ws.plan.s(i, i)));
        for j in p.graph.out_neighbors(i) {
            if p.active[*j] {
                ws.coords.push((Some(*j), ws.plan.s(i, *j)));
            }
        }
        ws.values.clear();
        ws.values.extend(ws.coords.iter().map(|&(_, v)| v));
        project_simplex_into(&ws.values, &mut ws.scratch, &mut ws.projected);
        // zero the whole row, then write back the projected coordinates
        ws.plan.r[i] = 0.0;
        for j in 0..n {
            ws.plan.s[i * n + j] = 0.0;
        }
        for (&(target, _), &v) in ws.coords.iter().zip(ws.projected.iter()) {
            match target {
                None => ws.plan.r[i] = v,
                Some(j) => ws.plan.s[i * n + j] = v,
            }
        }
    }
}

/// Euclidean projection of `v` onto the probability simplex
/// (Held–Wolfe–Crowder / Duchi et al. algorithm).
pub fn project_simplex(v: &[f64]) -> Vec<f64> {
    let mut scratch = Vec::new();
    let mut out = Vec::new();
    project_simplex_into(v, &mut scratch, &mut out);
    out
}

/// Allocation-free variant of [`project_simplex`]: `scratch` holds the
/// descending sort, `out` receives the projection.
pub fn project_simplex_into(v: &[f64], scratch: &mut Vec<f64>, out: &mut Vec<f64>) {
    scratch.clear();
    scratch.extend_from_slice(v);
    scratch.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut theta = 0.0;
    for (k, &uk) in scratch.iter().enumerate() {
        css += uk;
        let candidate = (css - 1.0) / (k + 1) as f64;
        if uk - candidate > 0.0 {
            theta = candidate;
        }
    }
    out.clear();
    out.extend(v.iter().map(|&x| (x - theta).max(0.0)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::movement::problem::DiscardModel;
    use crate::movement::theory;
    use crate::prop::for_all;
    use crate::topology::generators::{erdos_renyi, star};

    #[test]
    fn simplex_projection_basics() {
        let p = project_simplex(&[0.5, 0.5]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(p, vec![0.5, 0.5]);

        let p = project_simplex(&[2.0, 0.0]);
        assert_eq!(p, vec![1.0, 0.0]);

        let p = project_simplex(&[-1.0, -2.0]);
        assert!((p[0] - 1.0).abs() < 1e-12);
        assert_eq!(p[1], 0.0);
    }

    #[test]
    fn prop_simplex_projection_valid() {
        for_all("simplex_proj", 200, |g| {
            let len = g.usize_in(1, 12);
            let v = g.vec_f64(len, -3.0, 3.0);
            let p = project_simplex(&v);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
            // projection is the closest point: spot-check vs a few random
            // feasible points
            let d_proj: f64 = v.iter().zip(&p).map(|(a, b)| (a - b) * (a - b)).sum();
            for _ in 0..5 {
                let mut q = g.vec_f64(len, 0.0, 1.0);
                let s: f64 = q.iter().sum();
                if s > 0.0 {
                    for x in q.iter_mut() {
                        *x /= s;
                    }
                    let d_q: f64 = v.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
                    assert!(d_proj <= d_q + 1e-9);
                }
            }
        });
    }

    /// PGD must recover the Theorem-4 closed form on the hierarchical
    /// (star) scenario: n devices offloading to a cheap edge server.
    #[test]
    fn pgd_matches_theorem4_closed_form() {
        let n_dev = 4;
        let n = n_dev + 1; // device `n_dev` is the edge server
        let server = n_dev;
        let graph = star(n, server);
        let d_i = 600.0;
        let gamma = 60.0;
        let c_dev = 0.6;
        let c_server = 0.12;
        let c_t = 0.05;

        let mut costs = CostSchedule::zeros(n, 3);
        for t in 0..3 {
            for i in 0..n_dev {
                costs.compute[t][i] = c_dev;
                costs.error_weight[t][i] = gamma;
                costs.link[t][i * n + server] = c_t;
            }
            costs.compute[t][server] = c_server;
            costs.error_weight[t][server] = gamma;
        }
        let mut d = vec![d_i; n_dev];
        d.push(0.0); // server collects nothing
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::Sqrt,
        };
        let plan = solve(&p, PgdOptions { iterations: 3000, step0: 0.0 });
        plan.assert_feasible(&p, 1e-6);

        let closed = theory::theorem4_closed_form(
            gamma,
            &vec![c_dev; n_dev],
            c_server,
            c_t,
            &vec![d_i; n_dev],
        );

        // the closed form is the optimum of the unsmoothed objective;
        // compare decisions within tolerance
        for i in 0..n_dev {
            assert!(
                (plan.r[i] - closed.r[i]).abs() < 0.05,
                "device {i}: pgd r={} closed r={}",
                plan.r[i],
                closed.r[i]
            );
            assert!(
                (plan.s(i, server) - closed.s[i]).abs() < 0.05,
                "device {i}: pgd s={} closed s={}",
                plan.s(i, server),
                closed.s[i]
            );
        }

        // and the PGD objective must not be worse than the closed form's
        let mut closed_plan = MovementPlan::keep_all(n);
        for i in 0..n_dev {
            closed_plan.set_s(i, i, 1.0 - closed.r[i] - closed.s[i]);
            closed_plan.set_s(i, server, closed.s[i]);
            closed_plan.r[i] = closed.r[i];
        }
        assert!(plan.objective(&p) <= closed_plan.objective(&p) + 1e-2);
    }

    /// Property: PGD output is always feasible and never worse than the
    /// greedy warm start under the Sqrt objective.
    #[test]
    fn prop_pgd_feasible_and_improves() {
        for_all("pgd_improves", 20, |g| {
            let n = g.usize_in(2, 6);
            let graph = erdos_renyi(n, g.f64_in(0.3, 1.0), g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.1, 3.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 0.5);
                        }
                    }
                }
            }
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 20.0)).collect();
            let inbound: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 5.0)).collect();
            let active = vec![true; n];
            let p = MovementProblem {
                t: 0,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: DiscardModel::Sqrt,
            };
            let warm = crate::movement::greedy::solve(&p);
            let plan = solve(&p, PgdOptions { iterations: 150, step0: 0.0 });
            plan.assert_feasible(&p, 1e-6);
            assert!(plan.objective(&p) <= warm.objective(&p) + 1e-9);
        });
    }
}
