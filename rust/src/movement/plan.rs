//! Movement decision variables `s_ij(t)`, `r_i(t)` and their evaluation.

use crate::movement::problem::{DiscardModel, MovementProblem};

/// A (fractional) movement plan for one interval: `s[i*n + j]` is the
/// fraction of `D_i(t)` offloaded to `j` (`s[i*n + i]` = fraction processed
/// locally), `r[i]` the fraction discarded. Row invariant (eq. 8):
/// `r_i + Σ_j s_ij = 1` whenever `D_i(t) > 0`.
#[derive(Debug, PartialEq)]
pub struct MovementPlan {
    pub n: usize,
    pub s: Vec<f64>,
    pub r: Vec<f64>,
}

impl Clone for MovementPlan {
    fn clone(&self) -> Self {
        MovementPlan { n: self.n, s: self.s.clone(), r: self.r.clone() }
    }

    /// Delegates to `Vec::clone_from` so the PGD best-iterate tracking in
    /// the solver workspace reuses buffer capacity instead of reallocating
    /// an n²-sized plan per improving iterate.
    fn clone_from(&mut self, source: &Self) {
        self.n = source.n;
        self.s.clone_from(&source.s);
        self.r.clone_from(&source.r);
    }
}

/// Realized cost components of a plan (the paper's Table III columns).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostBreakdown {
    pub process: f64,
    pub transfer: f64,
    pub discard: f64,
}

impl CostBreakdown {
    pub fn total(&self) -> f64 {
        self.process + self.transfer + self.discard
    }

    pub fn add(&mut self, other: &CostBreakdown) {
        self.process += other.process;
        self.transfer += other.transfer;
        self.discard += other.discard;
    }
}

impl MovementPlan {
    /// The no-movement plan: every device processes everything it collects
    /// (`G_i(t) = D_i(t)`, classic federated learning).
    pub fn keep_all(n: usize) -> Self {
        let mut s = vec![0.0; n * n];
        for i in 0..n {
            s[i * n + i] = 1.0;
        }
        MovementPlan { n, s, r: vec![0.0; n] }
    }

    /// Reset this plan in place to the keep-all state for `n` devices,
    /// reusing the existing allocations (workspace path: one plan buffer
    /// serves every interval of a run).
    pub fn reset_keep_all(&mut self, n: usize) {
        self.n = n;
        self.s.clear();
        self.s.resize(n * n, 0.0);
        self.r.clear();
        self.r.resize(n, 0.0);
        for i in 0..n {
            self.s[i * n + i] = 1.0;
        }
    }

    /// Heap footprint in bytes — the O(n²) number the scaling bench
    /// compares against [`crate::movement::SparsePlan::heap_bytes`].
    pub fn heap_bytes(&self) -> usize {
        use std::mem::size_of;
        self.s.capacity() * size_of::<f64>() + self.r.capacity() * size_of::<f64>()
    }

    #[inline]
    pub fn s(&self, i: usize, j: usize) -> f64 {
        self.s[i * self.n + j]
    }

    #[inline]
    pub fn set_s(&mut self, i: usize, j: usize, v: f64) {
        self.s[i * self.n + j] = v;
    }

    /// Fraction of `D_i(t)` offloaded anywhere.
    pub fn offloaded_fraction(&self, i: usize) -> f64 {
        (0..self.n).filter(|&j| j != i).map(|j| self.s(i, j)).sum()
    }

    /// `G_i(t)` for every device: locally-kept collection plus last
    /// interval's inbound.
    pub fn processed(&self, p: &MovementProblem) -> Vec<f64> {
        (0..self.n)
            .map(|i| self.s(i, i) * p.d[i] + p.inbound_prev[i])
            .collect()
    }

    /// Data each device receives *this* interval (processed next interval).
    pub fn inbound_next(&self, p: &MovementProblem) -> Vec<f64> {
        let mut inbound = vec![0.0; self.n];
        for i in 0..self.n {
            if p.d[i] == 0.0 {
                continue;
            }
            for j in 0..self.n {
                if j != i {
                    inbound[j] += self.s(i, j) * p.d[i];
                }
            }
        }
        inbound
    }

    /// Realized cost components under the *charging* schedule in `p` (call
    /// with the actual schedule even when the plan was computed from an
    /// estimated one). The discard column reports the realized error cost
    /// `f_i(t) D_i(t) r_i(t)` for every model so Table IV rows are
    /// comparable, matching the paper's presentation.
    pub fn cost(&self, p: &MovementProblem) -> CostBreakdown {
        let mut c = CostBreakdown::default();
        let g = self.processed(p);
        for i in 0..self.n {
            c.process += g[i] * p.costs.c_node(p.t, i);
            c.discard += p.costs.f(p.t, i) * p.d[i] * self.r[i];
            if p.d[i] > 0.0 {
                for j in 0..self.n {
                    if j != i && self.s(i, j) > 0.0 {
                        c.transfer += p.d[i] * self.s(i, j) * p.costs.c_link(p.t, i, j);
                    }
                }
            }
        }
        c
    }

    /// The *objective* value the optimizer minimizes (model-dependent; this
    /// is what solvers compare, while [`Self::cost`] is what the ledger
    /// reports). Offloaded data is charged the receiver's next-interval
    /// processing cost, consistent with the solvers' marginal costs.
    pub fn objective(&self, p: &MovementProblem) -> f64 {
        self.objective_chunked(p, crate::util::par::CHUNK_ROWS)
    }

    /// [`Self::objective`] on explicit chunk geometry: per chunk, the
    /// linear terms of its rows then its model terms, partials combined in
    /// ascending chunk order. This is the same accumulation tree the fused
    /// solver passes build (DESIGN.md §Perf rule 12), so the PGD loop's
    /// in-flight objectives agree with this function bitwise — a unit test
    /// in [`crate::movement::convex`] pins that down. A single chunk
    /// (n ≤ [`crate::util::par::CHUNK_ROWS`]) reproduces the
    /// historical single-accumulator sweep exactly.
    pub(crate) fn objective_chunked(&self, p: &MovementProblem, chunk_rows: usize) -> f64 {
        // this-interval inbound for the Sqrt model (the scatter loop's
        // per-target chains match the solver's gather bitwise)
        let inbound_now = match p.discard_model {
            DiscardModel::Sqrt => Some(self.inbound_next(p)),
            _ => None,
        };
        let nc = crate::util::par::num_chunks(self.n, chunk_rows);
        let mut partials = vec![0.0; nc];
        for (c, partial) in partials.iter_mut().enumerate() {
            let rows = crate::util::par::chunk_range(c, self.n, chunk_rows);
            let mut obj = 0.0;
            for i in rows.clone() {
                // local processing of own data + inbound
                let g_local = self.s(i, i) * p.d[i] + p.inbound_prev[i];
                obj += g_local * p.costs.c_node(p.t, i);
                if p.d[i] > 0.0 {
                    for j in 0..self.n {
                        if j != i && self.s(i, j) > 0.0 {
                            let amount = p.d[i] * self.s(i, j);
                            obj += amount
                                * (p.costs.c_link(p.t, i, j) + p.costs.c_node(p.t + 1, j));
                        }
                    }
                }
            }
            match p.discard_model {
                DiscardModel::LinearR => {
                    for i in rows {
                        obj += p.costs.f(p.t, i) * p.d[i] * self.r[i];
                    }
                }
                DiscardModel::LinearG => {
                    // -f_i(t) per point processed now; -f_j(t+1) per point
                    // offloaded to j (processed there next interval)
                    for i in rows {
                        let g_local = self.s(i, i) * p.d[i] + p.inbound_prev[i];
                        obj -= p.costs.f(p.t, i) * g_local;
                        for j in 0..self.n {
                            if j != i && p.d[i] > 0.0 {
                                obj -= p.costs.f(p.t + 1, j) * p.d[i] * self.s(i, j);
                            }
                        }
                    }
                }
                DiscardModel::Sqrt => {
                    // f_i / sqrt(G̃_i): processed now + received now
                    // (credited to the receiver, where it is processed
                    // next interval)
                    let inbound_now = inbound_now.as_ref().expect("computed for Sqrt");
                    for i in rows {
                        if !p.active[i] {
                            continue;
                        }
                        let g = self.s(i, i) * p.d[i] + p.inbound_prev[i] + inbound_now[i];
                        obj += p.costs.f(p.t, i)
                            / (g + crate::movement::convex::SQRT_EPS).sqrt();
                    }
                }
            }
            *partial = obj;
        }
        crate::util::par::combine(&partials)
    }

    /// Panics with a description if the plan violates feasibility (eqs.
    /// 6–9): simplex rows, non-edges, capacities.
    pub fn assert_feasible(&self, p: &MovementProblem, tol: f64) {
        for i in 0..self.n {
            let mut row = self.r[i];
            for j in 0..self.n {
                let sij = self.s(i, j);
                assert!(sij >= -tol, "s[{i},{j}] = {sij} < 0");
                row += sij;
                if i != j && sij > tol {
                    assert!(
                        p.graph.has_edge(i, j) && p.active[i] && p.active[j],
                        "offload on missing/inactive link ({i},{j})"
                    );
                    let cap = p.costs.cap_link_at(p.t, i, j);
                    assert!(
                        sij * p.d[i] <= cap + tol,
                        "link cap violated on ({i},{j}): {} > {cap}",
                        sij * p.d[i]
                    );
                }
            }
            assert!(self.r[i] >= -tol, "r[{i}] < 0");
            if p.d[i] > 0.0 && p.active[i] {
                assert!(
                    (row - 1.0).abs() < tol.max(1e-9),
                    "simplex violated at {i}: r+Σs = {row}"
                );
            }
            let g = self.s(i, i) * p.d[i] + p.inbound_prev[i];
            let cap = p.costs.cap_node_at(p.t, i);
            assert!(
                g <= cap + tol,
                "node cap violated at {i}: G={g} > C={cap}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::topology::generators::fully_connected;

    fn setup(n: usize) -> (crate::topology::Graph, CostSchedule, Vec<f64>, Vec<f64>, Vec<bool>) {
        let graph = fully_connected(n);
        let mut costs = CostSchedule::zeros(n, 3);
        for t in 0..3 {
            for i in 0..n {
                costs.compute[t][i] = 0.2 + 0.1 * i as f64;
                costs.error_weight[t][i] = 0.5;
                for j in 0..n {
                    if i != j {
                        costs.link[t][i * n + j] = 0.1;
                    }
                }
            }
        }
        (graph, costs, vec![10.0; n], vec![0.0; n], vec![true; n])
    }

    #[test]
    fn keep_all_cost_is_pure_processing() {
        let n = 3;
        let (graph, costs, d, inbound, active) = setup(n);
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let plan = MovementPlan::keep_all(n);
        let c = plan.cost(&p);
        assert_eq!(c.transfer, 0.0);
        assert_eq!(c.discard, 0.0);
        let expected: f64 = (0..n).map(|i| 10.0 * (0.2 + 0.1 * i as f64)).sum();
        assert!((c.process - expected).abs() < 1e-9);
        plan.assert_feasible(&p, 1e-9);
    }

    #[test]
    fn offload_moves_cost_to_transfer_column() {
        let n = 2;
        let (graph, costs, d, inbound, active) = setup(n);
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let mut plan = MovementPlan::keep_all(n);
        plan.set_s(0, 0, 0.0);
        plan.set_s(0, 1, 1.0);
        let c = plan.cost(&p);
        assert!((c.transfer - 10.0 * 0.1).abs() < 1e-9);
        // device 0 processes nothing this interval
        assert!((c.process - 10.0 * 0.3).abs() < 1e-9);
        assert_eq!(plan.inbound_next(&p), vec![0.0, 10.0]);
        plan.assert_feasible(&p, 1e-9);
    }

    #[test]
    fn discard_charges_error_cost() {
        let n = 2;
        let (graph, costs, d, inbound, active) = setup(n);
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let mut plan = MovementPlan::keep_all(n);
        plan.set_s(1, 1, 0.0);
        plan.r[1] = 1.0;
        let c = plan.cost(&p);
        assert!((c.discard - 0.5 * 10.0).abs() < 1e-9);
        plan.assert_feasible(&p, 1e-9);
    }

    #[test]
    #[should_panic(expected = "missing/inactive link")]
    fn offload_without_edge_panics() {
        let n = 3;
        let (_, costs, d, inbound, active) = setup(n);
        let empty = crate::topology::Graph::empty(n);
        let p = MovementProblem {
            t: 0,
            graph: &empty,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let mut plan = MovementPlan::keep_all(n);
        plan.set_s(0, 0, 0.0);
        plan.set_s(0, 1, 1.0);
        plan.assert_feasible(&p, 1e-9);
    }

    #[test]
    fn objective_linear_g_rewards_processing() {
        let n = 2;
        let (graph, costs, d, inbound, active) = setup(n);
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearG,
        };
        let keep = MovementPlan::keep_all(n);
        let mut drop_all = MovementPlan::keep_all(n);
        for i in 0..n {
            drop_all.set_s(i, i, 0.0);
            drop_all.r[i] = 1.0;
        }
        // f=0.5 > c for device 0 (0.2): processing should beat discarding
        assert!(keep.objective(&p) < drop_all.objective(&p));
    }
}
