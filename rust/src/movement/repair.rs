//! Capacity-constraint repair (§IV-B, guided by Theorem 6).
//!
//! Both solvers ignore the capacity constraints (9); the paper argues (via
//! Theorem 6) that when expected violations are few, the unconstrained
//! solution plus "minimal adjustments" — re-deciding only the affected
//! variables, or bumping `r_i(t)` until constraints hold — is near-optimal
//! and far cheaper than a generic constrained solver.
//!
//! This pass enforces, in order:
//!   1. link capacities       `s_ij(t) D_i(t) ≤ C_ij(t)`,
//!   2. receiver capacities   `Σ_i s_ij(t) D_i(t) ≤ C_j(t+1)` (offloaded
//!      data is processed by `j` next interval),
//!   3. sender capacities     `s_ii(t) D_i(t) + inbound_i ≤ C_i(t)`,
//! and then redistributes every displaced fraction to that device's
//! cheapest still-feasible option (process → best neighbors → discard),
//! updating shared slacks as it assigns. Discarding is always feasible, so
//! the pass terminates with a feasible plan in one sweep.

use crate::movement::plan::MovementPlan;
use crate::movement::problem::MovementProblem;
use crate::movement::sparse::SparsePlan;

/// One redistribution option for a displaced fraction: process locally or
/// offload to neighbor `j` (whose edge slot, for the sparse path, is
/// `slot`; the dense path ignores it).
#[derive(Clone, Copy)]
enum Opt {
    Process,
    Offload { j: usize, slot: usize },
}

/// Reusable buffers for the repair pass, so the per-interval hot path
/// allocates nothing (the original implementation allocated `excess`,
/// `recv_slack`, and — per device, per sweep — an option list plus a
/// collected neighbor Vec).
#[derive(Debug, Default)]
pub struct RepairScratch {
    excess: Vec<f64>,
    recv_slack: Vec<f64>,
    options: Vec<(f64, Opt)>,
}

impl std::fmt::Debug for Opt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Opt::Process => write!(f, "Process"),
            Opt::Offload { j, .. } => write!(f, "Offload({j})"),
        }
    }
}

/// Repair `plan` in place to satisfy all capacity constraints of `p`.
/// Convenience wrapper over [`repair_with`] with one-shot scratch.
pub fn repair(p: &MovementProblem, plan: &mut MovementPlan) {
    repair_with(p, plan, &mut RepairScratch::default());
}

/// Scratch-reusing variant of [`repair`] — bit-identical results; the
/// buffers are fully overwritten per call.
pub fn repair_with(p: &MovementProblem, plan: &mut MovementPlan, ws: &mut RepairScratch) {
    let n = p.n();
    ws.excess.clear();
    ws.excess.resize(n, 0.0); // displaced fraction per sender

    // --- 1. link capacities -------------------------------------------------
    for i in 0..n {
        if p.d[i] <= 0.0 {
            continue;
        }
        for j in 0..n {
            if j == i || plan.s(i, j) == 0.0 {
                continue;
            }
            let cap = p.costs.cap_link_at(p.t, i, j);
            let max_frac = if cap.is_infinite() { f64::INFINITY } else { cap / p.d[i] };
            if plan.s(i, j) > max_frac {
                ws.excess[i] += plan.s(i, j) - max_frac;
                plan.set_s(i, j, max_frac);
            }
        }
    }

    // --- 2. receiver capacities ---------------------------------------------
    // inbound to j this interval is processed at t+1 and must fit C_j(t+1)
    for j in 0..n {
        let cap = p.costs.cap_node_at(p.t + 1, j);
        if cap.is_infinite() {
            continue;
        }
        let inbound: f64 = (0..n)
            .filter(|&i| i != j && p.d[i] > 0.0)
            .map(|i| plan.s(i, j) * p.d[i])
            .sum();
        if inbound > cap {
            let scale = cap / inbound;
            for i in 0..n {
                if i != j && p.d[i] > 0.0 && plan.s(i, j) > 0.0 {
                    let removed = plan.s(i, j) * (1.0 - scale);
                    ws.excess[i] += removed;
                    plan.set_s(i, j, plan.s(i, j) * scale);
                }
            }
        }
    }

    // --- 3. sender local capacities ------------------------------------------
    for i in 0..n {
        if p.d[i] <= 0.0 {
            continue;
        }
        let cap = p.costs.cap_node_at(p.t, i);
        if cap.is_infinite() {
            continue;
        }
        let avail = (cap - p.inbound_prev[i]).max(0.0);
        let max_frac = avail / p.d[i];
        if plan.s(i, i) > max_frac {
            ws.excess[i] += plan.s(i, i) - max_frac;
            plan.set_s(i, i, max_frac);
        }
    }

    // --- 4. redistribute displaced fractions ---------------------------------
    // shared slacks after the clamping above
    ws.recv_slack.clear();
    ws.recv_slack.extend((0..n).map(|j| {
        let cap = p.costs.cap_node_at(p.t + 1, j);
        if cap.is_infinite() {
            return f64::INFINITY;
        }
        let inbound: f64 = (0..n)
            .filter(|&i| i != j && p.d[i] > 0.0)
            .map(|i| plan.s(i, j) * p.d[i])
            .sum();
        (cap - inbound).max(0.0)
    }));

    for i in 0..n {
        if ws.excess[i] <= 0.0 || p.d[i] <= 0.0 {
            continue;
        }
        let mut remaining = ws.excess[i];

        // option list sorted by marginal cost: (cost, target); the
        // neighbor iterator is consumed directly — no per-device collect
        ws.options.clear();
        ws.options.push((p.process_cost(i), Opt::Process));
        for j in p.active_neighbors(i) {
            ws.options.push((p.offload_cost(i, j), Opt::Offload { j, slot: 0 }));
        }
        ws.options.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        for &(cost, opt) in ws.options.iter() {
            if remaining <= 1e-12 {
                break;
            }
            // anything pricier than discarding goes to discard
            if cost >= p.discard_cost(i) {
                break;
            }
            match opt {
                Opt::Process => {
                    let cap = p.costs.cap_node_at(p.t, i);
                    let slack_frac = if cap.is_infinite() {
                        f64::INFINITY
                    } else {
                        ((cap - p.inbound_prev[i]).max(0.0) / p.d[i] - plan.s(i, i)).max(0.0)
                    };
                    let take = remaining.min(slack_frac);
                    plan.set_s(i, i, plan.s(i, i) + take);
                    remaining -= take;
                }
                Opt::Offload { j, .. } => {
                    let link_cap = p.costs.cap_link_at(p.t, i, j);
                    let link_slack = if link_cap.is_infinite() {
                        f64::INFINITY
                    } else {
                        (link_cap / p.d[i] - plan.s(i, j)).max(0.0)
                    };
                    let recv_frac = if ws.recv_slack[j].is_infinite() {
                        f64::INFINITY
                    } else {
                        ws.recv_slack[j] / p.d[i]
                    };
                    let take = remaining.min(link_slack).min(recv_frac);
                    if take > 0.0 {
                        plan.set_s(i, j, plan.s(i, j) + take);
                        if !ws.recv_slack[j].is_infinite() {
                            ws.recv_slack[j] -= take * p.d[i];
                        }
                        remaining -= take;
                    }
                }
            }
        }
        // whatever could not be placed is discarded
        plan.r[i] += remaining;
    }
}

/// Sparse mirror of [`repair_with`]: same four phases, same float-op
/// sequence, but every scan touches only stored edge slots (O(V + E) per
/// pass instead of O(n²)). Receiver-side sums walk the transpose rows,
/// whose ascending-source order matches the dense `for i in 0..n` loop
/// (off-edge dense terms are `+0.0` no-ops on nonnegative sums), so the
/// repaired sparse plan densifies bit-identically.
pub fn repair_sparse(p: &MovementProblem, sp: &mut SparsePlan, ws: &mut RepairScratch) {
    let n = p.n();
    assert_eq!(sp.n, n, "sparse plan size mismatch");
    ws.excess.clear();
    ws.excess.resize(n, 0.0);

    // --- 1. link capacities -------------------------------------------------
    for i in 0..n {
        if p.d[i] <= 0.0 {
            continue;
        }
        for e in sp.offsets[i]..sp.offsets[i + 1] {
            if sp.s_edge[e] == 0.0 {
                continue;
            }
            let cap = p.costs.cap_link_at(p.t, i, sp.targets[e]);
            let max_frac = if cap.is_infinite() { f64::INFINITY } else { cap / p.d[i] };
            if sp.s_edge[e] > max_frac {
                ws.excess[i] += sp.s_edge[e] - max_frac;
                sp.s_edge[e] = max_frac;
            }
        }
    }

    // --- 2. receiver capacities ---------------------------------------------
    for j in 0..n {
        let cap = p.costs.cap_node_at(p.t + 1, j);
        if cap.is_infinite() {
            continue;
        }
        let mut inbound = 0.0;
        for te in sp.t_offsets[j]..sp.t_offsets[j + 1] {
            let i = sp.t_sources[te];
            if p.d[i] > 0.0 {
                inbound += sp.s_edge[sp.t_slot[te]] * p.d[i];
            }
        }
        if inbound > cap {
            let scale = cap / inbound;
            for te in sp.t_offsets[j]..sp.t_offsets[j + 1] {
                let i = sp.t_sources[te];
                let slot = sp.t_slot[te];
                if p.d[i] > 0.0 && sp.s_edge[slot] > 0.0 {
                    let removed = sp.s_edge[slot] * (1.0 - scale);
                    ws.excess[i] += removed;
                    sp.s_edge[slot] *= scale;
                }
            }
        }
    }

    // --- 3. sender local capacities ------------------------------------------
    for i in 0..n {
        if p.d[i] <= 0.0 {
            continue;
        }
        let cap = p.costs.cap_node_at(p.t, i);
        if cap.is_infinite() {
            continue;
        }
        let avail = (cap - p.inbound_prev[i]).max(0.0);
        let max_frac = avail / p.d[i];
        if sp.local[i] > max_frac {
            ws.excess[i] += sp.local[i] - max_frac;
            sp.local[i] = max_frac;
        }
    }

    // --- 4. redistribute displaced fractions ---------------------------------
    ws.recv_slack.clear();
    ws.recv_slack.extend((0..n).map(|j| {
        let cap = p.costs.cap_node_at(p.t + 1, j);
        if cap.is_infinite() {
            return f64::INFINITY;
        }
        let mut inbound = 0.0;
        for te in sp.t_offsets[j]..sp.t_offsets[j + 1] {
            let i = sp.t_sources[te];
            if p.d[i] > 0.0 {
                inbound += sp.s_edge[sp.t_slot[te]] * p.d[i];
            }
        }
        (cap - inbound).max(0.0)
    }));

    for i in 0..n {
        if ws.excess[i] <= 0.0 || p.d[i] <= 0.0 {
            continue;
        }
        let mut remaining = ws.excess[i];

        ws.options.clear();
        ws.options.push((p.process_cost(i), Opt::Process));
        // same filter as `p.active_neighbors(i)` (active target only), in
        // the same ascending order
        for e in sp.offsets[i]..sp.offsets[i + 1] {
            let j = sp.targets[e];
            if p.active[j] {
                ws.options.push((p.offload_cost(i, j), Opt::Offload { j, slot: e }));
            }
        }
        ws.options.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        for &(cost, opt) in ws.options.iter() {
            if remaining <= 1e-12 {
                break;
            }
            if cost >= p.discard_cost(i) {
                break;
            }
            match opt {
                Opt::Process => {
                    let cap = p.costs.cap_node_at(p.t, i);
                    let slack_frac = if cap.is_infinite() {
                        f64::INFINITY
                    } else {
                        ((cap - p.inbound_prev[i]).max(0.0) / p.d[i] - sp.local[i]).max(0.0)
                    };
                    let take = remaining.min(slack_frac);
                    sp.local[i] += take;
                    remaining -= take;
                }
                Opt::Offload { j, slot } => {
                    let link_cap = p.costs.cap_link_at(p.t, i, j);
                    let link_slack = if link_cap.is_infinite() {
                        f64::INFINITY
                    } else {
                        (link_cap / p.d[i] - sp.s_edge[slot]).max(0.0)
                    };
                    let recv_frac = if ws.recv_slack[j].is_infinite() {
                        f64::INFINITY
                    } else {
                        ws.recv_slack[j] / p.d[i]
                    };
                    let take = remaining.min(link_slack).min(recv_frac);
                    if take > 0.0 {
                        sp.s_edge[slot] += take;
                        if !ws.recv_slack[j].is_infinite() {
                            ws.recv_slack[j] -= take * p.d[i];
                        }
                        remaining -= take;
                    }
                }
            }
        }
        sp.discard[i] += remaining;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::{CapacityMode, CostSchedule};
    use crate::movement::problem::DiscardModel;
    use crate::movement::{convex, greedy};
    use crate::prop::for_all;
    use crate::topology::generators::{erdos_renyi, fully_connected};

    fn base_costs(n: usize) -> CostSchedule {
        let mut costs = CostSchedule::zeros(n, 3);
        for t in 0..3 {
            for i in 0..n {
                costs.compute[t][i] = 0.1 + 0.2 * i as f64;
                costs.error_weight[t][i] = 0.5;
                for j in 0..n {
                    if i != j {
                        costs.link[t][i * n + j] = 0.02;
                    }
                }
            }
        }
        costs
    }

    #[test]
    fn no_op_when_unconstrained() {
        let n = 4;
        let graph = fully_connected(n);
        let costs = base_costs(n);
        let d = vec![10.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let plan = greedy::solve(&p);
        let mut repaired = plan.clone();
        repair(&p, &mut repaired);
        assert_eq!(plan, repaired);
    }

    #[test]
    fn receiver_capacity_spreads_load() {
        // all devices want to offload to cheap device 0, but its capacity
        // only fits part of the load
        let n = 4;
        let graph = fully_connected(n);
        let mut costs = base_costs(n);
        costs.set_capacities(CapacityMode::Uniform(12.0));
        // make device 0 very cheap so everyone targets it
        for t in 0..3 {
            costs.compute[t] = vec![0.01, 0.9, 0.9, 0.9];
        }
        let d = vec![10.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let mut plan = greedy::solve(&p);
        // before repair: 30 units inbound to device 0 > cap 12
        let inbound_before: f64 = (1..n).map(|i| plan.s(i, 0) * d[i]).sum();
        assert!(inbound_before > 12.0);
        repair(&p, &mut plan);
        plan.assert_feasible(&p, 1e-9);
        let inbound_after: f64 = (1..n).map(|i| plan.s(i, 0) * d[i]).sum();
        assert!(inbound_after <= 12.0 + 1e-9);
        // load was spread, not silently dropped from the simplex
        for i in 1..n {
            let row: f64 = plan.r[i] + (0..n).map(|j| plan.s(i, j)).sum::<f64>();
            assert!((row - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn sender_capacity_forces_discard_or_offload() {
        let n = 2;
        let mut costs = base_costs(n);
        costs.set_capacities(CapacityMode::Uniform(4.0));
        // both devices process-favorable, but capacity 4 < d 10
        for t in 0..3 {
            costs.compute[t] = vec![0.1, 0.1];
            costs.error_weight[t] = vec![0.9, 0.9];
        }
        let graph = fully_connected(n);
        let d = vec![10.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let mut plan = greedy::solve(&p);
        repair(&p, &mut plan);
        plan.assert_feasible(&p, 1e-9);
        // each can keep only 0.4 locally; the rest must move or drop
        for i in 0..n {
            assert!(plan.s(i, i) <= 0.4 + 1e-9);
        }
    }

    #[test]
    fn prop_repair_always_feasible() {
        for_all("repair_feasible", 60, |g| {
            let n = g.usize_in(2, 7);
            let graph = erdos_renyi(n, g.f64_in(0.2, 1.0), g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.0, 1.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 1.0);
                        }
                    }
                }
            }
            let cap = g.f64_in(2.0, 15.0);
            costs.set_capacities(CapacityMode::Uniform(cap));
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 25.0)).collect();
            // inbound bounded by capacity (engine invariant: last interval's
            // repaired plan respected the receiver constraint)
            let inbound: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, cap)).collect();
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.85)).collect();
            let restricted = graph.restrict(&active);
            let model = match g.usize_in(0, 2) {
                0 => DiscardModel::LinearR,
                1 => DiscardModel::LinearG,
                _ => DiscardModel::Sqrt,
            };
            let p = MovementProblem {
                t: 0,
                graph: &restricted,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let mut plan = match model {
                DiscardModel::Sqrt => {
                    convex::solve(&p, convex::PgdOptions { iterations: 60, step0: 0.0, tol: 0.0 })
                }
                _ => greedy::solve(&p),
            };
            repair(&p, &mut plan);
            plan.assert_feasible(&p, 1e-6);
        });
    }
}
