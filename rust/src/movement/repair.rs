//! Capacity-constraint repair (§IV-B, guided by Theorem 6).
//!
//! Both solvers ignore the capacity constraints (9); the paper argues (via
//! Theorem 6) that when expected violations are few, the unconstrained
//! solution plus "minimal adjustments" — re-deciding only the affected
//! variables, or bumping `r_i(t)` until constraints hold — is near-optimal
//! and far cheaper than a generic constrained solver.
//!
//! This pass enforces, in order:
//!   1. link capacities       `s_ij(t) D_i(t) ≤ C_ij(t)`,
//!   2. receiver capacities   `Σ_i s_ij(t) D_i(t) ≤ C_j(t+1)` (offloaded
//!      data is processed by `j` next interval),
//!   3. sender capacities     `s_ii(t) D_i(t) + inbound_i ≤ C_i(t)`,
//! and then redistributes every displaced fraction to that device's
//! cheapest still-feasible option (process → best neighbors → discard),
//! updating shared slacks as it assigns. Discarding is always feasible, so
//! the pass terminates with a feasible plan in one sweep.

use crate::util::par;
use crate::movement::plan::MovementPlan;
use crate::movement::problem::MovementProblem;
use crate::movement::sparse::SparsePlan;
use std::ops::Range;

/// One redistribution option for a displaced fraction: process locally or
/// offload to neighbor `j` (whose edge slot, for the sparse path, is
/// `slot`; the dense path ignores it).
#[derive(Clone, Copy)]
enum Opt {
    Process,
    Offload { j: usize, slot: usize },
}

/// Reusable buffers for the repair pass, so the per-interval hot path
/// allocates nothing (the original implementation allocated `excess`,
/// `recv_slack`, and — per device, per sweep — an option list plus a
/// collected neighbor Vec).
#[derive(Debug, Default)]
pub struct RepairScratch {
    excess: Vec<f64>,
    recv_slack: Vec<f64>,
    options: Vec<(f64, Opt)>,
    /// Per-target inbound sums for the receiver phases, gathered
    /// target-parallel before the (order-dependent, serial) scaling loop.
    inbound: Vec<f64>,
}

impl std::fmt::Debug for Opt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Opt::Process => write!(f, "Process"),
            Opt::Offload { j, .. } => write!(f, "Offload({j})"),
        }
    }
}

/// Repair `plan` in place to satisfy all capacity constraints of `p`.
/// Convenience wrapper over [`repair_with`] with one-shot scratch.
pub fn repair(p: &MovementProblem, plan: &mut MovementPlan) {
    repair_with(p, plan, &mut RepairScratch::default());
}

/// Scratch-reusing variant of [`repair`] — bit-identical results; the
/// buffers are fully overwritten per call.
pub fn repair_with(p: &MovementProblem, plan: &mut MovementPlan, ws: &mut RepairScratch) {
    repair_chunked(p, plan, ws, 1, par::CHUNK_ROWS);
}

/// Per-target inbound sums on the current plan, one entry per target with
/// a finite `C_j(t+1)` (others stay 0.0, unused). Each target's sum walks
/// sources ascending — the exact chain of the historical serial
/// `filter().map().sum()` — and targets are independent, so the gather
/// fans out over chunks without reductions.
fn gather_inbound(
    p: &MovementProblem,
    plan: &MovementPlan,
    inbound: &mut [f64],
    threads: usize,
    chunk_rows: usize,
) {
    struct GatherChunk<'a> {
        targets: Range<usize>,
        inb: &'a mut [f64],
    }
    let n = p.n();
    let mut items: Vec<GatherChunk> = Vec::with_capacity(par::num_chunks(n, chunk_rows));
    for (c, inb) in par::split_rows(inbound, 1, chunk_rows).enumerate() {
        items.push(GatherChunk { targets: par::chunk_range(c, n, chunk_rows), inb });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.targets.start;
        for j in it.targets.clone() {
            if p.costs.cap_node_at(p.t + 1, j).is_infinite() {
                it.inb[j - base] = 0.0;
                continue;
            }
            let mut sum = 0.0;
            for i in 0..n {
                if i != j && p.d[i] > 0.0 {
                    sum += plan.s(i, j) * p.d[i];
                }
            }
            it.inb[j - base] = sum;
        }
    });
}

/// Row-parallel variant of [`repair_with`] (DESIGN.md §Perf rule 12).
/// Phases 1 and 3 clamp row-locally and fan out over chunks; phase 2
/// pre-gathers the per-target inbound sums target-parallel (columns are
/// disjoint, so the values match the historical lazy inline sums exactly)
/// and then scales serially in ascending target order, because each
/// scaling mutates sender rows whose excess the redistribution consumes
/// in device order. Phase 4's redistribution mutates shared receiver
/// slacks and stays serial.
pub fn repair_chunked(
    p: &MovementProblem,
    plan: &mut MovementPlan,
    ws: &mut RepairScratch,
    threads: usize,
    chunk_rows: usize,
) {
    struct RowChunk<'a> {
        rows: Range<usize>,
        s: &'a mut [f64],
        excess: &'a mut [f64],
    }
    let n = p.n();
    ws.excess.clear();
    ws.excess.resize(n, 0.0); // displaced fraction per sender

    // --- 1. link capacities -------------------------------------------------
    {
        let mut items: Vec<RowChunk> = Vec::with_capacity(par::num_chunks(n, chunk_rows));
        for ((c, s), excess) in par::split_rows(&mut plan.s, n, chunk_rows)
            .enumerate()
            .zip(par::split_rows(&mut ws.excess, 1, chunk_rows))
        {
            items.push(RowChunk { rows: par::chunk_range(c, n, chunk_rows), s, excess });
        }
        par::run_chunks(threads, &mut items, |_, it| {
            let base = it.rows.start;
            for i in it.rows.clone() {
                if p.d[i] <= 0.0 {
                    continue;
                }
                let li = i - base;
                for j in 0..n {
                    if j == i || it.s[li * n + j] == 0.0 {
                        continue;
                    }
                    let cap = p.costs.cap_link_at(p.t, i, j);
                    let max_frac = if cap.is_infinite() { f64::INFINITY } else { cap / p.d[i] };
                    if it.s[li * n + j] > max_frac {
                        it.excess[li] += it.s[li * n + j] - max_frac;
                        it.s[li * n + j] = max_frac;
                    }
                }
            }
        });
    }

    // --- 2. receiver capacities ---------------------------------------------
    // inbound to j this interval is processed at t+1 and must fit C_j(t+1)
    ws.inbound.clear();
    ws.inbound.resize(n, 0.0);
    gather_inbound(p, plan, &mut ws.inbound, threads, chunk_rows);
    for j in 0..n {
        let cap = p.costs.cap_node_at(p.t + 1, j);
        if cap.is_infinite() {
            continue;
        }
        let inbound = ws.inbound[j];
        if inbound > cap {
            let scale = cap / inbound;
            for i in 0..n {
                if i != j && p.d[i] > 0.0 && plan.s(i, j) > 0.0 {
                    let removed = plan.s(i, j) * (1.0 - scale);
                    ws.excess[i] += removed;
                    plan.set_s(i, j, plan.s(i, j) * scale);
                }
            }
        }
    }

    // --- 3. sender local capacities ------------------------------------------
    {
        let mut items: Vec<RowChunk> = Vec::with_capacity(par::num_chunks(n, chunk_rows));
        for ((c, s), excess) in par::split_rows(&mut plan.s, n, chunk_rows)
            .enumerate()
            .zip(par::split_rows(&mut ws.excess, 1, chunk_rows))
        {
            items.push(RowChunk { rows: par::chunk_range(c, n, chunk_rows), s, excess });
        }
        par::run_chunks(threads, &mut items, |_, it| {
            let base = it.rows.start;
            for i in it.rows.clone() {
                if p.d[i] <= 0.0 {
                    continue;
                }
                let cap = p.costs.cap_node_at(p.t, i);
                if cap.is_infinite() {
                    continue;
                }
                let li = i - base;
                let avail = (cap - p.inbound_prev[i]).max(0.0);
                let max_frac = avail / p.d[i];
                if it.s[li * n + i] > max_frac {
                    it.excess[li] += it.s[li * n + i] - max_frac;
                    it.s[li * n + i] = max_frac;
                }
            }
        });
    }

    // --- 4. redistribute displaced fractions ---------------------------------
    // shared slacks after the clamping above
    gather_inbound(p, plan, &mut ws.inbound, threads, chunk_rows);
    ws.recv_slack.clear();
    let inbound = &ws.inbound;
    ws.recv_slack.extend((0..n).map(|j| {
        let cap = p.costs.cap_node_at(p.t + 1, j);
        if cap.is_infinite() {
            return f64::INFINITY;
        }
        (cap - inbound[j]).max(0.0)
    }));

    for i in 0..n {
        if ws.excess[i] <= 0.0 || p.d[i] <= 0.0 {
            continue;
        }
        let mut remaining = ws.excess[i];

        // option list sorted by marginal cost: (cost, target); the
        // neighbor iterator is consumed directly — no per-device collect
        ws.options.clear();
        ws.options.push((p.process_cost(i), Opt::Process));
        for j in p.active_neighbors(i) {
            ws.options.push((p.offload_cost(i, j), Opt::Offload { j, slot: 0 }));
        }
        ws.options.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        for &(cost, opt) in ws.options.iter() {
            if remaining <= 1e-12 {
                break;
            }
            // anything pricier than discarding goes to discard
            if cost >= p.discard_cost(i) {
                break;
            }
            match opt {
                Opt::Process => {
                    let cap = p.costs.cap_node_at(p.t, i);
                    let slack_frac = if cap.is_infinite() {
                        f64::INFINITY
                    } else {
                        ((cap - p.inbound_prev[i]).max(0.0) / p.d[i] - plan.s(i, i)).max(0.0)
                    };
                    let take = remaining.min(slack_frac);
                    plan.set_s(i, i, plan.s(i, i) + take);
                    remaining -= take;
                }
                Opt::Offload { j, .. } => {
                    let link_cap = p.costs.cap_link_at(p.t, i, j);
                    let link_slack = if link_cap.is_infinite() {
                        f64::INFINITY
                    } else {
                        (link_cap / p.d[i] - plan.s(i, j)).max(0.0)
                    };
                    let recv_frac = if ws.recv_slack[j].is_infinite() {
                        f64::INFINITY
                    } else {
                        ws.recv_slack[j] / p.d[i]
                    };
                    let take = remaining.min(link_slack).min(recv_frac);
                    if take > 0.0 {
                        plan.set_s(i, j, plan.s(i, j) + take);
                        if !ws.recv_slack[j].is_infinite() {
                            ws.recv_slack[j] -= take * p.d[i];
                        }
                        remaining -= take;
                    }
                }
            }
        }
        // whatever could not be placed is discarded
        plan.r[i] += remaining;
    }
}

/// Sparse mirror of [`repair_with`]: same four phases, same float-op
/// sequence, but every scan touches only stored edge slots (O(V + E) per
/// pass instead of O(n²)). Receiver-side sums walk the transpose rows,
/// whose ascending-source order matches the dense `for i in 0..n` loop
/// (off-edge dense terms are `+0.0` no-ops on nonnegative sums), so the
/// repaired sparse plan densifies bit-identically.
pub fn repair_sparse(p: &MovementProblem, sp: &mut SparsePlan, ws: &mut RepairScratch) {
    repair_sparse_chunked(p, sp, ws, 1, par::CHUNK_ROWS);
}

/// Sparse mirror of [`gather_inbound`]: per-target sums via the CSR
/// transpose rows (sources ascending — the historical serial chain).
fn gather_inbound_sparse(
    p: &MovementProblem,
    sp: &SparsePlan,
    inbound: &mut [f64],
    threads: usize,
    chunk_rows: usize,
) {
    struct GatherChunk<'a> {
        targets: Range<usize>,
        inb: &'a mut [f64],
    }
    let n = sp.n;
    let mut items: Vec<GatherChunk> = Vec::with_capacity(par::num_chunks(n, chunk_rows));
    for (c, inb) in par::split_rows(inbound, 1, chunk_rows).enumerate() {
        items.push(GatherChunk { targets: par::chunk_range(c, n, chunk_rows), inb });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.targets.start;
        for j in it.targets.clone() {
            if p.costs.cap_node_at(p.t + 1, j).is_infinite() {
                it.inb[j - base] = 0.0;
                continue;
            }
            let mut sum = 0.0;
            for te in sp.t_offsets[j]..sp.t_offsets[j + 1] {
                let i = sp.t_sources[te];
                if p.d[i] > 0.0 {
                    sum += sp.s_edge[sp.t_slot[te]] * p.d[i];
                }
            }
            it.inb[j - base] = sum;
        }
    });
}

/// Row-parallel variant of [`repair_sparse`] — same phase layout as
/// [`repair_chunked`], over CSR row chunks and transpose gathers.
pub fn repair_sparse_chunked(
    p: &MovementProblem,
    sp: &mut SparsePlan,
    ws: &mut RepairScratch,
    threads: usize,
    chunk_rows: usize,
) {
    struct RowChunk<'a> {
        rows: Range<usize>,
        s_edge: &'a mut [f64],
        excess: &'a mut [f64],
    }
    let n = p.n();
    assert_eq!(sp.n, n, "sparse plan size mismatch");
    ws.excess.clear();
    ws.excess.resize(n, 0.0);

    // --- 1. link capacities -------------------------------------------------
    {
        let offsets = &sp.offsets;
        let targets = &sp.targets;
        let mut items: Vec<RowChunk> = Vec::with_capacity(par::num_chunks(n, chunk_rows));
        for ((c, s_edge), excess) in par::split_csr(&mut sp.s_edge, offsets, n, chunk_rows)
            .into_iter()
            .enumerate()
            .zip(par::split_rows(&mut ws.excess, 1, chunk_rows))
        {
            items.push(RowChunk { rows: par::chunk_range(c, n, chunk_rows), s_edge, excess });
        }
        par::run_chunks(threads, &mut items, |_, it| {
            let base = it.rows.start;
            let ebase = offsets[base];
            for i in it.rows.clone() {
                if p.d[i] <= 0.0 {
                    continue;
                }
                let li = i - base;
                for e in offsets[i]..offsets[i + 1] {
                    if it.s_edge[e - ebase] == 0.0 {
                        continue;
                    }
                    let cap = p.costs.cap_link_at(p.t, i, targets[e]);
                    let max_frac = if cap.is_infinite() { f64::INFINITY } else { cap / p.d[i] };
                    if it.s_edge[e - ebase] > max_frac {
                        it.excess[li] += it.s_edge[e - ebase] - max_frac;
                        it.s_edge[e - ebase] = max_frac;
                    }
                }
            }
        });
    }

    // --- 2. receiver capacities ---------------------------------------------
    ws.inbound.clear();
    ws.inbound.resize(n, 0.0);
    gather_inbound_sparse(p, sp, &mut ws.inbound, threads, chunk_rows);
    for j in 0..n {
        let cap = p.costs.cap_node_at(p.t + 1, j);
        if cap.is_infinite() {
            continue;
        }
        let inbound = ws.inbound[j];
        if inbound > cap {
            let scale = cap / inbound;
            for te in sp.t_offsets[j]..sp.t_offsets[j + 1] {
                let i = sp.t_sources[te];
                let slot = sp.t_slot[te];
                if p.d[i] > 0.0 && sp.s_edge[slot] > 0.0 {
                    let removed = sp.s_edge[slot] * (1.0 - scale);
                    ws.excess[i] += removed;
                    sp.s_edge[slot] *= scale;
                }
            }
        }
    }

    // --- 3. sender local capacities ------------------------------------------
    {
        struct LocalChunk<'a> {
            rows: Range<usize>,
            local: &'a mut [f64],
            excess: &'a mut [f64],
        }
        let mut items: Vec<LocalChunk> = Vec::with_capacity(par::num_chunks(n, chunk_rows));
        for ((c, local), excess) in par::split_rows(&mut sp.local, 1, chunk_rows)
            .enumerate()
            .zip(par::split_rows(&mut ws.excess, 1, chunk_rows))
        {
            items.push(LocalChunk { rows: par::chunk_range(c, n, chunk_rows), local, excess });
        }
        par::run_chunks(threads, &mut items, |_, it| {
            let base = it.rows.start;
            for i in it.rows.clone() {
                if p.d[i] <= 0.0 {
                    continue;
                }
                let cap = p.costs.cap_node_at(p.t, i);
                if cap.is_infinite() {
                    continue;
                }
                let li = i - base;
                let avail = (cap - p.inbound_prev[i]).max(0.0);
                let max_frac = avail / p.d[i];
                if it.local[li] > max_frac {
                    it.excess[li] += it.local[li] - max_frac;
                    it.local[li] = max_frac;
                }
            }
        });
    }

    // --- 4. redistribute displaced fractions ---------------------------------
    gather_inbound_sparse(p, sp, &mut ws.inbound, threads, chunk_rows);
    ws.recv_slack.clear();
    let inbound = &ws.inbound;
    ws.recv_slack.extend((0..n).map(|j| {
        let cap = p.costs.cap_node_at(p.t + 1, j);
        if cap.is_infinite() {
            return f64::INFINITY;
        }
        (cap - inbound[j]).max(0.0)
    }));

    for i in 0..n {
        if ws.excess[i] <= 0.0 || p.d[i] <= 0.0 {
            continue;
        }
        let mut remaining = ws.excess[i];

        ws.options.clear();
        ws.options.push((p.process_cost(i), Opt::Process));
        // same filter as `p.active_neighbors(i)` (active target only), in
        // the same ascending order
        for e in sp.offsets[i]..sp.offsets[i + 1] {
            let j = sp.targets[e];
            if p.active[j] {
                ws.options.push((p.offload_cost(i, j), Opt::Offload { j, slot: e }));
            }
        }
        ws.options.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        for &(cost, opt) in ws.options.iter() {
            if remaining <= 1e-12 {
                break;
            }
            if cost >= p.discard_cost(i) {
                break;
            }
            match opt {
                Opt::Process => {
                    let cap = p.costs.cap_node_at(p.t, i);
                    let slack_frac = if cap.is_infinite() {
                        f64::INFINITY
                    } else {
                        ((cap - p.inbound_prev[i]).max(0.0) / p.d[i] - sp.local[i]).max(0.0)
                    };
                    let take = remaining.min(slack_frac);
                    sp.local[i] += take;
                    remaining -= take;
                }
                Opt::Offload { j, slot } => {
                    let link_cap = p.costs.cap_link_at(p.t, i, j);
                    let link_slack = if link_cap.is_infinite() {
                        f64::INFINITY
                    } else {
                        (link_cap / p.d[i] - sp.s_edge[slot]).max(0.0)
                    };
                    let recv_frac = if ws.recv_slack[j].is_infinite() {
                        f64::INFINITY
                    } else {
                        ws.recv_slack[j] / p.d[i]
                    };
                    let take = remaining.min(link_slack).min(recv_frac);
                    if take > 0.0 {
                        sp.s_edge[slot] += take;
                        if !ws.recv_slack[j].is_infinite() {
                            ws.recv_slack[j] -= take * p.d[i];
                        }
                        remaining -= take;
                    }
                }
            }
        }
        sp.discard[i] += remaining;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::{CapacityMode, CostSchedule};
    use crate::movement::problem::DiscardModel;
    use crate::movement::{convex, greedy};
    use crate::prop::for_all;
    use crate::topology::generators::{erdos_renyi, fully_connected};

    fn base_costs(n: usize) -> CostSchedule {
        let mut costs = CostSchedule::zeros(n, 3);
        for t in 0..3 {
            for i in 0..n {
                costs.compute[t][i] = 0.1 + 0.2 * i as f64;
                costs.error_weight[t][i] = 0.5;
                for j in 0..n {
                    if i != j {
                        costs.link[t][i * n + j] = 0.02;
                    }
                }
            }
        }
        costs
    }

    #[test]
    fn no_op_when_unconstrained() {
        let n = 4;
        let graph = fully_connected(n);
        let costs = base_costs(n);
        let d = vec![10.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let plan = greedy::solve(&p);
        let mut repaired = plan.clone();
        repair(&p, &mut repaired);
        assert_eq!(plan, repaired);
    }

    #[test]
    fn receiver_capacity_spreads_load() {
        // all devices want to offload to cheap device 0, but its capacity
        // only fits part of the load
        let n = 4;
        let graph = fully_connected(n);
        let mut costs = base_costs(n);
        costs.set_capacities(CapacityMode::Uniform(12.0));
        // make device 0 very cheap so everyone targets it
        for t in 0..3 {
            costs.compute[t] = vec![0.01, 0.9, 0.9, 0.9];
        }
        let d = vec![10.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let mut plan = greedy::solve(&p);
        // before repair: 30 units inbound to device 0 > cap 12
        let inbound_before: f64 = (1..n).map(|i| plan.s(i, 0) * d[i]).sum();
        assert!(inbound_before > 12.0);
        repair(&p, &mut plan);
        plan.assert_feasible(&p, 1e-9);
        let inbound_after: f64 = (1..n).map(|i| plan.s(i, 0) * d[i]).sum();
        assert!(inbound_after <= 12.0 + 1e-9);
        // load was spread, not silently dropped from the simplex
        for i in 1..n {
            let row: f64 = plan.r[i] + (0..n).map(|j| plan.s(i, j)).sum::<f64>();
            assert!((row - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn sender_capacity_forces_discard_or_offload() {
        let n = 2;
        let mut costs = base_costs(n);
        costs.set_capacities(CapacityMode::Uniform(4.0));
        // both devices process-favorable, but capacity 4 < d 10
        for t in 0..3 {
            costs.compute[t] = vec![0.1, 0.1];
            costs.error_weight[t] = vec![0.9, 0.9];
        }
        let graph = fully_connected(n);
        let d = vec![10.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let mut plan = greedy::solve(&p);
        repair(&p, &mut plan);
        plan.assert_feasible(&p, 1e-9);
        // each can keep only 0.4 locally; the rest must move or drop
        for i in 0..n {
            assert!(plan.s(i, i) <= 0.4 + 1e-9);
        }
    }

    #[test]
    fn prop_repair_always_feasible() {
        for_all("repair_feasible", 60, |g| {
            let n = g.usize_in(2, 7);
            let graph = erdos_renyi(n, g.f64_in(0.2, 1.0), g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.0, 1.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 1.0);
                        }
                    }
                }
            }
            let cap = g.f64_in(2.0, 15.0);
            costs.set_capacities(CapacityMode::Uniform(cap));
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 25.0)).collect();
            // inbound bounded by capacity (engine invariant: last interval's
            // repaired plan respected the receiver constraint)
            let inbound: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, cap)).collect();
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.85)).collect();
            let restricted = graph.restrict(&active);
            let model = match g.usize_in(0, 2) {
                0 => DiscardModel::LinearR,
                1 => DiscardModel::LinearG,
                _ => DiscardModel::Sqrt,
            };
            let p = MovementProblem {
                t: 0,
                graph: &restricted,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let mut plan = match model {
                DiscardModel::Sqrt => {
                    convex::solve(&p, convex::PgdOptions { iterations: 60, step0: 0.0, tol: 0.0 })
                }
                _ => greedy::solve(&p),
            };
            repair(&p, &mut plan);
            plan.assert_feasible(&p, 1e-6);
        });
    }
}
