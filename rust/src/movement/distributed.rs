//! Distributed implementation of the Theorem-3 movement rule (§IV-B).
//!
//! The paper notes that the closed-form solution (12) "can be implemented
//! distributedly, if each device j sends each of its neighbors i (i) its
//! processing cost c_j(t) and (ii) estimates of c_ij(t)" — no central
//! solver required. This module simulates exactly that message-passing
//! protocol:
//!
//! 1. **Advertise**: every device broadcasts `c_j(t+1)` to its in-neighbors
//!    along with the link-cost estimate `c_ij(t)` for each incoming link.
//! 2. **Decide**: each device compares, purely from its inbox,
//!    `min_k (c_ik + c_k)` against its own `c_i(t)` and `f_i(t)` and picks
//!    the cheapest action (Theorem 3's rule).
//!
//! The result must equal the centralized greedy solver's plan exactly —
//! asserted by the equivalence tests — while exchanging only
//! `O(|E(t)|)` scalar messages per interval.

use crate::movement::greedy;
use crate::movement::plan::MovementPlan;
use crate::movement::problem::MovementProblem;
use crate::movement::sparse::SparsePlan;

/// One advertisement message on link (j -> i's inbox).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Advertisement {
    /// The advertising neighbor.
    pub from: usize,
    /// Its processing cost for the next interval, `c_j(t+1)` (already
    /// model-adjusted: `-f_j(t+1)` folded in under the `-f·G` objective).
    pub neighbor_cost: f64,
    /// The link cost estimate `c_ij(t)` as measured at the receiver.
    pub link_cost: f64,
}

/// Counters the protocol reports (for the message-complexity claim).
#[derive(Debug, Clone, Copy, Default)]
pub struct ProtocolStats {
    pub messages: usize,
    pub deciding_devices: usize,
}

/// Run the two-phase protocol and return (plan, stats).
pub fn solve(p: &MovementProblem) -> (MovementPlan, ProtocolStats) {
    let n = p.n();
    let mut stats = ProtocolStats::default();

    // Phase 1 — advertise: inboxes are built only from per-link messages,
    // never from global state.
    let mut inbox: Vec<Vec<Advertisement>> = vec![Vec::new(); n];
    for j in 0..n {
        if !p.active[j] {
            continue;
        }
        // j advertises to every device i that can offload to it (i -> j edge)
        for &i in p.graph.in_neighbors(j) {
            if !p.active[i] {
                continue;
            }
            inbox[i].push(Advertisement {
                from: j,
                // offload_cost(i, j) = c_ij(t) + c_j(t+1) [- f_j(t+1)];
                // split so the message carries what the paper says it does
                link_cost: p.costs.c_link(p.t, i, j),
                neighbor_cost: p.offload_cost(i, j) - p.costs.c_link(p.t, i, j),
            });
            stats.messages += 1;
        }
    }

    // Phase 2 — decide locally from the inbox.
    let mut plan = MovementPlan::keep_all(n);
    for i in 0..n {
        if !p.active[i] || p.d[i] == 0.0 {
            continue;
        }
        stats.deciding_devices += 1;
        let best = inbox[i]
            .iter()
            .map(|ad| (ad.from, ad.link_cost + ad.neighbor_cost))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let process = p.process_cost(i);
        let discard = p.discard_cost(i);

        plan.set_s(i, i, 0.0);
        match best {
            Some((k, offload)) if offload < process && offload < discard => {
                plan.set_s(i, k, 1.0);
            }
            _ if process <= discard => {
                plan.set_s(i, i, 1.0);
            }
            _ => {
                plan.r[i] = 1.0;
            }
        }
    }
    (plan, stats)
}

/// Sparse mirror of [`solve`]: the decided plan lands in `sp` (structure
/// rebuilt from `p.graph`), and only O(|E(t)|) work is done — no inbox
/// vectors are materialized, because device `i`'s inbox is exactly its
/// active out-neighbors' advertisements in ascending-id order (the dense
/// builder's advertiser loop runs `j = 0..n`), so folding the minimum over
/// the sorted edge row reproduces the same decision including tie-breaks
/// (`min_by` keeps the first minimal element; so does the `c < best` fold).
pub fn solve_sparse(p: &MovementProblem, sp: &mut SparsePlan) -> ProtocolStats {
    sp.rebuild(p.graph);
    let n = p.n();
    let mut stats = ProtocolStats::default();

    // Phase 1 — advertise: one message per active edge, counted per
    // receiver row (identical total to the dense sender-side count).
    for i in 0..n {
        if !p.active[i] {
            continue;
        }
        for e in sp.offsets[i]..sp.offsets[i + 1] {
            if p.active[sp.targets[e]] {
                stats.messages += 1;
            }
        }
    }

    // Phase 2 — decide locally from the (implicit) inbox.
    for i in 0..n {
        if !p.active[i] || p.d[i] == 0.0 {
            continue;
        }
        stats.deciding_devices += 1;
        let mut best: Option<(usize, f64)> = None; // (edge slot, offload cost)
        for e in sp.offsets[i]..sp.offsets[i + 1] {
            let j = sp.targets[e];
            if !p.active[j] {
                continue;
            }
            let c = p.offload_cost(i, j);
            let better = match best {
                None => true,
                Some((_, bc)) => c < bc,
            };
            if better {
                best = Some((e, c));
            }
        }
        let process = p.process_cost(i);
        let discard = p.discard_cost(i);

        sp.local[i] = 0.0;
        match best {
            Some((slot, offload)) if offload < process && offload < discard => {
                sp.s_edge[slot] = 1.0;
            }
            _ if process <= discard => {
                sp.local[i] = 1.0;
            }
            _ => {
                sp.discard[i] = 1.0;
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::movement::problem::DiscardModel;
    use crate::prop::for_all;
    use crate::topology::generators::erdos_renyi;

    /// Property: the distributed protocol computes exactly the centralized
    /// greedy plan, for both linear objectives, on random instances.
    #[test]
    fn prop_distributed_equals_centralized() {
        for_all("distributed_eq_greedy", 80, |g| {
            let n = g.usize_in(2, 9);
            let graph = erdos_renyi(n, g.f64_in(0.0, 1.0), g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.0, 1.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 1.0);
                        }
                    }
                }
            }
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 15.0)).collect();
            let inbound = vec![0.0; n];
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.85)).collect();
            let restricted = graph.restrict(&active);
            let model = if g.bool(0.5) { DiscardModel::LinearR } else { DiscardModel::LinearG };
            let p = MovementProblem {
                t: 0,
                graph: &restricted,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let central = greedy::solve(&p);
            let (dist, stats) = solve(&p);
            assert_eq!(central, dist, "protocol diverged from Theorem 3");
            // message complexity: exactly one message per active edge
            let active_edges = restricted
                .edges()
                .filter(|&(i, j)| active[i] && active[j])
                .count();
            assert_eq!(stats.messages, active_edges);
        });
    }

    /// Property: the sparse protocol produces the same plan and the same
    /// message counts as the dense one — over the base graph + mask (no
    /// `restrict`), which is how the engine's sparse path runs it.
    #[test]
    fn prop_sparse_protocol_equals_dense() {
        let mut sp = crate::movement::sparse::SparsePlan::empty();
        for_all("distributed_sparse_eq_dense", 60, |g| {
            let n = g.usize_in(2, 9);
            let graph = erdos_renyi(n, g.f64_in(0.0, 1.0), g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.0, 1.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 1.0);
                        }
                    }
                }
            }
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 15.0)).collect();
            let inbound = vec![0.0; n];
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.85)).collect();
            let model = if g.bool(0.5) { DiscardModel::LinearR } else { DiscardModel::LinearG };
            let p = MovementProblem {
                t: 0,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let (dense, dense_stats) = solve(&p);
            let sparse_stats = solve_sparse(&p, &mut sp);
            assert_eq!(sp.to_dense(), dense, "sparse protocol diverged");
            assert_eq!(sparse_stats.messages, dense_stats.messages);
            assert_eq!(sparse_stats.deciding_devices, dense_stats.deciding_devices);
        });
    }

    #[test]
    fn message_counts_on_known_graph() {
        let n = 4;
        let graph = crate::topology::generators::fully_connected(n);
        let costs = CostSchedule::zeros(n, 2);
        let d = vec![1.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        let p = MovementProblem {
            t: 0,
            graph: &graph,
            active: &active,
            d: &d,
            inbound_prev: &inbound,
            costs: &costs,
            discard_model: DiscardModel::LinearR,
        };
        let (_, stats) = solve(&p);
        assert_eq!(stats.messages, n * (n - 1));
        assert_eq!(stats.deciding_devices, n);
    }
}
