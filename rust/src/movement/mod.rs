//! The paper's core contribution: the data-movement optimization (eqs. 5–9).
//!
//! Every time interval, each device decides per collected datapoint whether
//! to **process** it locally (`s_ii`), **offload** it to a neighbor
//! (`s_ij`), or **discard** it (`r_i`), trading off processing cost
//! `c_i(t)`, link cost `c_ij(t)` and the error (discard) cost weighted by
//! `f_i(t)`.
//!
//! * [`problem`] — the per-interval problem instance and discard-cost models.
//! * [`plan`] — the decision variables, feasibility checks, cost evaluation.
//! * [`greedy`] — Theorem 3's closed-form optimal solution for linear
//!   discard costs (and the `-f·G` variant via modified link costs).
//! * [`convex`] — projected-gradient solver for the convex `f/√G` model.
//! * [`repair`] — capacity-constraint repair pass (§IV-B's "minimal
//!   adjustment" procedure justified by Theorem 6).
//! * [`theory`] — closed forms of Theorems 4, 5, 6 + their validators.

pub mod convex;
pub mod distributed;
pub mod greedy;
pub mod plan;
pub mod problem;
pub mod repair;
pub mod theory;

pub use plan::{CostBreakdown, MovementPlan};
pub use problem::{DiscardModel, MovementProblem};

/// Solve a problem instance with the solver matching its discard model,
/// then repair capacity violations. This is the entry point the federated
/// engine calls once per interval.
pub fn solve(p: &MovementProblem) -> MovementPlan {
    let mut plan = match p.discard_model {
        DiscardModel::LinearR | DiscardModel::LinearG => greedy::solve(p),
        DiscardModel::Sqrt => convex::solve(p, convex::PgdOptions::default()),
    };
    repair::repair(p, &mut plan);
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::topology::generators::fully_connected;

    #[test]
    fn solve_dispatches_and_is_feasible() {
        let n = 6;
        let graph = fully_connected(n);
        let mut costs = CostSchedule::zeros(n, 4);
        for t in 0..4 {
            for i in 0..n {
                costs.compute[t][i] = 0.1 * (i + 1) as f64;
                costs.error_weight[t][i] = 0.35;
                for j in 0..n {
                    if i != j {
                        costs.link[t][i * n + j] = 0.05;
                    }
                }
            }
        }
        let d = vec![8.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        for model in [DiscardModel::LinearR, DiscardModel::LinearG, DiscardModel::Sqrt] {
            let p = MovementProblem {
                t: 1,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let plan = solve(&p);
            plan.assert_feasible(&p, 1e-6);
        }
    }
}
