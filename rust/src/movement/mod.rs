//! The paper's core contribution: the data-movement optimization (eqs. 5–9).
//!
//! Every time interval, each device decides per collected datapoint whether
//! to **process** it locally (`s_ii`), **offload** it to a neighbor
//! (`s_ij`), or **discard** it (`r_i`), trading off processing cost
//! `c_i(t)`, link cost `c_ij(t)` and the error (discard) cost weighted by
//! `f_i(t)`.
//!
//! * [`problem`] — the per-interval problem instance and discard-cost models.
//! * [`plan`] — the dense decision variables, feasibility checks, cost
//!   evaluation.
//! * [`sparse`] — edge-indexed plans (O(V + E) storage) for large sparse
//!   topologies; bit-identical to dense under `to_dense`.
//! * [`greedy`] — Theorem 3's closed-form optimal solution for linear
//!   discard costs (and the `-f·G` variant via modified link costs).
//! * [`convex`] — projected-gradient solver for the convex `f/√G` model.
//! * [`repair`] — capacity-constraint repair pass (§IV-B's "minimal
//!   adjustment" procedure justified by Theorem 6).
//! * [`par`] — the fixed-chunk row-parallel execution layer every solver
//!   pass runs on (DESIGN.md §Perf rule 12).
//! * [`theory`] — closed forms of Theorems 4, 5, 6 + their validators.
//!
//! Both [`solve_with`] (dense) and [`solve_sparse_with`] (edge-indexed)
//! produce the same plan bitwise for the same instance; the engine picks
//! per [`crate::config::MovementBackend`]. Plans are also bit-invariant
//! to [`SolverWorkspace::solver_threads`]: chunk geometry depends on n
//! only and reductions combine per-chunk partials in ascending order.

pub mod convex;
pub mod distributed;
pub mod greedy;
pub mod par;
pub mod plan;
pub mod problem;
pub mod repair;
pub mod sparse;
pub mod theory;

pub use plan::{CostBreakdown, MovementPlan};
pub use problem::{DiscardModel, MovementProblem};
pub use sparse::SparsePlan;

/// Reusable scratch for the per-interval solvers. The engine solves one
/// movement problem per time interval; routing every solve through one
/// workspace keeps the hot path free of the ~`n²`-sized (dense) or
/// `O(V + E)`-sized (sparse) allocations the solvers would otherwise make
/// per call (plan rows, PGD gradients, projection buffers, repair slacks —
/// DESIGN.md §Perf).
///
/// All buffers are zeroed or fully overwritten per solve, so reuse is
/// bit-identical to fresh allocation.
///
/// With `warm_start` set (off by default — DESIGN.md §Perf rule 11), the
/// workspace additionally remembers the previous interval's solution and
/// the PGD solver starts from it (reprojected onto the new active set)
/// instead of the greedy vertex. Greedy solves are closed-form and ignore
/// the starting point, so warm starts only affect the `Sqrt` model.
#[derive(Debug)]
pub struct SolverWorkspace {
    /// The most recent dense solution (valid after [`solve_with`]).
    pub plan: MovementPlan,
    /// The most recent sparse solution (valid after [`solve_sparse_with`]).
    pub sparse: SparsePlan,
    /// Opt-in warm starting (set from `EngineConfig::warm_start`).
    pub warm_start: bool,
    /// Resolved worker count for the row-parallel solver passes (set from
    /// `EngineConfig::solver_threads` via `SolverThreads::resolve`;
    /// 1 = serial). Plans are **bit-invariant** to this knob — DESIGN.md
    /// §Perf rule 12.
    pub solver_threads: usize,
    /// Rows per reduction chunk. Defaults to [`par::CHUNK_ROWS`] and must
    /// stay there in production (chunk geometry is a function of n only);
    /// tests override it to force multi-chunk reductions at small n.
    /// Changing it changes float-addition association — and therefore
    /// bits — while `solver_threads` never does.
    pub chunk_rows: usize,
    /// Best-iterate tracking buffer for the PGD solver.
    pub(crate) best: MovementPlan,
    pub(crate) sparse_best: SparsePlan,
    /// ∂F/∂s gradient buffers (dense n×n / per-edge + per-device).
    pub(crate) grad_s: Vec<f64>,
    pub(crate) grad_edge: Vec<f64>,
    pub(crate) grad_local: Vec<f64>,
    /// G̃ accumulator for the convex objective gradient.
    pub(crate) g_tilde: Vec<f64>,
    /// This-interval inbound accumulator for the fused objective pass.
    pub(crate) inbound_now: Vec<f64>,
    /// Per-chunk objective partial sums (combined ascending).
    pub(crate) partials: Vec<f64>,
    /// Per-chunk simplex-projection scratch.
    pub(crate) proj: Vec<par::ProjBuffers>,
    /// Capacity-repair scratch (excess/slack/option buffers).
    pub(crate) repair: repair::RepairScratch,
    /// Previous interval's solutions for warm starts.
    pub(crate) prev: MovementPlan,
    pub(crate) prev_valid: bool,
    pub(crate) prev_sparse: SparsePlan,
    pub(crate) prev_sparse_valid: bool,
}

impl SolverWorkspace {
    pub fn new() -> SolverWorkspace {
        SolverWorkspace {
            plan: MovementPlan::keep_all(0),
            sparse: SparsePlan::empty(),
            warm_start: false,
            solver_threads: 1,
            chunk_rows: par::CHUNK_ROWS,
            best: MovementPlan::keep_all(0),
            sparse_best: SparsePlan::empty(),
            grad_s: Vec::new(),
            grad_edge: Vec::new(),
            grad_local: Vec::new(),
            g_tilde: Vec::new(),
            inbound_now: Vec::new(),
            partials: Vec::new(),
            proj: Vec::new(),
            repair: repair::RepairScratch::default(),
            prev: MovementPlan::keep_all(0),
            prev_valid: false,
            prev_sparse: SparsePlan::empty(),
            prev_sparse_valid: false,
        }
    }

    /// Forget any remembered previous solution (e.g. between independent
    /// runs sharing one workspace).
    pub fn reset_warm_state(&mut self) {
        self.prev_valid = false;
        self.prev_sparse_valid = false;
    }

    /// Size the per-chunk buffers for an `n`-row solve: the objective
    /// partials and one projection-scratch set per chunk.
    pub(crate) fn ensure_chunks(&mut self, n: usize) {
        let nc = par::num_chunks(n, self.chunk_rows);
        self.partials.clear();
        self.partials.resize(nc, 0.0);
        if self.proj.len() < nc {
            self.proj.resize_with(nc, par::ProjBuffers::default);
        }
    }
}

impl Default for SolverWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// Solve a problem instance with the solver matching its discard model,
/// then repair capacity violations. This is the entry point the federated
/// engine calls once per interval.
pub fn solve(p: &MovementProblem) -> MovementPlan {
    let mut ws = SolverWorkspace::new();
    solve_with(p, &mut ws);
    ws.plan
}

/// Workspace-reusing variant of [`solve`]: the solution lands in
/// `ws.plan` (already capacity-repaired).
pub fn solve_with(p: &MovementProblem, ws: &mut SolverWorkspace) {
    match p.discard_model {
        DiscardModel::LinearR | DiscardModel::LinearG => {
            greedy::solve_into_chunked(p, &mut ws.plan, ws.solver_threads, ws.chunk_rows)
        }
        DiscardModel::Sqrt => convex::solve_with(p, convex::PgdOptions::default(), ws),
    }
    repair::repair_chunked(p, &mut ws.plan, &mut ws.repair, ws.solver_threads, ws.chunk_rows);
    if ws.warm_start {
        ws.prev.clone_from(&ws.plan);
        ws.prev_valid = true;
    }
}

/// Edge-indexed mirror of [`solve_with`]: the solution lands in
/// `ws.sparse` (already capacity-repaired). For the same instance this
/// produces exactly `solve_with`'s plan under [`SparsePlan::to_dense`] —
/// see the bit-identity contract in [`sparse`]'s module docs — while doing
/// O(V + E) work and storage per interval instead of O(n²).
pub fn solve_sparse_with(p: &MovementProblem, ws: &mut SolverWorkspace) {
    match p.discard_model {
        DiscardModel::LinearR | DiscardModel::LinearG => {
            greedy::solve_sparse_into_chunked(p, &mut ws.sparse, ws.solver_threads, ws.chunk_rows)
        }
        DiscardModel::Sqrt => convex::solve_sparse_with(p, convex::PgdOptions::default(), ws),
    }
    repair::repair_sparse_chunked(
        p,
        &mut ws.sparse,
        &mut ws.repair,
        ws.solver_threads,
        ws.chunk_rows,
    );
    if ws.warm_start {
        ws.prev_sparse.clone_from(&ws.sparse);
        ws.prev_sparse_valid = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::topology::generators::{erdos_renyi, fully_connected};
    use crate::util::rng::Rng;

    #[test]
    fn solve_dispatches_and_is_feasible() {
        let n = 6;
        let graph = fully_connected(n);
        let mut costs = CostSchedule::zeros(n, 4);
        for t in 0..4 {
            for i in 0..n {
                costs.compute[t][i] = 0.1 * (i + 1) as f64;
                costs.error_weight[t][i] = 0.35;
                for j in 0..n {
                    if i != j {
                        costs.link[t][i * n + j] = 0.05;
                    }
                }
            }
        }
        let d = vec![8.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        for model in [DiscardModel::LinearR, DiscardModel::LinearG, DiscardModel::Sqrt] {
            let p = MovementProblem {
                t: 1,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let plan = solve(&p);
            plan.assert_feasible(&p, 1e-6);
        }
    }

    /// A shared workspace must produce bit-identical plans to fresh
    /// allocation, across solves of different sizes and models (the engine
    /// reuses one workspace for a whole run).
    #[test]
    fn workspace_reuse_matches_fresh_solve() {
        let mut ws = SolverWorkspace::new();
        for (n, model) in [
            (6, DiscardModel::Sqrt),
            (3, DiscardModel::LinearR),
            (5, DiscardModel::LinearG),
            (6, DiscardModel::Sqrt),
        ] {
            let graph = fully_connected(n);
            let mut costs = CostSchedule::zeros(n, 4);
            for t in 0..4 {
                for i in 0..n {
                    costs.compute[t][i] = 0.07 * (i + 1) as f64;
                    costs.error_weight[t][i] = 0.4;
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = 0.03 + 0.01 * j as f64;
                        }
                    }
                }
            }
            let d = vec![7.0; n];
            let inbound = vec![1.0; n];
            let active = vec![true; n];
            let p = MovementProblem {
                t: 1,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let fresh = solve(&p);
            solve_with(&p, &mut ws);
            assert_eq!(fresh, ws.plan, "n={n} model={model:?}");
        }
    }

    /// The sparse entry point must agree with the dense one bitwise, with
    /// and without capacities, across all three models.
    #[test]
    fn sparse_solve_matches_dense_solve() {
        let mut rng = Rng::new(11);
        let mut ws = SolverWorkspace::new();
        for model in [DiscardModel::LinearR, DiscardModel::LinearG, DiscardModel::Sqrt] {
            let n = 8;
            let graph = erdos_renyi(n, 0.45, &mut rng);
            let mut costs = CostSchedule::zeros(n, 3);
            for t in 0..3 {
                for i in 0..n {
                    costs.compute[t][i] = 0.05 + 0.04 * i as f64;
                    costs.error_weight[t][i] = 0.45;
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = 0.02 + 0.015 * j as f64;
                        }
                    }
                }
            }
            let d: Vec<f64> = (0..n).map(|i| 2.0 + i as f64).collect();
            let inbound = vec![0.3; n];
            let active: Vec<bool> = (0..n).map(|i| i != 2).collect();
            let p = MovementProblem {
                t: 0,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let dense = solve(&p);
            solve_sparse_with(&p, &mut ws);
            assert_eq!(ws.sparse.to_dense(), dense, "{model:?}");
        }
    }

    /// Warm-started PGD still returns a feasible plan and the warm state
    /// machinery only engages when the flag is set.
    #[test]
    fn warm_start_stays_feasible_and_is_opt_in() {
        let n = 6;
        let graph = fully_connected(n);
        let mut costs = CostSchedule::zeros(n, 5);
        for t in 0..5 {
            for i in 0..n {
                costs.compute[t][i] = 0.1 + 0.06 * i as f64;
                costs.error_weight[t][i] = 0.5;
                for j in 0..n {
                    if i != j {
                        costs.link[t][i * n + j] = 0.04;
                    }
                }
            }
        }
        let d = vec![6.0; n];
        let inbound = vec![0.0; n];

        let mut cold = SolverWorkspace::new();
        let mut warm = SolverWorkspace::new();
        warm.warm_start = true;
        for t in 0..3 {
            // churn: one device drops out at t = 1, returns at t = 2
            let active: Vec<bool> = (0..n).map(|i| !(t == 1 && i == 3)).collect();
            let p = MovementProblem {
                t,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: DiscardModel::Sqrt,
            };
            solve_with(&p, &mut cold);
            solve_with(&p, &mut warm);
            warm.plan.assert_feasible(&p, 1e-6);
            assert!(!cold.prev_valid, "warm state must stay off by default");
            assert!(warm.prev_valid);
        }
        // first interval has no previous plan -> both start cold and agree
        // (checked implicitly: warm.prev_valid only flips after a solve)
    }
}
