//! The paper's core contribution: the data-movement optimization (eqs. 5–9).
//!
//! Every time interval, each device decides per collected datapoint whether
//! to **process** it locally (`s_ii`), **offload** it to a neighbor
//! (`s_ij`), or **discard** it (`r_i`), trading off processing cost
//! `c_i(t)`, link cost `c_ij(t)` and the error (discard) cost weighted by
//! `f_i(t)`.
//!
//! * [`problem`] — the per-interval problem instance and discard-cost models.
//! * [`plan`] — the decision variables, feasibility checks, cost evaluation.
//! * [`greedy`] — Theorem 3's closed-form optimal solution for linear
//!   discard costs (and the `-f·G` variant via modified link costs).
//! * [`convex`] — projected-gradient solver for the convex `f/√G` model.
//! * [`repair`] — capacity-constraint repair pass (§IV-B's "minimal
//!   adjustment" procedure justified by Theorem 6).
//! * [`theory`] — closed forms of Theorems 4, 5, 6 + their validators.

pub mod convex;
pub mod distributed;
pub mod greedy;
pub mod plan;
pub mod problem;
pub mod repair;
pub mod theory;

pub use plan::{CostBreakdown, MovementPlan};
pub use problem::{DiscardModel, MovementProblem};

/// Reusable scratch for the per-interval solvers. The engine solves one
/// movement problem per time interval; routing every solve through one
/// workspace keeps the hot path free of the ~`n²`-sized allocations the
/// solvers would otherwise make per call (plan rows, PGD gradients,
/// projection buffers — DESIGN.md §Perf).
///
/// All buffers are zeroed or fully overwritten per solve, so reuse is
/// bit-identical to fresh allocation.
#[derive(Debug)]
pub struct SolverWorkspace {
    /// The most recent solution (valid after [`solve_with`]).
    pub plan: MovementPlan,
    /// Best-iterate tracking buffer for the PGD solver.
    pub(crate) best: MovementPlan,
    /// ∂F/∂s gradient buffer (n×n).
    pub(crate) grad_s: Vec<f64>,
    /// G̃ accumulator for the convex objective gradient.
    pub(crate) g_tilde: Vec<f64>,
    /// Free-coordinate gathering for per-row simplex projection.
    pub(crate) coords: Vec<(Option<usize>, f64)>,
    pub(crate) values: Vec<f64>,
    pub(crate) projected: Vec<f64>,
    pub(crate) scratch: Vec<f64>,
}

impl SolverWorkspace {
    pub fn new() -> SolverWorkspace {
        SolverWorkspace {
            plan: MovementPlan::keep_all(0),
            best: MovementPlan::keep_all(0),
            grad_s: Vec::new(),
            g_tilde: Vec::new(),
            coords: Vec::new(),
            values: Vec::new(),
            projected: Vec::new(),
            scratch: Vec::new(),
        }
    }
}

impl Default for SolverWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// Solve a problem instance with the solver matching its discard model,
/// then repair capacity violations. This is the entry point the federated
/// engine calls once per interval.
pub fn solve(p: &MovementProblem) -> MovementPlan {
    let mut ws = SolverWorkspace::new();
    solve_with(p, &mut ws);
    ws.plan
}

/// Workspace-reusing variant of [`solve`]: the solution lands in
/// `ws.plan` (already capacity-repaired).
pub fn solve_with(p: &MovementProblem, ws: &mut SolverWorkspace) {
    match p.discard_model {
        DiscardModel::LinearR | DiscardModel::LinearG => greedy::solve_into(p, &mut ws.plan),
        DiscardModel::Sqrt => convex::solve_with(p, convex::PgdOptions::default(), ws),
    }
    repair::repair(p, &mut ws.plan);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::topology::generators::fully_connected;

    #[test]
    fn solve_dispatches_and_is_feasible() {
        let n = 6;
        let graph = fully_connected(n);
        let mut costs = CostSchedule::zeros(n, 4);
        for t in 0..4 {
            for i in 0..n {
                costs.compute[t][i] = 0.1 * (i + 1) as f64;
                costs.error_weight[t][i] = 0.35;
                for j in 0..n {
                    if i != j {
                        costs.link[t][i * n + j] = 0.05;
                    }
                }
            }
        }
        let d = vec![8.0; n];
        let inbound = vec![0.0; n];
        let active = vec![true; n];
        for model in [DiscardModel::LinearR, DiscardModel::LinearG, DiscardModel::Sqrt] {
            let p = MovementProblem {
                t: 1,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let plan = solve(&p);
            plan.assert_feasible(&p, 1e-6);
        }
    }

    /// A shared workspace must produce bit-identical plans to fresh
    /// allocation, across solves of different sizes and models (the engine
    /// reuses one workspace for a whole run).
    #[test]
    fn workspace_reuse_matches_fresh_solve() {
        let mut ws = SolverWorkspace::new();
        for (n, model) in [
            (6, DiscardModel::Sqrt),
            (3, DiscardModel::LinearR),
            (5, DiscardModel::LinearG),
            (6, DiscardModel::Sqrt),
        ] {
            let graph = fully_connected(n);
            let mut costs = CostSchedule::zeros(n, 4);
            for t in 0..4 {
                for i in 0..n {
                    costs.compute[t][i] = 0.07 * (i + 1) as f64;
                    costs.error_weight[t][i] = 0.4;
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = 0.03 + 0.01 * j as f64;
                        }
                    }
                }
            }
            let d = vec![7.0; n];
            let inbound = vec![1.0; n];
            let active = vec![true; n];
            let p = MovementProblem {
                t: 1,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let fresh = solve(&p);
            solve_with(&p, &mut ws);
            assert_eq!(fresh, ws.plan, "n={n} model={model:?}");
        }
    }
}
