//! Theorem 3: closed-form optimal movement under linear discard costs.
//!
//! With the error cost `f_i(t) D_i(t) r_i(t)` (and no binding capacities),
//! the optimum is integral: each device sends *all* of its collected data to
//! whichever option has the least marginal cost —
//!
//! ```text
//! s*_ik = 1  if c_ik(t) + c_k(t+1) ≤ min{ f_i(t), c_i(t) }
//! s*_ii = 1  if c_i(t)             ≤ min{ f_i(t), c_ik(t) + c_k(t+1) }
//! r*_i  = 1  if f_i(t)             ≤ min{ c_i(t), c_ik(t) + c_k(t+1) }
//! k = argmin_{j : (i,j) ∈ E(t)} { c_ij(t) + c_j(t+1) }
//! ```
//!
//! The `-f·G` model reduces to the same rule with modified marginal costs
//! (§IV-A2), which [`MovementProblem::process_cost`] etc. already encode.
//! Ties break process > offload > discard, matching the paper's preference
//! for keeping data when indifferent.

use crate::util::par;
use crate::movement::plan::MovementPlan;
use crate::movement::problem::MovementProblem;
use crate::movement::sparse::SparsePlan;
use std::ops::Range;

/// Solve by the Theorem-3 rule. Inactive devices (or devices with no data)
/// get `s_ii = 1` rows, which is vacuous since `D_i(t) = 0`.
pub fn solve(p: &MovementProblem) -> MovementPlan {
    let mut plan = MovementPlan::keep_all(p.n());
    solve_into(p, &mut plan);
    plan
}

/// In-place variant for workspace reuse: `plan` is reset to keep-all and
/// then filled exactly as [`solve`] would.
pub fn solve_into(p: &MovementProblem, plan: &mut MovementPlan) {
    solve_into_chunked(p, plan, 1, par::CHUNK_ROWS);
}

/// Row-parallel variant of [`solve_into`]. Each device's decision is a
/// closed form of its own costs — rows never interact — so fanning chunks
/// across workers is trivially bit-invariant to `threads` (DESIGN.md
/// §Perf rule 12).
pub fn solve_into_chunked(
    p: &MovementProblem,
    plan: &mut MovementPlan,
    threads: usize,
    chunk_rows: usize,
) {
    struct RowChunk<'a> {
        rows: Range<usize>,
        s: &'a mut [f64],
        r: &'a mut [f64],
    }
    let n = p.n();
    plan.reset_keep_all(n);
    let mut items: Vec<RowChunk> = Vec::with_capacity(par::num_chunks(n, chunk_rows));
    for ((c, s), r) in par::split_rows(&mut plan.s, n, chunk_rows)
        .enumerate()
        .zip(par::split_rows(&mut plan.r, 1, chunk_rows))
    {
        items.push(RowChunk { rows: par::chunk_range(c, n, chunk_rows), s, r });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.rows.start;
        for i in it.rows.clone() {
            if !p.active[i] || p.d[i] == 0.0 {
                continue;
            }
            let li = i - base;
            let process = p.process_cost(i);
            let discard = p.discard_cost(i);
            let best = p.best_neighbor(i);

            it.s[li * n + i] = 0.0;
            match best {
                Some((k, offload)) if offload < process && offload < discard => {
                    it.s[li * n + k] = 1.0;
                }
                _ if process <= discard => {
                    it.s[li * n + i] = 1.0;
                }
                _ => {
                    it.r[li] = 1.0;
                }
            }
        }
    });
}

/// Sparse mirror of [`solve_into`]: rebuilds `sp`'s structure from
/// `p.graph` and applies the Theorem-3 rule per device touching only that
/// device's edge row — O(V + E) total, no n² scan.
///
/// `p.best_neighbor` iterates the graph's sorted out-neighbor slice, which
/// is exactly the sparse row order, so tie-breaks are identical and
/// `sp.to_dense()` equals [`solve`]'s plan bitwise.
pub fn solve_sparse_into(p: &MovementProblem, sp: &mut SparsePlan) {
    solve_sparse_into_chunked(p, sp, 1, par::CHUNK_ROWS);
}

/// Row-parallel variant of [`solve_sparse_into`] over CSR row chunks.
pub fn solve_sparse_into_chunked(
    p: &MovementProblem,
    sp: &mut SparsePlan,
    threads: usize,
    chunk_rows: usize,
) {
    struct SparseRowChunk<'a> {
        rows: Range<usize>,
        s_edge: &'a mut [f64],
        local: &'a mut [f64],
        discard: &'a mut [f64],
    }
    sp.rebuild(p.graph);
    let n = p.n();
    let offsets = &sp.offsets;
    let targets = &sp.targets;
    let mut items: Vec<SparseRowChunk> = Vec::with_capacity(par::num_chunks(n, chunk_rows));
    for (((c, s_edge), local), discard) in par::split_csr(&mut sp.s_edge, offsets, n, chunk_rows)
        .into_iter()
        .enumerate()
        .zip(par::split_rows(&mut sp.local, 1, chunk_rows))
        .zip(par::split_rows(&mut sp.discard, 1, chunk_rows))
    {
        items.push(SparseRowChunk {
            rows: par::chunk_range(c, n, chunk_rows),
            s_edge,
            local,
            discard,
        });
    }
    par::run_chunks(threads, &mut items, |_, it| {
        let base = it.rows.start;
        let ebase = offsets[base];
        for i in it.rows.clone() {
            if !p.active[i] || p.d[i] == 0.0 {
                continue;
            }
            let li = i - base;
            let process = p.process_cost(i);
            let discard = p.discard_cost(i);
            let best = p.best_neighbor(i);

            it.local[li] = 0.0;
            match best {
                Some((k, offload)) if offload < process && offload < discard => {
                    let slot = offsets[i]
                        + targets[offsets[i]..offsets[i + 1]]
                            .binary_search(&k)
                            .expect("best neighbor must be an edge");
                    it.s_edge[slot - ebase] = 1.0;
                }
                _ if process <= discard => {
                    it.local[li] = 1.0;
                }
                _ => {
                    it.discard[li] = 1.0;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::movement::problem::DiscardModel;
    use crate::prop::for_all;
    use crate::topology::generators::{erdos_renyi, fully_connected};
    use crate::topology::Graph;

    struct Fixture {
        graph: Graph,
        costs: CostSchedule,
        d: Vec<f64>,
        inbound: Vec<f64>,
        active: Vec<bool>,
    }

    impl Fixture {
        fn problem(&self, model: DiscardModel) -> MovementProblem<'_> {
            MovementProblem {
                t: 0,
                graph: &self.graph,
                active: &self.active,
                d: &self.d,
                inbound_prev: &self.inbound,
                costs: &self.costs,
                discard_model: model,
            }
        }
    }

    fn fixture(n: usize) -> Fixture {
        Fixture {
            graph: fully_connected(n),
            costs: CostSchedule::zeros(n, 2),
            d: vec![5.0; n],
            inbound: vec![0.0; n],
            active: vec![true; n],
        }
    }

    #[test]
    fn processes_when_cheapest() {
        let mut f = fixture(2);
        f.costs.compute[0] = vec![0.1, 0.9];
        f.costs.compute[1] = vec![0.1, 0.9];
        f.costs.error_weight[0] = vec![0.5, 0.5];
        for t in 0..2 {
            f.costs.link[t][1] = 0.3; // 0 -> 1
            f.costs.link[t][2] = 0.3; // 1 -> 0
        }
        let plan = solve(&f.problem(DiscardModel::LinearR));
        // device 0: process (0.1) < offload (0.3+0.9) and < discard (0.5)
        assert_eq!(plan.s(0, 0), 1.0);
        // device 1: offload to 0 (0.3+0.1=0.4) < process 0.9, < discard 0.5
        assert_eq!(plan.s(1, 0), 1.0);
        assert_eq!(plan.r[1], 0.0);
    }

    #[test]
    fn discards_when_everything_expensive() {
        let mut f = fixture(2);
        f.costs.compute[0] = vec![0.9, 0.95];
        f.costs.compute[1] = vec![0.9, 0.95];
        f.costs.error_weight[0] = vec![0.1, 0.1];
        for t in 0..2 {
            f.costs.link[t][1] = 0.8;
            f.costs.link[t][2] = 0.8;
        }
        let plan = solve(&f.problem(DiscardModel::LinearR));
        assert_eq!(plan.r, vec![1.0, 1.0]);
    }

    #[test]
    fn linear_g_never_discards_when_f_dominates() {
        // -f·G: discard marginal cost 0, process c - f < 0 when f > c
        let mut f = fixture(3);
        for t in 0..2 {
            f.costs.compute[t] = vec![0.8, 0.8, 0.8];
            f.costs.error_weight[t] = vec![0.9, 0.9, 0.9];
            for i in 0..3 {
                for j in 0..3 {
                    if i != j {
                        f.costs.link[t][i * 3 + j] = 0.9;
                    }
                }
            }
        }
        let plan = solve(&f.problem(DiscardModel::LinearG));
        for i in 0..3 {
            assert_eq!(plan.r[i], 0.0, "device {i} discarded despite f > c");
            assert_eq!(plan.s(i, i), 1.0);
        }
        // same costs under LinearR: discard (f=0.9) loses to process (0.8)
        let plan_r = solve(&f.problem(DiscardModel::LinearR));
        for i in 0..3 {
            assert_eq!(plan_r.s(i, i), 1.0);
        }
    }

    #[test]
    fn inactive_devices_do_nothing() {
        let mut f = fixture(3);
        f.active = vec![true, false, true];
        f.costs.compute[0] = vec![0.9, 0.0, 0.5];
        f.costs.compute[1] = vec![0.9, 0.0, 0.5];
        f.costs.error_weight[0] = vec![0.95; 3];
        // device 1 would be the best target but is inactive
        let plan = solve(&f.problem(DiscardModel::LinearR));
        assert_eq!(plan.s(0, 1), 0.0);
        assert_eq!(plan.s(0, 2), 1.0); // falls back to device 2 (0 link cost + 0.5)
    }

    /// Property: on random instances, the greedy plan is optimal among all
    /// *integral single-choice* plans (which Theorem 3 proves is the global
    /// optimum for linear discard costs without capacities) — verified by
    /// brute force per device.
    #[test]
    fn prop_greedy_beats_every_single_choice_plan() {
        for_all("greedy_optimal", 60, |g| {
            let n = g.usize_in(2, 6);
            let rho = g.f64_in(0.2, 1.0);
            let graph = erdos_renyi(n, rho, g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.0, 1.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 1.0);
                        }
                    }
                }
            }
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(1.0, 20.0)).collect();
            let inbound = vec![0.0; n];
            let active = vec![true; n];
            let p = MovementProblem {
                t: 0,
                graph: &graph,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: DiscardModel::LinearR,
            };
            let greedy_plan = solve(&p);
            let greedy_obj = greedy_plan.objective(&p);

            // brute force: every per-device integral choice
            for i in 0..n {
                let mut options: Vec<MovementPlan> = Vec::new();
                let mut base = greedy_plan.clone();
                base.set_s(i, i, 0.0);
                base.r[i] = 0.0;
                for j in 0..n {
                    if j != i {
                        base.set_s(i, j, 0.0);
                    }
                }
                let mut keep = base.clone();
                keep.set_s(i, i, 1.0);
                options.push(keep);
                let mut drop = base.clone();
                drop.r[i] = 1.0;
                options.push(drop);
                for j in 0..n {
                    if j != i && graph.has_edge(i, j) {
                        let mut off = base.clone();
                        off.set_s(i, j, 1.0);
                        options.push(off);
                    }
                }
                for alt in options {
                    assert!(
                        greedy_obj <= alt.objective(&p) + 1e-9,
                        "greedy {} beaten by alternative {} at device {i}",
                        greedy_obj,
                        alt.objective(&p)
                    );
                }
            }
        });
    }

    /// Property: greedy plans always satisfy the simplex constraint and
    /// never offload on missing links.
    #[test]
    fn prop_greedy_feasible() {
        for_all("greedy_feasible", 80, |g| {
            let n = g.usize_in(1, 8);
            let graph = erdos_renyi(n, g.f64_in(0.0, 1.0), g.rng());
            let mut costs = CostSchedule::zeros(n, 2);
            for t in 0..2 {
                for i in 0..n {
                    costs.compute[t][i] = g.f64_in(0.0, 1.0);
                    costs.error_weight[t][i] = g.f64_in(0.0, 1.0);
                    for j in 0..n {
                        if i != j {
                            costs.link[t][i * n + j] = g.f64_in(0.0, 1.0);
                        }
                    }
                }
            }
            let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 10.0)).collect();
            let inbound = vec![0.0; n];
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.8)).collect();
            let model = if g.bool(0.5) { DiscardModel::LinearR } else { DiscardModel::LinearG };
            let restricted = graph.restrict(&active);
            let p = MovementProblem {
                t: 0,
                graph: &restricted,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            let plan = solve(&p);
            plan.assert_feasible(&p, 1e-9);
        });
    }
}
