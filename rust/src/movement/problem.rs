//! Per-interval data-movement problem instance (§III-C).

use crate::costs::MovementCosts;
use crate::topology::Graph;

/// The three discard-cost models compared in §IV-A2 / Table IV.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiscardModel {
    /// `f_i(t) · D_i(t) · r_i(t)` — cost proportional to discarded data
    /// (the linear form Theorem 3 optimizes, *without* the link-cost
    /// modification).
    LinearR,
    /// `-f_i(t) · G_i(t)` — reward for processed data; equivalent to
    /// `LinearR` after redefining `c_ij ← c_ij + f_i(t) - f_j(t+1)`
    /// (§IV-A2). Prioritizes accuracy: offloading stays attractive even
    /// when links are pricey, because processing *anywhere* earns `f`.
    LinearG,
    /// `f_i(t) / √G_i(t)` — the convex bound from Lemma 1/Theorem 1 with
    /// diminishing marginal returns in processed data.
    Sqrt,
}

/// One interval's optimization input. All slices are indexed by device id;
/// `costs` may be the *actual* schedule (perfect information) or the
/// estimator's output (§IV-A imperfect information) — the ledger always
/// charges actual costs.
#[derive(Debug, Clone, Copy)]
pub struct MovementProblem<'a> {
    /// Current interval (the optimizer reads `costs` at `t` and `t+1`:
    /// offloaded data is processed by the receiver in the next interval).
    pub t: usize,
    /// Offloading links E(t) (already restricted to active devices).
    pub graph: &'a Graph,
    /// Active-device mask V(t).
    pub active: &'a [bool],
    /// `D_i(t)`: datapoints collected by each device this interval.
    pub d: &'a [f64],
    /// `Σ_j s_ji(t-1) D_j(t-1)`: data offloaded *to* i last interval, which
    /// i processes now (enters `G_i(t)` and consumes node capacity).
    pub inbound_prev: &'a [f64],
    /// Cost/capacity oracle the optimizer believes. Usually a dense
    /// [`crate::costs::CostSchedule`] (which coerces automatically at the
    /// struct literal); scaling runs plug in procedural O(n)-memory models.
    pub costs: &'a dyn MovementCosts,
    pub discard_model: DiscardModel,
}

impl<'a> MovementProblem<'a> {
    pub fn n(&self) -> usize {
        self.d.len()
    }

    /// Marginal cost of processing one datapoint locally at `i` (under the
    /// linear models; `LinearG` earns back `f_i(t)` per processed point).
    pub fn process_cost(&self, i: usize) -> f64 {
        match self.discard_model {
            DiscardModel::LinearR | DiscardModel::Sqrt => self.costs.c_node(self.t, i),
            DiscardModel::LinearG => self.costs.c_node(self.t, i) - self.costs.f(self.t, i),
        }
    }

    /// Marginal cost of offloading one datapoint from `i` to `j` (link now
    /// + processing at the receiver next interval; `LinearG` earns back
    /// `f_j(t+1)`).
    pub fn offload_cost(&self, i: usize, j: usize) -> f64 {
        let base = self.costs.c_link(self.t, i, j) + self.costs.c_node(self.t + 1, j);
        match self.discard_model {
            DiscardModel::LinearR | DiscardModel::Sqrt => base,
            DiscardModel::LinearG => base - self.costs.f(self.t + 1, j),
        }
    }

    /// Marginal cost of discarding one datapoint at `i`.
    pub fn discard_cost(&self, i: usize) -> f64 {
        match self.discard_model {
            DiscardModel::LinearR | DiscardModel::Sqrt => self.costs.f(self.t, i),
            DiscardModel::LinearG => 0.0,
        }
    }

    /// Out-neighbors of `i` that are active this interval.
    pub fn active_neighbors(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.graph
            .out_neighbors(i)
            .iter()
            .copied()
            .filter(move |&j| self.active[j])
    }

    /// The cheapest offloading target `k = argmin_j c_ij(t) + c_j(t+1)`
    /// from Theorem 3 (model-adjusted), if any neighbor is active.
    pub fn best_neighbor(&self, i: usize) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for j in self.active_neighbors(i) {
            let c = self.offload_cost(i, j);
            let better = match best {
                None => true,
                Some((_, bc)) => c < bc,
            };
            if better {
                best = Some((j, c));
            }
        }
        best
    }
}
