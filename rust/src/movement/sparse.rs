//! Edge-indexed movement plans: O(E) storage for sparse topologies.
//!
//! The dense [`MovementPlan`] stores `s` as an `n×n` matrix — fine for the
//! paper's n ≤ 50 experiments, hopeless at N = 10⁵ (10¹⁰ entries). A
//! [`SparsePlan`] stores exactly one `f64` per **edge** of the topology
//! (CSR layout over the graph's sorted out-neighbor rows) plus two per
//! device (`local` = s_ii, `discard` = r_i): O(V + E) total, which on the
//! random-geometric topologies the scaling bench uses is O(V).
//!
//! **Bit-identity contract** (DESIGN.md §Perf rule 11): every evaluation
//! mirror here (`objective`, `cost`, `processed`, `inbound_next_into`) and
//! every sparse solver pass iterates edges in the same order the dense
//! code visits nonzero entries — rows ascending, targets ascending within
//! a row (the graph keeps adjacency sorted) — and the dense code's
//! visits to *off-edge* entries are exact float no-ops (adding `0.0` to a
//! nonnegative partial sum, subtracting `step·0.0`). So a sparse solve and
//! a dense solve of the same instance produce plans equal under
//! [`SparsePlan::to_dense`] **bitwise**, enforced by the dense≡sparse
//! property suite in `tests/solver_agreement.rs`.

use crate::movement::plan::{CostBreakdown, MovementPlan};
use crate::movement::problem::{DiscardModel, MovementProblem};
use crate::topology::Graph;

/// A movement plan stored per-edge. Structure (offsets/targets + the
/// in-edge transpose) mirrors the topology; values (`s_edge`, `local`,
/// `discard`) are the decision variables.
#[derive(Debug, Clone, PartialEq)]
pub struct SparsePlan {
    pub n: usize,
    /// CSR row offsets: row i's edge slots are `offsets[i]..offsets[i+1]`.
    pub offsets: Vec<usize>,
    /// Edge targets per slot, ascending within each row.
    pub targets: Vec<usize>,
    /// `s_ij` per edge slot.
    pub s_edge: Vec<f64>,
    /// `s_ii` per device.
    pub local: Vec<f64>,
    /// `r_i` per device.
    pub discard: Vec<f64>,
    /// Transpose row offsets: in-edges of j are `t_offsets[j]..t_offsets[j+1]`.
    pub t_offsets: Vec<usize>,
    /// Source device of each in-edge, ascending within each transpose row.
    pub t_sources: Vec<usize>,
    /// Forward edge slot of each in-edge (index into `s_edge`/`targets`).
    pub t_slot: Vec<usize>,
}

impl SparsePlan {
    /// An empty plan over zero devices (workspace initial state).
    pub fn empty() -> Self {
        SparsePlan {
            n: 0,
            offsets: vec![0],
            targets: Vec::new(),
            s_edge: Vec::new(),
            local: Vec::new(),
            discard: Vec::new(),
            t_offsets: vec![0],
            t_sources: Vec::new(),
            t_slot: Vec::new(),
        }
    }

    /// Keep-all plan with structure taken from `graph`.
    pub fn keep_all(graph: &Graph) -> Self {
        let mut sp = SparsePlan::empty();
        sp.rebuild(graph);
        sp
    }

    /// Rebuild structure from `graph` (reusing allocations) and reset the
    /// values to keep-all (`local = 1`, everything else 0). O(V + E).
    pub fn rebuild(&mut self, graph: &Graph) {
        let n = graph.n();
        self.n = n;
        self.offsets.clear();
        self.offsets.reserve(n + 1);
        self.targets.clear();
        self.offsets.push(0);
        for i in 0..n {
            self.targets.extend_from_slice(graph.out_neighbors(i));
            self.offsets.push(self.targets.len());
        }
        let m = self.targets.len();
        self.s_edge.clear();
        self.s_edge.resize(m, 0.0);
        self.local.clear();
        self.local.resize(n, 1.0);
        self.discard.clear();
        self.discard.resize(n, 0.0);

        // transpose by counting sort: forward slots are visited with i
        // ascending, so each transpose row fills with sources ascending
        self.t_offsets.clear();
        self.t_offsets.resize(n + 1, 0);
        for &j in &self.targets {
            self.t_offsets[j + 1] += 1;
        }
        for j in 0..n {
            self.t_offsets[j + 1] += self.t_offsets[j];
        }
        self.t_sources.clear();
        self.t_sources.resize(m, 0);
        self.t_slot.clear();
        self.t_slot.resize(m, 0);
        let mut cursor: Vec<usize> = self.t_offsets[..n].to_vec();
        for i in 0..n {
            for e in self.offsets[i]..self.offsets[i + 1] {
                let j = self.targets[e];
                let at = cursor[j];
                self.t_sources[at] = i;
                self.t_slot[at] = e;
                cursor[j] += 1;
            }
        }
    }

    /// Reset the values (not the structure) to keep-all.
    pub fn reset_keep_all(&mut self) {
        self.s_edge.iter_mut().for_each(|v| *v = 0.0);
        self.local.iter_mut().for_each(|v| *v = 1.0);
        self.discard.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Number of edge slots.
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Edge slot of (i, j), if the edge exists.
    pub fn slot(&self, i: usize, j: usize) -> Option<usize> {
        let row = &self.targets[self.offsets[i]..self.offsets[i + 1]];
        row.binary_search(&j).ok().map(|pos| self.offsets[i] + pos)
    }

    /// Row i's (targets, values) as parallel slices.
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let span = self.offsets[i]..self.offsets[i + 1];
        (&self.targets[span.clone()], &self.s_edge[span])
    }

    /// Heap footprint in bytes (the O(E)-vs-O(n²) number the scaling bench
    /// reports).
    pub fn heap_bytes(&self) -> usize {
        use std::mem::size_of;
        self.offsets.capacity() * size_of::<usize>()
            + self.targets.capacity() * size_of::<usize>()
            + self.s_edge.capacity() * size_of::<f64>()
            + self.local.capacity() * size_of::<f64>()
            + self.discard.capacity() * size_of::<f64>()
            + self.t_offsets.capacity() * size_of::<usize>()
            + self.t_sources.capacity() * size_of::<usize>()
            + self.t_slot.capacity() * size_of::<usize>()
    }

    /// Lossless conversion to the dense representation.
    pub fn to_dense(&self) -> MovementPlan {
        let mut plan = MovementPlan::keep_all(self.n);
        self.to_dense_into(&mut plan);
        plan
    }

    /// In-place dense conversion (reuses `plan`'s buffers).
    pub fn to_dense_into(&self, plan: &mut MovementPlan) {
        let n = self.n;
        plan.reset_keep_all(n);
        for i in 0..n {
            plan.set_s(i, i, self.local[i]);
            plan.r[i] = self.discard[i];
            for e in self.offsets[i]..self.offsets[i + 1] {
                plan.set_s(i, self.targets[e], self.s_edge[e]);
            }
        }
    }

    /// Adopt the values of a dense plan whose support lies on this
    /// structure's edges (+ diagonal). Debug-asserts that no off-edge mass
    /// is lost, making the round-trip lossless.
    pub fn from_dense(&mut self, plan: &MovementPlan) {
        assert_eq!(plan.n, self.n, "dense plan size mismatch");
        for i in 0..self.n {
            self.local[i] = plan.s(i, i);
            self.discard[i] = plan.r[i];
            for e in self.offsets[i]..self.offsets[i + 1] {
                self.s_edge[e] = plan.s(i, self.targets[e]);
            }
        }
        #[cfg(debug_assertions)]
        {
            let mut back = MovementPlan::keep_all(self.n);
            self.to_dense_into(&mut back);
            for i in 0..self.n {
                for j in 0..self.n {
                    debug_assert!(
                        back.s(i, j) == plan.s(i, j),
                        "dense plan carries off-edge mass at ({i},{j})"
                    );
                }
            }
        }
    }

    /// `G_i(t)` mirror of [`MovementPlan::processed`].
    pub fn processed(&self, p: &MovementProblem) -> Vec<f64> {
        (0..self.n)
            .map(|i| self.local[i] * p.d[i] + p.inbound_prev[i])
            .collect()
    }

    /// Mirror of [`MovementPlan::inbound_next`] writing into `out`
    /// (resized to n): data each device receives this interval. Bitwise
    /// equal to the dense loop — the dense version adds `0.0 · d_i` for
    /// every off-edge pair, an exact no-op on these nonnegative sums.
    pub fn inbound_next_into(&self, p: &MovementProblem, out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.n, 0.0);
        for i in 0..self.n {
            if p.d[i] == 0.0 {
                continue;
            }
            for e in self.offsets[i]..self.offsets[i + 1] {
                out[self.targets[e]] += self.s_edge[e] * p.d[i];
            }
        }
    }

    /// Mirror of [`MovementPlan::cost`] (same visit order over nonzero
    /// entries ⇒ bit-identical breakdown).
    pub fn cost(&self, p: &MovementProblem) -> CostBreakdown {
        let mut c = CostBreakdown::default();
        for i in 0..self.n {
            let g = self.local[i] * p.d[i] + p.inbound_prev[i];
            c.process += g * p.costs.c_node(p.t, i);
            c.discard += p.costs.f(p.t, i) * p.d[i] * self.discard[i];
            if p.d[i] > 0.0 {
                for e in self.offsets[i]..self.offsets[i + 1] {
                    if self.s_edge[e] > 0.0 {
                        c.transfer += p.d[i]
                            * self.s_edge[e]
                            * p.costs.c_link(p.t, i, self.targets[e]);
                    }
                }
            }
        }
        c
    }

    /// Mirror of [`MovementPlan::objective`]. The dense LinearG branch
    /// subtracts `f · d_i · 0.0` for off-edge pairs — an exact no-op — so
    /// skipping them here preserves bits.
    pub fn objective(&self, p: &MovementProblem) -> f64 {
        self.objective_chunked(p, crate::util::par::CHUNK_ROWS)
    }

    /// Mirror of [`MovementPlan::objective_chunked`]: the same per-chunk
    /// linear-then-model accumulation tree over the sparse support, so the
    /// fused sparse solver passes agree with this function bitwise.
    pub(crate) fn objective_chunked(&self, p: &MovementProblem, chunk_rows: usize) -> f64 {
        let inbound_now = match p.discard_model {
            DiscardModel::Sqrt => {
                let mut inb = Vec::new();
                self.inbound_next_into(p, &mut inb);
                Some(inb)
            }
            _ => None,
        };
        let nc = crate::util::par::num_chunks(self.n, chunk_rows);
        let mut partials = vec![0.0; nc];
        for (c, partial) in partials.iter_mut().enumerate() {
            let rows = crate::util::par::chunk_range(c, self.n, chunk_rows);
            let mut obj = 0.0;
            for i in rows.clone() {
                let g_local = self.local[i] * p.d[i] + p.inbound_prev[i];
                obj += g_local * p.costs.c_node(p.t, i);
                if p.d[i] > 0.0 {
                    for e in self.offsets[i]..self.offsets[i + 1] {
                        if self.s_edge[e] > 0.0 {
                            let j = self.targets[e];
                            let amount = p.d[i] * self.s_edge[e];
                            obj += amount
                                * (p.costs.c_link(p.t, i, j) + p.costs.c_node(p.t + 1, j));
                        }
                    }
                }
            }
            match p.discard_model {
                DiscardModel::LinearR => {
                    for i in rows {
                        obj += p.costs.f(p.t, i) * p.d[i] * self.discard[i];
                    }
                }
                DiscardModel::LinearG => {
                    for i in rows {
                        let g_local = self.local[i] * p.d[i] + p.inbound_prev[i];
                        obj -= p.costs.f(p.t, i) * g_local;
                        if p.d[i] > 0.0 {
                            for e in self.offsets[i]..self.offsets[i + 1] {
                                obj -= p.costs.f(p.t + 1, self.targets[e])
                                    * p.d[i]
                                    * self.s_edge[e];
                            }
                        }
                    }
                }
                DiscardModel::Sqrt => {
                    let inbound_now = inbound_now.as_ref().expect("computed for Sqrt");
                    for i in rows {
                        if !p.active[i] {
                            continue;
                        }
                        let g = self.local[i] * p.d[i] + p.inbound_prev[i] + inbound_now[i];
                        obj += p.costs.f(p.t, i)
                            / (g + crate::movement::convex::SQRT_EPS).sqrt();
                    }
                }
            }
            *partial = obj;
        }
        crate::util::par::combine(&partials)
    }

    /// Mirror of [`MovementPlan::assert_feasible`] over the sparse support
    /// (off-edge entries are structurally zero, so only the stored slots
    /// need checking).
    pub fn assert_feasible(&self, p: &MovementProblem, tol: f64) {
        for i in 0..self.n {
            let mut row = self.discard[i] + self.local[i];
            assert!(self.local[i] >= -tol, "s[{i},{i}] = {} < 0", self.local[i]);
            assert!(self.discard[i] >= -tol, "r[{i}] < 0");
            for e in self.offsets[i]..self.offsets[i + 1] {
                let sij = self.s_edge[e];
                let j = self.targets[e];
                assert!(sij >= -tol, "s[{i},{j}] = {sij} < 0");
                row += sij;
                if sij > tol {
                    assert!(
                        p.active[i] && p.active[j],
                        "offload on inactive link ({i},{j})"
                    );
                    let cap = p.costs.cap_link_at(p.t, i, j);
                    assert!(
                        sij * p.d[i] <= cap + tol,
                        "link cap violated on ({i},{j}): {} > {cap}",
                        sij * p.d[i]
                    );
                }
            }
            if p.d[i] > 0.0 && p.active[i] {
                assert!(
                    (row - 1.0).abs() < tol.max(1e-9),
                    "simplex violated at {i}: r+Σs = {row}"
                );
            }
            let g = self.local[i] * p.d[i] + p.inbound_prev[i];
            let cap = p.costs.cap_node_at(p.t, i);
            assert!(g <= cap + tol, "node cap violated at {i}: G={g} > C={cap}");
        }
        // receiver capacities
        for j in 0..self.n {
            let cap = p.costs.cap_node_at(p.t + 1, j);
            if cap.is_finite() {
                let mut inbound = 0.0;
                for te in self.t_offsets[j]..self.t_offsets[j + 1] {
                    let i = self.t_sources[te];
                    if p.d[i] > 0.0 {
                        inbound += self.s_edge[self.t_slot[te]] * p.d[i];
                    }
                }
                assert!(
                    inbound <= cap + tol,
                    "receiver cap violated at {j}: {inbound} > {cap}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::CostSchedule;
    use crate::topology::generators::{erdos_renyi, fully_connected};
    use crate::util::rng::Rng;

    #[test]
    fn structure_mirrors_graph() {
        let mut rng = Rng::new(1);
        let g = erdos_renyi(8, 0.4, &mut rng);
        let sp = SparsePlan::keep_all(&g);
        assert_eq!(sp.num_edges(), g.num_edges());
        for i in 0..8 {
            let (targets, vals) = sp.row(i);
            assert_eq!(targets, g.out_neighbors(i));
            assert!(vals.iter().all(|&v| v == 0.0));
            assert_eq!(sp.local[i], 1.0);
        }
        // transpose agrees with in_neighbors and points at the right slots
        for j in 0..8 {
            let sources: Vec<usize> =
                sp.t_sources[sp.t_offsets[j]..sp.t_offsets[j + 1]].to_vec();
            assert_eq!(sources.as_slice(), g.in_neighbors(j));
            for te in sp.t_offsets[j]..sp.t_offsets[j + 1] {
                assert_eq!(sp.targets[sp.t_slot[te]], j);
            }
        }
    }

    #[test]
    fn dense_round_trip_is_lossless() {
        let mut rng = Rng::new(2);
        let g = erdos_renyi(6, 0.5, &mut rng);
        let mut sp = SparsePlan::keep_all(&g);
        // put arbitrary mass on edges
        let mut frac = 0.05;
        for i in 0..6 {
            let span = sp.offsets[i]..sp.offsets[i + 1];
            for e in span {
                sp.s_edge[e] = frac;
                frac += 0.03;
            }
            sp.local[i] = 0.2;
            sp.discard[i] = 0.1;
        }
        let dense = sp.to_dense();
        let mut back = SparsePlan::keep_all(&g);
        back.from_dense(&dense);
        assert_eq!(sp, back);
        assert_eq!(dense, back.to_dense());
    }

    #[test]
    fn rebuild_reuses_and_resets() {
        let g1 = fully_connected(5);
        let mut rng = Rng::new(3);
        let g2 = erdos_renyi(9, 0.3, &mut rng);
        let mut sp = SparsePlan::keep_all(&g1);
        sp.s_edge[0] = 0.7;
        sp.rebuild(&g2);
        assert_eq!(sp.n, 9);
        assert_eq!(sp.num_edges(), g2.num_edges());
        assert!(sp.s_edge.iter().all(|&v| v == 0.0));
        assert!(sp.local.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn evaluation_mirrors_match_dense() {
        use crate::movement::problem::{DiscardModel, MovementProblem};
        let mut rng = Rng::new(4);
        let g = erdos_renyi(7, 0.6, &mut rng);
        let n = 7;
        let mut costs = CostSchedule::zeros(n, 2);
        for t in 0..2 {
            for i in 0..n {
                costs.compute[t][i] = 0.1 + 0.05 * i as f64;
                costs.error_weight[t][i] = 0.4;
                for j in 0..n {
                    if i != j {
                        costs.link[t][i * n + j] = 0.02 * (1 + j) as f64;
                    }
                }
            }
        }
        let d: Vec<f64> = (0..n).map(|i| 3.0 + i as f64).collect();
        let inbound = vec![0.5; n];
        let active = vec![true; n];

        let mut sp = SparsePlan::keep_all(&g);
        let mut frac = 0.02;
        for i in 0..n {
            for e in sp.offsets[i]..sp.offsets[i + 1] {
                sp.s_edge[e] = frac;
                frac += 0.01;
            }
            let off: f64 = sp.row(i).1.iter().sum();
            sp.local[i] = (1.0 - off).max(0.0) * 0.8;
            sp.discard[i] = (1.0 - off - sp.local[i]).max(0.0);
        }
        let dense = sp.to_dense();
        for model in [DiscardModel::LinearR, DiscardModel::LinearG, DiscardModel::Sqrt] {
            let p = MovementProblem {
                t: 0,
                graph: &g,
                active: &active,
                d: &d,
                inbound_prev: &inbound,
                costs: &costs,
                discard_model: model,
            };
            assert_eq!(sp.objective(&p), dense.objective(&p), "{model:?} objective");
            assert_eq!(sp.cost(&p), dense.cost(&p), "{model:?} cost");
            assert_eq!(sp.processed(&p), dense.processed(&p));
            let mut inb = Vec::new();
            sp.inbound_next_into(&p, &mut inb);
            assert_eq!(inb, dense.inbound_next(&p));
        }
    }
}
