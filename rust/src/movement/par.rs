//! Compatibility re-export: the fixed-chunk deterministic parallel layer
//! was born here for the row-parallel movement solvers (DESIGN.md §Perf
//! rule 12) and has been promoted crate-wide to [`crate::util::par`] so
//! the federated aggregation data plane (§Perf rule 14) can share the
//! same geometry and ascending-combine contract. The public surface
//! (`CHUNK_ROWS`, chunk geometry, projection scratch) stays reachable
//! under the historical `movement::par` path; crate-internal helpers
//! (`run_chunks`, `combine`, the split helpers) now live in `util::par`
//! and are imported from there directly.

pub use crate::util::par::{chunk_range, num_chunks, ProjBuffers, CHUNK_ROWS};
