//! Figures 9 and 10: network dynamics sweeps.
//!
//! Fig 9 varies `p_exit` ∈ {0, 1, ..., 5}% with `p_entry = 2%`;
//! Fig 10 varies `p_entry` ∈ {0, 1, ..., 5}% with `p_exit = 2%`.
//!
//! Panels: average active nodes, total data + processed/discarded ratio,
//! movement rate, cost components, accuracy (iid and non-iid).
//!
//! Expected shapes (paper): active nodes fall sharply in p_exit and rise
//! (saturating) in p_entry; fewer active nodes → less data, lower total
//! cost but discard-skewed unit costs, and lower accuracy (non-iid hit
//! hardest by exits).
//!
//! Each figure's (churn point × {iid, non-iid} × seed) grid fans out
//! through one [`crate::coordinator::SimPool`] batch, and shards across
//! processes via `--shard I/N` ([`crate::coordinator::shard`]).

use anyhow::Result;

use crate::config::{Churn, EngineConfig};
use crate::coordinator::SweepCtx;
use crate::experiments::common::{emit_iid_pair_curves, run_avg_iid_pairs, with_eval};
use crate::experiments::ExpOptions;
use crate::util::table::{fnum, pct, Table};

/// One churn sweep. Under `--curve` each point also evaluates an
/// accuracy-vs-time curve through the `fed::eval` planner — the paper's
/// §V-C dynamics question (how entry/exit bends the learning curve, not
/// just the endpoint) — and the sweep emits `<csv_name>_curve.csv`.
fn churn_sweep(
    title: &str,
    csv_name: &str,
    param_name: &str,
    points: Vec<(String, Churn)>,
    opts: &ExpOptions,
    ctx: &SweepCtx,
) -> Result<()> {
    let base = opts.base_config();

    let cfgs: Vec<EngineConfig> = points
        .iter()
        .map(|(_, churn)| {
            with_eval(base.clone().with(|c| c.churn = Some(*churn)), opts)
        })
        .collect();
    let pairs = run_avg_iid_pairs(ctx, &cfgs, opts.seeds)?;

    let mut table = Table::new(
        title,
        &[
            param_name,
            "Nodes",
            "Data",
            "Proc ratio",
            "Disc ratio",
            "Move rate",
            "Process",
            "Transfer",
            "Discard",
            "Unit",
            "Acc iid",
            "Acc non-iid",
        ],
    );

    for ((label, _), (avg, avg_noniid)) in points.iter().zip(&pairs) {
        table.row(vec![
            label.clone(),
            fnum(avg.mean_active, 1),
            fnum(avg.collected, 0),
            fnum(avg.processed_ratio, 3),
            fnum(avg.discarded_ratio, 3),
            fnum(avg.movement_rate, 3),
            fnum(avg.process, 0),
            fnum(avg.transfer, 0),
            fnum(avg.discard, 0),
            fnum(avg.unit, 3),
            pct(avg.accuracy),
            pct(avg_noniid.accuracy),
        ]);
    }
    ctx.emit_table(&table, &opts.out_dir, csv_name)?;
    let labels: Vec<&str> = points.iter().map(|(l, _)| l.as_str()).collect();
    emit_iid_pair_curves(ctx, param_name, &labels, &pairs, &opts.out_dir, csv_name)
}

/// Fig 9: vary p_exit, p_entry fixed at 2%.
pub fn run_fig9(opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let points = (0..=5)
        .map(|k| {
            let p = k as f64 / 100.0;
            (format!("{k}%"), Churn { p_exit: p, p_entry: 0.02 })
        })
        .collect();
    churn_sweep(
        "Fig 9 — impact of node-exit probability (p_entry = 2%)",
        "fig9_pexit",
        "p_exit",
        points,
        opts,
        ctx,
    )
}

/// Fig 10: vary p_entry, p_exit fixed at 2%.
pub fn run_fig10(opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let points = (0..=5)
        .map(|k| {
            let p = k as f64 / 100.0;
            (format!("{k}%"), Churn { p_exit: 0.02, p_entry: p })
        })
        .collect();
    churn_sweep(
        "Fig 10 — impact of node-entry probability (p_exit = 2%)",
        "fig10_pentry",
        "p_entry",
        points,
        opts,
        ctx,
    )
}
