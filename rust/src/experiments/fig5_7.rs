//! Figures 5, 6, 7: sweeps of the network system characteristics —
//! number of nodes n, connectivity ρ, aggregation period τ — each reported
//! through the same four panels:
//!
//! (a) fraction of data processed vs discarded,
//! (b) data movement rate (mean + range over intervals),
//! (c) unit cost and its process/transfer/discard breakdown,
//! (d) testing accuracy for iid and non-iid data.
//!
//! Expected shapes (paper): unit cost ↓ in n and ρ (more low-cost
//! neighbors), accuracy ↑ in n and ρ (dramatically for non-iid); higher τ
//! lowers cost but hurts accuracy (especially non-iid).
//!
//! Each figure's whole (point × {iid, non-iid} × seed) grid fans out
//! through one [`crate::coordinator::SimPool`] batch, and shards across
//! processes via `--shard I/N` ([`crate::coordinator::shard`]).

use anyhow::Result;

use crate::config::{EngineConfig, TopologyKind};
use crate::coordinator::SweepCtx;
use crate::experiments::common::{emit_iid_pair_curves, run_avg_iid_pairs, with_eval};
use crate::experiments::ExpOptions;
use crate::util::table::{fnum, pct, Table};

/// One sweep point = the four panels' numbers. Under `--curve` each point
/// additionally evaluates an accuracy curve through the `fed::eval`
/// planner (honoring `--eval-schedule`) and the sweep emits
/// `<csv_name>_curve.csv` with one iid + one non-iid series per point.
fn sweep(
    title: &str,
    csv_name: &str,
    param_name: &str,
    points: Vec<(String, EngineConfig)>,
    opts: &ExpOptions,
    ctx: &SweepCtx,
) -> Result<()> {
    let cfgs: Vec<EngineConfig> =
        points.iter().map(|(_, cfg)| with_eval(cfg.clone(), opts)).collect();
    let pairs = run_avg_iid_pairs(ctx, &cfgs, opts.seeds)?;

    let mut table = Table::new(
        title,
        &[
            param_name,
            "Proc ratio",
            "Disc ratio",
            "Move rate",
            "Rate min",
            "Rate max",
            "Unit",
            "U.proc",
            "U.trans",
            "U.disc",
            "Acc iid",
            "Acc non-iid",
        ],
    );
    for ((label, _), (avg, avg_noniid)) in points.iter().zip(&pairs) {
        let coll = avg.collected.max(1.0);
        table.row(vec![
            label.clone(),
            fnum(avg.processed_ratio, 3),
            fnum(avg.discarded_ratio, 3),
            fnum(avg.movement_rate, 3),
            fnum(avg.movement_rate_min, 3),
            fnum(avg.movement_rate_max, 3),
            fnum(avg.unit, 3),
            fnum(avg.process / coll, 3),
            fnum(avg.transfer / coll, 3),
            fnum(avg.discard / coll, 3),
            pct(avg.accuracy),
            pct(avg_noniid.accuracy),
        ]);
    }
    ctx.emit_table(&table, &opts.out_dir, csv_name)?;
    let labels: Vec<&str> = points.iter().map(|(l, _)| l.as_str()).collect();
    emit_iid_pair_curves(ctx, param_name, &labels, &pairs, &opts.out_dir, csv_name)
}

/// Figure 5: n ∈ {5, 10, ..., 50}, fully connected.
pub fn run_fig5(opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let base = opts.base_config();
    let points = (1..=10)
        .map(|k| {
            let n = 5 * k;
            (n.to_string(), base.clone().with(|c| c.n = n))
        })
        .collect();
    sweep(
        "Fig 5 — impact of the number of nodes n",
        "fig5_nodes",
        "n",
        points,
        opts,
        ctx,
    )
}

/// Figure 6: connectivity ρ ∈ {0, 0.2, ..., 1.0}, ER random graph.
pub fn run_fig6(opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let base = opts.base_config();
    let points = (0..=5)
        .map(|k| {
            let rho = 0.2 * k as f64;
            (
                format!("{rho:.1}"),
                base.clone().with(|c| c.topology = TopologyKind::Random(rho)),
            )
        })
        .collect();
    sweep(
        "Fig 6 — impact of network connectivity ρ",
        "fig6_connectivity",
        "rho",
        points,
        opts,
        ctx,
    )
}

/// Figure 7: aggregation period τ ∈ {2, 5, 10, 20, 25, 50}.
pub fn run_fig7(opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let base = opts.base_config();
    let points = [2usize, 5, 10, 20, 25, 50]
        .iter()
        .map(|&tau| (tau.to_string(), base.clone().with(|c| c.tau = tau)))
        .collect();
    sweep(
        "Fig 7 — impact of the aggregation period τ",
        "fig7_tau",
        "tau",
        points,
        opts,
        ctx,
    )
}
