//! Shared experiment machinery: multi-seed averaging and result output.

use anyhow::Result;

use crate::config::EngineConfig;
use crate::fed::{self, EngineOutput};
use crate::runtime::Runtime;
use crate::util::stats;
use crate::util::table::Table;

/// Seed-averaged summary of a configuration.
#[derive(Debug, Clone, Default)]
pub struct Avg {
    pub accuracy: f64,
    pub accuracy_std: f64,
    pub process: f64,
    pub transfer: f64,
    pub discard: f64,
    pub total: f64,
    pub unit: f64,
    pub collected: f64,
    pub processed_ratio: f64,
    pub discarded_ratio: f64,
    pub movement_rate: f64,
    pub movement_rate_min: f64,
    pub movement_rate_max: f64,
    pub similarity_before: f64,
    pub similarity_after: f64,
    pub mean_active: f64,
}

impl Avg {
    pub fn from_outputs(outs: &[EngineOutput]) -> Avg {
        let k = outs.len().max(1) as f64;
        let accs: Vec<f64> = outs.iter().map(|o| o.accuracy).collect();
        let mut a = Avg {
            accuracy: stats::mean(&accs),
            accuracy_std: stats::std_dev(&accs),
            ..Default::default()
        };
        for o in outs {
            a.process += o.ledger.process / k;
            a.transfer += o.ledger.transfer / k;
            a.discard += o.ledger.discard / k;
            a.total += o.ledger.total() / k;
            a.unit += o.ledger.unit_cost(o.total_collected as f64) / k;
            a.collected += o.total_collected as f64 / k;
            a.processed_ratio += o.movement.processed_ratio() / k;
            a.discarded_ratio += o.movement.discarded_ratio() / k;
            let (mean, min, max) = o.movement.movement_rate_stats();
            a.movement_rate += mean / k;
            a.movement_rate_min += min / k;
            a.movement_rate_max += max / k;
            a.similarity_before += o.similarity.0 / k;
            a.similarity_after += o.similarity.1 / k;
            a.mean_active += o.mean_active / k;
        }
        a
    }
}

/// Run `cfg` under `seeds` different seeds and average.
pub fn run_avg(rt: &Runtime, cfg: &EngineConfig, seeds: usize) -> Result<(Avg, Vec<EngineOutput>)> {
    let mut outs = Vec::with_capacity(seeds);
    for s in 0..seeds {
        let cfg_s = cfg.clone().seeded(cfg.seed + 1000 * s as u64);
        outs.push(fed::run(&cfg_s, rt)?);
    }
    Ok((Avg::from_outputs(&outs), outs))
}

/// Print a table and persist its CSV under `<out_dir>/<name>.csv`.
pub fn emit(table: &Table, out_dir: &str, name: &str) -> Result<()> {
    table.print();
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/{name}.csv"), table.to_csv())?;
    Ok(())
}

/// Write raw lines (e.g. per-interval series) to `<out_dir>/<name>.csv`.
pub fn emit_raw(lines: &str, out_dir: &str, name: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/{name}.csv"), lines)?;
    Ok(())
}
