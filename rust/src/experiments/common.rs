//! Shared experiment machinery: multi-seed averaging (serial, pooled, and
//! shard-aware via [`SweepCtx`]) and result output.

use anyhow::Result;

use crate::config::EngineConfig;
use crate::coordinator::{SimPool, SweepCtx};
use crate::experiments::ExpOptions;
use crate::fed::{self, EngineOutput};
use crate::runtime::Runtime;
use crate::util::stats;
use crate::util::table::Table;

/// Seed-averaged summary of a configuration.
#[derive(Debug, Clone, Default)]
pub struct Avg {
    pub accuracy: f64,
    pub accuracy_std: f64,
    pub process: f64,
    pub transfer: f64,
    pub discard: f64,
    pub total: f64,
    pub unit: f64,
    pub collected: f64,
    pub processed_ratio: f64,
    pub discarded_ratio: f64,
    pub movement_rate: f64,
    pub movement_rate_min: f64,
    pub movement_rate_max: f64,
    pub similarity_before: f64,
    pub similarity_after: f64,
    pub mean_active: f64,
    /// Seed-mean accuracy curve `(t, acc)` — populated only when the runs
    /// carried `eval_curve` (all seeds of one config share aggregation
    /// times, so the pointwise mean is well-defined).
    pub curve: Vec<(usize, f64)>,
}

impl Avg {
    pub fn from_outputs(outs: &[EngineOutput]) -> Avg {
        let k = outs.len().max(1) as f64;
        let accs: Vec<f64> = outs.iter().map(|o| o.accuracy).collect();
        let mut a = Avg {
            accuracy: stats::mean(&accs),
            accuracy_std: stats::std_dev(&accs),
            ..Default::default()
        };
        for o in outs {
            a.process += o.ledger.process / k;
            a.transfer += o.ledger.transfer / k;
            a.discard += o.ledger.discard / k;
            a.total += o.ledger.total() / k;
            a.unit += o.ledger.unit_cost(o.total_collected as f64) / k;
            a.collected += o.total_collected as f64 / k;
            a.processed_ratio += o.movement.processed_ratio() / k;
            a.discarded_ratio += o.movement.discarded_ratio() / k;
            let (mean, min, max) = o.movement.movement_rate_stats();
            a.movement_rate += mean / k;
            a.movement_rate_min += min / k;
            a.movement_rate_max += max / k;
            a.similarity_before += o.similarity.0 / k;
            a.similarity_after += o.similarity.1 / k;
            a.mean_active += o.mean_active / k;
        }
        if !outs.is_empty() && outs.iter().all(|o| !o.accuracy_curve.is_empty()) {
            let len = outs.iter().map(|o| o.accuracy_curve.len()).min().unwrap();
            for p in 0..len {
                let t = outs[0].accuracy_curve[p].0;
                let mean =
                    outs.iter().map(|o| o.accuracy_curve[p].1).sum::<f64>() / k;
                a.curve.push((t, mean));
            }
        }
        a
    }
}

/// Apply the shared evaluation options to a driver's config: curve
/// production on/off and the eval schedule every curve point follows
/// (the session routes both through `fed::eval`'s planner).
pub fn with_eval(cfg: EngineConfig, opts: &ExpOptions) -> EngineConfig {
    cfg.with(|c| {
        c.eval_curve = opts.curve;
        c.eval_schedule = opts.eval_schedule;
    })
}

/// [`emit_curves`] for a labeled iid/non-iid sweep: one
/// `<param>=<label>/iid` and one `/non-iid` series per sweep point (the
/// shape every `run_avg_iid_pairs` driver reports).
pub fn emit_iid_pair_curves(
    ctx: &SweepCtx,
    param_name: &str,
    labels: &[&str],
    pairs: &[(Avg, Avg)],
    out_dir: &str,
    name: &str,
) -> Result<()> {
    let series: Vec<(String, &[(usize, f64)])> = labels
        .iter()
        .zip(pairs)
        .flat_map(|(label, (iid, noniid))| {
            [
                (format!("{param_name}={label}/iid"), iid.curve.as_slice()),
                (format!("{param_name}={label}/non-iid"), noniid.curve.as_slice()),
            ]
        })
        .collect();
    emit_curves(ctx, &series, out_dir, name)
}

/// Write accuracy-curve series to `<out_dir>/<name>_curve.csv` as
/// `label,t,accuracy` rows — one series per labeled config. No-op when
/// every series is empty (curves were not requested); suppressed in
/// shard mode like every artifact.
pub fn emit_curves(
    ctx: &SweepCtx,
    series: &[(String, &[(usize, f64)])],
    out_dir: &str,
    name: &str,
) -> Result<()> {
    if series.iter().all(|(_, c)| c.is_empty()) {
        return Ok(());
    }
    let mut csv = String::from("label,t,accuracy\n");
    for (label, curve) in series {
        for (t, acc) in curve.iter() {
            csv.push_str(&format!("{label},{t},{acc}\n"));
        }
    }
    ctx.emit_raw(&csv, out_dir, &format!("{name}_curve"))
}

/// The `seeds` configs a seed-averaged cell expands to: same config, seeds
/// `base, base+1000, base+2000, …` (the historical spacing — load-bearing
/// for reproducing pre-pool numbers).
pub fn seed_sweep(cfg: &EngineConfig, seeds: usize) -> Vec<EngineConfig> {
    (0..seeds)
        .map(|s| cfg.clone().seeded(cfg.seed + 1000 * s as u64))
        .collect()
}

/// Run `cfg` under `seeds` different seeds and average — serial path on a
/// borrowed runtime (used by the non-shardable drivers table2/fig8; the
/// sweep drivers fan out through [`run_avg_ctx`] / [`run_avg_batch`] on a
/// [`SweepCtx`] instead).
pub fn run_avg(rt: &Runtime, cfg: &EngineConfig, seeds: usize) -> Result<(Avg, Vec<EngineOutput>)> {
    let mut outs = Vec::with_capacity(seeds);
    for cfg_s in seed_sweep(cfg, seeds) {
        outs.push(fed::run(&cfg_s, rt)?);
    }
    Ok((Avg::from_outputs(&outs), outs))
}

/// Pooled equivalent of [`run_avg`]: the seed fan-out runs through the
/// pool's workers. Bit-identical to [`run_avg`] at any job count.
pub fn run_avg_pool(
    pool: &SimPool,
    cfg: &EngineConfig,
    seeds: usize,
) -> Result<(Avg, Vec<EngineOutput>)> {
    let outs = pool.run_many(&seed_sweep(cfg, seeds))?;
    Ok((Avg::from_outputs(&outs), outs))
}

/// [`run_avg_pool`] through a [`SweepCtx`]: the seed fan-out becomes one
/// canonical grid segment, so the cell shards and merges like any batch
/// (used by the lighter drivers — table5, fig4 — that average one cell at
/// a time).
pub fn run_avg_ctx(
    ctx: &SweepCtx,
    cfg: &EngineConfig,
    seeds: usize,
) -> Result<(Avg, Vec<EngineOutput>)> {
    let outs = ctx.run_many(&seed_sweep(cfg, seeds))?;
    Ok((Avg::from_outputs(&outs), outs))
}

/// Fan out a whole sweep at once: every config × every seed in one
/// batch (so the pool stays saturated across sweep points, not just within
/// one cell), averaged back per config in input order. The expansion
/// order — config-major, seed-minor — is the canonical order the
/// sharding contract round-robins over (`coordinator::shard`).
pub fn run_avg_batch(ctx: &SweepCtx, cfgs: &[EngineConfig], seeds: usize) -> Result<Vec<Avg>> {
    if seeds == 0 {
        // mirror run_avg's zero-seed behavior: a zeros row per config
        return Ok(cfgs.iter().map(|_| Avg::from_outputs(&[])).collect());
    }
    let expanded: Vec<EngineConfig> =
        cfgs.iter().flat_map(|c| seed_sweep(c, seeds)).collect();
    let outs = ctx.run_many(&expanded)?;
    Ok(outs.chunks(seeds).map(Avg::from_outputs).collect())
}

/// Expand each config into its (iid, non-iid) twin, fan the whole grid out
/// in one batch, and pair the averages back per input config — the
/// shape every paper table/figure reports. Centralizing the expansion and
/// the pairing keeps drivers free of index arithmetic that could silently
/// swap the iid/non-iid columns.
pub fn run_avg_iid_pairs(
    ctx: &SweepCtx,
    cfgs: &[EngineConfig],
    seeds: usize,
) -> Result<Vec<(Avg, Avg)>> {
    let expanded: Vec<EngineConfig> = cfgs
        .iter()
        .flat_map(|c| {
            [c.clone().with(|x| x.iid = true), c.clone().with(|x| x.iid = false)]
        })
        .collect();
    let avgs = run_avg_batch(ctx, &expanded, seeds)?;
    let mut it = avgs.into_iter();
    let mut pairs = Vec::with_capacity(cfgs.len());
    while let (Some(iid), Some(noniid)) = (it.next(), it.next()) {
        pairs.push((iid, noniid));
    }
    Ok(pairs)
}

/// Print a table and persist its CSV under `<out_dir>/<name>.csv` — the
/// plain writer for the non-shardable drivers (table2/fig8/theory).
/// Shardable drivers must go through [`SweepCtx::emit_table`] /
/// [`SweepCtx::emit_raw`] instead, which suppress artifacts in shard
/// mode.
pub fn emit(table: &Table, out_dir: &str, name: &str) -> Result<()> {
    table.print();
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/{name}.csv"), table.to_csv())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_sweep_spacing_matches_legacy() {
        let cfg = EngineConfig::default().seeded(7);
        let sweep = seed_sweep(&cfg, 3);
        assert_eq!(sweep.len(), 3);
        assert_eq!(
            sweep.iter().map(|c| c.seed).collect::<Vec<_>>(),
            vec![7, 1007, 2007]
        );
        // everything but the seed is identical
        assert_eq!(sweep[0].n, cfg.n);
        assert_eq!(sweep[2].t_max, cfg.t_max);
    }

    #[test]
    fn avg_from_outputs_handles_empty() {
        let a = Avg::from_outputs(&[]);
        assert_eq!(a.accuracy, 0.0);
        assert_eq!(a.total, 0.0);
        assert!(a.curve.is_empty());
    }

    #[test]
    fn avg_curves_are_pointwise_means() {
        let mk = |curve: Vec<(usize, f64)>| crate::fed::EngineOutput {
            accuracy: 0.5,
            accuracy_curve: curve,
            per_device_loss: Vec::new(),
            ledger: Default::default(),
            movement: Default::default(),
            similarity: (0.0, 0.0),
            mean_active: 0.0,
            total_collected: 0,
        };
        // exactly-representable values so the pointwise mean is exact
        let a = Avg::from_outputs(&[
            mk(vec![(10, 0.25), (20, 0.5)]),
            mk(vec![(10, 0.75), (20, 1.0)]),
        ]);
        assert_eq!(a.curve, vec![(10, 0.5), (20, 0.75)]);
        // any run without a curve suppresses the mean (mixed grids)
        let b = Avg::from_outputs(&[mk(vec![(10, 0.25)]), mk(Vec::new())]);
        assert!(b.curve.is_empty());
    }
}
