//! Table II: accuracy of centralized / federated / network-aware learning
//! across {MLP, CNN} × {synthetic, testbed} costs × {iid, non-iid}.
//!
//! Expected shape (paper): network-aware within ~4% of federated in every
//! cell; non-iid below iid; network-aware slightly better on testbed than
//! synthetic costs (compute–communication correlation enables cheaper
//! offloading and hence more processed data).

use anyhow::Result;

use crate::config::{EngineConfig, Method};
use crate::costs::{CostSource, Medium};
use crate::experiments::common::{emit, run_avg};
use crate::experiments::ExpOptions;
use crate::runtime::{ModelKind, Runtime};
use crate::util::table::{pct, Table};

pub fn run(opts: &ExpOptions) -> Result<()> {
    let rt = Runtime::load_default()?;
    let models = match opts.model {
        Some(m) => vec![m],
        None => vec![ModelKind::Mlp, ModelKind::Cnn],
    };

    let mut table = Table::new(
        "Table II — learning methodology vs accuracy",
        &["Methodology", "Synthetic MLP", "Synthetic CNN", "Testbed MLP", "Testbed CNN"],
    );

    let cell = |cfg: EngineConfig| -> Result<String> {
        let (avg, _) = run_avg(&rt, &cfg, opts.seeds)?;
        Ok(pct(avg.accuracy))
    };

    let row = |label: &str, build: &dyn Fn(CostSource, ModelKind) -> EngineConfig| -> Result<Vec<String>> {
        let mut cells = vec![label.to_string()];
        for source in [CostSource::Synthetic, CostSource::Testbed(Medium::Lte)] {
            for &model in &[ModelKind::Mlp, ModelKind::Cnn] {
                if models.contains(&model) {
                    cells.push(cell(build(source, model))?);
                } else {
                    cells.push("-".into());
                }
            }
        }
        Ok(cells)
    };

    let base = EngineConfig::default();

    // Centralized and federated ignore network costs: same numbers across
    // the cost columns, as in the paper.
    let b = base.clone();
    table.row(row("Centralized", &move |src, m| {
        b.clone().with(|c| {
            c.method = Method::Centralized;
            c.model = m;
            c.lr = crate::config::default_lr(m);
            c.cost_source = src;
        })
    })?);
    let b = base.clone();
    table.row(row("Federated (iid)", &move |src, m| {
        b.clone().with(|c| {
            c.method = Method::Federated;
            c.model = m;
            c.lr = crate::config::default_lr(m);
            c.cost_source = src;
        })
    })?);
    let b = base.clone();
    table.row(row("Federated (non-iid)", &move |src, m| {
        b.clone().with(|c| {
            c.method = Method::Federated;
            c.model = m;
            c.lr = crate::config::default_lr(m);
            c.cost_source = src;
            c.iid = false;
        })
    })?);
    let b = base.clone();
    table.row(row("Network-aware (iid)", &move |src, m| {
        b.clone().with(|c| {
            c.method = Method::NetworkAware;
            c.model = m;
            c.lr = crate::config::default_lr(m);
            c.cost_source = src;
        })
    })?);
    let b = base.clone();
    table.row(row("Network-aware (non-iid)", &move |src, m| {
        b.clone().with(|c| {
            c.method = Method::NetworkAware;
            c.model = m;
            c.lr = crate::config::default_lr(m);
            c.cost_source = src;
            c.iid = false;
        })
    })?);

    emit(&table, &opts.out_dir, "table2")
}
