//! Figure 4: (a) per-device training loss over time under network-aware
//! learning; (b) data similarity between devices before vs after offloading
//! (non-iid, many runs).
//!
//! Expected shape (paper): loss mean and variance decrease over time; the
//! after-offloading similarity sits above the y = x diagonal in almost all
//! runs (≈ +10% average).

use anyhow::Result;

use crate::config::EngineConfig;
use crate::experiments::common::{emit_curves, emit_raw, run_avg, with_eval};
use crate::experiments::ExpOptions;
use crate::fed;
use crate::runtime::Runtime;
use crate::util::stats;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let rt = Runtime::load_default()?;
    let mut base = EngineConfig::default();
    if let Some(m) = opts.model {
        base = base.with_model(m);
    }

    // --- (a) per-device loss trajectories (single representative run) ------
    // under --curve the same run also traces test accuracy through the
    // fed::eval planner (fig4a_curve.csv)
    let cfg = with_eval(base.clone().with(|c| c.iid = false), opts);
    let out = fed::run(&cfg, &rt)?;
    emit_curves(
        &[("network-aware/non-iid".to_string(), out.accuracy_curve.as_slice())],
        &opts.out_dir,
        "fig4a",
    )?;
    let mut csv = String::from("t,device,loss\n");
    let mut first_window = Vec::new();
    let mut last_window = Vec::new();
    for (t, row) in out.per_device_loss.iter().enumerate() {
        for (i, loss) in row.iter().enumerate() {
            if let Some(l) = loss {
                csv.push_str(&format!("{t},{i},{l}\n"));
                if t < cfg.t_max / 5 {
                    first_window.push(*l as f64);
                } else if t >= cfg.t_max * 4 / 5 {
                    last_window.push(*l as f64);
                }
            }
        }
    }
    emit_raw(&csv, &opts.out_dir, "fig4a_loss")?;
    println!("== Fig 4a — per-device training loss (network-aware, non-iid) ==");
    println!(
        "first fifth: mean {:.3} (σ {:.3});  last fifth: mean {:.3} (σ {:.3})",
        stats::mean(&first_window),
        stats::std_dev(&first_window),
        stats::mean(&last_window),
        stats::std_dev(&last_window),
    );
    println!();

    // --- (b) similarity before vs after over many short runs ----------------
    // the paper uses 100 experiments; scale by --seeds (seeds × 8 runs)
    let runs = (opts.seeds * 8).max(8);
    let mut csv = String::from("run,before,after\n");
    let mut improved = 0usize;
    let mut deltas = Vec::new();
    for r in 0..runs {
        let cfg_r = base
            .clone()
            .with(|c| {
                c.iid = false;
                // keep these cheap: similarity needs no long horizon
                c.t_max = 40;
                c.n_train = 3200;
            })
            .seeded(2000 + r as u64);
        let (avg, _) = run_avg(&rt, &cfg_r, 1)?;
        csv.push_str(&format!("{r},{},{}\n", avg.similarity_before, avg.similarity_after));
        if avg.similarity_after > avg.similarity_before {
            improved += 1;
        }
        deltas.push(avg.similarity_after - avg.similarity_before);
    }
    emit_raw(&csv, &opts.out_dir, "fig4b_similarity")?;
    println!("== Fig 4b — data similarity before vs after offloading ({runs} runs, non-iid) ==");
    println!(
        "improved in {improved}/{runs} runs; mean improvement {:+.1}%",
        100.0 * stats::mean(&deltas)
    );
    println!();
    Ok(())
}
