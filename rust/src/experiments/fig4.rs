//! Figure 4: (a) per-device training loss over time under network-aware
//! learning; (b) data similarity between devices before vs after offloading
//! (non-iid, many runs).
//!
//! Expected shape (paper): loss mean and variance decrease over time; the
//! after-offloading similarity sits above the y = x diagonal in almost all
//! runs (≈ +10% average).
//!
//! Every run — the representative trajectory and the similarity batch —
//! goes through the shared [`crate::coordinator::SweepCtx`], so the
//! driver shards across processes via `--shard I/N`
//! ([`crate::coordinator::shard`]).

use anyhow::Result;

use crate::coordinator::SweepCtx;
use crate::experiments::common::{emit_curves, with_eval};
use crate::experiments::ExpOptions;
use crate::util::stats;

/// Run Fig. 4. Routes runs and output through `ctx`, so the same code
/// serves full, `--shard I/N` and `fogml merge` invocations.
pub fn run(opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let base = opts.base_config();

    // --- (a) per-device loss trajectories (single representative run) ------
    // under --curve the same run also traces test accuracy through the
    // fed::eval planner (fig4a_curve.csv)
    // fig4a reads the dense per-device loss rows: opt in to the trace
    // state explicitly (on by default, but this driver *requires* it —
    // DESIGN.md §Perf rule 14)
    let cfg = with_eval(
        base.clone().with(|c| {
            c.iid = false;
            c.trace = true;
        }),
        opts,
    );
    let out = ctx.run_many(std::slice::from_ref(&cfg))?.remove(0);
    emit_curves(
        ctx,
        &[("network-aware/non-iid".to_string(), out.accuracy_curve.as_slice())],
        &opts.out_dir,
        "fig4a",
    )?;
    let mut csv = String::from("t,device,loss\n");
    let mut first_window = Vec::new();
    let mut last_window = Vec::new();
    for (t, row) in out.per_device_loss.iter().enumerate() {
        for (i, loss) in row.iter().enumerate() {
            if let Some(l) = loss {
                csv.push_str(&format!("{t},{i},{l}\n"));
                if t < cfg.t_max / 5 {
                    first_window.push(*l as f64);
                } else if t >= cfg.t_max * 4 / 5 {
                    last_window.push(*l as f64);
                }
            }
        }
    }
    ctx.emit_raw(&csv, &opts.out_dir, "fig4a_loss")?;
    ctx.say("== Fig 4a — per-device training loss (network-aware, non-iid) ==");
    ctx.say(&format!(
        "first fifth: mean {:.3} (σ {:.3});  last fifth: mean {:.3} (σ {:.3})",
        stats::mean(&first_window),
        stats::std_dev(&first_window),
        stats::mean(&last_window),
        stats::std_dev(&last_window),
    ));
    ctx.say("");

    // --- (b) similarity before vs after over many short runs ----------------
    // the paper uses 100 experiments; scale by --seeds (seeds × 8 runs),
    // fanned out as ONE batch so --jobs (and --shard) actually parallelize
    let runs = (opts.seeds * 8).max(8);
    let cfgs: Vec<_> = (0..runs)
        .map(|r| {
            base.clone()
                .with(|c| {
                    c.iid = false;
                    // keep these cheap: similarity needs no long horizon
                    c.t_max = 40;
                    c.n_train = 3200;
                    // the similarity pipeline is *built from* the
                    // collected/processed trace logs — explicit opt-in
                    c.trace = true;
                })
                .seeded(2000 + r as u64)
        })
        .collect();
    let outs = ctx.run_many(&cfgs)?;
    let mut csv = String::from("run,before,after\n");
    let mut improved = 0usize;
    let mut deltas = Vec::new();
    for (r, o) in outs.iter().enumerate() {
        let (before, after) = o.similarity;
        csv.push_str(&format!("{r},{before},{after}\n"));
        if after > before {
            improved += 1;
        }
        deltas.push(after - before);
    }
    ctx.emit_raw(&csv, &opts.out_dir, "fig4b_similarity")?;
    ctx.say(&format!(
        "== Fig 4b — data similarity before vs after offloading ({runs} runs, non-iid) =="
    ));
    ctx.say(&format!(
        "improved in {improved}/{runs} runs; mean improvement {:+.1}%",
        100.0 * stats::mean(&deltas)
    ));
    ctx.say("");
    Ok(())
}
