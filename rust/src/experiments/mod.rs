//! Experiment drivers: one per table and figure of the paper's evaluation
//! (§V), plus the theorem-validation suite. Each driver prints the paper's
//! rows/series as console tables and writes CSV under `results/`.
//!
//! See DESIGN.md §4 for the experiment index mapping every driver to the
//! paper artifact it regenerates and the expected qualitative shape. The
//! sweep drivers (Tables III–IV, Figs. 5–7, 9–10) fan their (config, seed)
//! grids out through [`crate::coordinator::SimPool`]; `--jobs N` controls
//! the worker count (`--jobs 1` reproduces serial numbers bit-for-bit).

pub mod common;
pub mod fig4;
pub mod fig5_7;
pub mod fig8;
pub mod fig9_10;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod theory;

use anyhow::{bail, Result};

use crate::coordinator::SimPool;
use crate::fed::eval::EvalSchedule;
use crate::runtime::ModelKind;

/// Options shared by all drivers.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Seeds per configuration (the paper averages ≥ 5; default 3 for
    /// wall-clock friendliness — pass `--seeds 5` for the paper protocol).
    pub seeds: usize,
    /// Override the model for sweep drivers (Table II always runs both).
    pub model: Option<ModelKind>,
    pub out_dir: String,
    /// Concurrent engine runs for the pooled sweep drivers (`--jobs`).
    pub jobs: usize,
    /// Evaluate an accuracy curve per run and emit `<name>_curve.csv`
    /// (`--curve`). Off by default: curves cost one evaluation per
    /// aggregation per run.
    pub curve: bool,
    /// What each curve point evaluates (`--eval-schedule`): a full test
    /// pass, or rotating seeded subsets for ≈K× cheaper curves
    /// (`fed::eval::EvalSchedule`).
    pub eval_schedule: EvalSchedule,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            seeds: 3,
            model: None,
            out_dir: "results".into(),
            jobs: 1,
            curve: false,
            eval_schedule: EvalSchedule::Full,
        }
    }
}

/// Run one named experiment (or `all`). One [`SimPool`] is shared by every
/// pooled driver of this invocation, so `exp all --jobs N` compiles the XLA
/// entry points once per worker instead of once per driver (DESIGN.md §Perf
/// "compile once").
pub fn dispatch(which: &str, opts: &ExpOptions) -> Result<()> {
    let pool = SimPool::new(opts.jobs);
    dispatch_with(which, opts, &pool)
}

fn dispatch_with(which: &str, opts: &ExpOptions, pool: &SimPool) -> Result<()> {
    let started = std::time::Instant::now();
    match which {
        "table2" => table2::run(opts)?,
        "table3" => table3::run(opts, pool)?,
        "table4" => table4::run(opts, pool)?,
        "table5" => table5::run(opts)?,
        "fig4" => fig4::run(opts)?,
        "fig5" => fig5_7::run_fig5(opts, pool)?,
        "fig6" => fig5_7::run_fig6(opts, pool)?,
        "fig7" => fig5_7::run_fig7(opts, pool)?,
        "fig8" => fig8::run(opts)?,
        "fig9" => fig9_10::run_fig9(opts, pool)?,
        "fig10" => fig9_10::run_fig10(opts, pool)?,
        "theory" => theory::run(opts)?,
        "all" => {
            for name in [
                "table2", "table3", "table4", "table5", "fig4", "fig5", "fig6",
                "fig7", "fig8", "fig9", "fig10", "theory",
            ] {
                dispatch_with(name, opts, pool)?;
            }
        }
        other => bail!("unknown experiment '{other}'"),
    }
    eprintln!("[{which} done in {:.1?}]", started.elapsed());
    Ok(())
}
