//! Experiment drivers: one per table and figure of the paper's evaluation
//! (§V), plus the theorem-validation suite. Each driver prints the paper's
//! rows/series as console tables and writes CSV under `results/`.
//!
//! See DESIGN.md §4 for the experiment index mapping every driver to the
//! paper artifact it regenerates and the expected qualitative shape, and
//! EXPERIMENTS.md for the command ↔ output-file table. The sweep drivers
//! (Tables III–V, Figs. 4–7, 9–10) fan their (config, seed) grids out
//! through [`crate::coordinator::SimPool`]; `--jobs N` controls the
//! worker count (`--jobs 1` reproduces serial numbers bit-for-bit).
//!
//! The same drivers also shard across processes: `fogml exp <name>
//! --shard I/N --out DIR` runs the I-th round-robin slice of the grid and
//! serializes it to `DIR/shard_I_of_N.json` (or `.fsb` under
//! `--shard-format binary`); `fogml merge DIR` validates the set and
//! regenerates artifacts byte-identical to an unsharded run whichever
//! format the shards used (the contract lives in
//! [`crate::coordinator::shard`]).

pub mod common;
pub mod fig4;
pub mod fig5_7;
pub mod fig8;
pub mod fig9_10;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod theory;

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::config::{EngineConfig, SolverThreads};
use crate::coordinator::shard::{self, ShardFormat, ShardSpec, SweepCtx};
use crate::coordinator::SimPool;
use crate::fed::eval::EvalSchedule;
use crate::fed::participation::ParticipationSchedule;
use crate::runtime::ModelKind;
use crate::util::json::Json;

/// Options shared by all drivers.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Seeds per configuration (the paper averages ≥ 5; default 3 for
    /// wall-clock friendliness — pass `--seeds 5` for the paper protocol).
    pub seeds: usize,
    /// Override the model for sweep drivers (Table II always runs both).
    pub model: Option<ModelKind>,
    /// Output directory for CSV artifacts — and for
    /// `shard_I_of_N.{json,fsb}` when sharding.
    pub out_dir: String,
    /// Concurrent engine runs for the pooled sweep drivers (`--jobs`).
    pub jobs: usize,
    /// Evaluate an accuracy curve per run and emit `<name>_curve.csv`
    /// (`--curve`). Off by default: curves cost one evaluation per
    /// aggregation per run.
    pub curve: bool,
    /// What each curve point evaluates (`--eval-schedule`): a full test
    /// pass, or rotating seeded subsets for ≈K× cheaper curves
    /// (`fed::eval::EvalSchedule`).
    pub eval_schedule: EvalSchedule,
    /// Share `K` **coalescing** runtime services across the pool's
    /// workers instead of one classic service per worker
    /// (`--services K`; [`SimPool::coalescing`]): concurrent sessions'
    /// batched train/eval requests pack into shared largest-tile
    /// dispatches. Outputs are invariant to `K`, to `jobs` and to the
    /// co-scheduled partners, and agree with the default per-worker
    /// services within the DESIGN.md §Perf rule 7/8 tolerances (the tile
    /// policy differs) — which is why the value is recorded in the shard
    /// opts blob: `fogml merge` refuses to mix shards run under
    /// different service modes.
    pub services: Option<usize>,
    /// Override the movement solvers' worker-thread budget
    /// (`--solver-threads`; [`SolverThreads`]). `None` keeps the config
    /// default (`Auto`). Purely a wall-clock knob: chunked reductions
    /// make every setting bit-identical (DESIGN.md §Perf rule 12), so —
    /// unlike `services` — merges never need to reject mixed values.
    pub solver_threads: Option<SolverThreads>,
    /// Per-period device sampling schedule (`--participation`;
    /// [`ParticipationSchedule`]). `None` keeps the config default
    /// (`Full`). Sampling changes which devices train — unlike
    /// `solver_threads` this is grid identity, so the value is recorded
    /// in the shard opts blob and `fogml merge` refuses mixed-schedule
    /// sets (DESIGN.md §Perf rule 13).
    pub participation: Option<ParticipationSchedule>,
    /// Run only this round-robin slice of the grid and write a shard
    /// file instead of artifacts (`--shard I/N`; see
    /// [`crate::coordinator::shard`]). Only the pool-backed drivers
    /// ([`SHARDABLE`]) support it.
    pub shard: Option<ShardSpec>,
    /// On-disk encoding of the shard file written under `--shard`
    /// (`--shard-format json|binary`; default JSON). Deliberately *not*
    /// part of the recorded opts blob: the format is pure I/O, not grid
    /// identity, and `fogml merge` auto-detects it per file.
    pub shard_format: ShardFormat,
    /// Override the base config the pool-backed drivers expand their
    /// grids from (library/test hook — no CLI flag; scaled-down smoke
    /// grids and `tests/shard_merge.rs` use it). `None` means
    /// [`EngineConfig::default`], the paper protocol.
    pub base: Option<EngineConfig>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            seeds: 3,
            model: None,
            out_dir: "results".into(),
            jobs: 1,
            curve: false,
            eval_schedule: EvalSchedule::Full,
            services: None,
            solver_threads: None,
            participation: None,
            shard: None,
            shard_format: ShardFormat::default(),
            base: None,
        }
    }
}

impl ExpOptions {
    /// The base config a driver expands its grid from: the `base`
    /// override (or the paper defaults) with the `--model` override
    /// applied on top.
    pub fn base_config(&self) -> EngineConfig {
        let mut base = self.base.clone().unwrap_or_default();
        if let Some(t) = self.solver_threads {
            base.solver_threads = t;
        }
        if let Some(p) = self.participation {
            base.participation = p;
        }
        match self.model {
            Some(m) => base.with_model(m),
            None => base,
        }
    }
}

/// The experiments whose grids shard across processes: every pool-backed
/// driver. `table2`, `fig8` and `theory` run serial cells on a local
/// runtime and stay single-process.
pub const SHARDABLE: &[&str] = &[
    "table3", "table4", "table5", "fig4", "fig5", "fig6", "fig7", "fig9", "fig10",
];

/// Run one named experiment (or `all`). One [`SimPool`] is shared by every
/// pooled driver of this invocation, so `exp all --jobs N` compiles the XLA
/// entry points once per worker instead of once per driver (DESIGN.md §Perf
/// "compile once"). With `opts.shard` set, runs only that slice of a
/// [`SHARDABLE`] experiment's grid and writes `shard_I_of_N.{json,fsb}`
/// (per `opts.shard_format`) under `opts.out_dir` instead of artifacts.
pub fn dispatch(which: &str, opts: &ExpOptions) -> Result<()> {
    if opts.shard.is_some() && !SHARDABLE.contains(&which) {
        bail!(
            "experiment '{which}' is not shardable — --shard supports one of: {}",
            SHARDABLE.join(", ")
        );
    }
    let pool = match opts.services {
        None => SimPool::new(opts.jobs),
        Some(k) => SimPool::coalescing(opts.jobs, k),
    };
    match opts.shard {
        None => dispatch_with(which, opts, &SweepCtx::full(&pool)),
        Some(spec) => {
            let ctx = SweepCtx::sharded(&pool, spec);
            dispatch_with(which, opts, &ctx)?;
            let owned = ctx.runs_owned();
            let path = ctx.write_shard_file(
                which,
                opts_to_json(opts),
                Path::new(&opts.out_dir),
                opts.shard_format,
            )?;
            eprintln!("[shard {spec} of {which}: {owned} runs -> {}]", path.display());
            Ok(())
        }
    }
}

/// Merge a shard directory produced by `fogml exp <name> --shard I/N`:
/// validate the set, then replay the driver against the recorded runs so
/// every artifact lands in `out_dir` (default: the shard directory
/// itself) byte-identical to an unsharded run. Driver options are
/// reconstructed from the shard files.
pub fn merge(dir: &str, out_dir: Option<&str>) -> Result<()> {
    let set = shard::load_shard_set(Path::new(dir))?;
    let mut opts = opts_from_json(&set.opts)
        .map_err(|e| anyhow!("reconstructing options from {dir}: {e}"))?;
    opts.out_dir = out_dir.unwrap_or(dir).to_string();
    merge_set(set, &opts)
}

/// [`merge`] with caller-supplied options — the library/test entry point
/// for grids that were sharded under an `ExpOptions::base` override
/// (which the shard files record only by fingerprint). The options must
/// reproduce the sharded grid exactly; any drift fails the per-run
/// fingerprint validation.
pub fn merge_with_opts(dir: &str, opts: &ExpOptions) -> Result<()> {
    merge_set(shard::load_shard_set(Path::new(dir))?, opts)
}

fn merge_set(set: shard::ShardSet, opts: &ExpOptions) -> Result<()> {
    if !SHARDABLE.contains(&set.experiment.as_str()) {
        bail!("shard set names experiment '{}', which is not shardable", set.experiment);
    }
    eprintln!(
        "[merging {} runs of {} from {} shard(s)]",
        set.runs.len(),
        set.experiment,
        set.count
    );
    // merge replays recorded outputs — the pool spawns no PJRT runtime
    // because no compute request ever reaches it
    let pool = SimPool::new(1);
    let ctx = SweepCtx::merged(&pool, set.runs);
    dispatch_with(&set.experiment, opts, &ctx)?;
    ctx.finish_merge()
}

fn opts_to_json(o: &ExpOptions) -> Json {
    Json::obj(vec![
        ("seeds", Json::from(o.seeds)),
        (
            "model",
            match o.model {
                None => Json::Null,
                Some(ModelKind::Mlp) => Json::from("mlp"),
                Some(ModelKind::Cnn) => Json::from("cnn"),
            },
        ),
        ("curve", Json::from(o.curve)),
        (
            "eval_schedule",
            Json::from(match o.eval_schedule {
                EvalSchedule::Full => "full".to_string(),
                EvalSchedule::Subset { shards } => format!("subset:{shards}"),
            }),
        ),
        (
            "services",
            match o.services {
                None => Json::Null,
                Some(k) => Json::from(k),
            },
        ),
        (
            "solver_threads",
            match o.solver_threads {
                None => Json::Null,
                Some(SolverThreads::Auto) => Json::from("auto".to_string()),
                Some(SolverThreads::Fixed(k)) => Json::from(k.to_string()),
            },
        ),
        (
            "participation",
            match o.participation {
                None => Json::Null,
                Some(p) => Json::from(p.label()),
            },
        ),
    ])
}

fn opts_from_json(j: &Json) -> Result<ExpOptions> {
    let mut opts = ExpOptions::default();
    opts.seeds = j
        .get("seeds")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("shard opts: missing 'seeds'"))?;
    opts.model = match j.get("model") {
        None | Some(Json::Null) => None,
        Some(m) => Some(ModelKind::parse(
            m.as_str().ok_or_else(|| anyhow!("shard opts: 'model' not a string"))?,
        )?),
    };
    opts.curve = matches!(j.get("curve"), Some(Json::Bool(true)));
    opts.eval_schedule = EvalSchedule::parse(
        j.get("eval_schedule").and_then(Json::as_str).unwrap_or("full"),
    )?;
    // absent (pre-scheduler shard files) and explicit null both mean the
    // default per-worker services
    opts.services = j.get("services").and_then(Json::as_usize);
    // same convention: absent (older shard files) and null both mean the
    // config default (and the knob is output-invariant anyway)
    opts.solver_threads = match j.get("solver_threads").and_then(Json::as_str) {
        Some(s) => Some(SolverThreads::parse(s)?),
        None => None,
    };
    // absent (pre-sampling shard files) and null both mean the config
    // default (Full). The merge-time opts equality check compares the
    // raw blobs, so a Full-vs-uniform mix is refused before this runs.
    opts.participation = match j.get("participation").and_then(Json::as_str) {
        Some(s) => Some(ParticipationSchedule::parse(s)?),
        None => None,
    };
    Ok(opts)
}

fn dispatch_with(which: &str, opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let started = std::time::Instant::now();
    match which {
        "table2" => table2::run(opts)?,
        "table3" => table3::run(opts, ctx)?,
        "table4" => table4::run(opts, ctx)?,
        "table5" => table5::run(opts, ctx)?,
        "fig4" => fig4::run(opts, ctx)?,
        "fig5" => fig5_7::run_fig5(opts, ctx)?,
        "fig6" => fig5_7::run_fig6(opts, ctx)?,
        "fig7" => fig5_7::run_fig7(opts, ctx)?,
        "fig8" => fig8::run(opts)?,
        "fig9" => fig9_10::run_fig9(opts, ctx)?,
        "fig10" => fig9_10::run_fig10(opts, ctx)?,
        "theory" => theory::run(opts)?,
        "all" => {
            for name in [
                "table2", "table3", "table4", "table5", "fig4", "fig5", "fig6",
                "fig7", "fig8", "fig9", "fig10", "theory",
            ] {
                dispatch_with(name, opts, ctx)?;
            }
        }
        other => bail!("unknown experiment '{other}'"),
    }
    eprintln!("[{which} done in {:.1?}]", started.elapsed());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opts_round_trip_through_json() {
        let mut o = ExpOptions::default();
        o.seeds = 5;
        o.model = Some(ModelKind::Cnn);
        o.curve = true;
        o.eval_schedule = EvalSchedule::Subset { shards: 4 };
        o.services = Some(2);
        o.solver_threads = Some(SolverThreads::Fixed(4));
        let back = opts_from_json(&opts_to_json(&o)).unwrap();
        assert_eq!(back.seeds, 5);
        assert_eq!(back.model, Some(ModelKind::Cnn));
        assert!(back.curve);
        assert_eq!(back.eval_schedule, EvalSchedule::Subset { shards: 4 });
        assert_eq!(back.services, Some(2));
        assert_eq!(back.solver_threads, Some(SolverThreads::Fixed(4)));

        o.solver_threads = Some(SolverThreads::Auto);
        let back = opts_from_json(&opts_to_json(&o)).unwrap();
        assert_eq!(back.solver_threads, Some(SolverThreads::Auto));

        o.participation = Some(ParticipationSchedule::ImportanceK { k: 3 });
        let back = opts_from_json(&opts_to_json(&o)).unwrap();
        assert_eq!(back.participation, Some(ParticipationSchedule::ImportanceK { k: 3 }));

        let d = opts_from_json(&opts_to_json(&ExpOptions::default())).unwrap();
        assert_eq!(d.seeds, 3);
        assert_eq!(d.model, None);
        assert!(!d.curve);
        assert_eq!(d.eval_schedule, EvalSchedule::Full);
        assert_eq!(d.services, None);
        assert_eq!(d.solver_threads, None);
        assert_eq!(d.participation, None);
    }

    #[test]
    fn shard_rejects_non_shardable() {
        let opts = ExpOptions {
            shard: Some(ShardSpec { index: 1, count: 2 }),
            ..Default::default()
        };
        for which in ["table2", "fig8", "theory", "all"] {
            let err = dispatch(which, &opts).unwrap_err().to_string();
            assert!(err.contains("not shardable") || err.contains("unknown"), "{which}: {err}");
        }
    }

    #[test]
    fn base_config_applies_model_on_top() {
        let tiny = EngineConfig::default().with(|c| c.n = 4);
        let opts = ExpOptions {
            base: Some(tiny),
            model: Some(ModelKind::Cnn),
            ..Default::default()
        };
        let base = opts.base_config();
        assert_eq!(base.n, 4);
        assert_eq!(base.model, ModelKind::Cnn);
        assert_eq!(base.lr, crate::config::default_lr(ModelKind::Cnn));
    }
}
