//! Theorem validation: the analytical results of §IV checked against
//! simulation on this implementation.
//!
//! * Theorem 2 — the D/M/1 capacity rule bounds the mean waiting time.
//! * Theorem 4 — closed-form r*, s* vs the convex PGD solver.
//! * Theorem 5 — eq. (15) offloading savings vs Monte-Carlo, linear in C.
//! * Theorem 6 — expected capacity violations vs simulation.

use anyhow::Result;

use crate::experiments::common::emit;
use crate::experiments::ExpOptions;
use crate::movement::theory as mv_theory;
use crate::queueing::{capacity_for_waiting_time, dm1, straggler};
use crate::topology::generators;
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

pub fn run(opts: &ExpOptions) -> Result<()> {
    theorem2(opts)?;
    theorem4(opts)?;
    theorem5(opts)?;
    theorem6(opts)?;
    Ok(())
}

fn theorem2(opts: &ExpOptions) -> Result<()> {
    let mut table = Table::new(
        "Theorem 2 — D/M/1 capacity rule vs simulated waiting time",
        &["mu", "sigma", "C (rule)", "W analytic", "W simulated", "W <= sigma"],
    );
    let mut rng = Rng::new(42);
    for (mu, sigma) in [(1.0, 1.0), (2.0, 0.5), (0.5, 2.0), (1.0, 0.25)] {
        let c = capacity_for_waiting_time(mu, sigma);
        let analytic = dm1::mean_waiting_time(mu, c);
        let sim = straggler::simulate(mu, c, 200_000, &mut rng);
        table.row(vec![
            fnum(mu, 2),
            fnum(sigma, 2),
            fnum(c, 4),
            fnum(analytic, 4),
            fnum(sim.mean_wait, 4),
            (sim.mean_wait <= sigma * 1.05).to_string(),
        ]);
    }
    emit(&table, &opts.out_dir, "theory_thm2")
}

fn theorem4(opts: &ExpOptions) -> Result<()> {
    use crate::costs::CostSchedule;
    use crate::movement::convex::{self, PgdOptions};
    use crate::movement::problem::{DiscardModel, MovementProblem};

    let mut table = Table::new(
        "Theorem 4 — closed form vs convex solver (hierarchical offloading)",
        &["c_i", "r* closed", "r* PGD", "s* closed", "s* PGD"],
    );

    let n_dev = 3;
    let n = n_dev + 1;
    let server = n_dev;
    let graph = generators::star(n, server);
    let gamma = 60.0;
    let c_t = 0.05;
    let c_server = 0.12;
    let c_dev = [0.4, 0.6, 0.8];
    let d_i = 600.0;

    let mut costs = CostSchedule::zeros(n, 2);
    for t in 0..2 {
        for i in 0..n_dev {
            costs.compute[t][i] = c_dev[i];
            costs.error_weight[t][i] = gamma;
            costs.link[t][i * n + server] = c_t;
        }
        costs.compute[t][server] = c_server;
        costs.error_weight[t][server] = gamma;
    }
    let mut d = vec![d_i; n_dev];
    d.push(0.0);
    let inbound = vec![0.0; n];
    let active = vec![true; n];
    let p = MovementProblem {
        t: 0,
        graph: &graph,
        active: &active,
        d: &d,
        inbound_prev: &inbound,
        costs: &costs,
        discard_model: DiscardModel::Sqrt,
    };
    let plan = convex::solve(&p, PgdOptions { iterations: 4000, step0: 0.0, tol: 0.0 });
    let closed = mv_theory::theorem4_closed_form(gamma, &c_dev, c_server, c_t, &vec![d_i; n_dev]);
    for i in 0..n_dev {
        table.row(vec![
            fnum(c_dev[i], 2),
            fnum(closed.r[i], 4),
            fnum(plan.r[i], 4),
            fnum(closed.s[i], 4),
            fnum(plan.s(i, server), 4),
        ]);
    }
    emit(&table, &opts.out_dir, "theory_thm4")
}

fn theorem5(opts: &ExpOptions) -> Result<()> {
    let mut table = Table::new(
        "Theorem 5 — value of offloading: eq. (15) vs Monte-Carlo (scale-free, γ = 2.5)",
        &["C", "savings eq15", "savings MC", "savings / C"],
    );
    let fracs = mv_theory::scale_free_degree_fracs(2.5, 20);
    let mut rng = Rng::new(7);
    for c in [0.5, 1.0, 2.0, 4.0] {
        let analytic = mv_theory::theorem5_savings(c, &fracs);
        // Monte-Carlo with degrees drawn from the same distribution
        let mut mc = 0.0;
        let trials = 40_000;
        for _ in 0..trials {
            let k = sample_degree(&fracs, &mut rng);
            mc += mv_theory::simulate_savings(c, k as u64, 1, &mut rng);
        }
        mc /= trials as f64;
        table.row(vec![
            fnum(c, 1),
            fnum(analytic, 4),
            fnum(mc, 4),
            fnum(analytic / c, 4),
        ]);
    }
    emit(&table, &opts.out_dir, "theory_thm5")
}

fn sample_degree(fracs: &[f64], rng: &mut Rng) -> usize {
    let u = rng.f64();
    let mut acc = 0.0;
    for (k, &f) in fracs.iter().enumerate() {
        acc += f;
        if u < acc {
            return k.max(1);
        }
    }
    fracs.len() - 1
}

fn theorem6(opts: &ExpOptions) -> Result<()> {
    let mut table = Table::new(
        "Theorem 6 — expected capacity violations: formula vs simulation",
        &["graph", "D", "E[viol] formula", "E[viol] simulated"],
    );
    let mut rng = Rng::new(9);
    let cap_samples: Vec<f64> = (0..400).map(|_| rng.uniform(2.0, 14.0)).collect();
    for (name, graph) in [
        ("scale-free(60,2)", generators::scale_free(60, 2, &mut rng)),
        ("erdos-renyi(40,0.1)", generators::erdos_renyi(40, 0.1, &mut rng)),
        ("small-world(50,4)", generators::watts_strogatz(50, 4, 0.3, &mut rng)),
    ] {
        let d = 5.0;
        let formula = mv_theory::theorem6_expected_violations(&graph, d, &cap_samples);
        let sim = mv_theory::simulate_violations(&graph, d, 1.0, &cap_samples, 2000, &mut rng);
        table.row(vec![
            name.to_string(),
            fnum(d, 1),
            fnum(formula, 2),
            fnum(sim, 2),
        ]);
    }
    emit(&table, &opts.out_dir, "theory_thm6")
}
