//! Table IV: effect of the discard-cost model — `f·D·r` vs `−f·G` vs
//! `f/√G` — under settings B (unconstrained) and D (capacities).
//!
//! Expected shape (paper): `−f·G` trades cost for accuracy (more transfer,
//! more data processed, higher accuracy); `f·D·r` is close to the convex
//! `f/√G` on both cost and accuracy.

use anyhow::Result;

use crate::config::{CapacityPolicy, EngineConfig};
use crate::experiments::common::{emit, run_avg};
use crate::experiments::ExpOptions;
use crate::movement::DiscardModel;
use crate::runtime::Runtime;
use crate::util::table::{fnum, pct, Table};

pub fn run(opts: &ExpOptions) -> Result<()> {
    let rt = Runtime::load_default()?;
    let mut base = EngineConfig::default();
    if let Some(m) = opts.model {
        base = base.with_model(m);
    }

    let mut table = Table::new(
        "Table IV — discard-cost model comparison (settings B and D)",
        &["Objective", "Setting", "Acc iid", "Acc non-iid", "Pr", "Tr", "Di", "Tot"],
    );

    for (model, label) in [
        (DiscardModel::LinearR, "f·D·r"),
        (DiscardModel::LinearG, "-f·G"),
        (DiscardModel::Sqrt, "f/sqrt(G)"),
    ] {
        for (setting, cap) in [("B", CapacityPolicy::Unconstrained), ("D", CapacityPolicy::MeanArrivals)] {
            let cfg = base.clone().with(|c| {
                c.discard_model = model;
                c.capacity = cap;
            });
            let (avg_iid, _) = run_avg(&rt, &cfg, opts.seeds)?;
            let (avg_noniid, _) =
                run_avg(&rt, &cfg.clone().with(|c| c.iid = false), opts.seeds)?;
            table.row(vec![
                label.to_string(),
                setting.to_string(),
                pct(avg_iid.accuracy),
                pct(avg_noniid.accuracy),
                fnum(avg_iid.process, 0),
                fnum(avg_iid.transfer, 0),
                fnum(avg_iid.discard, 0),
                fnum(avg_iid.total, 0),
            ]);
        }
    }

    emit(&table, &opts.out_dir, "table4")
}
