//! Table IV: effect of the discard-cost model — `f·D·r` vs `−f·G` vs
//! `f/√G` — under settings B (unconstrained) and D (capacities).
//!
//! Expected shape (paper): `−f·G` trades cost for accuracy (more transfer,
//! more data processed, higher accuracy); `f·D·r` is close to the convex
//! `f/√G` on both cost and accuracy.
//!
//! All (model × setting × {iid, non-iid} × seed) runs fan out through one
//! [`crate::coordinator::SimPool`] batch, and shard across processes
//! via `--shard I/N` ([`crate::coordinator::shard`]).

use anyhow::Result;

use crate::config::{CapacityPolicy, EngineConfig};
use crate::coordinator::SweepCtx;
use crate::experiments::common::run_avg_iid_pairs;
use crate::experiments::ExpOptions;
use crate::movement::DiscardModel;
use crate::util::table::{fnum, pct, Table};

/// Run Table IV. Routes runs and output through `ctx`, so the same code
/// serves full, `--shard I/N` and `fogml merge` invocations.
pub fn run(opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let base = opts.base_config();

    let mut rows: Vec<(&'static str, &'static str, EngineConfig)> = Vec::new();
    for (model, label) in [
        (DiscardModel::LinearR, "f·D·r"),
        (DiscardModel::LinearG, "-f·G"),
        (DiscardModel::Sqrt, "f/sqrt(G)"),
    ] {
        for (setting, cap) in
            [("B", CapacityPolicy::Unconstrained), ("D", CapacityPolicy::MeanArrivals)]
        {
            let cfg = base.clone().with(|c| {
                c.discard_model = model;
                c.capacity = cap;
            });
            rows.push((label, setting, cfg));
        }
    }

    let cfgs: Vec<EngineConfig> = rows.iter().map(|(_, _, cfg)| cfg.clone()).collect();
    let pairs = run_avg_iid_pairs(ctx, &cfgs, opts.seeds)?;

    let mut table = Table::new(
        "Table IV — discard-cost model comparison (settings B and D)",
        &["Objective", "Setting", "Acc iid", "Acc non-iid", "Pr", "Tr", "Di", "Tot"],
    );

    for ((label, setting, _), (avg_iid, avg_noniid)) in rows.iter().zip(&pairs) {
        table.row(vec![
            label.to_string(),
            setting.to_string(),
            pct(avg_iid.accuracy),
            pct(avg_noniid.accuracy),
            fnum(avg_iid.process, 0),
            fnum(avg_iid.transfer, 0),
            fnum(avg_iid.discard, 0),
            fnum(avg_iid.total, 0),
        ]);
    }

    ctx.emit_table(&table, &opts.out_dir, "table4")
}
