//! Table III: network costs and accuracy across settings A–E.
//!
//! A — offloading and discarding disabled (plain federated),
//! B — perfect information, no capacity constraints,
//! C — imperfect information, no capacity constraints,
//! D — perfect information, capacity constraints,
//! E — imperfect information, capacity constraints.
//!
//! Expected shape (paper): A has the highest unit cost (all processing);
//! B cuts unit cost ≈ 50% by offloading/discarding; C ≈ B (robust to
//! estimation error); D/E discard more due to capacities; accuracy ordering
//! A ≈ B ≈ C > D ≈ E, with non-iid uniformly below iid.
//!
//! All (setting × {iid, non-iid} × seed) runs fan out through one
//! [`crate::coordinator::SimPool`] batch, and shard across processes
//! via `--shard I/N` ([`crate::coordinator::shard`]).

use anyhow::Result;

use crate::config::{CapacityPolicy, EngineConfig, InfoMode, Method};
use crate::coordinator::SweepCtx;
use crate::experiments::common::run_avg_iid_pairs;
use crate::experiments::ExpOptions;
use crate::util::table::{fnum, pct, Table};

/// The five settings as config transforms.
pub fn settings(base: &EngineConfig) -> Vec<(&'static str, EngineConfig)> {
    vec![
        ("A", base.clone().with(|c| c.method = Method::Federated)),
        ("B", base.clone()),
        (
            "C",
            base.clone()
                .with(|c| c.info = InfoMode::Estimated(EngineConfig::DEFAULT_EST_WINDOWS)),
        ),
        ("D", base.clone().with(|c| c.capacity = CapacityPolicy::MeanArrivals)),
        (
            "E",
            base.clone().with(|c| {
                c.info = InfoMode::Estimated(EngineConfig::DEFAULT_EST_WINDOWS);
                c.capacity = CapacityPolicy::MeanArrivals;
            }),
        ),
    ]
}

/// Run Table III. Routes runs and output through `ctx`, so the same code
/// serves full, `--shard I/N` and `fogml merge` invocations.
pub fn run(opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let base = opts.base_config();

    let named = settings(&base);
    let cfgs: Vec<EngineConfig> = named.iter().map(|(_, cfg)| cfg.clone()).collect();
    let pairs = run_avg_iid_pairs(ctx, &cfgs, opts.seeds)?;

    let mut table = Table::new(
        "Table III — settings A–E: accuracy and network costs",
        &["Setting", "Acc iid", "Acc non-iid", "Process", "Transfer", "Discard", "Total", "Unit"],
    );

    for ((name, _), (avg_iid, avg_noniid)) in named.iter().zip(&pairs) {
        // costs are identical for iid/non-iid (the optimization is
        // distribution-agnostic) — report the iid ledger like the paper
        table.row(vec![
            name.to_string(),
            pct(avg_iid.accuracy),
            pct(avg_noniid.accuracy),
            fnum(avg_iid.process, 0),
            fnum(avg_iid.transfer, 0),
            fnum(avg_iid.discard, 0),
            fnum(avg_iid.total, 0),
            fnum(avg_iid.unit, 3),
        ]);
    }

    ctx.emit_table(&table, &opts.out_dir, "table3")
}
