//! Figure 8: cost components across fog topologies (social / hierarchical /
//! fully connected) over LTE vs WiFi media.
//!
//! Expected shape (paper): the fully-connected topology maximizes offload
//! opportunities, the hierarchical minimizes them (sparser edges → more
//! local processing/discarding); WiFi's dearer, heavier-tailed links skew
//! all topologies toward discarding, with both transfer and discard costs
//! above their LTE counterparts.

use anyhow::Result;

use crate::config::{EngineConfig, TopologyKind};
use crate::costs::{CostSource, Medium};
use crate::experiments::common::{emit, run_avg};
use crate::experiments::ExpOptions;
use crate::runtime::Runtime;
use crate::util::table::{fnum, Table};

pub fn run(opts: &ExpOptions) -> Result<()> {
    let rt = Runtime::load_default()?;
    let mut base = EngineConfig::default();
    if let Some(m) = opts.model {
        base = base.with_model(m);
    }

    let mut table = Table::new(
        "Fig 8 — cost components by topology and medium",
        &["Medium", "Topology", "Process", "Transfer", "Discard", "Total", "Unit"],
    );

    for (medium, med_name) in [(Medium::Lte, "LTE"), (Medium::Wifi, "WiFi")] {
        for (topo, topo_name) in [
            (TopologyKind::SmallWorld, "social"),
            (TopologyKind::Hierarchical, "hierarchical"),
            (TopologyKind::Full, "fully-connected"),
        ] {
            let cfg = base.clone().with(|c| {
                c.cost_source = CostSource::Testbed(medium);
                c.topology = topo;
            });
            let (avg, _) = run_avg(&rt, &cfg, opts.seeds)?;
            table.row(vec![
                med_name.to_string(),
                topo_name.to_string(),
                fnum(avg.process, 0),
                fnum(avg.transfer, 0),
                fnum(avg.discard, 0),
                fnum(avg.total, 0),
                fnum(avg.unit, 3),
            ]);
        }
    }

    emit(&table, &opts.out_dir, "fig8_topologies")
}
