//! Table V: network-aware learning on static vs dynamic networks
//! (`p_exit = p_entry = 1%`).
//!
//! Expected shape (paper): ~20% fewer active nodes per period, ≈ 6% higher
//! unit cost, ≈ 1% accuracy decline.
//!
//! Both cells run through the shared [`crate::coordinator::SweepCtx`],
//! so the driver shards across processes via `--shard I/N`
//! ([`crate::coordinator::shard`]).

use anyhow::Result;

use crate::config::{Churn, EngineConfig};
use crate::coordinator::SweepCtx;
use crate::experiments::common::{emit_curves, run_avg_ctx, with_eval};
use crate::experiments::ExpOptions;
use crate::util::table::{fnum, pct, Table};

/// Run Table V. Routes runs and output through `ctx`, so the same code
/// serves full, `--shard I/N` and `fogml merge` invocations.
pub fn run(opts: &ExpOptions, ctx: &SweepCtx) -> Result<()> {
    let base = opts.base_config();

    let mut table = Table::new(
        "Table V — static vs dynamic networks (p_exit = p_entry = 1%)",
        &["Setting", "Acc", "Nodes", "Process", "Transfer", "Discard", "Unit"],
    );

    let static_cfg = with_eval(base.clone(), opts);
    let dynamic_cfg = with_eval(
        base.clone()
            .with(|c| c.churn = Some(Churn { p_exit: 0.01, p_entry: 0.01 })),
        opts,
    );

    // under --curve this also traces accuracy over time for both settings
    // (how churn bends the curve, not just the endpoint — §V-E)
    let mut curves: Vec<(String, Vec<(usize, f64)>)> = Vec::new();
    for (name, cfg) in [("Static", static_cfg), ("Dynamic", dynamic_cfg)] {
        let (avg, _) = run_avg_ctx(ctx, &cfg, opts.seeds)?;
        table.row(vec![
            name.to_string(),
            pct(avg.accuracy),
            fnum(avg.mean_active, 1),
            fnum(avg.process, 0),
            fnum(avg.transfer, 0),
            fnum(avg.discard, 0),
            fnum(avg.unit, 3),
        ]);
        curves.push((name.to_string(), avg.curve));
    }

    ctx.emit_table(&table, &opts.out_dir, "table5")?;
    let series: Vec<(String, &[(usize, f64)])> = curves
        .iter()
        .map(|(label, c)| (label.clone(), c.as_slice()))
        .collect();
    emit_curves(ctx, &series, &opts.out_dir, "table5")
}
