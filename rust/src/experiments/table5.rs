//! Table V: network-aware learning on static vs dynamic networks
//! (`p_exit = p_entry = 1%`).
//!
//! Expected shape (paper): ~20% fewer active nodes per period, ≈ 6% higher
//! unit cost, ≈ 1% accuracy decline.

use anyhow::Result;

use crate::config::{Churn, EngineConfig};
use crate::experiments::common::{emit, run_avg};
use crate::experiments::ExpOptions;
use crate::runtime::Runtime;
use crate::util::table::{fnum, pct, Table};

pub fn run(opts: &ExpOptions) -> Result<()> {
    let rt = Runtime::load_default()?;
    let mut base = EngineConfig::default();
    if let Some(m) = opts.model {
        base = base.with_model(m);
    }

    let mut table = Table::new(
        "Table V — static vs dynamic networks (p_exit = p_entry = 1%)",
        &["Setting", "Acc", "Nodes", "Process", "Transfer", "Discard", "Unit"],
    );

    let static_cfg = base.clone();
    let dynamic_cfg = base
        .clone()
        .with(|c| c.churn = Some(Churn { p_exit: 0.01, p_entry: 0.01 }));

    for (name, cfg) in [("Static", static_cfg), ("Dynamic", dynamic_cfg)] {
        let (avg, _) = run_avg(&rt, &cfg, opts.seeds)?;
        table.row(vec![
            name.to_string(),
            pct(avg.accuracy),
            fnum(avg.mean_active, 1),
            fnum(avg.process, 0),
            fnum(avg.transfer, 0),
            fnum(avg.discard, 0),
            fnum(avg.unit, 3),
        ]);
    }

    emit(&table, &opts.out_dir, "table5")
}
