//! Distribution of the training set across fog devices over time.
//!
//! Implements the paper's data-collection model (§V-A): the number of
//! samples `|D_i(t)|` collected by device `i` in interval `t` is Poisson
//! with mean `|D_V| / (n·T)`; under **iid** each device samples uniformly at
//! random without replacement from the global pool, while under **non-iid**
//! each device is restricted to a random subset of 5 of the 10 labels and
//! samples only from those.

use crate::data::dataset::{Dataset, NUM_CLASSES};
use crate::util::rng::Rng;

/// Per-device, per-interval arrival schedule: `schedule[i][t]` holds the
/// indices (into the training [`Dataset`]) collected by device `i` at `t`.
#[derive(Debug, Clone)]
pub struct Arrivals {
    pub schedule: Vec<Vec<Vec<u32>>>,
    /// Labels available to each device (all 10 under iid).
    pub device_labels: Vec<Vec<u8>>,
}

impl Arrivals {
    pub fn num_devices(&self) -> usize {
        self.schedule.len()
    }

    pub fn num_intervals(&self) -> usize {
        self.schedule.first().map_or(0, |s| s.len())
    }

    /// Total datapoints collected by all devices over all time (= |D_V|
    /// actually dealt, ≤ dataset size under iid-without-replacement).
    pub fn total_collected(&self) -> usize {
        self.schedule
            .iter()
            .flat_map(|dev| dev.iter().map(|iv| iv.len()))
            .sum()
    }

    /// D_i(t) as a count matrix [i][t].
    pub fn counts(&self) -> Vec<Vec<usize>> {
        self.schedule
            .iter()
            .map(|dev| dev.iter().map(|iv| iv.len()).collect())
            .collect()
    }
}

/// How many labels a non-iid device can observe (paper: 5 of 10).
pub const NON_IID_LABELS: usize = 5;

/// Builds [`Arrivals`] from a dataset.
#[derive(Debug, Clone, Copy)]
pub struct Partitioner {
    pub n_devices: usize,
    pub t_max: usize,
    pub iid: bool,
}

impl Partitioner {
    /// Deal the dataset. Mean arrivals per device-interval is
    /// `train.len() / (n_devices * t_max)` as in the paper.
    pub fn partition(&self, train: &Dataset, rng: &mut Rng) -> Arrivals {
        let mean = train.len() as f64 / (self.n_devices * self.t_max) as f64;

        // Pools of available sample indices, per label.
        let mut by_label: Vec<Vec<u32>> = vec![Vec::new(); NUM_CLASSES];
        for (i, &l) in train.labels.iter().enumerate() {
            by_label[l as usize].push(i as u32);
        }
        for pool in by_label.iter_mut() {
            rng.shuffle(pool);
        }

        // Device label menus.
        let device_labels: Vec<Vec<u8>> = (0..self.n_devices)
            .map(|_| {
                if self.iid {
                    (0..NUM_CLASSES as u8).collect()
                } else {
                    let mut ls = rng.sample_indices(NUM_CLASSES, NON_IID_LABELS);
                    ls.sort_unstable();
                    ls.into_iter().map(|l| l as u8).collect()
                }
            })
            .collect();

        let mut schedule =
            vec![vec![Vec::<u32>::new(); self.t_max]; self.n_devices];
        for t in 0..self.t_max {
            for i in 0..self.n_devices {
                let count = rng.poisson(mean);
                let menu = &device_labels[i];
                let mut taken = Vec::with_capacity(count);
                for _ in 0..count {
                    // draw a label uniformly from the device's menu, then pop
                    // an unused sample of that label; skip exhausted labels.
                    let mut attempts = 0;
                    while attempts < menu.len() {
                        let l = *rng.choose(menu) as usize;
                        if let Some(idx) = by_label[l].pop() {
                            taken.push(idx);
                            break;
                        }
                        attempts += 1;
                    }
                    if attempts == menu.len() {
                        // all menu labels exhausted: sweep for any remaining
                        if let Some(idx) = menu
                            .iter()
                            .find_map(|&l| by_label[l as usize].pop())
                        {
                            taken.push(idx);
                        }
                    }
                }
                schedule[i][t] = taken;
            }
        }
        Arrivals { schedule, device_labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::SynthDigits;

    fn dataset(n: usize) -> Dataset {
        let gen = SynthDigits::new(1);
        let mut rng = Rng::new(2);
        gen.generate(n, &mut rng)
    }

    #[test]
    fn iid_deals_most_of_the_pool_once() {
        let ds = dataset(2000);
        let p = Partitioner { n_devices: 10, t_max: 20, iid: true };
        let mut rng = Rng::new(3);
        let arr = p.partition(&ds, &mut rng);
        let total = arr.total_collected();
        // Poisson total ~ N(2000, sqrt); allow slack + pool exhaustion
        assert!(total > 1700 && total <= 2000, "total={total}");

        // no index dealt twice
        let mut seen = vec![false; ds.len()];
        for dev in &arr.schedule {
            for iv in dev {
                for &idx in iv {
                    assert!(!seen[idx as usize], "duplicate {idx}");
                    seen[idx as usize] = true;
                }
            }
        }
    }

    #[test]
    fn non_iid_devices_see_only_their_labels() {
        let ds = dataset(3000);
        let p = Partitioner { n_devices: 8, t_max: 25, iid: false };
        let mut rng = Rng::new(4);
        let arr = p.partition(&ds, &mut rng);
        for (i, dev) in arr.schedule.iter().enumerate() {
            let menu = &arr.device_labels[i];
            assert_eq!(menu.len(), NON_IID_LABELS);
            for iv in dev {
                for &idx in iv {
                    assert!(
                        menu.contains(&ds.labels[idx as usize]),
                        "device {i} saw foreign label"
                    );
                }
            }
        }
    }

    #[test]
    fn arrivals_are_poisson_like() {
        let ds = dataset(8000);
        let p = Partitioner { n_devices: 10, t_max: 100, iid: true };
        let mut rng = Rng::new(5);
        let arr = p.partition(&ds, &mut rng);
        let counts = arr.counts();
        let mean = 8000.0 / (10.0 * 100.0); // 8
        let all: Vec<f64> = counts.iter().flatten().map(|&c| c as f64).collect();
        let m = crate::util::stats::mean(&all);
        // pool exhaustion near the end biases down slightly
        assert!((m - mean).abs() < 1.0, "mean={m}");
        let v = crate::util::stats::variance(&all);
        assert!(v > 0.5 * mean && v < 2.0 * mean, "var={v}");
    }

    #[test]
    fn deterministic_partition() {
        let ds = dataset(500);
        let p = Partitioner { n_devices: 4, t_max: 10, iid: false };
        let a = p.partition(&ds, &mut Rng::new(7));
        let b = p.partition(&ds, &mut Rng::new(7));
        assert_eq!(a.schedule, b.schedule);
        assert_eq!(a.device_labels, b.device_labels);
    }
}
