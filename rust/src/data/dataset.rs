//! SynthDigits: a deterministic synthetic stand-in for MNIST.
//!
//! The paper evaluates on MNIST (70K 28×28 grayscale digits, 10 classes).
//! This environment is offline, so we synthesize a visually-structured
//! 10-class image dataset with the properties the experiments actually
//! exercise:
//!
//! * fixed class-conditional distributions (the paper's `D_i` model §III-A3),
//! * enough intra-class variation that model accuracy is a meaningful,
//!   non-saturated signal (centralized > federated-noniid, accuracy grows
//!   with data volume),
//! * deterministic generation under a seed.
//!
//! Each class gets a smooth random prototype image (low-frequency random
//! field, built by box-blurring white noise); a sample is its prototype with
//! a random ±1-pixel cyclic shift (spatial jitter), multiplicative contrast
//! jitter, and additive pixel noise. Classes overlap enough that a linear
//! model cannot reach 100%.

use crate::util::rng::Rng;

/// Image side length; must match `python/compile/common.py::IMG_SIDE`
/// (checked against artifacts/manifest.json at runtime load).
pub const IMG_SIDE: usize = 14;
/// Flattened image size.
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
/// Number of classes.
pub const NUM_CLASSES: usize = 10;

/// A labelled image dataset in flattened row-major f32 form.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `len * IMG_PIXELS` pixel values (roughly zero-mean, unit-ish range).
    pub images: Vec<f32>,
    /// `len` labels in `0..NUM_CLASSES`.
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Pixel slice of sample `i`.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]
    }

    /// Per-class sample counts.
    pub fn class_counts(&self) -> [usize; NUM_CLASSES] {
        let mut counts = [0usize; NUM_CLASSES];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }
}

/// Generator for the SynthDigits distribution (holds the class prototypes).
#[derive(Debug, Clone)]
pub struct SynthDigits {
    prototypes: Vec<f32>, // NUM_CLASSES * IMG_PIXELS
    noise_std: f32,
}

/// Amount of additive pixel noise. Chosen (together with [`COMMON_BLEND`])
/// so an MLP trained centrally on a few thousand samples lands in the
/// low-90s accuracy range (comparable signal-to-headroom as MNIST MLP in
/// the paper's Table II) while a nearest-prototype classifier stays well
/// below 100%.
const DEFAULT_NOISE_STD: f32 = 1.1;

/// Fraction of each prototype that is class-unique; the rest is a shared
/// background field, which makes classes overlap (no classifier can win on
/// the background component).
const COMMON_BLEND: f32 = 0.40;

impl SynthDigits {
    /// Build class prototypes deterministically from `seed`.
    pub fn new(seed: u64) -> Self {
        Self::with_noise(seed, DEFAULT_NOISE_STD)
    }

    pub fn with_noise(seed: u64, noise_std: f32) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EED_DA7A);
        let smooth_field = |rng: &mut Rng| {
            // white noise -> 2 passes of 3x3 box blur -> standardize
            let mut field: Vec<f32> = (0..IMG_PIXELS).map(|_| rng.normal() as f32).collect();
            for _ in 0..2 {
                field = box_blur(&field);
            }
            standardize(&mut field);
            field
        };
        let common = smooth_field(&mut rng);
        let mut prototypes = vec![0f32; NUM_CLASSES * IMG_PIXELS];
        for c in 0..NUM_CLASSES {
            let unique = smooth_field(&mut rng);
            let proto = &mut prototypes[c * IMG_PIXELS..(c + 1) * IMG_PIXELS];
            for (p, (u, bg)) in proto.iter_mut().zip(unique.iter().zip(&common)) {
                *p = COMMON_BLEND * u + (1.0 - COMMON_BLEND) * bg;
            }
            standardize(proto);
        }
        SynthDigits { prototypes, noise_std }
    }

    /// Draw one sample of class `label` into `out`.
    pub fn sample_into(&self, label: usize, rng: &mut Rng, out: &mut [f32]) {
        debug_assert_eq!(out.len(), IMG_PIXELS);
        let proto = &self.prototypes[label * IMG_PIXELS..(label + 1) * IMG_PIXELS];
        // cyclic spatial jitter in {-1, 0, 1}^2
        let dx = rng.below(3) as isize - 1;
        let dy = rng.below(3) as isize - 1;
        // contrast jitter
        let gain = 1.0 + 0.2 * rng.normal() as f32;
        for y in 0..IMG_SIDE {
            for x in 0..IMG_SIDE {
                let sy = (y as isize + dy).rem_euclid(IMG_SIDE as isize) as usize;
                let sx = (x as isize + dx).rem_euclid(IMG_SIDE as isize) as usize;
                let noise = self.noise_std * rng.normal() as f32;
                out[y * IMG_SIDE + x] = gain * proto[sy * IMG_SIDE + sx] + noise;
            }
        }
    }

    /// Generate a dataset of `n` samples with uniformly-random labels.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Dataset {
        let mut images = vec![0f32; n * IMG_PIXELS];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let label = rng.below(NUM_CLASSES);
            labels.push(label as u8);
            self.sample_into(label, rng, &mut images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]);
        }
        Dataset { images, labels }
    }

    /// Standard train/test split generation used by all experiments.
    pub fn train_test(&self, n_train: usize, n_test: usize, rng: &mut Rng) -> (Dataset, Dataset) {
        (self.generate(n_train, rng), self.generate(n_test, rng))
    }
}

fn box_blur(field: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; IMG_PIXELS];
    for y in 0..IMG_SIDE {
        for x in 0..IMG_SIDE {
            let mut acc = 0f32;
            for dy in -1isize..=1 {
                for dx in -1isize..=1 {
                    let sy = (y as isize + dy).rem_euclid(IMG_SIDE as isize) as usize;
                    let sx = (x as isize + dx).rem_euclid(IMG_SIDE as isize) as usize;
                    acc += field[sy * IMG_SIDE + sx];
                }
            }
            out[y * IMG_SIDE + x] = acc / 9.0;
        }
    }
    out
}

fn standardize(xs: &mut [f32]) {
    let n = xs.len() as f32;
    let mean = xs.iter().sum::<f32>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    for x in xs.iter_mut() {
        *x = (*x - mean) / std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let gen = SynthDigits::new(1);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = gen.generate(50, &mut r1);
        let b = gen.generate(50, &mut r2);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images, b.images);
    }

    #[test]
    fn class_balance_roughly_uniform() {
        let gen = SynthDigits::new(2);
        let mut rng = Rng::new(3);
        let ds = gen.generate(5000, &mut rng);
        for &c in ds.class_counts().iter() {
            assert!((c as f64 - 500.0).abs() < 120.0, "{:?}", ds.class_counts());
        }
    }

    #[test]
    fn prototypes_distinct_between_classes() {
        let gen = SynthDigits::new(4);
        for a in 0..NUM_CLASSES {
            for b in (a + 1)..NUM_CLASSES {
                let pa = &gen.prototypes[a * IMG_PIXELS..(a + 1) * IMG_PIXELS];
                let pb = &gen.prototypes[b * IMG_PIXELS..(b + 1) * IMG_PIXELS];
                let dist: f32 = pa.iter().zip(pb).map(|(x, y)| (x - y) * (x - y)).sum();
                assert!(dist > 0.5, "classes {a},{b} too close: {dist}");
            }
        }
    }

    #[test]
    fn samples_cluster_around_own_prototype() {
        // nearest-prototype classification on clean-ish samples should beat
        // chance by a wide margin — guarantees the task is learnable.
        let gen = SynthDigits::new(5);
        let mut rng = Rng::new(6);
        let ds = gen.generate(500, &mut rng);
        let mut correct = 0;
        for i in 0..ds.len() {
            let img = ds.image(i);
            let mut best = 0;
            let mut best_d = f32::INFINITY;
            for c in 0..NUM_CLASSES {
                let proto = &gen.prototypes[c * IMG_PIXELS..(c + 1) * IMG_PIXELS];
                let d: f32 = img.iter().zip(proto).map(|(x, y)| (x - y) * (x - y)).sum();
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if best == ds.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.len() as f64;
        assert!(acc > 0.55, "nearest-prototype acc too low: {acc}");
        assert!(acc < 0.995, "task degenerate (acc={acc})");
    }

    #[test]
    fn image_accessor_bounds() {
        let gen = SynthDigits::new(7);
        let mut rng = Rng::new(8);
        let ds = gen.generate(3, &mut rng);
        assert_eq!(ds.image(2).len(), IMG_PIXELS);
        assert_eq!(ds.len(), 3);
    }
}
