//! Data substrate: the SynthDigits dataset and its distribution over fog
//! devices (iid and non-iid, Poisson arrivals), replacing the paper's MNIST
//! per DESIGN.md §2 (offline environment).

pub mod dataset;
pub mod partition;

pub use dataset::{Dataset, SynthDigits};
pub use partition::{Arrivals, Partitioner};
