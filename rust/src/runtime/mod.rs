//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `make artifacts` and executes them on the XLA CPU client.
//!
//! Python never runs here — the HLO text is parsed, compiled once per entry
//! point, and executed from the L3 hot path. See DESIGN.md for why HLO
//! *text* (not serialized protos) is the interchange format.
//!
//! NOTE: `xla::PjRtClient` is `Rc`-based (not `Send`), so a [`Runtime`] is
//! confined to the thread that created it. The [`crate::coordinator`]
//! module provides the message-passing service wrapper for multi-threaded
//! use.

pub mod artifact;
pub mod model;
pub mod tensor;

pub use artifact::Manifest;
pub use model::{backend_available, test_runtime, ModelKind, Runtime};
pub use tensor::{literal_from_slice, HostTensor};
