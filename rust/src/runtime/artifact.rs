//! Artifact manifest: the positional ABI contract between the python AOT
//! pipeline and the rust runtime.
//!
//! `artifacts/manifest.json` (written by `python -m compile.aot`) records,
//! for every entry point, the input/output dtypes+shapes and the shared
//! shape constants. The runtime refuses to start on a mismatch with the
//! crate's compiled-in constants — shape drift between the layers is a
//! build error, not a runtime surprise.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::dataset::{IMG_PIXELS, NUM_CLASSES};
use crate::util::json::Json;

/// Shape+dtype of one tensor in an entry's signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Device-stack capacity `D` of a batched `*_train_many_d<D>` entry
    /// (leading axis of every mapped tensor); `None` for scalar entries.
    pub devices: Option<usize>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    /// Compiled device-stack sizes of the batched train entries, ascending
    /// (empty when the artifacts predate the batched path).
    pub device_tiles: Vec<usize>,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl Manifest {
    /// Default artifacts location: `$FOGML_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("FOGML_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> Result<Manifest> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        if json.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("unexpected manifest format (want hlo-text)");
        }

        let consts = json
            .get("constants")
            .ok_or_else(|| anyhow!("manifest missing constants"))?;
        let get_const = |k: &str| -> Result<usize> {
            consts
                .get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest missing constant {k}"))
        };
        // cross-layer shape agreement
        let img_pixels = get_const("img_pixels")?;
        let num_classes = get_const("num_classes")?;
        if img_pixels != IMG_PIXELS || num_classes != NUM_CLASSES {
            bail!(
                "artifact shape drift: python built img_pixels={img_pixels}, \
                 num_classes={num_classes}; rust expects {IMG_PIXELS}/{NUM_CLASSES}. \
                 Re-run `make artifacts`."
            );
        }
        let batch = get_const("batch")?;
        // absent in pre-batching artifact sets: the runtime then serves
        // scalar train entries only and the trainer falls back per device
        let mut device_tiles: Vec<usize> = consts
            .get("device_tiles")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        device_tiles.sort_unstable();

        let mut entries = BTreeMap::new();
        let raw_entries = json
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        for (name, e) in raw_entries {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry {name} missing file"))?;
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("entry {name} missing {key}"))?
                    .iter()
                    .map(|s| {
                        Ok(TensorSpec {
                            dtype: s
                                .get("dtype")
                                .and_then(Json::as_str)
                                .unwrap_or("float32")
                                .to_string(),
                            shape: s
                                .get("shape")
                                .and_then(Json::as_arr)
                                .ok_or_else(|| anyhow!("bad shape in {name}"))?
                                .iter()
                                .map(|d| d.as_usize().unwrap_or(0))
                                .collect(),
                        })
                    })
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    devices: e.get("devices").and_then(Json::as_usize),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), batch, device_tiles, entries })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact entry '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Requires `make artifacts` (Makefile runs it before `cargo test`).
    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load_default().expect("run `make artifacts` first");
        assert_eq!(m.batch, 32);
        for name in ["mlp_train", "mlp_eval", "cnn_train", "cnn_eval", "dense_micro"] {
            let e = m.entry(name).unwrap();
            assert!(e.file.exists(), "{} missing", e.file.display());
            assert!(!e.inputs.is_empty());
            assert!(!e.outputs.is_empty());
        }
        // train ABI: params..., x, onehot, wt, lr
        let train = m.entry("mlp_train").unwrap();
        assert_eq!(train.inputs.len(), 8);
        assert_eq!(train.outputs.len(), 5);
        assert_eq!(train.devices, None);
        let x = &train.inputs[4];
        assert_eq!(x.shape, vec![m.batch, IMG_PIXELS]);
        // batched variants: every tile size present with a [D, B, ...] x
        assert!(!m.device_tiles.is_empty());
        assert!(m.device_tiles.windows(2).all(|w| w[0] < w[1]));
        for &d in &m.device_tiles {
            let many = m.entry(&format!("mlp_train_many_d{d}")).unwrap();
            assert_eq!(many.devices, Some(d));
            assert_eq!(many.inputs.len(), 8);
            assert_eq!(many.inputs[4].shape, vec![d, m.batch, IMG_PIXELS]);
            assert_eq!(many.outputs[4].shape, vec![d]);
        }
        // batched eval variants: params..., x, onehot, wt -> correct[D]
        for &d in &m.device_tiles {
            let many = m.entry(&format!("mlp_eval_many_d{d}")).unwrap();
            assert_eq!(many.devices, Some(d));
            assert_eq!(many.inputs.len(), 7);
            assert_eq!(many.inputs[4].shape, vec![d, m.batch, IMG_PIXELS]);
            assert_eq!(many.inputs[5].shape, vec![d, m.batch, NUM_CLASSES]);
            assert_eq!(many.inputs[6].shape, vec![d, m.batch]);
            assert_eq!(many.outputs.len(), 1);
            assert_eq!(many.outputs[0].shape, vec![d]);
        }
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent/path")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
