//! Host-side tensors: the bridge between rust `Vec<f32>` data and XLA
//! literals.

use anyhow::{ensure, Result};

/// A shaped f32 tensor in host memory (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let len = shape.iter().product();
        HostTensor { shape, data: vec![0.0; len] }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Convert to an XLA literal of matching shape (single copy via the
    /// untyped-data constructor; `vec1 + reshape` would copy twice — see
    /// DESIGN.md §Perf).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        literal_from_slice(&self.shape, &self.data)
    }

    /// Read a literal back into host memory.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        ensure!(
            dims.iter().product::<usize>() == data.len(),
            "literal shape/data mismatch"
        );
        Ok(HostTensor { shape: dims, data })
    }

    /// Elementwise in-place axpy: `self += alpha * other` (shapes must match).
    pub fn axpy(&mut self, alpha: f32, other: &HostTensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale all elements in place.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// L2 norm (for tests / diagnostics).
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

/// Build an f32 literal of `shape` directly from a borrowed slice: the
/// zero-`HostTensor` path for staging buffers that are refilled every chunk
/// (a single copy into the literal; cloning the buffer into a fresh
/// `HostTensor` first would copy twice — DESIGN.md §Perf).
pub fn literal_from_slice(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    ensure!(
        shape.iter().product::<usize>() == data.len(),
        "shape {shape:?} does not match data length {}",
        data.len()
    );
    let bytes = unsafe {
        std::slice::from_raw_parts(
            data.as_ptr() as *const u8,
            std::mem::size_of_val(data),
        )
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_ops() {
        let mut a = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::new(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0, 4.0, 5.0, 6.0]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5, 2.0, 2.5, 3.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn borrowed_slice_literal_matches_owned() {
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let lit = literal_from_slice(&[3, 2], &data).unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape, vec![3, 2]);
        assert_eq!(back.data, data);
        assert!(literal_from_slice(&[4, 2], &data).is_err());
    }

    #[test]
    fn scalar_literal_roundtrip() {
        let t = HostTensor::scalar(0.01);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape, Vec::<usize>::new());
        assert_eq!(back.data, vec![0.01]);
    }
}
