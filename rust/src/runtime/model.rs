//! The executable runtime: compile HLO-text artifacts once, then execute
//! train/eval steps from the L3 hot path.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::{EntrySpec, Manifest};
use crate::runtime::tensor::HostTensor;
use crate::util::rng::Rng;

/// Which classifier an experiment trains (§V-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Mlp,
    Cnn,
}

impl ModelKind {
    pub fn train_entry(&self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp_train",
            ModelKind::Cnn => "cnn_train",
        }
    }

    pub fn eval_entry(&self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp_eval",
            ModelKind::Cnn => "cnn_eval",
        }
    }

    /// Name of the batched train entry compiled for a `d`-device stack.
    pub fn train_many_entry(&self, d: usize) -> String {
        format!("{}_many_d{d}", self.train_entry())
    }

    /// Name of the batched eval entry compiled for a `d`-slot stack.
    pub fn eval_many_entry(&self, d: usize) -> String {
        format!("{}_many_d{d}", self.eval_entry())
    }

    /// Number of parameter tensors (leading inputs of the train entry).
    pub fn num_params(&self) -> usize {
        match self {
            ModelKind::Mlp => 4,
            ModelKind::Cnn => 6,
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "mlp" => Ok(ModelKind::Mlp),
            "cnn" => Ok(ModelKind::Cnn),
            other => bail!("unknown model '{other}' (want mlp|cnn)"),
        }
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelKind::Mlp => write!(f, "MLP"),
            ModelKind::Cnn => write!(f, "CNN"),
        }
    }
}

/// Compiled entry point plus its signature.
pub struct Executable {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        let parts = self.run_literals(&refs)?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Hot-path variant: literals in (by reference, no copies), literals
    /// out. Lets callers keep model parameters literal-resident across
    /// successive steps instead of converting through `HostTensor` each
    /// call (DESIGN.md §Perf).
    pub fn run_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: got {} inputs, want {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        let result = self.exe.execute::<&xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // python lowers with return_tuple=True: always a tuple
        Ok(result.to_tuple()?)
    }
}

/// The per-thread runtime: PJRT CPU client + compile cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, std::rc::Rc<Executable>>>,
}

impl Runtime {
    /// Load the manifest and create the CPU client. Compilation of each
    /// entry happens lazily on first use and is cached.
    pub fn load_default() -> Result<Runtime> {
        Self::load(Manifest::load_default()?)
    }

    pub fn load(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { manifest, client, cache: RefCell::new(HashMap::new()) })
    }

    pub fn batch(&self) -> usize {
        self.manifest.batch
    }

    /// Get (compiling if necessary) an entry point.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.entry(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let executable = std::rc::Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Shared tile-selection policy of the batched entries: the smallest
    /// compiled variant with `D >= want`, or the largest one when `want`
    /// exceeds every tile (the caller then splits into several stacked
    /// executions). Returns `None` when the artifact set predates the
    /// requested batched entries, so callers can fall back to the scalar
    /// path against old artifacts.
    fn many_executable(
        &self,
        want: usize,
        entry: impl Fn(usize) -> String,
    ) -> Result<Option<(usize, std::rc::Rc<Executable>)>> {
        let tiles = &self.manifest.device_tiles;
        let Some(&d) = tiles.iter().find(|&&d| d >= want).or_else(|| tiles.last()) else {
            return Ok(None);
        };
        let name = entry(d);
        if !self.manifest.entries.contains_key(&name) {
            return Ok(None);
        }
        Ok(Some((d, self.executable(&name)?)))
    }

    /// The batched train executable sized for `want` concurrently-training
    /// devices (see [`Runtime::many_executable`] for the policy).
    pub fn train_many_executable(
        &self,
        kind: ModelKind,
        want: usize,
    ) -> Result<Option<(usize, std::rc::Rc<Executable>)>> {
        self.many_executable(want, |d| kind.train_many_entry(d))
    }

    /// The batched eval executable sized for `want` concurrently-evaluated
    /// chunk slots (see [`Runtime::many_executable`] for the policy).
    pub fn eval_many_executable(
        &self,
        kind: ModelKind,
        want: usize,
    ) -> Result<Option<(usize, std::rc::Rc<Executable>)>> {
        self.many_executable(want, |d| kind.eval_many_entry(d))
    }

    /// He-style initialization of a model's parameter tensors, shaped per
    /// the manifest (deterministic under `seed`). Weights ~ N(0, 2/fan_in),
    /// biases zero.
    pub fn init_params(&self, kind: ModelKind, seed: u64) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.entry(kind.train_entry())?;
        let mut rng = Rng::new(seed ^ 0x1217_AB1E);
        let mut params = Vec::with_capacity(kind.num_params());
        for ts in spec.inputs.iter().take(kind.num_params()) {
            let len: usize = ts.shape.iter().product();
            if ts.shape.len() >= 2 {
                // fan_in = product of all dims but the last
                let fan_in: usize = ts.shape[..ts.shape.len() - 1].iter().product();
                let scale = (2.0 / fan_in as f64).sqrt();
                let data: Vec<f32> =
                    (0..len).map(|_| (rng.normal() * scale) as f32).collect();
                params.push(HostTensor::new(ts.shape.clone(), data));
            } else {
                params.push(HostTensor::zeros(ts.shape.clone()));
            }
        }
        Ok(params)
    }
}

/// Whether this process can create a real PJRT backend. `false` only
/// under the pure-CPU `xla` stub the CI hard gate builds against
/// (`rust/ci/xla-stub`, patched in via `.cargo/config.toml`); any other
/// client-creation failure reports `true` so broken real installs fail
/// tests loudly instead of skipping them. Probed once per process.
pub fn backend_available() -> bool {
    use std::sync::OnceLock;
    static AVAILABLE: OnceLock<bool> = OnceLock::new();
    *AVAILABLE.get_or_init(|| match xla::PjRtClient::cpu() {
        Ok(_) => true,
        Err(e) => !e.to_string().contains("xla stub"),
    })
}

/// The default runtime for runtime-dependent tests: `None` (the test
/// skips, with a note on stderr) only when this build has no real XLA
/// backend — the pure-CPU CI gate. Every other failure (e.g. missing or
/// stale artifacts) panics with the classic `make artifacts` hint, so
/// the skip never masks a genuinely broken setup.
pub fn test_runtime() -> Option<Runtime> {
    if !backend_available() {
        eprintln!("test skipped: no XLA backend in this build (pure-CPU gate)");
        return None;
    }
    Some(Runtime::load_default().expect("run `make artifacts` first"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{IMG_PIXELS, NUM_CLASSES};

    fn runtime() -> Option<Runtime> {
        test_runtime()
    }

    #[test]
    fn dense_micro_executes_and_matches_cpu_reference() {
        let Some(rt) = runtime() else { return };
        let exe = rt.executable("dense_micro").unwrap();
        let (m, k, n) = (128usize, IMG_PIXELS, 128usize);
        let mut rng = Rng::new(3);
        let x = HostTensor::new(vec![m, k], (0..m * k).map(|_| rng.f32() - 0.5).collect());
        let w = HostTensor::new(vec![k, n], (0..k * n).map(|_| rng.f32() - 0.5).collect());
        let b = HostTensor::new(vec![n], (0..n).map(|_| rng.f32()).collect());
        let out = exe.run(&[x.clone(), w.clone(), b.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![m, n]);
        // reference matmul + bias + relu on host
        for row in [0usize, 17, 127] {
            for col in [0usize, 63, 127] {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += x.data[row * k + kk] * w.data[kk * n + col];
                }
                let want = (acc + b.data[col]).max(0.0);
                let got = out[0].data[row * n + col];
                assert!(
                    (want - got).abs() < 1e-3 * (1.0 + want.abs()),
                    "({row},{col}): want {want} got {got}"
                );
            }
        }
    }

    #[test]
    fn mlp_train_step_decreases_loss() {
        let Some(rt) = runtime() else { return };
        let exe = rt.executable("mlp_train").unwrap();
        let b = rt.batch();
        let mut params = rt.init_params(ModelKind::Mlp, 7).unwrap();

        // a separable toy batch: class = argmax over first NUM_CLASSES pixels
        let mut rng = Rng::new(5);
        let mut x = vec![0f32; b * IMG_PIXELS];
        let mut onehot = vec![0f32; b * NUM_CLASSES];
        for i in 0..b {
            let label = rng.below(NUM_CLASSES);
            for p in 0..IMG_PIXELS {
                x[i * IMG_PIXELS + p] = rng.f32() * 0.1;
            }
            x[i * IMG_PIXELS + label] = 3.0;
            onehot[i * NUM_CLASSES + label] = 1.0;
        }
        let xt = HostTensor::new(vec![b, IMG_PIXELS], x);
        let yt = HostTensor::new(vec![b, NUM_CLASSES], onehot);
        let wt = HostTensor::new(vec![b], vec![1.0; b]);
        let lr = HostTensor::scalar(0.1);

        let mut losses = Vec::new();
        for _ in 0..15 {
            let mut inputs = params.clone();
            inputs.extend([xt.clone(), yt.clone(), wt.clone(), lr.clone()]);
            let out = exe.run(&inputs).unwrap();
            assert_eq!(out.len(), 5);
            losses.push(out[4].data[0]);
            params = out[..4].to_vec();
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "loss did not decrease: {losses:?}"
        );
    }

    #[test]
    fn eval_entry_returns_logits() {
        let Some(rt) = runtime() else { return };
        let exe = rt.executable("mlp_eval").unwrap();
        let b = rt.batch();
        let params = rt.init_params(ModelKind::Mlp, 9).unwrap();
        let x = HostTensor::zeros(vec![b, IMG_PIXELS]);
        let mut inputs = params;
        inputs.push(x);
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![b, NUM_CLASSES]);
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn train_many_picks_smallest_sufficient_variant() {
        let Some(rt) = runtime() else { return };
        let tiles = rt.manifest.device_tiles.clone();
        assert!(!tiles.is_empty(), "artifacts predate batched entries");
        let (d, exe) = rt
            .train_many_executable(ModelKind::Mlp, 3)
            .unwrap()
            .expect("batched variant");
        assert_eq!(d, tiles.iter().copied().find(|&t| t >= 3).unwrap());
        assert_eq!(exe.spec.devices, Some(d));
        // beyond the largest tile: the largest variant (caller chunks)
        let max = *tiles.last().unwrap();
        let (d, _) = rt
            .train_many_executable(ModelKind::Mlp, max + 1)
            .unwrap()
            .unwrap();
        assert_eq!(d, max);
    }

    #[test]
    fn eval_many_picks_smallest_sufficient_variant_and_counts() {
        let Some(rt) = runtime() else { return };
        let tiles = rt.manifest.device_tiles.clone();
        let (d, exe) = rt
            .eval_many_executable(ModelKind::Mlp, 2)
            .unwrap()
            .expect("batched eval variant");
        assert_eq!(d, tiles.iter().copied().find(|&t| t >= 2).unwrap());
        assert_eq!(exe.spec.devices, Some(d));

        // zero-weight slots report exactly zero correct; all-weight slots
        // report at most the batch size
        let b = rt.batch();
        let params = rt.init_params(ModelKind::Mlp, 3).unwrap();
        let mut inputs = Vec::new();
        for p in &params {
            let mut shape = vec![d];
            shape.extend_from_slice(&p.shape);
            let mut data = Vec::with_capacity(d * p.data.len());
            for _ in 0..d {
                data.extend_from_slice(&p.data);
            }
            inputs.push(HostTensor::new(shape, data));
        }
        inputs.push(HostTensor::zeros(vec![d, b, IMG_PIXELS]));
        let mut onehot = HostTensor::zeros(vec![d, b, NUM_CLASSES]);
        for row in 0..d * b {
            onehot.data[row * NUM_CLASSES] = 1.0;
        }
        inputs.push(onehot);
        let mut wt = HostTensor::zeros(vec![d, b]);
        for col in 0..b {
            wt.data[col] = 1.0; // slot 0 live, all others idle
        }
        inputs.push(wt);
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![d]);
        assert!(out[0].data[0] >= 0.0 && out[0].data[0] <= b as f32);
        assert_eq!(out[0].data[0].fract(), 0.0, "count must be integral");
        for slot in 1..d {
            assert_eq!(out[0].data[slot], 0.0, "idle slot {slot} counted");
        }
    }

    #[test]
    fn executables_are_cached() {
        let Some(rt) = runtime() else { return };
        let a = rt.executable("mlp_eval").unwrap();
        let b = rt.executable("mlp_eval").unwrap();
        assert!(std::rc::Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn init_params_shapes_match_manifest() {
        let Some(rt) = runtime() else { return };
        for kind in [ModelKind::Mlp, ModelKind::Cnn] {
            let params = rt.init_params(kind, 1).unwrap();
            assert_eq!(params.len(), kind.num_params());
            let spec = rt.manifest.entry(kind.train_entry()).unwrap();
            for (p, s) in params.iter().zip(&spec.inputs) {
                assert_eq!(p.shape, s.shape);
            }
            // deterministic
            let again = rt.init_params(kind, 1).unwrap();
            assert_eq!(params[0].data, again[0].data);
        }
    }
}
