//! Command-line argument parsing (offline stand-in for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and an auto-generated usage block.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map_or(false, |next| !next.starts_with("--"))
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{name} {raw}: {e}")),
        }
    }

    /// Typed option with default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(name)?.unwrap_or(default))
    }

    /// First positional argument (the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn mixed_styles() {
        let a = parse(&["exp", "table2", "--seeds", "5", "--model=cnn", "--verbose"]);
        assert_eq!(a.subcommand(), Some("exp"));
        assert_eq!(a.positional[1], "table2");
        assert_eq!(a.get("seeds"), Some("5"));
        assert_eq!(a.get("model"), Some("cnn"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_access() {
        let a = parse(&["--n", "20", "--rho", "0.4"]);
        assert_eq!(a.get_or("n", 10usize).unwrap(), 20);
        assert_eq!(a.get_or("rho", 1.0f64).unwrap(), 0.4);
        assert_eq!(a.get_or("tau", 10usize).unwrap(), 10);
        assert!(a.get_parsed::<usize>("rho").is_err());
    }

    #[test]
    fn flag_before_value_option() {
        // a flag followed by another option stays a flag
        let a = parse(&["--verbose", "--n", "3"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("n"), Some("3"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse(&["--offset", "-3"]);
        // "-3" does not start with -- so it is a value
        assert_eq!(a.get("offset"), Some("-3"));
    }
}
