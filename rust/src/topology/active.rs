//! Incremental active-set view over a fixed device graph.
//!
//! Churn (§V-E) toggles a handful of devices per interval. The original
//! engine rebuilt the whole topology every interval via
//! [`Graph::restrict`] — O(V + E) allocation and reinsertion even when
//! nothing changed. `ActiveView` replaces that with a persistent bit mask:
//! entering/exiting devices flip bits in place (O(1) each, driven by the
//! [`ChurnDelta`](crate::topology::dynamics::ChurnDelta) a churn step
//! reports), and filtered adjacency is an O(degree) scan of the base
//! graph's sorted neighbor slices.
//!
//! **Equivalence contract** (pinned by the tests below against the
//! `restrict` oracle): for every device `i` active in the mask,
//! `filtered_out(g, i)` yields exactly `g.restrict(mask).out_neighbors(i)`
//! in the same ascending order — so a solver that iterates
//! (base graph + mask) sees the identical edge sequence it would have seen
//! on the restricted graph, and plans stay bit-identical
//! (DESIGN.md §Perf rule 11).

use crate::topology::dynamics::ChurnDelta;
use crate::topology::graph::Graph;

/// A mutable activity mask over device ids `0..n` with an O(1) active
/// counter and a maintained ascending active-id list. Indexable like the
/// `Vec<bool>` it replaces: `view[i]`.
///
/// The id list lets the session's per-interval stats sweeps visit
/// `O(n_active)` devices instead of `0..n` (DESIGN.md §Perf rule 14); it
/// is rebuilt by a single ascending merge per churn delta, so a quiet
/// interval costs `O(n_active)` with no per-flip `Vec::insert` memmoves.
#[derive(Debug, Clone)]
pub struct ActiveView {
    bits: Vec<bool>,
    n_active: usize,
    ids: Vec<usize>,
    scratch: Vec<usize>,
}

impl PartialEq for ActiveView {
    // `ids` is derived from `bits` (an invariant, not state) and
    // `scratch` is garbage between calls — neither participates
    fn eq(&self, other: &Self) -> bool {
        self.bits == other.bits
    }
}

impl Eq for ActiveView {}

impl ActiveView {
    /// All devices active (the engine's initial state).
    pub fn all_active(n: usize) -> Self {
        ActiveView {
            bits: vec![true; n],
            n_active: n,
            ids: (0..n).collect(),
            scratch: Vec::new(),
        }
    }

    /// All devices inactive.
    pub fn all_inactive(n: usize) -> Self {
        ActiveView {
            bits: vec![false; n],
            n_active: 0,
            ids: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Adopt an explicit mask.
    pub fn from_mask(mask: &[bool]) -> Self {
        let mut view = ActiveView {
            bits: mask.to_vec(),
            n_active: 0,
            ids: Vec::new(),
            scratch: Vec::new(),
        };
        view.rebuild_ids();
        view
    }

    fn rebuild_ids(&mut self) {
        self.ids.clear();
        self.ids
            .extend(self.bits.iter().enumerate().filter(|&(_, &b)| b).map(|(i, _)| i));
        self.n_active = self.ids.len();
    }

    pub fn n(&self) -> usize {
        self.bits.len()
    }

    /// Number of active devices — O(1), maintained across flips.
    pub fn num_active(&self) -> usize {
        self.n_active
    }

    pub fn is_active(&self, i: usize) -> bool {
        self.bits[i]
    }

    /// Flip device `i` to `on`, maintaining the counter and the sorted id
    /// list (O(n_active) memmove — `apply` merges instead on the churn
    /// hot path). Idempotent.
    pub fn set(&mut self, i: usize, on: bool) {
        if self.bits[i] != on {
            self.bits[i] = on;
            if on {
                self.n_active += 1;
                if let Err(pos) = self.ids.binary_search(&i) {
                    self.ids.insert(pos, i);
                }
            } else {
                self.n_active -= 1;
                if let Ok(pos) = self.ids.binary_search(&i) {
                    self.ids.remove(pos);
                }
            }
        }
    }

    /// Apply one churn interval's delta: exits then entries. The sets are
    /// disjoint (a device cannot both exit and enter in one step), so the
    /// order is immaterial; exits-first matches the churn semantics.
    ///
    /// The sorted id list is rebuilt with one ascending merge of the old
    /// list against the delta — O(n_active + |Δ|) total, relying on
    /// [`ChurnDelta`]'s contract that `entered`/`exited` are ascending,
    /// disjoint, and that entered devices were inactive.
    pub fn apply(&mut self, delta: &ChurnDelta) {
        for &i in &delta.exited {
            if self.bits[i] {
                self.bits[i] = false;
                self.n_active -= 1;
            }
        }
        for &i in &delta.entered {
            if !self.bits[i] {
                self.bits[i] = true;
                self.n_active += 1;
            }
        }
        self.scratch.clear();
        let mut entered = delta.entered.iter().copied().peekable();
        for &i in &self.ids {
            while let Some(&j) = entered.peek() {
                if j >= i {
                    break;
                }
                if self.bits[j] {
                    self.scratch.push(j);
                }
                entered.next();
            }
            // i was active before the delta: keep it unless it just exited
            if self.bits[i] {
                self.scratch.push(i);
            }
        }
        for j in entered {
            if self.bits[j] {
                self.scratch.push(j);
            }
        }
        std::mem::swap(&mut self.ids, &mut self.scratch);
        debug_assert_eq!(self.ids.len(), self.n_active);
    }

    /// Overwrite from a full mask (used when a session resets).
    pub fn copy_from(&mut self, mask: &[bool]) {
        assert_eq!(mask.len(), self.bits.len());
        self.bits.copy_from_slice(mask);
        self.rebuild_ids();
    }

    /// Borrow the raw mask — the shape every movement solver takes as
    /// `active: &[bool]`.
    pub fn as_slice(&self) -> &[bool] {
        &self.bits
    }

    /// Active device ids, ascending — the `O(n_active)` sweep order the
    /// session's stats loops use instead of scanning `0..n`.
    pub fn ids(&self) -> &[usize] {
        &self.ids
    }

    /// Out-neighbors of `i` in the masked graph, ascending: exactly
    /// `g.restrict(self.as_slice()).out_neighbors(i)` when `i` is active,
    /// without materializing the restricted graph.
    pub fn filtered_out<'a>(
        &'a self,
        g: &'a Graph,
        i: usize,
    ) -> impl Iterator<Item = usize> + 'a {
        let live = self.bits[i];
        g.out_neighbors(i)
            .iter()
            .copied()
            .filter(move |&j| live && self.bits[j])
    }

    /// In-neighbors of `i` in the masked graph, ascending (the transpose
    /// counterpart of [`filtered_out`](Self::filtered_out)).
    pub fn filtered_in<'a>(
        &'a self,
        g: &'a Graph,
        i: usize,
    ) -> impl Iterator<Item = usize> + 'a {
        let live = self.bits[i];
        g.in_neighbors(i)
            .iter()
            .copied()
            .filter(move |&j| live && self.bits[j])
    }
}

impl std::ops::Index<usize> for ActiveView {
    type Output = bool;
    fn index(&self, i: usize) -> &bool {
        &self.bits[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::dynamics::ChurnProcess;
    use crate::topology::generators::{erdos_renyi, watts_strogatz};
    use crate::util::rng::Rng;

    fn assert_ids_invariant(view: &ActiveView) {
        let expect: Vec<usize> = (0..view.n()).filter(|&i| view[i]).collect();
        assert_eq!(view.ids(), expect.as_slice(), "id list drifted from mask");
        assert_eq!(view.ids().len(), view.num_active());
    }

    fn assert_matches_restrict(g: &Graph, view: &ActiveView) {
        let oracle = g.restrict(view.as_slice());
        for i in 0..g.n() {
            let got_out: Vec<usize> = view.filtered_out(g, i).collect();
            assert_eq!(
                got_out,
                oracle.out_neighbors(i),
                "out-neighbors of {i} diverge from restrict"
            );
            let got_in: Vec<usize> = view.filtered_in(g, i).collect();
            assert_eq!(
                got_in,
                oracle.in_neighbors(i),
                "in-neighbors of {i} diverge from restrict"
            );
        }
    }

    #[test]
    fn counter_tracks_flips() {
        let mut v = ActiveView::all_active(5);
        assert_eq!(v.num_active(), 5);
        v.set(2, false);
        v.set(2, false); // idempotent
        assert_eq!(v.num_active(), 4);
        assert!(!v[2]);
        v.set(2, true);
        assert_eq!(v.num_active(), 5);
        let m = ActiveView::from_mask(&[true, false, true]);
        assert_eq!(v.n(), 5);
        assert_eq!(m.num_active(), 2);
    }

    #[test]
    fn enter_exit_reenter_matches_restrict_oracle() {
        let mut rng = Rng::new(11);
        let g = erdos_renyi(12, 0.4, &mut rng);
        let mut view = ActiveView::all_active(12);
        assert_matches_restrict(&g, &view);

        // exit a few
        for &i in &[3, 7, 0] {
            view.set(i, false);
        }
        assert_matches_restrict(&g, &view);
        // re-enter one, exit another
        view.set(7, true);
        view.set(5, false);
        assert_matches_restrict(&g, &view);
        // everyone back
        for i in 0..12 {
            view.set(i, true);
        }
        assert_matches_restrict(&g, &view);
        assert_eq!(view.num_active(), 12);
    }

    #[test]
    fn churn_delta_application_matches_full_mask_copy() {
        let mut rng = Rng::new(21);
        let g = watts_strogatz(20, 4, 0.3, &mut rng);
        let mut churn = ChurnProcess::new(20, 0.2, 0.2);
        let mut view = ActiveView::all_active(20);
        let mut churn_rng = Rng::new(77);
        for _ in 0..30 {
            let delta = churn.step(&mut churn_rng).clone();
            let mask = churn.active().to_vec();
            view.apply(&delta);
            assert_eq!(view.as_slice(), mask.as_slice(), "delta drifted from mask");
            assert_eq!(view.num_active(), churn.num_active());
            assert_ids_invariant(&view);
            assert_matches_restrict(&g, &view);
        }
    }

    #[test]
    fn id_list_tracks_set_and_copy_from() {
        let mut v = ActiveView::all_active(6);
        assert_ids_invariant(&v);
        v.set(4, false);
        v.set(1, false);
        v.set(4, false); // idempotent
        assert_ids_invariant(&v);
        assert_eq!(v.ids(), &[0, 2, 3, 5]);
        v.set(1, true);
        assert_ids_invariant(&v);
        v.copy_from(&[false, true, false, true, false, false]);
        assert_eq!(v.ids(), &[1, 3]);
        assert_ids_invariant(&v);
        assert_ids_invariant(&ActiveView::all_inactive(3));
        assert_ids_invariant(&ActiveView::from_mask(&[true, false, true]));
    }

    #[test]
    fn copy_from_resets_counter() {
        let mut v = ActiveView::all_inactive(4);
        v.copy_from(&[true, true, false, true]);
        assert_eq!(v.num_active(), 3);
        assert_eq!(v.as_slice(), &[true, true, false, true]);
    }
}
