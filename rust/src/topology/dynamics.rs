//! Node churn process of §V-E.
//!
//! Devices in the network exit with probability `p_exit` per interval;
//! devices outside re-enter with probability `p_entry`. The paper's
//! worst-case semantics are preserved by the federated engine: an exiting
//! node cannot ship its local update first, and a re-entering node waits for
//! the next global aggregation before resuming (it is *present* but not
//! *synchronized*; see [`crate::fed::engine`]).
//!
//! State is O(n) regardless of how long the process runs: `step()` reports
//! the interval's delta through a reused scratch [`ChurnDelta`] (no per-call
//! allocation), the active count is a maintained counter rather than a
//! scan, and the trajectory mean is a running sum. The full per-step count
//! history — unbounded by construction — is **opt-in** via
//! [`ChurnProcess::record_history`] for analyses that genuinely need it.

use crate::util::rng::Rng;

/// The devices whose activity flipped in one churn interval. `entered` and
/// `exited` are disjoint, each ascending by device id (the step scans ids
/// in order).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChurnDelta {
    /// Devices that re-entered this step (they must wait for the next
    /// aggregation to sync).
    pub entered: Vec<usize>,
    /// Devices that exited this step (their unsent local state is lost).
    pub exited: Vec<usize>,
}

impl ChurnDelta {
    fn clear(&mut self) {
        self.entered.clear();
        self.exited.clear();
    }

    /// No device changed state this interval.
    pub fn is_empty(&self) -> bool {
        self.entered.is_empty() && self.exited.is_empty()
    }
}

/// Markov on/off churn over `n` devices.
#[derive(Debug, Clone)]
pub struct ChurnProcess {
    pub p_exit: f64,
    pub p_entry: f64,
    active: Vec<bool>,
    /// maintained count of `true` entries in `active`
    n_active: usize,
    /// running sum/length of post-step active counts (for `mean_active`)
    count_sum: u64,
    steps: usize,
    /// scratch delta reused across `step()` calls
    delta: ChurnDelta,
    /// opt-in full history of post-step active counts
    history: Option<Vec<usize>>,
}

impl ChurnProcess {
    /// All devices start active (paper §V-E: "initially, all devices are in
    /// the network").
    pub fn new(n: usize, p_exit: f64, p_entry: f64) -> Self {
        ChurnProcess {
            p_exit,
            p_entry,
            active: vec![true; n],
            n_active: n,
            count_sum: 0,
            steps: 0,
            delta: ChurnDelta::default(),
            history: None,
        }
    }

    /// A static network (no churn): step() never changes anything.
    pub fn static_network(n: usize) -> Self {
        Self::new(n, 0.0, 0.0)
    }

    /// Start recording the per-step active-count trajectory (unbounded
    /// memory — one usize per interval). Off by default.
    pub fn record_history(&mut self) {
        self.history.get_or_insert_with(Vec::new);
    }

    /// The recorded active-count trajectory, if
    /// [`record_history`](Self::record_history) was enabled; empty slice
    /// otherwise.
    pub fn history(&self) -> &[usize] {
        self.history.as_deref().unwrap_or(&[])
    }

    pub fn active(&self) -> &[bool] {
        &self.active
    }

    /// Number of active devices — O(1), maintained across steps.
    pub fn num_active(&self) -> usize {
        self.n_active
    }

    /// Advance one interval; returns the delta of devices that changed
    /// state. The returned borrow is scratch reused by the next `step()`
    /// call — clone it to keep it across steps.
    ///
    /// RNG discipline: ids are scanned ascending and every device draws
    /// exactly one Bernoulli (`p_exit` if active, `p_entry` if not), so the
    /// random stream is identical to the original implementation.
    pub fn step(&mut self, rng: &mut Rng) -> &ChurnDelta {
        self.delta.clear();
        for i in 0..self.active.len() {
            if self.active[i] {
                if rng.bool(self.p_exit) {
                    self.active[i] = false;
                    self.n_active -= 1;
                    self.delta.exited.push(i);
                }
            } else if rng.bool(self.p_entry) {
                self.active[i] = true;
                self.n_active += 1;
                self.delta.entered.push(i);
            }
        }
        self.count_sum += self.n_active as u64;
        self.steps += 1;
        if let Some(h) = &mut self.history {
            h.push(self.n_active);
        }
        &self.delta
    }

    /// Mean number of active devices over all steps so far.
    pub fn mean_active(&self) -> f64 {
        if self.steps == 0 {
            self.active.len() as f64
        } else {
            self.count_sum as f64 / self.steps as f64
        }
    }

    /// Stationary expected active fraction p_entry / (p_entry + p_exit)
    /// (both > 0), used by tests and the §V-E analysis.
    pub fn stationary_active_fraction(&self) -> f64 {
        if self.p_exit == 0.0 && self.p_entry == 0.0 {
            1.0
        } else if self.p_entry + self.p_exit == 0.0 {
            1.0
        } else {
            self.p_entry / (self.p_entry + self.p_exit)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_network_never_changes() {
        let mut c = ChurnProcess::static_network(10);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let delta = c.step(&mut rng);
            assert!(delta.is_empty());
            assert_eq!(c.num_active(), 10);
        }
        assert_eq!(c.mean_active(), 10.0);
    }

    #[test]
    fn all_exit_with_p_one() {
        let mut c = ChurnProcess::new(10, 1.0, 0.0);
        let mut rng = Rng::new(2);
        let delta = c.step(&mut rng);
        assert_eq!(delta.exited, (0..10).collect::<Vec<_>>());
        assert_eq!(c.num_active(), 0);
    }

    #[test]
    fn converges_to_stationary_fraction() {
        let mut c = ChurnProcess::new(200, 0.02, 0.02);
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            c.step(&mut rng);
        }
        // stationary fraction = 0.5; average over the trajectory (burn-in
        // from all-active start biases up slightly)
        let frac = c.mean_active() / 200.0;
        assert!(frac > 0.45 && frac < 0.65, "frac={frac}");
        assert_eq!(c.stationary_active_fraction(), 0.5);
    }

    #[test]
    fn entered_nodes_reported() {
        let mut c = ChurnProcess::new(5, 1.0, 1.0);
        let mut rng = Rng::new(4);
        c.step(&mut rng); // everyone exits
        assert_eq!(c.num_active(), 0);
        let entered = c.step(&mut rng).entered.clone(); // everyone re-enters
        assert_eq!(entered.len(), 5);
    }

    /// The delta, maintained counter, and running mean must agree with a
    /// from-scratch recount of the mask at every step.
    #[test]
    fn counter_and_mean_match_recount() {
        let mut c = ChurnProcess::new(50, 0.1, 0.15);
        c.record_history();
        let mut rng = Rng::new(5);
        let mut counts = Vec::new();
        for _ in 0..200 {
            let before: Vec<bool> = c.active().to_vec();
            let delta = c.step(&mut rng).clone();
            let recount = c.active().iter().filter(|&&a| a).count();
            assert_eq!(c.num_active(), recount);
            for &i in &delta.entered {
                assert!(!before[i] && c.active()[i]);
            }
            for &i in &delta.exited {
                assert!(before[i] && !c.active()[i]);
            }
            counts.push(recount);
        }
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        assert_eq!(c.mean_active(), mean);
        assert_eq!(c.history(), counts.as_slice());
    }

    /// History is opt-in; without it the process stores no trajectory.
    #[test]
    fn history_is_opt_in() {
        let mut c = ChurnProcess::new(20, 0.1, 0.1);
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            c.step(&mut rng);
        }
        assert!(c.history().is_empty());
        assert!(c.mean_active() > 0.0);
    }

    /// The reused-scratch step must draw the same RNG stream as the
    /// original per-call-allocation implementation: ascending ids, one
    /// Bernoulli per device.
    #[test]
    fn rng_stream_matches_reference() {
        let mut c = ChurnProcess::new(30, 0.2, 0.3);
        let mut rng = Rng::new(7);
        // reference trajectory computed inline with a twin RNG
        let mut ref_active = vec![true; 30];
        let mut ref_rng = Rng::new(7);
        for _ in 0..100 {
            let mut entered = Vec::new();
            for (i, a) in ref_active.iter_mut().enumerate() {
                if *a {
                    if ref_rng.bool(0.2) {
                        *a = false;
                    }
                } else if ref_rng.bool(0.3) {
                    *a = true;
                    entered.push(i);
                }
            }
            let delta = c.step(&mut rng);
            assert_eq!(delta.entered, entered);
            assert_eq!(c.active(), ref_active.as_slice());
        }
    }
}
