//! Node churn process of §V-E.
//!
//! Devices in the network exit with probability `p_exit` per interval;
//! devices outside re-enter with probability `p_entry`. The paper's
//! worst-case semantics are preserved by the federated engine: an exiting
//! node cannot ship its local update first, and a re-entering node waits for
//! the next global aggregation before resuming (it is *present* but not
//! *synchronized*; see [`crate::fed::engine`]).

use crate::util::rng::Rng;

/// Markov on/off churn over `n` devices.
#[derive(Debug, Clone)]
pub struct ChurnProcess {
    pub p_exit: f64,
    pub p_entry: f64,
    active: Vec<bool>,
    /// history of active counts, one per step() call
    active_counts: Vec<usize>,
}

impl ChurnProcess {
    /// All devices start active (paper §V-E: "initially, all devices are in
    /// the network").
    pub fn new(n: usize, p_exit: f64, p_entry: f64) -> Self {
        ChurnProcess {
            p_exit,
            p_entry,
            active: vec![true; n],
            active_counts: Vec::new(),
        }
    }

    /// A static network (no churn): step() never changes anything.
    pub fn static_network(n: usize) -> Self {
        Self::new(n, 0.0, 0.0)
    }

    pub fn active(&self) -> &[bool] {
        &self.active
    }

    pub fn num_active(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Advance one interval; returns the set of devices that re-entered
    /// this step (they must wait for the next aggregation to sync).
    pub fn step(&mut self, rng: &mut Rng) -> Vec<usize> {
        let mut entered = Vec::new();
        for i in 0..self.active.len() {
            if self.active[i] {
                if rng.bool(self.p_exit) {
                    self.active[i] = false;
                }
            } else if rng.bool(self.p_entry) {
                self.active[i] = true;
                entered.push(i);
            }
        }
        self.active_counts.push(self.num_active());
        entered
    }

    /// Mean number of active devices over all steps so far.
    pub fn mean_active(&self) -> f64 {
        if self.active_counts.is_empty() {
            self.active.len() as f64
        } else {
            self.active_counts.iter().sum::<usize>() as f64 / self.active_counts.len() as f64
        }
    }

    /// Stationary expected active fraction p_entry / (p_entry + p_exit)
    /// (both > 0), used by tests and the §V-E analysis.
    pub fn stationary_active_fraction(&self) -> f64 {
        if self.p_exit == 0.0 && self.p_entry == 0.0 {
            1.0
        } else if self.p_entry + self.p_exit == 0.0 {
            1.0
        } else {
            self.p_entry / (self.p_entry + self.p_exit)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_network_never_changes() {
        let mut c = ChurnProcess::static_network(10);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let entered = c.step(&mut rng);
            assert!(entered.is_empty());
            assert_eq!(c.num_active(), 10);
        }
        assert_eq!(c.mean_active(), 10.0);
    }

    #[test]
    fn all_exit_with_p_one() {
        let mut c = ChurnProcess::new(10, 1.0, 0.0);
        let mut rng = Rng::new(2);
        c.step(&mut rng);
        assert_eq!(c.num_active(), 0);
    }

    #[test]
    fn converges_to_stationary_fraction() {
        let mut c = ChurnProcess::new(200, 0.02, 0.02);
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            c.step(&mut rng);
        }
        // stationary fraction = 0.5; average over the trajectory (burn-in
        // from all-active start biases up slightly)
        let frac = c.mean_active() / 200.0;
        assert!(frac > 0.45 && frac < 0.65, "frac={frac}");
        assert_eq!(c.stationary_active_fraction(), 0.5);
    }

    #[test]
    fn entered_nodes_reported() {
        let mut c = ChurnProcess::new(5, 1.0, 1.0);
        let mut rng = Rng::new(4);
        c.step(&mut rng); // everyone exits
        assert_eq!(c.num_active(), 0);
        let entered = c.step(&mut rng); // everyone re-enters
        assert_eq!(entered.len(), 5);
    }
}
